"""L1 Pallas kernels for ParM: the inference hot-spot (fused linear /
conv-as-matmul) and the parity encoder. Each kernel has a pure-jnp oracle
in :mod:`ref`; pytest asserts agreement (see python/tests/test_kernels.py).
"""

from . import conv, encoder, linear, ref  # noqa: F401

"""L1 conv block: convolution lowered to the Pallas matmul hot-spot.

The paper's deployed models are CNNs; on TPU the standard high-performance
mapping of a conv is im2col followed by an MXU matmul (this is also what
XLA's own conv emitters do for small spatial dims). We express exactly
that: patch extraction is cheap data movement done with jax gathers (L2),
and the FLOPs all land in the fused Pallas matmul kernel (L1), so the conv
inherits the kernel's VMEM tiling and fused epilogue.

``conv2d(..., use_pallas=False)`` routes to the pure-jnp/lax reference —
the path used during training (interpret-mode Pallas has no reverse-mode
autodiff rule) and by the pytest oracle.
"""

import jax
import jax.numpy as jnp

from . import linear, ref


def _im2col(x, kh, kw, stride, padding):
    """x: (B, H, W, C) -> patches (B, OH, OW, KH*KW*C)."""
    b, h, w, c = x.shape
    if padding == "SAME":
        oh = -(-h // stride)
        ow = -(-w // stride)
        pad_h = max((oh - 1) * stride + kh - h, 0)
        pad_w = max((ow - 1) * stride + kw - w, 0)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
                (0, 0),
            ),
        )
    elif padding == "VALID":
        oh = (h - kh) // stride + 1
        ow = (w - kw) // stride + 1
    else:
        raise ValueError(padding)

    # Gather kh*kw shifted slices; unrolled python loop is fine at these
    # kernel sizes (3x3, 5x5) and keeps the HLO free of dynamic slicing.
    cols = []
    for i in range(kh):
        for j in range(kw):
            sl = x[:, i : i + (oh - 1) * stride + 1 : stride,
                      j : j + (ow - 1) * stride + 1 : stride, :]
            cols.append(sl)
    patches = jnp.concatenate(cols, axis=-1)  # (B, OH, OW, KH*KW*C)
    return patches, oh, ow


def conv2d(x, w, b, stride=1, padding="SAME", activation="linear",
           use_pallas=True, interpret=True):
    """NHWC conv + bias + activation via im2col + Pallas matmul.

    x: (B, H, W, Cin), w: (KH, KW, Cin, Cout), b: (Cout,).
    """
    if not use_pallas:
        return ref.conv2d(x, w, b, stride=stride, padding=padding,
                          activation=activation)

    kh, kw, cin, cout = w.shape
    patches, oh, ow = _im2col(x, kh, kw, stride, padding)
    bsz = x.shape[0]
    # Rearrange patch channels to match HWIO weight flattening order:
    # _im2col emits [(i,j) major, C minor] which is exactly w.reshape(-1, O).
    mat = patches.reshape(bsz * oh * ow, kh * kw * cin)
    wmat = w.reshape(kh * kw * cin, cout)
    y = linear.fused_linear(mat, wmat, b, activation=activation,
                            interpret=interpret)
    return y.reshape(bsz, oh, ow, cout)

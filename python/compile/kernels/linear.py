"""L1 Pallas kernel: fused tiled matmul + bias + activation.

This is the compute hot-spot of every model in the zoo (dense layers, and —
via im2col — convolutions). The kernel is written for the TPU mental model:

- the grid walks (M-tiles, N-tiles, K-tiles); each step holds one
  (bm, bk) x (bk, bn) product in VMEM and accumulates into the revisited
  (bm, bn) output tile, i.e. the HBM->VMEM schedule a GPU kernel would
  express with threadblocks is expressed here with BlockSpec index maps;
- tile shapes default to multiples of the MXU-native 128 lanes;
- the epilogue (bias add + activation) is fused into the final K step, so
  the pre-activation never round-trips to HBM.

Run under ``interpret=True`` (the only mode the CPU PJRT client can
execute); on a real TPU the same kernel lowers to a Mosaic custom-call.
VMEM footprint at defaults: (128*128 + 128*128 + 128*128) * 4B = 192 KiB,
comfortably under the ~16 MiB/core budget; see DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes. 128 matches the MXU systolic-array lane width; the
# K tile is kept equal so a single grid step is one MXU-shaped block.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation, nsteps_k):
    """One grid step: o += x_tile @ w_tile; fused epilogue on the last step.

    The output tile is revisited across the K axis of the grid (its index
    map ignores ``k``), so it doubles as the accumulator and stays resident
    in VMEM for the whole K loop.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_step == nsteps_k - 1)
    def _epilogue():
        o_ref[...] = ref.apply_activation(o_ref[...] + b_ref[...], activation)


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _fit_tile(tile, dim):
    """Shrink a tile to the smallest power-of-two >= dim (min 8)."""
    p = 8
    while p < dim:
        p *= 2
    return min(tile, p)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def fused_linear(x, w, b, activation="relu", bm=BM, bn=BN, bk=BK, interpret=True):
    """act(x @ w + b) as a tiled Pallas kernel.

    x: (B, I) f32, w: (I, O) f32, b: (O,) f32 -> (B, O) f32.
    Shapes are padded up to tile multiples and the result sliced back, so
    arbitrary shapes are supported with deterministic semantics (padding is
    zeros, which contribute nothing to the accumulation).
    """
    m, kdim = x.shape
    kdim2, n = w.shape
    assert kdim == kdim2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)

    # Shrink tiles for small problems so padding stays bounded.
    bm = _fit_tile(bm, m)
    bn = _fit_tile(bn, n)
    bk = _fit_tile(bk, kdim)

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(b.reshape(1, n), 1, bn)

    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]

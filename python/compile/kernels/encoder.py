"""L1 Pallas kernel: the ParM parity encoder, P_j = sum_i w_ji * X_i.

The paper's generic encoder (§3.2) is a plain feature-wise sum over the k
queries of a coding group; the r > 1 extension (§3.5) uses per-parity
weights (e.g. [1, 1] and [1, 2] for k = 2, r = 2). Both are served by this
one kernel.

TPU mapping: queries are flattened to (k, F) and the grid walks F in
lane-aligned tiles; each grid step streams the k rows of one feature tile
through VMEM and reduces them with the weight vector. On this image it runs
under ``interpret=True``; the identical math lives in ``ref.py`` for the
training path and the pytest oracle.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature-tile width: 8 sublanes x 128 lanes of f32.
BF = 1024


def _encode_kernel(x_ref, w_ref, o_ref):
    # x_ref: (k, BF) tile, w_ref: (k, 1), o_ref: (1, BF).
    o_ref[...] = jnp.sum(x_ref[...] * w_ref[...], axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def weighted_sum_encode(xs, weights, bf=BF, interpret=True):
    """Encode k stacked queries into one parity query.

    xs: (k, B, ...) f32 stacked queries; weights: (k,) f32.
    Returns (B, ...) parity query. ``weights = ones(k)`` is the paper's
    generic addition encoder.
    """
    k = xs.shape[0]
    batch_shape = xs.shape[1:]
    flat = xs.reshape(k, -1)
    f = flat.shape[1]

    rem = (-f) % bf
    if rem:
        flat = jnp.pad(flat, ((0, 0), (0, rem)))
    fp = flat.shape[1]

    out = pl.pallas_call(
        _encode_kernel,
        grid=(fp // bf,),
        in_specs=[
            pl.BlockSpec((k, bf), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bf), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, fp), jnp.float32),
        interpret=interpret,
    )(flat, weights.reshape(k, 1))
    return out[0, :f].reshape(batch_shape)


def sum_encode(xs, interpret=True):
    """The paper's generic addition encoder: P = sum_i X_i."""
    k = xs.shape[0]
    return weighted_sum_encode(xs, jnp.ones((k,), jnp.float32), interpret=interpret)

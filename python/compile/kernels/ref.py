"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an exact mathematical twin here.
Training (which needs reverse-mode autodiff that interpret-mode Pallas does
not support) runs through these references; the AOT export path runs through
the Pallas kernels; `python/tests/test_kernels.py` asserts the two agree to
float32 tolerance across a hypothesis-driven sweep of shapes.
"""

import jax.numpy as jnp


def fused_linear(x, w, b, activation="relu"):
    """y = act(x @ w + b).

    x: (B, I) float32, w: (I, O) float32, b: (O,) float32.
    """
    y = x @ w + b
    return apply_activation(y, activation)


def apply_activation(y, activation):
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "linear":
        return y
    if activation == "tanh":
        return jnp.tanh(y)
    raise ValueError(f"unknown activation {activation!r}")


def conv2d(x, w, b, stride=1, padding="SAME", activation="linear"):
    """NHWC conv with HWIO weights, plus bias and optional activation.

    x: (B, H, W, Cin), w: (KH, KW, Cin, Cout), b: (Cout,).
    """
    import jax

    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + b
    return apply_activation(y, activation)


def sum_encode(xs):
    """Parity encoder: P = sum_i X_i.

    xs: (k, B, ...) stacked queries -> (B, ...) parity query.
    """
    return jnp.sum(xs, axis=0)


def weighted_sum_encode(xs, weights):
    """Generalized encoder for r > 1: P_j = sum_i w_ji X_i (§3.5).

    xs: (k, B, ...), weights: (k,) -> (B, ...).
    """
    w = weights.reshape((-1,) + (1,) * (xs.ndim - 1))
    return jnp.sum(xs * w, axis=0)


def sub_decode(parity_out, available_outs):
    """Subtraction decoder: Fhat(X_j) = F_P(P) - sum_{i != j} F(X_i).

    parity_out: (B, n), available_outs: (k-1, B, n).
    """
    return parity_out - jnp.sum(available_outs, axis=0)


def avg_pool(x, window=2):
    """Non-overlapping average pool, NHWC."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // window, window, w // window, window, c)
    return x.mean(axis=(2, 4))


def global_avg_pool(x):
    """NHWC -> (B, C)."""
    return x.mean(axis=(1, 2))

"""L2 model zoo: MLP, LeNet-style CNN, MicroResNet — pure-functional JAX.

Scaled-to-CPU stand-ins for the paper's architectures (MLP, LeNet-5,
VGG-11, ResNet-18/152). Each model is a pair (init, apply):

- ``init(rng, input_shape, out_dim)`` -> params pytree (dict of np arrays)
- ``apply(params, x, use_pallas)``    -> (B, out_dim) logits / regression

``use_pallas=True`` routes every dense/conv through the L1 Pallas kernels
(the AOT export path); ``use_pallas=False`` routes through the jnp
references (the training path — interpret-mode Pallas has no reverse-mode
autodiff). pytest asserts both paths agree on every architecture.

Initialization follows the paper (§4.1): uniform Xavier for conv weights,
zero biases, N(0, 0.01) for other weights.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv as kconv
from .kernels import linear as klinear
from .kernels import ref


def _xavier_uniform(rng, shape, fan_in, fan_out):
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return rng.uniform(-limit, limit, size=shape).astype(np.float32)


def _normal(rng, shape, std=0.01):
    return (std * rng.normal(size=shape)).astype(np.float32)


def _dense(params, name, x, activation, use_pallas):
    w, b = params[f"{name}_w"], params[f"{name}_b"]
    if use_pallas:
        return klinear.fused_linear(x, w, b, activation=activation)
    return ref.fused_linear(x, w, b, activation=activation)


def _conv(params, name, x, stride, activation, use_pallas, padding="SAME"):
    w, b = params[f"{name}_w"], params[f"{name}_b"]
    return kconv.conv2d(x, w, b, stride=stride, padding=padding,
                        activation=activation, use_pallas=use_pallas)


# ---------------------------------------------------------------- MLP ----
def mlp_init(rng, input_shape, out_dim):
    """The paper's MLP: two hidden layers of 200 and 100 units, ReLU."""
    d = int(np.prod(input_shape))
    return {
        "fc1_w": _normal(rng, (d, 200)),
        "fc1_b": np.zeros((200,), np.float32),
        "fc2_w": _normal(rng, (200, 100)),
        "fc2_b": np.zeros((100,), np.float32),
        "out_w": _normal(rng, (100, out_dim)),
        "out_b": np.zeros((out_dim,), np.float32),
    }


def mlp_apply(params, x, use_pallas=False):
    b = x.shape[0]
    h = x.reshape(b, -1)
    h = _dense(params, "fc1", h, "relu", use_pallas)
    h = _dense(params, "fc2", h, "relu", use_pallas)
    return _dense(params, "out", h, "linear", use_pallas)


# -------------------------------------------------------------- LeNet ----
def lenet_init(rng, input_shape, out_dim):
    """LeNet-5-style: two 5x5 conv + avgpool stages, then 120-84-out FCs."""
    h, w, c = input_shape
    p = {
        "c1_w": _xavier_uniform(rng, (5, 5, c, 6), 25 * c, 25 * 6),
        "c1_b": np.zeros((6,), np.float32),
        "c2_w": _xavier_uniform(rng, (5, 5, 6, 16), 25 * 6, 25 * 16),
        "c2_b": np.zeros((16,), np.float32),
    }
    fh, fw = h // 4, w // 4  # two 2x2 pools
    d = fh * fw * 16
    p.update({
        "fc1_w": _normal(rng, (d, 120)),
        "fc1_b": np.zeros((120,), np.float32),
        "fc2_w": _normal(rng, (120, 84)),
        "fc2_b": np.zeros((84,), np.float32),
        "out_w": _normal(rng, (84, out_dim)),
        "out_b": np.zeros((out_dim,), np.float32),
    })
    return p


def lenet_apply(params, x, use_pallas=False):
    b = x.shape[0]
    h = _conv(params, "c1", x, 1, "relu", use_pallas)
    h = ref.avg_pool(h, 2)
    h = _conv(params, "c2", h, 1, "relu", use_pallas)
    h = ref.avg_pool(h, 2)
    h = h.reshape(b, -1)
    h = _dense(params, "fc1", h, "relu", use_pallas)
    h = _dense(params, "fc2", h, "relu", use_pallas)
    return _dense(params, "out", h, "linear", use_pallas)


# --------------------------------------------------------- MicroResNet ----
def microresnet_init(rng, input_shape, out_dim, width=16):
    """ResNet-18 stand-in: conv stem + 2 residual stages + GAP + FC.

    ``width`` scales every channel count; width=16 is the deployed model,
    width=12 is the "approximate backup" variant of §5.2.6 (cheaper but the
    same family, ~1.15-1.4x faster — deliberately NOT k-times faster).
    """
    h, w, c = input_shape
    w1, w2 = width, 2 * width

    def cw(shape):
        kh, kw, ci, co = shape
        return _xavier_uniform(rng, shape, kh * kw * ci, kh * kw * co)

    return {
        "stem_w": cw((3, 3, c, w1)), "stem_b": np.zeros((w1,), np.float32),
        # stage 1: identity residual block at width w1
        "s1a_w": cw((3, 3, w1, w1)), "s1a_b": np.zeros((w1,), np.float32),
        "s1b_w": cw((3, 3, w1, w1)), "s1b_b": np.zeros((w1,), np.float32),
        # stage 2: downsampling residual block w1 -> w2, stride 2
        "s2a_w": cw((3, 3, w1, w2)), "s2a_b": np.zeros((w2,), np.float32),
        "s2b_w": cw((3, 3, w2, w2)), "s2b_b": np.zeros((w2,), np.float32),
        "s2p_w": cw((1, 1, w1, w2)), "s2p_b": np.zeros((w2,), np.float32),
        "out_w": _normal(rng, (w2, out_dim)),
        "out_b": np.zeros((out_dim,), np.float32),
    }


def microresnet_apply(params, x, use_pallas=False):
    # Stride-2 stem (as in full ResNets): downsampling early keeps the
    # residual stages cheap without losing the architecture's shape.
    h = _conv(params, "stem", x, 2, "relu", use_pallas)
    # stage 1
    r = _conv(params, "s1a", h, 1, "relu", use_pallas)
    r = _conv(params, "s1b", r, 1, "linear", use_pallas)
    h = jnp.maximum(h + r, 0.0)
    # stage 2 (stride-2 downsample + 1x1 projection shortcut)
    r = _conv(params, "s2a", h, 2, "relu", use_pallas)
    r = _conv(params, "s2b", r, 1, "linear", use_pallas)
    p = _conv(params, "s2p", h, 2, "linear", use_pallas)
    h = jnp.maximum(p + r, 0.0)
    h = ref.global_avg_pool(h)
    return _dense(params, "out", h, "linear", use_pallas)


# ------------------------------------------------------------- registry ----
_ZOO = {
    "mlp": (mlp_init, mlp_apply),
    "lenet": (lenet_init, lenet_apply),
    "microresnet": (microresnet_init, microresnet_apply),
    "microresnet_narrow": (
        lambda rng, ishape, od: microresnet_init(rng, ishape, od, width=12),
        microresnet_apply,
    ),
}


def get(arch):
    """Return (init, apply) for an architecture name."""
    if arch not in _ZOO:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_ZOO)}")
    return _ZOO[arch]


ALL_ARCHS = sorted(_ZOO)

"""Build-time encoders/decoders used to construct parity-model training data.

The runtime (Rust, `rust/src/coordinator/{encoder,decoder}.rs`) implements
the same math on the request path; `python/tests/test_encoders.py` and the
Rust unit tests pin both sides to these semantics.

- ``sum``    : the paper's generic addition encoder (§3.2), P = sum X_i.
- ``concat`` : the image-classification-specific encoder (§4.2.3): each of
  the k queries is downsampled and placed in a grid cell, so the parity
  query keeps the feature count of a single query.

Decoder is always subtraction (§3.2): Fhat(X_j) = F_P(P) - sum_{i!=j} F(X_i).
"""

import math

import numpy as np


def sum_encode_np(xs, weights=None):
    """xs: (k, ...) -> (...). Optional per-query weights (r > 1, §3.5)."""
    if weights is None:
        return xs.sum(axis=0, dtype=np.float32)
    w = np.asarray(weights, np.float32).reshape((-1,) + (1,) * (xs.ndim - 1))
    return (xs * w).sum(axis=0, dtype=np.float32)


def downsample_np(x, out_h, out_w):
    """Area-average downsample of (H, W, C) to (out_h, out_w, C).

    Matches the Rust `tensor::resize_area` implementation bit-for-bit for
    integer scale factors (the only ones the concat encoder uses).
    """
    h, w, c = x.shape
    assert h % out_h == 0 and w % out_w == 0, (x.shape, out_h, out_w)
    fh, fw = h // out_h, w // out_w
    return x.reshape(out_h, fh, out_w, fw, c).mean(axis=(1, 3), dtype=np.float32)


def concat_encode_np(xs):
    """Downsample-and-tile k queries into one same-sized parity query.

    xs: (k, H, W, C). k must be a perfect square (paper uses k=4 -> 2x2
    grid) or 2 (side-by-side halves, downsampled in H only).
    """
    k, h, w, c = xs.shape
    if k == 2:
        halves = [downsample_np(x, h // 2, w) for x in xs]
        return np.concatenate(halves, axis=0).astype(np.float32)
    g = int(math.isqrt(k))
    assert g * g == k, f"concat encoder needs square k or k=2, got {k}"
    cells = [downsample_np(x, h // g, w // g) for x in xs]
    rows = [np.concatenate(cells[r * g:(r + 1) * g], axis=1) for r in range(g)]
    return np.concatenate(rows, axis=0).astype(np.float32)


def encode_np(xs, kind, weights=None):
    if kind == "sum":
        return sum_encode_np(xs, weights)
    if kind == "concat":
        assert weights is None, "concat encoder does not support r>1 weights"
        return concat_encode_np(xs)
    raise ValueError(f"unknown encoder {kind!r}")


def encode_batch_np(xs, kind, weights=None):
    """xs: (k, B, ...) -> (B, ...): encode across the stripe per sample."""
    k, b = xs.shape[:2]
    out = np.stack([encode_np(xs[:, i], kind, weights) for i in range(b)])
    return out.astype(np.float32)


def sub_decode_np(parity_out, available_outs):
    """parity_out: (n,), available_outs: (k-1, n) -> reconstruction (n,)."""
    return (parity_out - available_outs.sum(axis=0)).astype(np.float32)


def r1_weights(k):
    """Generic r=1 addition-code weights."""
    return np.ones((k,), np.float32)


def parity_weights(k, r_index):
    """Weights for the ``r_index``-th parity model in an r > 1 code (§3.5).

    Row j of a k x r Vandermonde-style matrix: w_i = (i+1)^r_index, so
    r_index=0 is the plain sum and successive parities are independent —
    any k of the (k+r) outputs determine the k originals.
    """
    return np.array([(i + 1) ** r_index for i in range(k)], np.float32)

"""Synthetic datasets standing in for the paper's benchmarks.

No dataset downloads exist in this image, so each of the paper's tasks is
replaced by a seeded synthetic generator with matched tensor shapes and
class cardinalities (see DESIGN.md "Substitutions"). The generators create
*learnable* tasks: every class has a smooth random prototype (low-frequency
Gaussian field) and samples are affine-jittered, scaled prototypes plus
noise. What the paper studies — whether a parity model can learn to act on
*summed/concatenated* queries — depends on the mixing structure of the
encoder, not on natural-image statistics, so the shape of the accuracy
results carries over.

Datasets:
- synthvision10  : CIFAR-10 stand-in, 32x32x3, 10 classes
- synthvision100 : CIFAR-100 stand-in, 32x32x3, 100 classes (top-5 metric)
- synthfashion   : Fashion-MNIST stand-in, 28x28x1, 10 classes
- synthdigits    : MNIST stand-in, 28x28x1, 10 classes (easier: less noise)
- synthspeech    : Google Commands stand-in, 32x32x1 "spectrograms", 10 cls
- synthpets      : Cat v. Dog stand-in, 64x64x3, 2 classes (latency workload)
- synthloc       : CUB-200 localization stand-in, 32x32x3 -> (cx,cy,w,h)
"""

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dataset:
    name: str
    train_x: np.ndarray  # (N, H, W, C) f32 in [0, 1]-ish
    train_y: np.ndarray  # (N,) int labels, or (N, 4) f32 boxes for synthloc
    test_x: np.ndarray
    test_y: np.ndarray
    num_classes: int  # 0 for regression
    task: str  # "classify" | "localize"

    @property
    def input_shape(self):
        return self.train_x.shape[1:]


def _smooth_field(rng, h, w, c, cutoff=6):
    """Low-frequency random field in [0,1]: a smooth 'prototype image'."""
    spec = np.zeros((h, w, c), np.complex128)
    kh, kw = min(cutoff, h), min(cutoff, w)
    spec[:kh, :kw] = rng.normal(size=(kh, kw, c)) + 1j * rng.normal(size=(kh, kw, c))
    img = np.real(np.fft.ifft2(spec, axes=(0, 1)))
    img -= img.min()
    rng_span = img.max() - img.min()
    return (img / (rng_span + 1e-9)).astype(np.float32)


def _jitter(rng, proto, max_shift):
    """Random circular shift + brightness/contrast jitter of a prototype."""
    dx, dy = rng.integers(-max_shift, max_shift + 1, size=2)
    img = np.roll(np.roll(proto, dy, axis=0), dx, axis=1)
    gain = 1.0 + 0.2 * rng.normal()
    bias = 0.1 * rng.normal()
    return gain * img + bias


def _make_classify(name, rng, n_train, n_test, h, w, c, num_classes,
                   noise=0.12, max_shift=3, cutoff=6):
    protos = np.stack([_smooth_field(rng, h, w, c, cutoff) for _ in range(num_classes)])
    def batch(n):
        ys = rng.integers(0, num_classes, size=n)
        xs = np.empty((n, h, w, c), np.float32)
        for i, y in enumerate(ys):
            xs[i] = _jitter(rng, protos[y], max_shift) + noise * rng.normal(size=(h, w, c))
        return xs.astype(np.float32), ys.astype(np.int32)
    tx, ty = batch(n_train)
    vx, vy = batch(n_test)
    return Dataset(name, tx, ty, vx, vy, num_classes, "classify")


def _make_localize(name, rng, n_train, n_test, h, w):
    """Bright smooth blob on textured background; label = (cx, cy, bw, bh)/dim."""
    def batch(n):
        xs = np.empty((n, h, w, 3), np.float32)
        ys = np.empty((n, 4), np.float32)
        for i in range(n):
            bg = 0.25 * _smooth_field(rng, h, w, 3, cutoff=4)
            bw = rng.integers(h // 4, h // 2)
            bh = rng.integers(h // 4, h // 2)
            x0 = rng.integers(0, w - bw)
            y0 = rng.integers(0, h - bh)
            obj = np.zeros((h, w, 1), np.float32)
            yy, xx = np.mgrid[0:h, 0:w]
            cx, cy = x0 + bw / 2, y0 + bh / 2
            mask = (np.abs(xx - cx) < bw / 2) & (np.abs(yy - cy) < bh / 2)
            obj[mask, 0] = 1.0
            img = bg + obj * (0.6 + 0.2 * rng.normal())
            img += 0.05 * rng.normal(size=(h, w, 3))
            xs[i] = img
            ys[i] = [cx / w, cy / h, bw / w, bh / h]
        return xs.astype(np.float32), ys
    tx, ty = batch(n_train)
    vx, vy = batch(n_test)
    return Dataset(name, tx, ty, vx, vy, 0, "localize")


# Sizes kept CPU-trainable: `make artifacts` trains every deployed + parity
# model in this file on a laptop-class CPU in minutes.
_SPECS = {
    "synthvision10": dict(h=32, w=32, c=3, num_classes=10, n_train=4000, n_test=600,
                          noise=0.12, max_shift=3),
    "synthvision100": dict(h=32, w=32, c=3, num_classes=100, n_train=8000, n_test=600,
                           noise=0.08, max_shift=2),
    "synthfashion": dict(h=28, w=28, c=1, num_classes=10, n_train=4000, n_test=600,
                         noise=0.15, max_shift=3),
    "synthdigits": dict(h=28, w=28, c=1, num_classes=10, n_train=3000, n_test=600,
                        noise=0.08, max_shift=2),
    "synthspeech": dict(h=32, w=32, c=1, num_classes=10, n_train=4000, n_test=600,
                        noise=0.15, max_shift=4, cutoff=8),
    "synthpets": dict(h=64, w=64, c=3, num_classes=2, n_train=2400, n_test=400,
                      noise=0.15, max_shift=4),
}


def load(name, seed=None):
    """Build a dataset by name. Deterministic per (name, seed)."""
    if seed is None:
        seed = abs(hash(name)) % (2**31)
        # hash() is salted per-process; derive a stable seed instead.
        seed = int.from_bytes(name.encode(), "little") % (2**31)
    rng = np.random.default_rng(seed)
    if name == "synthloc":
        return _make_localize(name, rng, n_train=3000, n_test=500, h=32, w=32)
    if name not in _SPECS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(_SPECS)} + ['synthloc']")
    s = dict(_SPECS[name])
    return _make_classify(name, rng,
                          n_train=s.pop("n_train"), n_test=s.pop("n_test"),
                          h=s.pop("h"), w=s.pop("w"), c=s.pop("c"),
                          num_classes=s.pop("num_classes"), **s)


ALL_NAMES = sorted(_SPECS) + ["synthloc"]

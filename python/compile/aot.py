"""AOT artifact build: train every model variant and export HLO text.

This is the only place Python runs in the whole system, and it runs once:
``make artifacts`` invokes ``python -m compile.aot --out ../artifacts`` and
is a no-op when the manifest is newer than the compile-path sources.

Per model variant we emit one HLO file per serving batch size. HLO **text**
(not ``.serialize()``) is the interchange format: the image's xla_extension
0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction ids); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Exported executables close over the trained parameters (they become HLO
constants), so the Rust runtime sees single-input programs: query -> output.
The export path routes through the L1 Pallas kernels (``use_pallas=True``)
so the kernels lower into the shipped HLO; training used the jnp reference
path (interpret-mode Pallas has no autodiff), and pytest pins the two paths
to each other.

Build matrix (see DESIGN.md experiment index):
- deployed models per dataset/arch used by Figures 6-9,
- parity models for k in {2,3,4}, sum + concat encoders, r in {1,2},
- the latency workload (synthpets, 1000-dim outputs per §5.1) at batch
  sizes 1, 2, 4, plus the approximate-backup model of §5.2.6.
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from jax._src.lib import xla_client as xc

from . import datasets, encoders, models, train

FAST = os.environ.get("PARM_FAST", "") not in ("", "0")

# (dataset, arch, deployed_epochs, parity_epochs, parity_ks)
# Paper mapping: microresnet~ResNet-18, lenet~LeNet-5/VGG-11, mlp~MLP.
ACCURACY_MATRIX = [
    # Fig 6 row + Fig 7/9 (k sweep) + Fig 10 (concat)
    dict(dataset="synthvision10", arch="microresnet", epochs=8, p_epochs=25,
         ks=(2, 3, 4), concat_ks=(2, 4), r2=True),
    # CIFAR-100 / ResNet-152 stand-in, top-5 metric
    dict(dataset="synthvision100", arch="microresnet", epochs=10, p_epochs=25,
         ks=(2,)),
    dict(dataset="synthfashion", arch="mlp", epochs=8, p_epochs=20, ks=(2,)),
    dict(dataset="synthfashion", arch="lenet", epochs=8, p_epochs=20, ks=(2,)),
    dict(dataset="synthfashion", arch="microresnet", epochs=8, p_epochs=20,
         ks=(2, 3, 4)),
    dict(dataset="synthdigits", arch="lenet", epochs=6, p_epochs=15,
         ks=(2, 3, 4)),
    # Google Commands / VGG-11 stand-in
    dict(dataset="synthspeech", arch="lenet", epochs=8, p_epochs=20,
         ks=(2, 3, 4)),
    # Object localization (Fig 8), regression
    dict(dataset="synthloc", arch="microresnet", epochs=10, p_epochs=25,
         ks=(2,)),
]

# Latency workload (§5.1): Cat-v-Dog stand-in, ResNet-18 stand-in, outputs
# padded to 1000 floats, batch sizes 1/2/4, parity k in {2,3,4}, plus the
# approximate-backup narrow model (§5.2.6).
LATENCY = dict(dataset="synthpets", arch="microresnet", epochs=8, p_epochs=18,
               ks=(2, 3, 4), out_dim=1000, batches=(1, 2, 4))

if FAST:
    for row in ACCURACY_MATRIX:
        row["epochs"] = min(row["epochs"], 2)
        row["p_epochs"] = min(row["p_epochs"], 2)
    LATENCY.update(epochs=2, p_epochs=2)


# ----------------------------------------------------------- param cache ----
def _params_dir(out_dir):
    d = os.path.join(out_dir, "params")
    os.makedirs(d, exist_ok=True)
    return d


def cached_train(out_dir, name, train_fn, log=print):
    """Training is the expensive step (~minutes per model); exporting is
    seconds. Cache trained parameters under artifacts/params/<name>.npz so
    export-path changes (e.g. HLO printer options) never force retraining.
    `make clean-artifacts` wipes the cache."""
    path = os.path.join(_params_dir(out_dir), f"{name}.npz")
    if os.path.exists(path):
        z = np.load(path)
        params = {k: z[k] for k in z.files if k != "__metric__"}
        metric = float(z["__metric__"]) if "__metric__" in z.files else float("nan")
        log(f"[cache] loaded params for {name} (metric={metric:.3f})")
        return params, metric
    result = train_fn()
    params = {k: np.asarray(v) for k, v in result.params.items()}
    np.savez(path, __metric__=np.float64(result.eval_metric), **params)
    return params, result.eval_metric


# ----------------------------------------------------------------- export ----
def to_hlo_text(lowered):
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False,
        return_tuple=True)
    # CRITICAL: print_large_constants. The default HLO printer elides big
    # constants as `constant({...})`, which the XLA text *parser* silently
    # accepts as zeros — the exported model would run but with all weights
    # zeroed. (Found the hard way; pinned by test_aot_roundtrip.py.)
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def export_model(out_dir, name, apply_fn, params, input_shape, batches):
    """Lower apply(params, .) at each batch size; return manifest entries."""
    files = {}
    for b in batches:
        spec = jax.ShapeDtypeStruct((b,) + tuple(input_shape), jnp.float32)
        fn = functools.partial(_apply_closed, apply_fn, params)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        fname = f"{name}.b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[str(b)] = fname
    return files


def _apply_closed(apply_fn, params, x):
    return (apply_fn(params, x, use_pallas=True),)


def _train_parity_with_ad(ds, arch, dep_params, k, enc_kind, epochs):
    """Train a parity model and stamp its degraded accuracy as the metric."""
    par = train.train_parity(ds, arch, dep_params, k, encoder=enc_kind,
                             epochs=epochs, log=lambda s: None)
    ad = train.degraded_accuracy(ds, arch, dep_params, par.params, k,
                                 encoder=enc_kind)
    par.eval_metric = ad
    return par


def pad_output(apply_fn, out_dim, real_dim):
    """Wrap apply() to emit `out_dim` floats (§5.1's 1000-float predictions)."""
    if out_dim == real_dim:
        return apply_fn

    def wrapped(params, x, use_pallas=False):
        y = apply_fn(params, x, use_pallas=use_pallas)
        pad = out_dim - y.shape[-1]
        return jnp.pad(y, ((0, 0), (0, pad)))

    return wrapped


def save_dataset(out_dir, ds, max_test=None):
    """Dump the test split as raw little-endian binaries for the Rust side."""
    tx, ty = ds.test_x, ds.test_y
    if max_test is not None:
        tx, ty = tx[:max_test], ty[:max_test]
    xf = f"{ds.name}.test_x.bin"
    yf = f"{ds.name}.test_y.bin"
    tx.astype("<f4").tofile(os.path.join(out_dir, xf))
    if ds.task == "classify":
        ty.astype("<i4").tofile(os.path.join(out_dir, yf))
    else:
        ty.astype("<f4").tofile(os.path.join(out_dir, yf))
    return dict(name=ds.name, task=ds.task, num_classes=ds.num_classes,
                input_shape=list(ds.input_shape), n_test=len(tx),
                test_x=xf, test_y=yf)


# ------------------------------------------------------------------ build ----
def build(out_dir, log=print):
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()
    manifest = {"models": [], "datasets": [], "fast_mode": FAST,
                "format": "hlo-text-v1"}
    seen_datasets = {}

    def ensure_dataset(name):
        if name not in seen_datasets:
            log(f"[data] generating {name}")
            ds = datasets.load(name)
            seen_datasets[name] = ds
            manifest["datasets"].append(save_dataset(out_dir, ds))
        return seen_datasets[name]

    def add_model(name, role, ds, arch, apply_fn, params, input_shape,
                  out_dim, batches, metric, *, k=0, r_index=0, encoder="",
                  train_seconds=0.0):
        files = export_model(out_dir, name, apply_fn, params, input_shape,
                             batches)
        manifest["models"].append(dict(
            name=name, role=role, dataset=ds.name, arch=arch,
            input_shape=list(input_shape), out_dim=out_dim,
            batches=sorted(int(b) for b in files), files=files,
            k=k, r_index=r_index, encoder=encoder,
            train_metric=metric, train_seconds=round(train_seconds, 1)))
        log(f"[aot ] exported {name} (batches {sorted(files)})")

    # ---- accuracy matrix ----
    for row in ACCURACY_MATRIX:
        ds = ensure_dataset(row["dataset"])
        arch = row["arch"]
        _, apply_fn = models.get(arch)
        out_dim = ds.num_classes if ds.task == "classify" else 4
        tag = f"{ds.name}.{arch}"

        log(f"[train] deployed {tag} ({row['epochs']} epochs)")
        dep_params, dep_metric = cached_train(
            out_dir, f"{tag}.deployed",
            lambda: train.train_deployed(ds, arch, epochs=row["epochs"],
                                         log=lambda s: None), log)
        log(f"[train] deployed {tag}: metric={dep_metric:.3f}")
        add_model(f"{tag}.deployed", "deployed", ds, arch, apply_fn,
                  dep_params, ds.input_shape, out_dim, (1, 50), dep_metric)

        for enc_kind, k_list in (("sum", row.get("ks", ())),
                                 ("concat", row.get("concat_ks", ()))):
            for k in k_list:
                name = f"{tag}.parity.k{k}.{enc_kind}"
                par_params, ad = cached_train(
                    out_dir, name,
                    lambda: _train_parity_with_ad(ds, arch, dep_params, k,
                                                  enc_kind, row["p_epochs"]),
                    log)
                log(f"[train] parity {tag} k={k} {enc_kind}: A_d={ad:.3f}")
                add_model(name, "parity", ds, arch, apply_fn, par_params,
                          ds.input_shape, out_dim, (1, 50), ad,
                          k=k, encoder=enc_kind)

        if row.get("r2"):
            # §3.5: second parity model with weights [1, 2, ...]; with the
            # k=2 sum parity above this forms a (k=2, r=2) code.
            k = 2
            wts = encoders.parity_weights(k, 1)
            name = f"{tag}.parity.k{k}.sum.r1"
            par_params, _ = cached_train(
                out_dir, name,
                lambda: train.train_parity(ds, arch, dep_params, k,
                                           encoder="sum", weights=wts,
                                           epochs=row["p_epochs"],
                                           log=lambda s: None), log)
            log(f"[train] parity {tag} k={k} r_index=1")
            add_model(name, "parity", ds, arch, apply_fn, par_params,
                      ds.input_shape, out_dim, (1, 50), float("nan"),
                      k=k, r_index=1, encoder="sum")

    # ---- latency workload ----
    row = LATENCY
    ds = ensure_dataset(row["dataset"])
    arch = row["arch"]
    _, apply_raw = models.get(arch)
    apply_1000 = pad_output(apply_raw, row["out_dim"], ds.num_classes)
    tag = f"{ds.name}.{arch}"

    log(f"[train] deployed {tag} (latency workload)")
    dep_params, dep_metric = cached_train(
        out_dir, f"{tag}.deployed1000",
        lambda: train.train_deployed(ds, arch, epochs=row["epochs"],
                                     log=lambda s: None), log)
    log(f"[train] deployed {tag}: acc={dep_metric:.3f}")
    add_model(f"{tag}.deployed1000", "deployed", ds, arch, apply_1000,
              dep_params, ds.input_shape, row["out_dim"], row["batches"],
              dep_metric)

    for k in row["ks"]:
        name = f"{tag}.parity1000.k{k}.sum"
        par_params, ad = cached_train(
            out_dir, name,
            lambda: _train_parity_with_ad(ds, arch, dep_params, k, "sum",
                                          row["p_epochs"]), log)
        log(f"[train] parity {tag} k={k}: A_d={ad:.3f}")
        add_model(name, "parity", ds, arch,
                  pad_output(apply_raw, row["out_dim"], ds.num_classes),
                  par_params, ds.input_shape, row["out_dim"], row["batches"],
                  ad, k=k, encoder="sum")

    # Approximate backup (§5.2.6): same family, narrower — NOT k-times faster.
    _, apply_narrow = models.get("microresnet_narrow")
    nar_params, nar_metric = cached_train(
        out_dir, f"{tag}.approx1000",
        lambda: train.train_deployed(ds, "microresnet_narrow",
                                     epochs=row["epochs"], log=lambda s: None),
        log)
    log(f"[train] approx backup: acc={nar_metric:.3f}")
    add_model(f"{tag}.approx1000", "approx",
              ds, "microresnet_narrow",
              pad_output(apply_narrow, row["out_dim"], ds.num_classes),
              nar_params, ds.input_shape, row["out_dim"], row["batches"],
              nar_metric)

    # ---- encoder-as-executable ablation (§3.2 design space) ----
    # The sum encoder exported as its own Pallas-lowered XLA program, so
    # the Rust side can compare "encoder on the frontend CPU (native)" vs
    # "encoder as an accelerator program" (bench: ablation_encoder_exec).
    from .kernels import encoder as kenc

    for k in (2, 3, 4):
        ishape = (64, 64, 3)  # latency-workload query shape

        def enc_fn(xs, _k=k):
            return (kenc.sum_encode(xs),)

        spec = jax.ShapeDtypeStruct((k,) + ishape, jnp.float32)
        lowered = jax.jit(enc_fn).lower(spec)
        fname = f"encoder.sum.k{k}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["models"].append(dict(
            name=f"encoder.sum.k{k}", role="encoder", dataset="synthpets",
            arch="pallas-sum", input_shape=[k] + list(ishape),
            out_dim=int(np.prod(ishape)), batches=[1],
            files={"1": fname}, k=k, r_index=0, encoder="sum",
            train_metric=float("nan"), train_seconds=0.0))
        log(f"[aot ] exported {fname}")

    manifest["build_seconds"] = round(time.time() - t_start, 1)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    log(f"[aot ] wrote manifest with {len(manifest['models'])} models, "
        f"{len(manifest['datasets'])} datasets in "
        f"{manifest['build_seconds']:.0f}s")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build(os.path.abspath(args.out))


if __name__ == "__main__":
    main()

"""Build-time encoder/decoder semantics (must match the Rust runtime
implementations in rust/src/coordinator/{encoder,decoder}.rs and
rust/src/tensor/ops.rs — the Rust unit tests mirror these cases)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import encoders


def rnd(*shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def test_sum_encode_is_sum():
    xs = rnd(3, 4, 4, 1)
    np.testing.assert_allclose(encoders.sum_encode_np(xs), xs.sum(axis=0), rtol=1e-6)


def test_weighted_sum_r2():
    xs = rnd(2, 5)
    got = encoders.sum_encode_np(xs, weights=[1.0, 2.0])
    np.testing.assert_allclose(got, xs[0] + 2 * xs[1], rtol=1e-6)


def test_parity_weights_vandermonde():
    np.testing.assert_array_equal(encoders.parity_weights(3, 0), [1, 1, 1])
    np.testing.assert_array_equal(encoders.parity_weights(3, 1), [1, 2, 3])
    np.testing.assert_array_equal(encoders.parity_weights(2, 2), [1, 4])


def test_downsample_area_average():
    x = np.arange(16, dtype=np.float32).reshape(4, 4, 1)
    y = encoders.downsample_np(x, 2, 2)
    # top-left quadrant: mean(0,1,4,5) = 2.5
    assert y[0, 0, 0] == 2.5
    assert y.shape == (2, 2, 1)


def test_downsample_rejects_non_divisible():
    with pytest.raises(AssertionError):
        encoders.downsample_np(rnd(5, 4, 1), 2, 2)


def test_concat_k4_grid_layout():
    xs = np.stack([np.full((8, 8, 3), i, np.float32) for i in range(4)])
    p = encoders.concat_encode_np(xs)
    assert p.shape == (8, 8, 3)
    assert p[0, 0, 0] == 0 and p[0, 7, 0] == 1
    assert p[7, 0, 0] == 2 and p[7, 7, 0] == 3


def test_concat_k2_stacks_downsampled_halves():
    xs = np.stack([np.full((4, 4, 1), 1, np.float32), np.full((4, 4, 1), 2, np.float32)])
    p = encoders.concat_encode_np(xs)
    assert p.shape == (4, 4, 1)
    assert np.all(p[:2] == 1) and np.all(p[2:] == 2)


def test_concat_k3_rejected():
    with pytest.raises(AssertionError):
        encoders.concat_encode_np(rnd(3, 8, 8, 1))


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 5), n=st.integers(1, 50))
def test_sub_decode_inverts_sum(k, n):
    outs = rnd(k, n, seed=k * 100 + n)
    parity_out = outs.sum(axis=0)
    for j in range(k):
        avail = np.delete(outs, j, axis=0)
        rec = encoders.sub_decode_np(parity_out, avail)
        np.testing.assert_allclose(rec, outs[j], rtol=1e-4, atol=1e-5)


def test_encode_batch_stripes_across_batch():
    xs = rnd(2, 3, 4)  # k=2, batch of 3, feature 4
    got = encoders.encode_batch_np(xs, "sum")
    np.testing.assert_allclose(got, xs[0] + xs[1], rtol=1e-6)

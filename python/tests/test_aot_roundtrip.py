"""AOT export invariants (regression tests for the artifact pipeline).

The nastiest failure mode in the compile path: XLA's HLO printer elides
large constants by default (`constant({...})`) and the HLO *parser*
accepts the placeholder as zeros — the exported model runs but with all
weights zeroed (A_d collapses to chance). These tests pin the export
options that prevent it, plus the manifest/file layout contract the Rust
loader depends on.
"""

import functools
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, models


@pytest.fixture(scope="module")
def tiny_model():
    rng = np.random.default_rng(5)
    init, apply = models.get("mlp")
    params = jax.tree_util.tree_map(jnp.asarray, init(rng, (8, 8, 1), 4))
    return apply, params


def test_hlo_text_contains_full_constants(tiny_model):
    apply, params = tiny_model
    spec = jax.ShapeDtypeStruct((2, 8, 8, 1), jnp.float32)
    lowered = jax.jit(
        functools.partial(aot._apply_closed, apply, params)
    ).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "{...}" not in text, "HLO printer elided constants"
    # The fc1 weight is 64x200 floats; its literal must appear inline.
    assert "f32[64,200]" in text
    assert len(text) > 100_000, f"suspiciously small HLO ({len(text)} chars)"


def test_hlo_entry_signature_single_arg_tuple_out(tiny_model):
    apply, params = tiny_model
    spec = jax.ShapeDtypeStruct((3, 8, 8, 1), jnp.float32)
    text = aot.to_hlo_text(
        jax.jit(functools.partial(aot._apply_closed, apply, params)).lower(spec)
    )
    # The Rust runtime contract: one input parameter, 1-tuple output.
    head = text.splitlines()[0]
    assert "(f32[3,8,8,1]" in head and "->(f32[3,4]" in head, head


def test_export_model_writes_per_batch_files(tiny_model, tmp_path):
    apply, params = tiny_model
    files = aot.export_model(str(tmp_path), "tiny", apply, params, (8, 8, 1), (1, 2))
    assert sorted(files) == ["1", "2"]
    for b, fname in files.items():
        path = tmp_path / fname
        assert path.exists()
        text = path.read_text()
        assert "{...}" not in text
        assert f"f32[{b},8,8,1]" in text.splitlines()[0]


def test_pad_output_pads_to_1000(tiny_model):
    apply, params = tiny_model
    wrapped = aot.pad_output(apply, 1000, 4)
    x = jnp.zeros((2, 8, 8, 1), jnp.float32)
    out = wrapped(params, x)
    assert out.shape == (2, 1000)
    base = apply(params, x)
    np.testing.assert_allclose(out[:, :4], base)
    assert np.all(np.asarray(out[:, 4:]) == 0.0)


def test_save_dataset_binary_layout(tmp_path):
    from compile import datasets

    ds = datasets.load("synthdigits")
    ds.test_x, ds.test_y = ds.test_x[:10], ds.test_y[:10]
    entry = aot.save_dataset(str(tmp_path), ds)
    x = np.fromfile(tmp_path / entry["test_x"], dtype="<f4")
    y = np.fromfile(tmp_path / entry["test_y"], dtype="<i4")
    assert x.shape[0] == 10 * 28 * 28 * 1
    np.testing.assert_array_equal(y, ds.test_y)
    np.testing.assert_allclose(
        x.reshape(ds.test_x.shape), ds.test_x, rtol=0, atol=0
    )


def test_manifest_is_valid_json_when_present():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    assert man["format"] == "hlo-text-v1"
    for m in man["models"]:
        for f in m["files"].values():
            assert os.path.exists(os.path.join(os.path.dirname(path), f)), f

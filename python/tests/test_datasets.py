"""Synthetic dataset generators: shapes, determinism, learnability basics."""

import numpy as np
import pytest

from compile import datasets


@pytest.mark.parametrize("name", datasets.ALL_NAMES)
def test_shapes_and_ranges(name):
    ds = datasets.load(name)
    assert ds.train_x.dtype == np.float32
    assert ds.train_x.ndim == 4
    assert ds.test_x.shape[1:] == ds.train_x.shape[1:]
    if ds.task == "classify":
        assert ds.train_y.min() >= 0
        assert ds.train_y.max() < ds.num_classes
    else:
        assert ds.train_y.shape[1] == 4
        assert (ds.train_y >= 0).all() and (ds.train_y <= 1.0).all()


def test_deterministic_per_name():
    a = datasets.load("synthdigits")
    b = datasets.load("synthdigits")
    np.testing.assert_array_equal(a.train_x, b.train_x)
    np.testing.assert_array_equal(a.test_y, b.test_y)


def test_different_names_differ():
    a = datasets.load("synthdigits")
    b = datasets.load("synthfashion")
    assert a.train_x.shape[1:] == b.train_x.shape[1:]
    assert not np.allclose(a.train_x[:8], b.train_x[:8])


def test_classes_are_separable_by_nearest_prototype():
    """Sanity: class structure must be strong enough to learn from."""
    ds = datasets.load("synthdigits")
    protos = np.stack([
        ds.train_x[ds.train_y == c].mean(axis=0) for c in range(ds.num_classes)
    ])
    correct = 0
    n = 300
    for i in range(n):
        d = ((protos - ds.test_x[i]) ** 2).sum(axis=(1, 2, 3))
        correct += int(d.argmin() == ds.test_y[i])
    assert correct / n > 0.6, f"nearest-prototype accuracy {correct / n}"


def test_localization_boxes_match_bright_region():
    ds = datasets.load("synthloc")
    # The object is the brightest region: the labeled box center should be
    # brighter than the image average for most samples.
    hits = 0
    n = 100
    h, w = ds.test_x.shape[1:3]
    for i in range(n):
        cx, cy = ds.test_y[i, 0] * w, ds.test_y[i, 1] * h
        px = ds.test_x[i, int(np.clip(cy, 0, h - 1)), int(np.clip(cx, 0, w - 1))].mean()
        hits += int(px > ds.test_x[i].mean())
    assert hits / n > 0.9


def test_unknown_name_raises():
    with pytest.raises(KeyError):
        datasets.load("cifar10")

"""L2 model zoo: shapes, pallas/ref path agreement, and initialization."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import models

RNG = np.random.default_rng(7)

CASES = [
    ("mlp", (28, 28, 1), 10),
    ("lenet", (28, 28, 1), 10),
    ("lenet", (32, 32, 3), 10),
    ("microresnet", (32, 32, 3), 10),
    ("microresnet", (64, 64, 3), 2),
    ("microresnet_narrow", (64, 64, 3), 2),
]


@pytest.mark.parametrize("arch,ishape,odim", CASES)
def test_output_shape(arch, ishape, odim):
    init, apply = models.get(arch)
    params = init(RNG, ishape, odim)
    x = jnp.asarray(RNG.normal(size=(3,) + ishape).astype(np.float32))
    out = apply(params, x, use_pallas=False)
    assert out.shape == (3, odim)


@pytest.mark.parametrize("arch,ishape,odim", CASES)
def test_pallas_path_matches_ref_path(arch, ishape, odim):
    """The property the AOT export depends on: use_pallas=True computes the
    same function as the training path."""
    init, apply = models.get(arch)
    params = init(RNG, ishape, odim)
    x = jnp.asarray(RNG.normal(size=(2,) + ishape).astype(np.float32))
    a = apply(params, x, use_pallas=False)
    b = apply(params, x, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_narrow_variant_is_smaller():
    i1, _ = models.get("microresnet")
    i2, _ = models.get("microresnet_narrow")
    p1 = i1(np.random.default_rng(0), (32, 32, 3), 10)
    p2 = i2(np.random.default_rng(0), (32, 32, 3), 10)
    n1 = sum(int(np.prod(v.shape)) for v in p1.values())
    n2 = sum(int(np.prod(v.shape)) for v in p2.values())
    assert n2 < n1, (n1, n2)


def test_biases_zero_initialized():
    init, _ = models.get("lenet")
    params = init(np.random.default_rng(0), (28, 28, 1), 10)
    for name, v in params.items():
        if name.endswith("_b"):
            assert np.all(v == 0.0), name


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        models.get("resnet152")


def test_deterministic_init_given_rng_seed():
    init, _ = models.get("mlp")
    a = init(np.random.default_rng(11), (28, 28, 1), 10)
    b = init(np.random.default_rng(11), (28, 28, 1), 10)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])

"""Training pipeline: Adam math, deployed/parity training smoke (tiny
configs), and the paper's core accuracy property — reconstructions beat the
default baseline by a wide margin."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import datasets, train


def test_adam_moves_toward_minimum():
    import jax

    params = {"w": jnp.asarray([5.0])}
    opt = train.adam_init(params)
    loss = lambda p: (p["w"][0] - 2.0) ** 2
    for _ in range(400):
        g = jax.grad(loss)(params)
        params, opt = train.adam_update(params, g, opt, lr=0.05)
    assert abs(float(params["w"][0]) - 2.0) < 0.05


def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[0.0, 1.0, 2.0]])
    labels = jnp.asarray([2])
    got = float(train.softmax_xent(logits, labels))
    z = np.exp([0.0, 1.0, 2.0])
    want = -np.log(z[2] / z.sum())
    assert abs(got - want) < 1e-5


def test_iou_basics():
    assert train.iou([0.5, 0.5, 0.2, 0.2], [0.5, 0.5, 0.2, 0.2]) == pytest.approx(1.0)
    assert train.iou([0.1, 0.1, 0.1, 0.1], [0.9, 0.9, 0.1, 0.1]) == 0.0


@pytest.fixture(scope="module")
def tiny_setup():
    # Full synthdigits: parity learning needs the stripe diversity of the
    # whole training set (4 * n/k encoded samples); MLP keeps it fast.
    ds = datasets.load("synthdigits")
    dep = train.train_deployed(ds, "mlp", epochs=8, log=lambda s: None)
    return ds, dep


def test_deployed_learns(tiny_setup):
    ds, dep = tiny_setup
    assert dep.eval_metric > 0.8, f"deployed accuracy {dep.eval_metric}"


def test_parity_reconstruction_beats_default(tiny_setup):
    """The paper's headline accuracy property, k=2 generic encoder."""
    ds, dep = tiny_setup
    par = train.train_parity(ds, "mlp", dep.params, k=2, epochs=12, log=lambda s: None)
    a_d = train.degraded_accuracy(ds, "mlp", dep.params, par.params, k=2)
    default = 1.0 / ds.num_classes
    assert a_d > default + 0.3, f"A_d={a_d} vs default={default}"
    assert a_d <= dep.eval_metric + 0.05, "degraded cannot beat available"


def test_parity_data_labels_are_summed_outputs():
    ds = datasets.load("synthdigits")
    ds.train_x, ds.train_y = ds.train_x[:100], ds.train_y[:100]
    from compile import models

    _, apply_fn = models.get("mlp")
    rng = np.random.default_rng(0)
    params = models.get("mlp")[0](rng, ds.input_shape, 10)
    px, py = train.make_parity_data(
        rng, ds, apply_fn, params, k=2, n_samples=10
    )
    assert px.shape == (10,) + ds.input_shape
    assert py.shape == (10, 10)
    # Parity queries of the sum encoder are sums of two training samples:
    # their stats should roughly double single-sample stats.
    assert abs(px.mean() - 2 * ds.train_x.mean()) < 0.2

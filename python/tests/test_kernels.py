"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the core correctness signal for the compile path: the AOT export
runs through the Pallas kernels while training ran through the references,
so their equivalence is what makes the shipped artifacts match the trained
parameters. Hypothesis sweeps shapes; fixed cases pin the edge geometries
(non-tile-multiple shapes, tiny dims, every activation).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import conv, encoder, linear, ref

RNG = np.random.default_rng(0xA0)


def arr(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------- linear ----
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n=st.integers(1, 40),
    act=st.sampled_from(["relu", "linear", "tanh"]),
)
def test_fused_linear_matches_ref(m, k, n, act):
    x, w, b = arr(m, k), arr(k, n), arr(n)
    got = linear.fused_linear(x, w, b, activation=act)
    want = ref.fused_linear(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (128, 128, 128), (129, 257, 65), (8, 1024, 8)])
def test_fused_linear_tile_boundaries(m, k, n):
    x, w, b = arr(m, k), arr(k, n), arr(n)
    np.testing.assert_allclose(
        linear.fused_linear(x, w, b, "relu"),
        ref.fused_linear(x, w, b, "relu"),
        rtol=1e-4,
        atol=1e-4,
    )


def test_fused_linear_relu_clamps():
    x = jnp.asarray([[-100.0, 100.0]], dtype=jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = np.asarray(linear.fused_linear(x, w, b, "relu"))
    assert out[0, 0] == 0.0 and out[0, 1] == 100.0


def test_fused_linear_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        linear.fused_linear(arr(4, 5), arr(6, 3), arr(3))


# ----------------------------------------------------------------- conv ----
@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.integers(6, 20),
    cin=st.integers(1, 4),
    cout=st.integers(1, 6),
    ksz=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
)
def test_conv2d_matches_ref(b, hw, cin, cout, ksz, stride, padding):
    x = arr(b, hw, hw, cin)
    w = arr(ksz, ksz, cin, cout)
    bias = arr(cout)
    got = conv.conv2d(x, w, bias, stride=stride, padding=padding, activation="relu")
    want = ref.conv2d(x, w, bias, stride=stride, padding=padding, activation="relu")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_conv2d_identity_kernel():
    # 1x1 identity conv must reproduce the input exactly.
    x = arr(2, 8, 8, 3)
    w = jnp.eye(3, dtype=jnp.float32).reshape(1, 1, 3, 3)
    b = jnp.zeros((3,), jnp.float32)
    np.testing.assert_allclose(conv.conv2d(x, w, b), x, rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- encoder ----
@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(2, 5),
    feat=st.integers(1, 2000),
)
def test_sum_encode_matches_ref(k, feat):
    xs = arr(k, feat)
    np.testing.assert_allclose(
        encoder.sum_encode(xs), ref.sum_encode(xs), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(k=st.integers(2, 4))
def test_weighted_encode_matches_ref(k):
    xs = arr(k, 3, 10, 10, 3)
    wts = jnp.asarray(np.arange(1, k + 1, dtype=np.float32))
    np.testing.assert_allclose(
        encoder.weighted_sum_encode(xs, wts),
        ref.weighted_sum_encode(xs, wts),
        rtol=1e-4,
        atol=1e-4,
    )


def test_encoder_decoder_roundtrip_linear_world():
    """For a linear F, sum-encode + sub-decode is exact (Table 1, row 1)."""
    k, d = 3, 17
    xs = arr(k, d)
    m = arr(d, d)  # linear F(x) = x @ m
    outs = jnp.stack([x @ m for x in xs])
    parity_out = encoder.sum_encode(xs) @ m
    for j in range(k):
        avail = jnp.stack([outs[i] for i in range(k) if i != j])
        rec = ref.sub_decode(parity_out, avail)
        np.testing.assert_allclose(rec, outs[j], rtol=1e-3, atol=1e-3)

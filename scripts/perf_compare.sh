#!/usr/bin/env bash
# Gate the saturation bench (rust/benches/saturation.rs) against the
# committed baseline in scripts/perf_baseline.json.
#
# Usage:
#   scripts/perf_compare.sh [results.json]
#       Compare rust/bench_out/throughput.json (or the given file)
#       against the baseline. Exits nonzero when sustained qps regresses
#       by more than PARM_PERF_TOLERANCE (default 0.10 = 10%) — either
#       on the sweep-wide max or on any client phase present in both.
#       While the baseline is marked "provisional": true the script
#       records the measurement and exits 0 instead of gating (the
#       bootstrap state before a reference runner has published
#       numbers).
#
#   scripts/perf_compare.sh --rebaseline [results.json]
#       Rewrite scripts/perf_baseline.json from the given results and
#       clear the provisional flag. Run this on the reference runner
#       after an intentional performance change, sanity-check the
#       numbers, and commit the file — the refreshed baseline is what
#       every subsequent CI run gates against.
#
# The results file is the telemetry::series::Capture emission: a JSON
# array of sampled rows; per-phase numbers live in the rows where the
# phase_qps gauge changes (the bench sets it once per client phase).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
MODE="compare"
if [ "${1:-}" = "--rebaseline" ]; then
    MODE="rebaseline"
    shift
fi
RESULTS="${1:-$ROOT/rust/bench_out/throughput.json}"
BASELINE="$ROOT/scripts/perf_baseline.json"
TOL="${PARM_PERF_TOLERANCE:-0.10}"

[ -f "$RESULTS" ] || { echo "perf_compare: no results at $RESULTS (run: cd rust && cargo bench --bench saturation)"; exit 1; }

python3 - "$RESULTS" "$BASELINE" "$TOL" "$MODE" <<'EOF'
import json, sys

results_path, baseline_path, tol, mode = sys.argv[1:5]
tol = float(tol)
rows = json.load(open(results_path))

# Extract one record per client phase: the bench publishes
# parm_bench_phase_qps exactly once at the end of each phase, while
# parm_bench_clients still holds that phase's client count.
phases = {}
prev = None
for row in rows:
    q = row.get("phase_qps") or 0.0
    if q > 0 and q != prev:
        clients = int(row.get("clients") or 0)
        phases[str(clients)] = {
            "qps": q,
            "p999_ms": row.get("phase_p999_ms") or 0.0,
        }
    prev = q

if not phases:
    sys.exit("perf_compare: no phase rows in %s (phase_qps never set)" % results_path)
max_qps = max(p["qps"] for p in phases.values())

print("measured phases:")
for c in sorted(phases, key=int):
    p = phases[c]
    print("  clients=%-4s qps=%-10.0f p999=%.3fms" % (c, p["qps"], p["p999_ms"]))
print("measured max sustained qps: %.0f" % max_qps)

if mode == "rebaseline":
    doc = {
        "bench": "saturation",
        "provisional": False,
        "max_qps": max_qps,
        "phase_qps": {c: p["qps"] for c, p in phases.items()},
        "phase_p999_ms": {c: p["p999_ms"] for c, p in phases.items()},
        "note": "Reference-runner numbers; refresh with scripts/perf_compare.sh --rebaseline after intentional perf changes.",
    }
    with open(baseline_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print("rebaselined %s" % baseline_path)
    sys.exit(0)

base = json.load(open(baseline_path))
if base.get("provisional") or base.get("max_qps") is None:
    print("baseline is provisional: recording only, not gating.")
    print("(publish one with: scripts/perf_compare.sh --rebaseline)")
    sys.exit(0)

failures = []
floor = base["max_qps"] * (1.0 - tol)
if max_qps < floor:
    failures.append(
        "max sustained qps %.0f < %.0f (baseline %.0f, tolerance %.0f%%)"
        % (max_qps, floor, base["max_qps"], tol * 100)
    )
for c, bq in (base.get("phase_qps") or {}).items():
    if c in phases and phases[c]["qps"] < bq * (1.0 - tol):
        failures.append(
            "clients=%s qps %.0f < %.0f (baseline %.0f, tolerance %.0f%%)"
            % (c, phases[c]["qps"], bq * (1.0 - tol), bq, tol * 100)
        )

if failures:
    print("PERF REGRESSION:")
    for f in failures:
        print("  " + f)
    sys.exit(1)
print("perf gate passed (tolerance %.0f%%)." % (tol * 100))
EOF

//! Concurrency properties of the multi-client serving frontend
//! (`coordinator::frontend`) against a real simulated cluster:
//!
//! - **query conservation**: N concurrent clients x M queries each, with
//!   an instance failure mid-run — every accepted query resolves exactly
//!   once, and every resolution lands in the inbox of the client that
//!   submitted it;
//! - **admission control**: with the cluster stalled (drain rate slowed
//!   far below the offered burst rate), `RejectAbove` sheds load at
//!   `submit` instead of letting the backlog grow unboundedly, and every
//!   accepted query still resolves.
//!
//! Like `service_integration.rs`, these spawn full simulated clusters, so
//! they run serialized and skip (with a message) if artifacts are
//! missing under `--features pjrt`.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::{AdmissionPolicy, SubmitError};
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::workload::QuerySource;

/// Each test spawns a full simulated cluster; running them concurrently
/// oversubscribes the host and distorts the timing paths.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> Option<(Manifest, QuerySource)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP frontend_concurrency: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    Some((m, src))
}

fn models(m: &Manifest, k: usize) -> Option<ModelSet> {
    match latency::load_models(m, 1, k, 1, false) {
        Ok(ms) => Some(ms),
        Err(e) => {
            eprintln!("SKIP frontend_concurrency: {e}");
            None
        }
    }
}

#[test]
fn concurrent_clients_conserve_queries() {
    let _guard = serial();
    const CLIENTS: usize = 6;
    const PER: u64 = 40;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg =
        ServiceConfig::defaults(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }, &GPU);
    cfg.m = 4;
    cfg.shuffles = 0;
    cfg.seed = 0xFACE;
    cfg.slo = Some(Duration::from_secs(3)); // backstop for doubly-lost groups
    // Undetected zombie mid-run (well inside the ~80 ms submit phase):
    // the fan-out must keep routing correctly while resolutions switch to
    // Reconstructed/Default.
    cfg.fault_schedule = vec![(0, Duration::from_millis(40), Duration::ZERO)];

    let frontend = ServiceBuilder::new(cfg)
        .serve(&models, &src.queries[0])
        .expect("frontend builds");

    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let client = frontend.client();
        let queries = src.queries.clone();
        joins.push(std::thread::spawn(move || {
            let mut submitted = HashSet::new();
            let mut got = Vec::new();
            for i in 0..PER {
                let id = client
                    .submit(queries[(c + i as usize) % queries.len()].clone())
                    .expect("unbounded admission accepts");
                assert!(submitted.insert(id), "frontend ids must be unique");
                got.extend(client.poll());
                std::thread::sleep(Duration::from_millis(2));
            }
            while got.len() < PER as usize {
                match client.next(Duration::from_secs(10)) {
                    Some(r) => got.push(r),
                    None => break,
                }
            }
            (submitted, got, client)
        }));
    }

    let mut grand_total = 0u64;
    for j in joins {
        let (submitted, got, client) = j.join().expect("client thread");
        assert_eq!(got.len(), PER as usize, "every query resolves exactly once");
        let ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), got.len(), "no duplicate resolutions");
        assert_eq!(ids, submitted, "resolutions routed to the submitting client");
        let st = client.stats();
        assert_eq!(st.submitted, PER);
        assert_eq!(st.resolved, PER);
        assert_eq!(st.rejected, 0);
        assert_eq!(st.in_flight(), 0);
        assert_eq!(
            st.native + st.recovered + st.defaulted,
            PER,
            "outcome counts partition the client's queries"
        );
        grand_total += st.resolved;
    }

    let res = frontend.shutdown().expect("clean shutdown");
    assert_eq!(res.metrics.total(), grand_total, "session metrics agree with clients");
    assert_eq!(res.rejected, 0);
    assert!(
        res.dropped_jobs > 0,
        "the killed instance must actually have swallowed jobs"
    );
}

#[test]
fn reject_above_bounds_backlog_under_stall() {
    let _guard = serial();
    const LIMIT: usize = 16;
    const ATTEMPTS: u64 = 400;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
    cfg.m = 2;
    cfg.shuffles = 0;
    cfg.seed = 0xDEAD;
    // Induced stall: scale every injected delay 5x, so each of the two
    // instances is busy >= ~0.75 ms per query (5x the 150 us dispatch
    // overhead alone) while the client submits in tight bursts — offered
    // load far beyond the drain rate, and the pool queue can only grow.
    cfg.time_scale = 5.0;
    cfg.admission = AdmissionPolicy::RejectAbove { backlog: LIMIT };

    let frontend = ServiceBuilder::new(cfg)
        .serve(&models, &src.queries[0])
        .expect("frontend builds");
    let client = frontend.client();

    let mut accepted = 0u64;
    let mut rejected = 0u64;
    let mut max_load = 0usize;
    for i in 0..ATTEMPTS {
        match client.submit(src.queries[(i as usize) % src.len()].clone()) {
            Ok(_) => accepted += 1,
            Err(SubmitError::Rejected { .. }) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        max_load = max_load.max(frontend.load());
        if i % 16 == 15 {
            // Brief gap between bursts: lets the dispatcher hand
            // submissions to the session, so the test exercises the
            // published-backlog path and not just the `queued` count.
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    assert!(accepted > 0, "admission must still admit up to the limit");
    assert!(
        rejected > 0,
        "a stalled cluster must shed load ({accepted} accepted of {ATTEMPTS})"
    );
    assert_eq!(accepted + rejected, ATTEMPTS);
    assert!(
        max_load <= LIMIT + 8,
        "backlog must stay bounded near the limit: saw {max_load} (limit {LIMIT})"
    );
    assert_eq!(client.stats().rejected, rejected, "per-client reject accounting");
    let w = client.window();
    assert_eq!(w.rejected, rejected, "rejects visible in the windowed metrics");
    assert!(w.reject_rate > 0.0);

    // Accepting a query remains a promise: the bounded backlog drains and
    // every accepted query resolves (healthy instances, so all native).
    let res = frontend.shutdown().expect("clean shutdown");
    let st = client.stats();
    assert_eq!(st.resolved, accepted, "accepted queries all resolve");
    assert_eq!(st.native, accepted, "healthy cluster resolves natively");
    assert_eq!(res.rejected, rejected, "rejects surface in the RunResult");
    assert_eq!(res.metrics.total(), accepted);
    assert_eq!(res.metrics.offered(), ATTEMPTS);
}

/// Weighted fairness: under `RejectAbove` with a stalled cluster, a
/// greedy flooder must absorb the rejects while a light paced client
/// keeps being admitted — one client can no longer starve the others by
/// racing the shared load limit.
#[test]
fn weighted_fairness_shields_light_client_from_flooder() {
    let _guard = serial();
    const LIMIT: usize = 16;
    // Safety cap only — the light client's window ends the flood.
    const FLOOD: u64 = 20_000;
    const LIGHT: u64 = 40;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
    cfg.m = 2;
    cfg.shuffles = 0;
    cfg.seed = 0xFA12;
    // Same induced stall as reject_above_bounds_backlog_under_stall: the
    // flooder's burst rate far exceeds the drain rate.
    cfg.time_scale = 5.0;
    cfg.admission = AdmissionPolicy::RejectAbove { backlog: LIMIT };

    let frontend = ServiceBuilder::new(cfg)
        .serve(&models, &src.queries[0])
        .expect("frontend builds");
    let flooder = frontend.client_with_weight(1.0);
    let light = frontend.client_with_weight(1.0);
    assert_eq!(light.weight(), 1.0);

    // The flooder hammers submit for the whole light-client window.
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flood_handle = {
        let queries = src.queries.clone();
        let flooder = flooder.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let (mut attempts, mut rejected) = (0u64, 0u64);
            while !done.load(Ordering::Relaxed) && attempts < FLOOD {
                if flooder
                    .submit(queries[(attempts as usize) % queries.len()].clone())
                    .is_err()
                {
                    rejected += 1;
                }
                attempts += 1;
                if attempts % 32 == 0 {
                    std::thread::sleep(Duration::from_micros(500));
                }
            }
            (attempts, rejected)
        })
    };
    // The light client offers one query every few ms — far below its
    // fair share of the limit — concurrently with the flood.
    let mut light_rejects = 0u64;
    for i in 0..LIGHT {
        if light.submit(src.queries[(i as usize) % src.len()].clone()).is_err() {
            light_rejects += 1;
        }
        std::thread::sleep(Duration::from_millis(3));
    }
    done.store(true, Ordering::Relaxed);
    let (flood_attempts, flood_rejects) = flood_handle.join().expect("flooder thread");

    assert!(
        flood_rejects > flood_attempts / 4,
        "the flooder must absorb rejects under the stall, saw {flood_rejects} of {flood_attempts}"
    );
    assert!(
        light_rejects <= LIGHT / 10,
        "the light client must keep its fair share: {light_rejects} of {LIGHT} rejected \
         (flooder: {flood_rejects} of {flood_attempts})"
    );

    // Accepting is still a promise for both clients.
    let res = frontend.shutdown().expect("clean shutdown");
    assert_eq!(light.stats().resolved, LIGHT - light_rejects);
    assert_eq!(flooder.stats().resolved, flood_attempts - flood_rejects);
    assert_eq!(res.rejected, light_rejects + flood_rejects);
}

/// Regression: `Block`-policy waiters interrupted by `shutdown` must be
/// tallied as shed load *before* the dispatcher folds rejects into the
/// session's `RunResult` — and shutdown must interrupt them promptly
/// instead of waiting out their (long) admission timeout. Before the
/// fix, a waiter blocked in admission never observed the close: it was
/// either silently admitted during teardown or sat until its own
/// timeout, and the run record under-counted the offered load.
#[test]
fn shutdown_tallies_interrupted_block_waiters() {
    let _guard = serial();
    const LIMIT: usize = 2;
    const WAITERS: usize = 8;
    const PER: usize = 200;
    const BLOCK_TIMEOUT: Duration = Duration::from_secs(8);
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
    cfg.m = 2;
    cfg.shuffles = 0;
    cfg.seed = 0xB10C;
    // Stall the drain (as in reject_above_bounds_backlog_under_stall) so
    // the load hovers at the limit and most waiters are blocked in
    // admission at any instant.
    cfg.time_scale = 25.0;
    cfg.admission = AdmissionPolicy::Block { backlog: LIMIT, timeout: BLOCK_TIMEOUT };

    let frontend = ServiceBuilder::new(cfg)
        .serve(&models, &src.queries[0])
        .expect("frontend builds");

    let accepted_total = Arc::new(AtomicU64::new(0));
    let mut joins = Vec::new();
    let mut clients = Vec::new();
    for c in 0..WAITERS {
        let client = frontend.client();
        clients.push(client.clone());
        let queries = src.queries.clone();
        let accepted_total = accepted_total.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..PER {
                match client.submit(queries[(c + i) % queries.len()].clone()) {
                    Ok(_) => {
                        accepted_total.fetch_add(1, Ordering::Relaxed);
                    }
                    // Interrupted by the close (tallied as a reject by
                    // the frontend) or failed fast after it (not
                    // tallied): either way, stop offering.
                    Err(SubmitError::Closed) => break,
                    Err(SubmitError::Timeout { .. }) => {
                        panic!("no waiter should sit out its 8 s timeout")
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }));
    }

    // Let the storm saturate admission, then shut down mid-storm while
    // (with LIMIT=2 and 8 submitters) several waiters are blocked.
    std::thread::sleep(Duration::from_millis(60));
    let t0 = Instant::now();
    let res = frontend.shutdown().expect("clean shutdown");
    let shutdown_took = t0.elapsed();
    for j in joins {
        j.join().expect("waiter thread");
    }

    assert!(
        shutdown_took < BLOCK_TIMEOUT / 2,
        "shutdown must interrupt Block waiters promptly, took {shutdown_took:?}"
    );
    let accepted = accepted_total.load(Ordering::Relaxed);
    let client_rejects: u64 = clients.iter().map(|c| c.stats().rejected).sum();
    assert!(
        client_rejects > 0,
        "with {WAITERS} submitters over limit {LIMIT}, shutdown must interrupt some waiter"
    );
    assert_eq!(
        res.rejected, client_rejects,
        "every interrupted waiter's reject is folded into the RunResult"
    );
    assert_eq!(res.metrics.total(), accepted, "accepted still implies resolved");
    assert_eq!(res.metrics.offered(), accepted + client_rejects);
    let client_resolved: u64 = clients.iter().map(|c| c.stats().resolved).sum();
    assert_eq!(client_resolved, accepted, "deliveries kept flowing through shutdown");
}

//! Integration tests over the PJRT runtime + artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a loud
//! message) when the manifest is missing so `cargo test` stays green on a
//! fresh checkout.

use std::sync::Arc;

use parm::artifacts::Manifest;
use parm::coordinator::{decoder, encoder::Encoder};
use parm::experiments::accuracy::run_all;
use parm::runtime::engine::Executable;
use parm::tensor::Tensor;
use parm::workload::QuerySource;

fn manifest() -> Option<Manifest> {
    // These tests assert *trained* model semantics (accuracy beats
    // chance, parity reconstructions classify correctly), which the
    // synthetic engine backend cannot provide — skip unless the real
    // PJRT backend is compiled in.
    if cfg!(not(feature = "pjrt")) {
        eprintln!(
            "SKIP runtime_smoke: synthetic engine backend \
             (build with --features pjrt and run `make artifacts`)"
        );
        return None;
    }
    // Tests run from the package root; `make artifacts` writes ../artifacts.
    match Manifest::load("artifacts").or_else(|_| Manifest::load("../artifacts")) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime_smoke: {e}");
            None
        }
    }
}

#[test]
fn load_and_execute_deployed_model() {
    let Some(m) = manifest() else { return };
    let e = m.deployed("synthdigits", "lenet").unwrap();
    let exe = Executable::load(m.hlo_path(e, 1).unwrap(), &e.name, &e.input_shape, 1, e.out_dim)
        .unwrap();
    let ds = m.dataset("synthdigits").unwrap();
    let src = QuerySource::from_dataset(&m, ds).unwrap();
    let out = exe.run_one(&src.queries[0]).unwrap();
    assert_eq!(out.shape(), &[e.out_dim]);
    assert!(out.data().iter().all(|v| v.is_finite()));
}

#[test]
fn deployed_model_beats_chance_through_full_runtime() {
    // The strongest wiring test: exported weights + PJRT execution + test
    // set loading must all line up or accuracy collapses to ~10%.
    let Some(m) = manifest() else { return };
    let e = m.deployed("synthdigits", "lenet").unwrap();
    let batch = *e.files.keys().max().unwrap();
    let exe =
        Executable::load(m.hlo_path(e, batch).unwrap(), &e.name, &e.input_shape, batch, e.out_dim)
            .unwrap();
    let ds = m.dataset("synthdigits").unwrap();
    let src = QuerySource::from_dataset(&m, ds).unwrap();
    let n = 200.min(src.len());
    let outs = run_all(&exe, &src.queries[..n]).unwrap();
    let correct = outs
        .iter()
        .enumerate()
        .filter(|(i, o)| o.argmax() as i32 == src.class_of(*i).unwrap())
        .count();
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.5, "runtime accuracy {acc} — artifacts or runtime broken");
}

#[test]
fn wrong_input_shape_is_rejected() {
    let Some(m) = manifest() else { return };
    let e = m.deployed("synthdigits", "lenet").unwrap();
    let exe = Executable::load(m.hlo_path(e, 1).unwrap(), &e.name, &e.input_shape, 1, e.out_dim)
        .unwrap();
    let bad = Tensor::zeros(vec![1, 3, 3, 1]);
    assert!(exe.run(&bad).is_err());
}

#[test]
fn concurrent_execution_is_consistent() {
    // Validates the Send/Sync wrappers around PJRT (see engine.rs SAFETY
    // comments): many threads execute the same compiled program and must
    // all observe identical results.
    let Some(m) = manifest() else { return };
    let e = m.deployed("synthdigits", "lenet").unwrap();
    let exe: Arc<Executable> =
        Executable::load(m.hlo_path(e, 1).unwrap(), &e.name, &e.input_shape, 1, e.out_dim)
            .unwrap();
    let ds = m.dataset("synthdigits").unwrap();
    let src = QuerySource::from_dataset(&m, ds).unwrap();
    let q = Arc::new(src.queries[0].clone());
    let expected = exe.run_one(&q).unwrap();

    let handles: Vec<_> = (0..8)
        .map(|_| {
            let exe = exe.clone();
            let q = q.clone();
            let expected = expected.clone();
            std::thread::spawn(move || {
                for _ in 0..25 {
                    let out = exe.run_one(&q).unwrap();
                    assert_eq!(out.data(), expected.data());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("concurrent execution thread panicked");
    }
}

#[test]
fn parity_pipeline_reconstructs_through_runtime() {
    // encode -> parity inference -> decode == usable reconstruction.
    let Some(m) = manifest() else { return };
    let dep = m.deployed("synthdigits", "lenet").unwrap();
    let par = m.parity("synthdigits", "lenet", 2, "sum", 0).unwrap();
    let dep_exe =
        Executable::load(m.hlo_path(dep, 1).unwrap(), &dep.name, &dep.input_shape, 1, dep.out_dim)
            .unwrap();
    let par_exe =
        Executable::load(m.hlo_path(par, 1).unwrap(), &par.name, &par.input_shape, 1, par.out_dim)
            .unwrap();
    let ds = m.dataset("synthdigits").unwrap();
    let src = QuerySource::from_dataset(&m, ds).unwrap();

    let enc = Encoder::sum(2);
    let n_pairs = 40;
    let mut recon_correct = 0;
    for s in 0..n_pairs {
        let (a, b) = (2 * s, 2 * s + 1);
        let p = enc.encode(&[&src.queries[a], &src.queries[b]]).unwrap();
        let fa = dep_exe.run_one(&src.queries[a]).unwrap();
        let fp = par_exe.run_one(&p).unwrap();
        let rec = decoder::decode_r1(&[1.0, 1.0], &fp, &[Some(fa), None], 1).unwrap();
        if rec.argmax() as i32 == src.class_of(b).unwrap() {
            recon_correct += 1;
        }
    }
    let acc = recon_correct as f64 / n_pairs as f64;
    assert!(
        acc > 0.4,
        "reconstruction accuracy {acc} through full runtime — decode wiring broken?"
    );
}

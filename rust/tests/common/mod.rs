//! Shared test-harness surface: the deterministic fault-injection
//! harness now lives in the library ([`parm::cluster::chaos`]) so
//! examples, benches, and the `parm` CLI can script chaos too; the
//! integration suites keep importing it from here.

#![allow(unused_imports)]

pub use parm::cluster::chaos::{FaultAction, FaultScript, FaultScriptBuilder, FaultSurface};

//! Deterministic fault-injection harness shared by the integration
//! suites.
//!
//! Chaos used to be ad hoc per test: a sleep, then a hand-rolled
//! `kill_instance` at whatever instant the scheduler reached. This
//! harness makes fault timelines *data*: a seeded [`FaultScript`] of
//! (step, action) events, where a step is the index of a submitted
//! query — not wall time — so the same seed produces the same fault
//! pattern relative to the traffic on every run and host. Tests drive
//! it with one line in their submit loop:
//!
//! ```ignore
//! let surface = FaultSurface::sharded(plans, m);
//! let mut script = FaultScript::builder(seed)
//!     .kill_shard_at(40, 1)
//!     .straggle_at(60, 0, 1, Duration::from_millis(50))
//!     .build();
//! for i in 0..n {
//!     script.apply(i, &surface);
//!     client.submit(...);
//! }
//! ```
//!
//! Actions cover the repo's failure models: single-instance zombies
//! (`KillInstance`), whole-fault-domain loss (`KillShard`), bounded
//! brown-outs (`Straggle`), and correlated multi-shard bursts
//! (`CorrelatedKill` — the case cross-shard coding sizes its r for).

#![allow(dead_code)]

use std::sync::Arc;
use std::time::Duration;

use parm::cluster::faults::FaultPlan;
use parm::util::rng::Pcg64;

/// One scripted fault.
#[derive(Clone, Debug)]
pub enum FaultAction {
    /// Permanently kill one instance of one shard (undetected zombie).
    KillInstance { shard: usize, instance: usize },
    /// Permanently kill every instance of one shard (whole fault
    /// domain).
    KillShard { shard: usize },
    /// Fail one instance for a bounded window (brown-out).
    Straggle { shard: usize, instance: usize, dur: Duration },
    /// Correlated burst: kill every instance of several shards at once.
    CorrelatedKill { shards: Vec<usize> },
}

/// Where scripted faults land: the per-shard fault plans of whatever is
/// under test (a bare session, a `ShardedFrontend`, a
/// `CrossShardFrontend` — all expose `fault_plan(...)`), plus the
/// instance count a whole-shard kill must cover.
pub struct FaultSurface {
    instances_per_shard: usize,
    plans: Vec<Arc<FaultPlan>>,
}

impl FaultSurface {
    /// A single-session target (shard index is always 0).
    pub fn single(plan: Arc<FaultPlan>, instances: usize) -> FaultSurface {
        FaultSurface { instances_per_shard: instances, plans: vec![plan] }
    }

    /// A sharded target: one fault plan per shard, `instances_per_shard`
    /// deployed instances each (ids 0..m within each shard's plan).
    pub fn sharded(plans: Vec<Arc<FaultPlan>>, instances_per_shard: usize) -> FaultSurface {
        assert!(!plans.is_empty());
        FaultSurface { instances_per_shard, plans }
    }

    pub fn shards(&self) -> usize {
        self.plans.len()
    }

    pub fn instances_per_shard(&self) -> usize {
        self.instances_per_shard
    }

    pub fn kill(&self, shard: usize, instance: usize) {
        self.plans[shard].kill(instance);
    }

    pub fn fail_for(&self, shard: usize, instance: usize, dur: Duration) {
        self.plans[shard].fail_for(instance, dur);
    }

    fn kill_shard(&self, shard: usize) {
        for i in 0..self.instances_per_shard {
            self.plans[shard].kill(i);
        }
    }
}

/// A seeded, step-indexed fault timeline. Build with
/// [`FaultScript::builder`]; call [`FaultScript::apply`] once per
/// submitted query with the query's index.
pub struct FaultScript {
    /// (step, action), sorted by step.
    events: Vec<(u64, FaultAction)>,
    next: usize,
}

impl FaultScript {
    pub fn builder(seed: u64) -> FaultScriptBuilder {
        FaultScriptBuilder { rng: Pcg64::new(seed), events: Vec::new() }
    }

    /// Fire every action due at or before `step`.
    pub fn apply(&mut self, step: u64, surface: &FaultSurface) {
        while self.next < self.events.len() && self.events[self.next].0 <= step {
            match &self.events[self.next].1 {
                FaultAction::KillInstance { shard, instance } => {
                    surface.kill(*shard, *instance);
                }
                FaultAction::KillShard { shard } => surface.kill_shard(*shard),
                FaultAction::Straggle { shard, instance, dur } => {
                    surface.fail_for(*shard, *instance, *dur);
                }
                FaultAction::CorrelatedKill { shards } => {
                    for &s in shards {
                        surface.kill_shard(s);
                    }
                }
            }
            self.next += 1;
        }
    }

    /// Whether every scripted action has fired.
    pub fn done(&self) -> bool {
        self.next >= self.events.len()
    }

    /// The scripted actions (inspection/logging).
    pub fn events(&self) -> &[(u64, FaultAction)] {
        &self.events
    }
}

/// Builder for [`FaultScript`]: explicit placements plus seeded random
/// choices (which shard dies, which shards fail together) so soak
/// suites get diverse-but-reproducible trials from one seed.
pub struct FaultScriptBuilder {
    rng: Pcg64,
    events: Vec<(u64, FaultAction)>,
}

impl FaultScriptBuilder {
    pub fn kill_instance_at(mut self, step: u64, shard: usize, instance: usize) -> Self {
        self.events.push((step, FaultAction::KillInstance { shard, instance }));
        self
    }

    pub fn kill_shard_at(mut self, step: u64, shard: usize) -> Self {
        self.events.push((step, FaultAction::KillShard { shard }));
        self
    }

    pub fn straggle_at(
        mut self,
        step: u64,
        shard: usize,
        instance: usize,
        dur: Duration,
    ) -> Self {
        self.events.push((step, FaultAction::Straggle { shard, instance, dur }));
        self
    }

    pub fn correlated_kill_at(mut self, step: u64, shards: Vec<usize>) -> Self {
        self.events.push((step, FaultAction::CorrelatedKill { shards }));
        self
    }

    /// Kill one seeded-random shard out of `shards` at `step`.
    pub fn random_shard_kill_at(mut self, step: u64, shards: usize) -> Self {
        let s = self.rng.below(shards as u64) as usize;
        self.events.push((step, FaultAction::KillShard { shard: s }));
        self
    }

    /// Kill `count` seeded-random distinct shards together at `step`
    /// (the correlated burst).
    pub fn random_correlated_kill_at(mut self, step: u64, shards: usize, count: usize) -> Self {
        let picked = self.rng.choose_distinct(shards, count.min(shards));
        self.events.push((step, FaultAction::CorrelatedKill { shards: picked }));
        self
    }

    /// A seeded step in `[lo, hi]` (for randomizing *when* a scripted
    /// fault lands).
    pub fn random_step(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn build(mut self) -> FaultScript {
        self.events.sort_by_key(|&(step, _)| step);
        FaultScript { events: self.events, next: 0 }
    }
}

//! Chaos soak for the cross-shard coding tier, driven by the
//! deterministic fault harness in `tests/common`:
//!
//! - **acceptance**: killing one *entire* data shard mid-run loses zero
//!   accepted queries — every query resolves natively or via cross-shard
//!   decode — while fixed single-shard ParM under the same seed, spec,
//!   and fault step loses queries to SLO defaults (its groups lose data
//!   and parity together);
//! - **soak**: many seeded trials (`PARM_CHAOS_TRIALS`, default 40 in
//!   debug / 200 in release; CI's chaos job runs 200) drive correlated
//!   shard kills at seeded-random steps through the harness, asserting
//!   exactly-once delivery and merged `RunResult` conservation
//!   (offered = resolved + rejected) on every trial.
//!
//! Like the other cluster suites these spawn full simulated clusters,
//! run serialized, and skip with a message when artifacts are missing
//! under `--features pjrt`.

mod common;

use std::collections::HashSet;
use std::time::{Duration, Instant};

use common::{FaultScript, FaultSurface};
use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::{AdmissionPolicy, SubmitError};
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::Resolved;
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedClient, ShardedFrontend};
use parm::experiments::latency;
use parm::workload::QuerySource;

/// Each test spawns full simulated clusters; serialize to keep the
/// timing paths representative.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(r_max: usize) -> Option<(QuerySource, ModelSet)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP cross_shard_chaos: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    match latency::load_models(&m, 1, 2, r_max, false) {
        Ok(models) => Some((src, models)),
        Err(e) => {
            eprintln!("SKIP cross_shard_chaos: {e}");
            None
        }
    }
}

/// Round-robin the queries over the clients from one thread, firing the
/// fault script at its scripted steps; returns (accepted ids, rejected
/// count, resolutions collected so far).
fn drive(
    clients: &[ShardedClient],
    src: &QuerySource,
    n: u64,
    script: &mut FaultScript,
    surface: &FaultSurface,
) -> (HashSet<u64>, u64, Vec<Resolved>) {
    let mut submitted = HashSet::new();
    let mut rejected = 0u64;
    let mut got = Vec::new();
    for i in 0..n {
        script.apply(i, surface);
        let c = &clients[(i as usize) % clients.len()];
        match c.submit(src.queries[(i as usize) % src.len()].clone()) {
            Ok(id) => {
                assert!(submitted.insert(id), "tier ids must be unique");
            }
            Err(SubmitError::Rejected { .. } | SubmitError::SloShed { .. }) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        for c in clients {
            got.extend(c.poll());
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    (submitted, rejected, got)
}

/// Sweep every client until `want` resolutions arrived (or timeout).
fn collect(clients: &[ShardedClient], got: &mut Vec<Resolved>, want: usize, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while got.len() < want && Instant::now() < deadline {
        let mut any = false;
        for c in clients {
            for r in c.poll() {
                got.push(r);
                any = true;
            }
        }
        if !any {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// The tentpole acceptance: a whole-shard kill mid-run costs the
/// cross-shard tier nothing (every query native or reconstructed),
/// while per-shard ParM under the same seed and fault step pays in SLO
/// defaults.
#[test]
fn whole_shard_kill_loses_zero_where_single_shard_parm_loses() {
    let _guard = serial();
    const SHARDS: usize = 3;
    const M: usize = 2;
    const CLIENTS: usize = 9;
    const N: u64 = 270;
    const KILL_STEP: u64 = 60;
    const SEED: u64 = 0xC505;
    let Some((src, models)) = setup(2) else { return };
    let spec = ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None };
    let slo = Duration::from_millis(1500);

    // --- cross-shard coding tier ---
    let mut cfg = ServiceConfig::defaults(
        Mode::CrossShard {
            k: 2,
            r_min: 1,
            r_max: 2,
            halflife: Duration::from_millis(150),
        },
        &GPU,
    );
    cfg.m = M;
    cfg.shuffles = 0;
    cfg.seed = SEED;
    cfg.slo = Some(slo);
    let tier = CrossShardFrontend::start(cfg, spec, &models, &src.queries[0])
        .expect("cross-shard tier builds");
    let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
    let victim = tier.route_of(clients[0].id()).expect("live shard");
    let surface = FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M);
    let mut script = FaultScript::builder(SEED).kill_shard_at(KILL_STEP, victim).build();

    let (submitted, rejected, mut got) = drive(&clients, &src, N, &mut script, &surface);
    assert_eq!(rejected, 0, "unbounded admission accepts everything");
    // Tail groups get their parity protection now instead of at the
    // loss horizon.
    tier.flush_open_groups();
    collect(&clients, &mut got, submitted.len(), Duration::from_secs(12));

    assert_eq!(got.len(), submitted.len(), "every accepted query resolves");
    let ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), got.len(), "no duplicate resolutions");
    assert_eq!(ids, submitted, "exactly the submitted ids");

    let res = tier.shutdown().expect("clean shutdown");
    let metrics = res.fleet.merged.metrics;
    assert_eq!(metrics.total(), N, "fleet record conserves the run");
    assert_eq!(
        metrics.defaulted, 0,
        "a whole-shard kill must lose nothing: every query resolves \
         natively or via cross-shard decode (recon={}, telemetry {:?})",
        metrics.reconstructed, res.telemetry
    );
    assert!(
        metrics.reconstructed > 0,
        "the killed shard's queries must come back via decode"
    );
    assert!(
        res.fleet.per_shard[victim].dropped_jobs > 0,
        "the killed shard must actually have swallowed jobs"
    );
    assert_eq!(res.telemetry.reconstructions, metrics.reconstructed);

    // --- baseline: per-shard ParM, same seed, same fault step ---
    let mut cfg = ServiceConfig::defaults(
        Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
        &GPU,
    );
    cfg.m = M;
    cfg.shuffles = 0;
    cfg.seed = SEED;
    cfg.slo = Some(slo);
    let parm = ShardedFrontend::start(cfg, spec, &models, &src.queries[0])
        .expect("sharded ParM builds");
    let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| parm.client()).collect();
    let parm_victim = parm.route_of(clients[0].id()).expect("live shard");
    assert_eq!(parm_victim, victim, "same seed, same routing, same victim");
    // Whole-shard kill for ParM includes its in-shard parity instances
    // (m + ceil(m/k) ids) — data and parity die together, the
    // correlated case intra-shard coding cannot absorb.
    let per_shard_instances = M + (M + 1) / 2;
    let surface = FaultSurface::sharded(
        (0..SHARDS).map(|s| parm.fault_plan(s)).collect(),
        per_shard_instances,
    );
    let mut script = FaultScript::builder(SEED).kill_shard_at(KILL_STEP, victim).build();

    let (submitted, _rejected, mut got) = drive(&clients, &src, N, &mut script, &surface);
    collect(&clients, &mut got, submitted.len(), Duration::from_secs(12));
    assert_eq!(got.len(), submitted.len(), "SLO backstop still resolves everything");

    let res = parm.shutdown().expect("clean shutdown");
    let metrics = res.merged.metrics;
    assert_eq!(metrics.total(), N);
    assert!(
        metrics.defaulted > 0,
        "single-shard ParM loses its killed shard's queries to defaults \
         (data + parity share the fault domain)"
    );
}

fn soak_trials() -> u64 {
    if let Ok(v) = std::env::var("PARM_CHAOS_TRIALS") {
        if let Ok(n) = v.parse() {
            return n;
        }
    }
    if cfg!(debug_assertions) {
        40
    } else {
        200
    }
}

/// Seeded soak: correlated shard kills at seeded-random steps; on every
/// trial the tier must deliver exactly once and its merged record must
/// conserve the offered traffic (submitted = resolved + rejected).
#[test]
fn chaos_soak_conserves_queries_across_seeded_trials() {
    let _guard = serial();
    const SHARDS: usize = 3;
    const M: usize = 1;
    const CLIENTS: usize = 6;
    const N: u64 = 36;
    let Some((src, models)) = setup(2) else { return };
    let trials = soak_trials();
    let t0 = Instant::now();

    for trial in 0..trials {
        let seed = 0x50AC + trial * 7919;
        let mut cfg = ServiceConfig::defaults(
            Mode::CrossShard {
                k: 2,
                r_min: 1,
                r_max: 2,
                halflife: Duration::from_millis(100),
            },
            &GPU,
        );
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = seed;
        cfg.slo = Some(Duration::from_millis(700));
        if trial % 2 == 1 {
            // Exercise the reject path of the conservation equation on
            // half the trials.
            cfg.admission = AdmissionPolicy::RejectAbove { backlog: 8 };
        }
        let spec = ShardSpec { shards: SHARDS, vnodes: 32, global_backlog: None };
        let tier = CrossShardFrontend::start(cfg, spec, &models, &src.queries[0])
            .unwrap_or_else(|e| panic!("trial {trial}: tier builds: {e}"));
        let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
        let surface =
            FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M);
        // Correlated burst: 1 or 2 whole shards die together at a
        // seeded-random step mid-run.
        let mut builder = FaultScript::builder(seed);
        let step = builder.random_step(4, 16);
        let burst = 1 + (trial % 2) as usize;
        let mut script = builder.random_correlated_kill_at(step, SHARDS, burst).build();

        let (submitted, rejected, mut got) = drive(&clients, &src, N, &mut script, &surface);
        assert!(script.done(), "trial {trial}: the scripted burst fired");
        tier.flush_open_groups();
        collect(&clients, &mut got, submitted.len(), Duration::from_secs(8));

        // Exactly-once delivery.
        assert_eq!(
            got.len(),
            submitted.len(),
            "trial {trial} (seed {seed:#x}): every accepted query resolves"
        );
        let ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), got.len(), "trial {trial}: no duplicate resolutions");
        assert_eq!(ids, submitted, "trial {trial}: exactly the accepted ids");

        // Merged-record conservation: offered = resolved + rejected.
        let res = tier.shutdown().unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let metrics = &res.fleet.merged.metrics;
        assert_eq!(
            metrics.total(),
            submitted.len() as u64,
            "trial {trial}: resolved equals accepted"
        );
        assert_eq!(res.fleet.merged.rejected, rejected, "trial {trial}: rejects conserved");
        assert_eq!(
            metrics.offered(),
            N,
            "trial {trial}: offered = resolved + rejected"
        );
        let sum_resolved: u64 = res.fleet.per_shard.iter().map(|r| r.metrics.total()).sum();
        let sum_rejected: u64 = res.fleet.per_shard.iter().map(|r| r.rejected).sum();
        assert_eq!(sum_resolved, metrics.total(), "trial {trial}: per-shard sums agree");
        assert_eq!(sum_rejected, res.fleet.merged.rejected, "trial {trial}");
    }
    eprintln!(
        "cross_shard_chaos soak: {trials} trials in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}

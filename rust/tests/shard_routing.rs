//! Properties of the sharded serving tier (`coordinator::shards`)
//! against real simulated clusters:
//!
//! - **cross-shard query conservation**: 16 concurrent clients over 4
//!   shards, with one shard's instance killed mid-run — every accepted
//!   query resolves exactly once, back to the client (and shard) that
//!   submitted it, and the merged shutdown record's totals equal the
//!   per-shard sums;
//! - **drain rerouting**: taking a shard out of the ring reroutes that
//!   client's *subsequent* submits to a surviving shard without losing
//!   anything already in flight;
//! - **global admission cap**: the fleet-wide offered-load cap sheds and
//!   its rejects land in the merged accounting;
//! - **`WindowSnapshot::merge`**: seeded property trials — merged counts
//!   are exact sums and merged quantiles stay bounded by the per-shard
//!   extremes.
//!
//! Like `frontend_concurrency.rs`, the cluster tests spawn full
//! simulated clusters, so they run serialized and skip (with a message)
//! if artifacts are missing under `--features pjrt`. Mid-run faults are
//! scripted through the deterministic harness in `tests/common` (step-
//! indexed, seeded) instead of ad-hoc sleep-then-kill logic.

mod common;

use std::collections::HashSet;
use std::time::{Duration, Instant};

use common::{FaultScript, FaultSurface};
use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::SubmitError;
use parm::coordinator::metrics::{LatencyWindow, Outcome, WindowSnapshot};
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::shards::{shard_of, ShardSpec, ShardedFrontend};
use parm::experiments::latency;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

/// Each test spawns a full simulated cluster; running them concurrently
/// oversubscribes the host and distorts the timing paths.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> Option<(Manifest, QuerySource)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP shard_routing: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    Some((m, src))
}

fn models(m: &Manifest, k: usize) -> Option<ModelSet> {
    match latency::load_models(m, 1, k, 1, false) {
        Ok(ms) => Some(ms),
        Err(e) => {
            eprintln!("SKIP shard_routing: {e}");
            None
        }
    }
}

#[test]
fn cross_shard_conservation_with_shard_kill() {
    let _guard = serial();
    const CLIENTS: usize = 16;
    const SHARDS: usize = 4;
    const PER: u64 = 25;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg =
        ServiceConfig::defaults(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }, &GPU);
    cfg.m = 2;
    cfg.shuffles = 0;
    cfg.seed = 0x5A4D;
    cfg.slo = Some(Duration::from_secs(3)); // backstop for doubly-lost groups

    let tier = ShardedFrontend::start(
        cfg,
        ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None },
        &models,
        &src.queries[0],
    )
    .expect("sharded tier builds");
    assert_eq!(tier.shards(), SHARDS);

    // Scripted whole-shard zombie, step-indexed on client 0's traffic:
    // at its 5th submit, *both* deployed instances (ids 0..m=2) of the
    // shard serving client 0 die, so that shard degrades to parity
    // reconstructions and SLO defaults while the other shards' routing
    // and accounting stay untouched.
    let killed_shard = tier.route_of(0).expect("live shard");

    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let client = tier.client();
        let queries = src.queries.clone();
        // The script is driven by client 0 alone (one chaos timeline).
        let mut chaos = if c == 0 {
            Some((
                FaultScript::builder(0x5A4D).kill_shard_at(5, killed_shard).build(),
                FaultSurface::sharded(
                    (0..SHARDS).map(|s| tier.fault_plan(s)).collect(),
                    2,
                ),
            ))
        } else {
            None
        };
        joins.push(std::thread::spawn(move || {
            let home = client.shard().expect("live shard");
            let mut submitted = HashSet::new();
            let mut got = Vec::new();
            for i in 0..PER {
                if let Some((script, surface)) = chaos.as_mut() {
                    script.apply(i, surface);
                }
                let id = client
                    .submit(queries[(c + i as usize) % queries.len()].clone())
                    .expect("unbounded admission accepts");
                assert!(submitted.insert(id), "sharded ids must be unique");
                assert_eq!(shard_of(id), home, "no drain: routing is stable");
                got.extend(client.poll());
                std::thread::sleep(Duration::from_millis(2));
            }
            while got.len() < PER as usize {
                match client.next(Duration::from_secs(10)) {
                    Some(r) => got.push(r),
                    None => break,
                }
            }
            (submitted, got, client)
        }));
    }

    let mut grand_total = 0u64;
    for j in joins {
        let (submitted, got, client) = j.join().expect("client thread");
        assert_eq!(got.len(), PER as usize, "every query resolves exactly once");
        let ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), got.len(), "no duplicate resolutions");
        assert_eq!(ids, submitted, "resolutions routed to the submitting client");
        let st = client.stats();
        assert_eq!(st.submitted, PER);
        assert_eq!(st.resolved, PER);
        assert_eq!(st.rejected, 0);
        assert_eq!(
            st.native + st.recovered + st.defaulted,
            PER,
            "outcome counts partition the client's queries"
        );
        grand_total += st.resolved;
    }
    assert_eq!(grand_total, CLIENTS as u64 * PER);

    let res = tier.shutdown().expect("clean shutdown");
    assert_eq!(res.per_shard.len(), SHARDS);
    // The merged record's totals equal the per-shard sums.
    let sum_resolved: u64 = res.per_shard.iter().map(|r| r.metrics.total()).sum();
    let sum_rejected: u64 = res.per_shard.iter().map(|r| r.rejected).sum();
    let sum_dropped: u64 = res.per_shard.iter().map(|r| r.dropped_jobs).sum();
    let sum_recon: u64 = res.per_shard.iter().map(|r| r.reconstructions).sum();
    assert_eq!(res.merged.metrics.total(), sum_resolved);
    assert_eq!(res.merged.rejected, sum_rejected);
    assert_eq!(res.merged.dropped_jobs, sum_dropped);
    assert_eq!(res.merged.reconstructions, sum_recon);
    assert_eq!(res.merged.metrics.total(), grand_total, "fleet metrics agree with clients");
    assert_eq!(res.merged.rejected, 0);
    assert!(
        res.per_shard[killed_shard].dropped_jobs > 0,
        "the killed shard's zombie must actually have swallowed jobs"
    );
    for (s, r) in res.per_shard.iter().enumerate() {
        if s != killed_shard {
            assert_eq!(
                r.dropped_jobs, 0,
                "shard {s} is a separate fault domain and must drop nothing"
            );
        }
    }
}

#[test]
fn drained_shard_reroutes_subsequent_submits() {
    let _guard = serial();
    const SHARDS: usize = 4;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
    cfg.m = 1;
    cfg.shuffles = 0;
    cfg.seed = 0xD2A1;

    let tier = ShardedFrontend::start(
        cfg,
        ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None },
        &models,
        &src.queries[0],
    )
    .expect("sharded tier builds");
    let client = tier.client();
    let home = client.shard().expect("live shard");

    let mut ids = HashSet::new();
    for i in 0..8 {
        let id = client.submit(src.queries[i % src.len()].clone()).expect("healthy accepts");
        assert_eq!(shard_of(id), home, "pre-drain submits land on the home shard");
        ids.insert(id);
    }

    tier.drain_shard(home).expect("first drain transitions");
    assert_eq!(tier.live_shards(), SHARDS - 1);
    let rerouted = client.shard().expect("surviving shards stay live");
    assert_ne!(rerouted, home, "drained shard must not receive new routes");
    assert_eq!(tier.route_of(client.id()), Some(rerouted));

    for i in 0..8 {
        let id = client.submit(src.queries[i % src.len()].clone()).expect("reroute accepts");
        assert_eq!(shard_of(id), rerouted, "post-drain submits land on the rerouted shard");
        ids.insert(id);
    }

    // Everything resolves exactly once — including the in-flight queries
    // of the drained shard.
    let mut got = Vec::new();
    while got.len() < 16 {
        match client.next(Duration::from_secs(10)) {
            Some(r) => got.push(r),
            None => break,
        }
    }
    assert_eq!(got.len(), 16, "drain must not strand in-flight queries");
    let got_ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
    assert_eq!(got_ids, ids);
    assert_eq!(client.stats().resolved, 16);

    // Restoring the shard brings the original route back (consistent
    // hashing: nothing else moved in between).
    tier.restore_shard(home).expect("restore of a drained shard transitions");
    assert_eq!(client.shard(), Some(home));

    let res = tier.shutdown().expect("clean shutdown");
    assert_eq!(res.merged.metrics.total(), 16);
    let sum: u64 = res.per_shard.iter().map(|r| r.metrics.total()).sum();
    assert_eq!(sum, 16);
}

#[test]
fn global_cap_sheds_and_lands_in_merged_accounting() {
    let _guard = serial();
    const CAP: usize = 4;
    const ATTEMPTS: usize = 120;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
    cfg.m = 1;
    cfg.shuffles = 0;
    cfg.seed = 0xCA9;
    // Slow the drain far below the offered burst so the fleet load pins
    // above the cap (same stall technique as frontend_concurrency.rs).
    cfg.time_scale = 25.0;

    let tier = ShardedFrontend::start(
        cfg,
        ShardSpec { shards: 2, vnodes: 32, global_backlog: Some(CAP) },
        &models,
        &src.queries[0],
    )
    .expect("sharded tier builds");
    let client = tier.client();

    let (mut accepted, mut rejected) = (0u64, 0u64);
    for i in 0..ATTEMPTS {
        match client.submit(src.queries[i % src.len()].clone()) {
            Ok(_) => accepted += 1,
            Err(SubmitError::Rejected { limit, .. }) => {
                assert_eq!(limit, CAP, "global cap is the binding limit");
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(accepted > 0, "the cap must still admit up to the limit");
    assert!(rejected > 0, "a stalled fleet must shed load");
    assert_eq!(accepted + rejected, ATTEMPTS as u64);
    assert_eq!(client.stats().rejected, rejected, "per-client tally");

    let res = tier.shutdown().expect("clean shutdown");
    assert_eq!(res.merged.rejected, rejected, "global-cap rejects land in the merged record");
    assert_eq!(res.merged.metrics.total(), accepted, "accepted queries all resolve");
    assert_eq!(res.merged.metrics.offered(), ATTEMPTS as u64);
    let sum_rejected: u64 = res.per_shard.iter().map(|r| r.rejected).sum();
    assert_eq!(sum_rejected, rejected, "rejects tallied against the routed shards");
}

/// Regression for the ROADMAP fairness-dilution item: a tier client's
/// admission weight is registered only on the shard the router assigns
/// it — not on every shard — and the weight moves with the route on
/// drain/restore, so each shard's fair-share denominator counts exactly
/// its own residents.
#[test]
fn weight_follows_router_on_drain() {
    let _guard = serial();
    const SHARDS: usize = 3;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
    cfg.m = 1;
    cfg.shuffles = 0;
    cfg.seed = 0xFA12;

    let tier = ShardedFrontend::start(
        cfg,
        ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None },
        &models,
        &src.queries[0],
    )
    .expect("sharded tier builds");

    let clients: Vec<_> = (0..12).map(|_| tier.client()).collect();
    let heavy = tier.client_with_weight(3.0);

    let placement = |tier: &ShardedFrontend| {
        let mut per = vec![0.0f64; SHARDS];
        for c in &clients {
            per[c.shard().expect("live shard")] += 1.0;
        }
        per[heavy.shard().expect("live shard")] += 3.0;
        per
    };
    let expect = placement(&tier);
    for (s, &w) in expect.iter().enumerate() {
        assert!(
            (tier.shard_total_weight(s) - w).abs() < 1e-9,
            "shard {s} must hold exactly its residents' weight ({w}), got {}",
            tier.shard_total_weight(s)
        );
    }
    let total: f64 = (0..SHARDS).map(|s| tier.shard_total_weight(s)).sum();
    assert!((total - 15.0).abs() < 1e-9, "weights registered once fleet-wide, not per shard");

    // Drain the heavy client's home: every resident's weight moves with
    // its new route; the drained shard holds none.
    let home = heavy.shard().expect("live shard");
    assert_eq!(heavy.weight_shard(), Some(home), "weight sits where the router points");
    tier.drain_shard(home).expect("first drain transitions");
    assert_ne!(heavy.shard().expect("survivors stay live"), home);
    assert_eq!(heavy.weight_shard(), heavy.shard(), "weight moved with the route");
    assert!(
        tier.shard_total_weight(home).abs() < 1e-9,
        "a drained shard keeps no admission weight"
    );
    let after = placement(&tier);
    for (s, &w) in after.iter().enumerate() {
        assert!(
            (tier.shard_total_weight(s) - w).abs() < 1e-9,
            "post-drain shard {s}: want {w}, got {}",
            tier.shard_total_weight(s)
        );
    }
    let total: f64 = (0..SHARDS).map(|s| tier.shard_total_weight(s)).sum();
    assert!((total - 15.0).abs() < 1e-9, "drain moves weight, never loses it");

    // Restore: consistent hashing brings every original route — and its
    // weight — back.
    tier.restore_shard(home).expect("restore of a drained shard transitions");
    for (s, &w) in expect.iter().enumerate() {
        assert!(
            (tier.shard_total_weight(s) - w).abs() < 1e-9,
            "post-restore shard {s}: want {w}, got {}",
            tier.shard_total_weight(s)
        );
    }
    tier.shutdown().expect("clean shutdown");
}

#[test]
fn window_snapshot_merge_property_trials() {
    // Pure property trials — no cluster. For seeded random per-shard
    // windows: merged counts are exact sums, merged quantiles stay inside
    // the per-shard [min, max] hull, and qps adds.
    let mut rng = Pcg64::new(0x3A9E);
    let t0 = Instant::now();
    for trial in 0..50 {
        let shards = 2 + (rng.below(4) as usize); // 2..=5
        let mut snaps: Vec<WindowSnapshot> = Vec::new();
        let mut total_events = 0u64;
        let mut total_rejects = 0u64;
        let mut total_recovered = 0u64;
        for _ in 0..shards {
            let mut w = LatencyWindow::new(Duration::from_secs(60));
            let events = 20 + rng.below(200);
            for _ in 0..events {
                let outcome = match rng.below(10) {
                    0 => Outcome::Reconstructed,
                    1 => Outcome::Replica,
                    2 => Outcome::Default,
                    _ => Outcome::Native,
                };
                if matches!(outcome, Outcome::Reconstructed | Outcome::Replica) {
                    total_recovered += 1;
                }
                let latency = Duration::from_secs_f64(0.001 + rng.exponential(100.0));
                w.record(outcome, latency, t0);
            }
            let rejects = rng.below(30);
            w.record_rejects(rejects, t0);
            total_events += events;
            total_rejects += rejects;
            snaps.push(w.snapshot(t0));
        }

        let merged = WindowSnapshot::merge_all(&snaps);
        assert_eq!(merged.resolved, total_events, "trial {trial}: resolved adds");
        assert_eq!(merged.rejected, total_rejects, "trial {trial}: rejected adds");
        let offered = (total_events + total_rejects) as f64;
        assert!(
            (merged.reject_rate - total_rejects as f64 / offered).abs() < 1e-9,
            "trial {trial}: reject rate recomputed from merged counts"
        );
        assert!(
            (merged.recovery_rate * merged.resolved as f64 - total_recovered as f64).abs() < 1e-6,
            "trial {trial}: recovery rate preserves the recovered count"
        );
        let sum_qps: f64 = snaps.iter().map(|s| s.qps).sum();
        assert!((merged.qps - sum_qps).abs() < 1e-6 * sum_qps.max(1.0), "trial {trial}: qps adds");

        // Every quantile stays inside the per-shard hull (all shards have
        // events, so every input carries weight).
        let picks: [(fn(&WindowSnapshot) -> f64, &str); 3] = [
            (|s| s.p50_ms, "p50"),
            (|s| s.p99_ms, "p99"),
            (|s| s.p999_ms, "p99.9"),
        ];
        for (pick, name) in picks {
            let lo = snaps.iter().map(pick).fold(f64::INFINITY, f64::min);
            let hi = snaps.iter().map(pick).fold(f64::NEG_INFINITY, f64::max);
            let got = pick(&merged);
            assert!(
                got >= lo - 1e-9 && got <= hi + 1e-9,
                "trial {trial}: merged {name} {got} outside per-shard hull [{lo}, {hi}]"
            );
        }
    }
}

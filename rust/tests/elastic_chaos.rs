//! Elastic-fleet chaos: drive scale-out and scale-in through the *real*
//! admin socket mid-run, under open-loop load, with a deterministic
//! `FaultScript` kill layered on top.
//!
//! Each seeded trial serves a cross-shard fleet wrapped in a
//! [`ControlPlane`] + [`AdminServer`], then — from the load loop, by
//! socket round-trips exactly as `parm admin` would issue them —
//! adds a shard, watches the shared parity pool re-provision to
//! `ceil(shards·m/k)`, kills a shard (one instance or the whole fault
//! domain, alternating by trial), drains and removes the added shard,
//! and watches the pool shrink back. Invariants per trial:
//!
//! - exactly-once delivery: every accepted query resolves exactly once,
//!   across both reconfigurations and the kill;
//! - conservation: offered = resolved + rejected in the merged record,
//!   and per-shard sums agree (including the retired shard's record);
//! - the parity pool tracks `ceil(shards·m/k)` across both resizes;
//! - the admin protocol answers every command with `"ok":true` and
//!   reports the removed shard as `"retired"`.
//!
//! Unix-only (the admin surface is a Unix socket). Trials:
//! `PARM_ELASTIC_TRIALS`, default 2.
#![cfg(unix)]

mod common;

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{FaultScript, FaultSurface};
use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::control::{AdminServer, ControlPlane, Fleet, FleetRunResult};
use parm::coordinator::frontend::SubmitError;
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::Resolved;
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedClient};
use parm::experiments::latency;
use parm::util::json::Json;
use parm::workload::QuerySource;

static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(r_max: usize) -> Option<(QuerySource, ModelSet)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP elastic_chaos: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    match latency::load_models(&m, 1, 2, r_max, false) {
        Ok(models) => Some((src, models)),
        Err(e) => {
            eprintln!("SKIP elastic_chaos: {e}");
            None
        }
    }
}

fn trials() -> u64 {
    std::env::var("PARM_ELASTIC_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
}

/// One `parm admin`-style round-trip: fresh connection, one request
/// line, one `"ok":true` reply (anything else panics with the error).
fn admin(socket: &std::path::Path, req: Json) -> Json {
    let stream = UnixStream::connect(socket)
        .unwrap_or_else(|e| panic!("connect {}: {e}", socket.display()));
    let mut writer = stream.try_clone().unwrap();
    writer.write_all(req.to_string().as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    let reply = Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"));
    assert_eq!(
        reply.at(&["ok"]).as_bool(),
        Some(true),
        "admin command {req} failed: {reply}"
    );
    reply
}

/// Poll `status` until the parity pool reaches its target (resizes are
/// generational and asynchronous) and the target equals `want`.
fn wait_pool(socket: &std::path::Path, want: usize, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    loop {
        let status = admin(socket, Json::obj().set("cmd", "status"));
        let size = status.at(&["parity_pool", "size"]).as_usize();
        let target = status.at(&["parity_pool", "target"]).as_usize();
        if size == Some(want) && target == Some(want) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "parity pool stuck at size={size:?} target={target:?}, want {want}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn collect(clients: &[ShardedClient], got: &mut Vec<Resolved>, want: usize, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while got.len() < want && Instant::now() < deadline {
        let mut any = false;
        for c in clients {
            for r in c.poll() {
                got.push(r);
                any = true;
            }
        }
        if !any {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// Scale 3 → 4 → 3 through the admin socket mid-run, with a scripted
/// kill in between: exactly-once delivery, offered = resolved +
/// rejected, and a parity pool that tracks `ceil(shards·m/k)`.
#[test]
fn elastic_scale_cycle_over_admin_socket_conserves_queries() {
    let _guard = serial();
    const SHARDS: usize = 3;
    const M: usize = 2;
    const K: usize = 2;
    const CLIENTS: usize = 8;
    const N: u64 = 160;
    const ADD_AT: u64 = 30;
    const KILL_AT: u64 = 70;
    const SHRINK_AT: u64 = 110;
    let Some((src, models)) = setup(2) else { return };
    let n_trials = trials();
    let t0 = Instant::now();

    for trial in 0..n_trials {
        let seed = 0xE1A5 + trial * 7919;
        let mut cfg = ServiceConfig::defaults(
            Mode::CrossShard {
                k: K,
                r_min: 1,
                r_max: 2,
                halflife: Duration::from_millis(150),
            },
            &GPU,
        );
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = seed;
        cfg.slo = Some(Duration::from_millis(1500));
        let spec = ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None };
        let tier = CrossShardFrontend::start(cfg, spec, &models, &src.queries[0])
            .unwrap_or_else(|e| panic!("trial {trial}: tier builds: {e}"));
        let surface =
            FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M);
        // Alternate the layered fault: an undetected zombie instance on
        // even trials, a whole-fault-domain loss on odd ones. The victim
        // (shard 1) is never the shard we scale in.
        let mut script = if trial % 2 == 0 {
            FaultScript::builder(seed).kill_instance_at(KILL_AT, 1, 0).build()
        } else {
            FaultScript::builder(seed).kill_shard_at(KILL_AT, 1).build()
        };

        let plane = Arc::new(ControlPlane::new(Fleet::CrossShard(tier)));
        let clients: Vec<ShardedClient> =
            (0..CLIENTS).map(|_| plane.client().expect("fleet is live")).collect();
        let socket = std::env::temp_dir()
            .join(format!("parm-elastic-{}-{trial}.sock", std::process::id()));
        let server = AdminServer::bind(&socket, plane.clone())
            .unwrap_or_else(|e| panic!("trial {trial}: {e}"));

        let status = admin(&socket, Json::obj().set("cmd", "status"));
        assert_eq!(status.at(&["shards"]).as_usize(), Some(SHARDS));
        assert_eq!(
            status.at(&["parity_pool", "target"]).as_usize(),
            Some((SHARDS * M + K - 1) / K),
        );

        let mut submitted = HashSet::new();
        let mut rejected = 0u64;
        let mut got = Vec::new();
        let mut added = usize::MAX;
        for i in 0..N {
            script.apply(i, &surface);
            if i == ADD_AT {
                let reply = admin(&socket, Json::obj().set("cmd", "add-shard"));
                added = reply.at(&["shard"]).as_usize().expect("new shard index");
                assert_eq!(added, SHARDS, "trial {trial}: append-only indices");
                wait_pool(&socket, ((SHARDS + 1) * M + K - 1) / K, Duration::from_secs(10));
            }
            if i == SHRINK_AT {
                let reply = admin(
                    &socket,
                    Json::obj().set("cmd", "drain").set("shard", added),
                );
                assert_eq!(reply.at(&["changed"]).as_bool(), Some(true), "trial {trial}");
                admin(&socket, Json::obj().set("cmd", "remove-shard").set("shard", added));
                wait_pool(&socket, (SHARDS * M + K - 1) / K, Duration::from_secs(10));
            }
            let c = &clients[(i as usize) % clients.len()];
            match c.submit(src.queries[(i as usize) % src.len()].clone()) {
                Ok(id) => {
                    assert!(submitted.insert(id), "trial {trial}: tier ids unique");
                }
                Err(SubmitError::Rejected { .. } | SubmitError::SloShed { .. }) => rejected += 1,
                Err(e) => panic!("trial {trial}: unexpected submit error: {e}"),
            }
            for c in &clients {
                got.extend(c.poll());
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(script.done(), "trial {trial}: the scripted kill fired");

        // The admin surface stays coherent after the full cycle: the
        // scaled-in shard reads as retired, the fleet is back to 3 live.
        let status = admin(&socket, Json::obj().set("cmd", "status"));
        assert_eq!(status.at(&["shards"]).as_usize(), Some(SHARDS + 1));
        assert_eq!(status.at(&["provisioned"]).as_usize(), Some(SHARDS));
        let states = status.at(&["shard_states"]).as_arr().expect("states");
        assert_eq!(states[added].at(&["state"]).as_str(), Some("retired"), "trial {trial}");
        let telemetry = admin(&socket, Json::obj().set("cmd", "telemetry"));
        assert!(telemetry.at(&["window", "qps"]).as_f64().is_some());
        let rec = admin(&socket, Json::obj().set("cmd", "recommend"));
        assert!(rec.at(&["action"]).as_str().is_some());

        plane.flush_open_groups().expect("fleet is live");
        collect(&clients, &mut got, submitted.len(), Duration::from_secs(15));

        // Exactly-once delivery across scale-out, kill, and scale-in.
        assert_eq!(
            got.len(),
            submitted.len(),
            "trial {trial} (seed {seed:#x}): every accepted query resolves"
        );
        let ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), got.len(), "trial {trial}: no duplicate resolutions");
        assert_eq!(ids, submitted, "trial {trial}: exactly the accepted ids");

        server.stop();
        let res = match plane.shutdown().unwrap_or_else(|e| panic!("trial {trial}: {e}")) {
            FleetRunResult::CrossShard(res) => res,
            FleetRunResult::Sharded(_) => unreachable!("cross-shard fleet"),
        };
        let metrics = &res.fleet.merged.metrics;
        assert_eq!(
            metrics.total(),
            submitted.len() as u64,
            "trial {trial}: resolved equals accepted"
        );
        assert_eq!(res.fleet.merged.rejected, rejected, "trial {trial}: rejects conserved");
        assert_eq!(metrics.offered(), N, "trial {trial}: offered = resolved + rejected");
        // Per-shard sums agree — including the retired shard's record.
        assert_eq!(res.fleet.per_shard.len(), SHARDS + 1, "trial {trial}");
        let sum_resolved: u64 = res.fleet.per_shard.iter().map(|r| r.metrics.total()).sum();
        assert_eq!(sum_resolved, metrics.total(), "trial {trial}: per-shard sums agree");
        // Shutdown tore the admin surface down with the fleet.
        assert!(plane.client().is_none(), "trial {trial}: plane is closed");
        assert!(
            UnixStream::connect(&socket).is_err(),
            "trial {trial}: stopped server removed its socket"
        );
    }
    eprintln!(
        "elastic_chaos: {n_trials} trials in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}

/// The reconfiguration contract over the wire: invalid operations come
/// back as clean `"ok":false` protocol errors — never a panic, never a
/// wedged fleet — and valid retries converge (idempotency).
#[test]
fn admin_protocol_rejects_invalid_ops_cleanly() {
    let _guard = serial();
    const SHARDS: usize = 3;
    let Some((src, models)) = setup(2) else { return };
    let mut cfg = ServiceConfig::defaults(
        Mode::CrossShard {
            k: 2,
            r_min: 1,
            r_max: 2,
            halflife: Duration::from_millis(150),
        },
        &GPU,
    );
    cfg.m = 1;
    cfg.shuffles = 0;
    cfg.seed = 0xBAD0;
    cfg.slo = Some(Duration::from_millis(1500));
    let spec = ShardSpec { shards: SHARDS, vnodes: 32, global_backlog: None };
    let tier = CrossShardFrontend::start(cfg, spec, &models, &src.queries[0])
        .expect("tier builds");
    let plane = Arc::new(ControlPlane::new(Fleet::CrossShard(tier)));
    let client = plane.client().expect("fleet is live");
    let socket =
        std::env::temp_dir().join(format!("parm-elastic-bad-{}.sock", std::process::id()));
    let server = AdminServer::bind(&socket, plane.clone()).expect("bind admin socket");

    let send = |req: Json| -> Json {
        let stream = UnixStream::connect(&socket).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writer.write_all(req.to_string().as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };
    let fails = |req: Json| {
        let reply = send(req.clone());
        assert_eq!(reply.at(&["ok"]).as_bool(), Some(false), "{req} must fail: {reply}");
        assert!(reply.at(&["error"]).as_str().is_some(), "{req}: error text present");
    };

    // Unknown shard, double-drain no-op, restore-of-live no-op.
    fails(Json::obj().set("cmd", "drain").set("shard", 99usize));
    fails(Json::obj().set("cmd", "remove-shard").set("shard", 99usize));
    fails(Json::obj().set("cmd", "set-admission").set("policy", "martian"));
    fails(Json::obj().set("cmd", "no-such-command"));
    let r = admin(&socket, Json::obj().set("cmd", "drain").set("shard", 1usize));
    assert_eq!(r.at(&["changed"]).as_bool(), Some(true));
    let r = admin(&socket, Json::obj().set("cmd", "drain").set("shard", 1usize));
    assert_eq!(r.at(&["changed"]).as_bool(), Some(false), "double-drain is a no-op");
    let r = admin(&socket, Json::obj().set("cmd", "restore").set("shard", 1usize));
    assert_eq!(r.at(&["changed"]).as_bool(), Some(true));
    let r = admin(&socket, Json::obj().set("cmd", "restore").set("shard", 1usize));
    assert_eq!(r.at(&["changed"]).as_bool(), Some(false), "restore-of-live is a no-op");
    // Remove-while-draining is allowed (a drained shard is the normal
    // removal candidate) — then a double-remove and a drain of the
    // retired slot are clean errors.
    let r = admin(&socket, Json::obj().set("cmd", "drain").set("shard", 2usize));
    assert_eq!(r.at(&["changed"]).as_bool(), Some(true));
    admin(&socket, Json::obj().set("cmd", "remove-shard").set("shard", 2usize));
    fails(Json::obj().set("cmd", "remove-shard").set("shard", 2usize));
    fails(Json::obj().set("cmd", "drain").set("shard", 2usize));
    // Shrinking below k distinct data shards is refused (2 provisioned
    // shards remain, and cross-shard groups stripe over k=2).
    fails(Json::obj().set("cmd", "remove-shard").set("shard", 0usize));
    // A valid admission swap round-trips.
    admin(
        &socket,
        Json::obj()
            .set("cmd", "set-admission")
            .set("policy", "reject-above")
            .set("backlog", 4096usize),
    );

    // The data path survived all of it.
    let id = client.submit(src.queries[0].clone()).expect("fleet still serves");
    plane.flush_open_groups().expect("fleet is live");
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut resolved = Vec::new();
    while resolved.is_empty() && Instant::now() < deadline {
        resolved.extend(client.poll());
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(resolved.len(), 1, "query resolves after the abuse");
    assert_eq!(resolved[0].id, id);

    server.stop();
    // Ops after shutdown: clean Closed over the wire too.
    let socket2 =
        std::env::temp_dir().join(format!("parm-elastic-bad2-{}.sock", std::process::id()));
    let server2 = AdminServer::bind(&socket2, plane.clone()).expect("rebind");
    let _ = plane.shutdown().expect("clean shutdown");
    let stream = UnixStream::connect(&socket2).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writer.write_all(b"{\"cmd\":\"status\"}\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    let reply = Json::parse(line.trim()).unwrap();
    assert_eq!(reply.at(&["ok"]).as_bool(), Some(false));
    assert!(reply.at(&["error"]).as_str().unwrap().contains("shut down"));
    server2.stop();
}

//! Trace-diagnostics regression suite for the journal-mining layer
//! (`coordinator::trace` + `workload::Trace::from_journal`):
//!
//! - **phase accounting property**: for recorded ParM and Rateless
//!   sharded runs, every completed span's phase durations sum exactly
//!   to its end-to-end journal latency, trace-level outcome counts
//!   equal the journal `End` footer totals, and seeded truncation /
//!   corruption of the *real* recorded bytes never panics or loops —
//!   it yields a structured `JournalError` or a clean prefix;
//! - **fault-impact acceptance**: a cross-shard flash-crowd run with a
//!   whole-shard kill mines into per-phase breakdowns, a group-fate
//!   timeline in which the killed shard's groups resolved by decode,
//!   and a kill window whose during-fault p99 exceeds the pre-fault
//!   p99;
//! - **mining fidelity**: a flash-crowd journal mines into a
//!   `workload::Trace` whose arrival count / mean gap / burst ratio
//!   match the generating scenario, and the mined trace replays
//!   through a fresh serving tier cleanly.
//!
//! Like the other cluster suites these spawn full simulated clusters,
//! run serialized, and skip with a message when artifacts are missing
//! under `--features pjrt`.

mod common;

use std::collections::HashSet;
use std::time::{Duration, Instant};

use common::{FaultScript, FaultSurface};
use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::SubmitError;
use parm::coordinator::journal::{self, Recorder};
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::Resolved;
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedClient, ShardedFrontend};
use parm::coordinator::trace::{analyze, AnalyzeOpts, Analysis};
use parm::experiments::latency;
use parm::workload::scenario;
use parm::workload::trace::Trace;
use parm::workload::QuerySource;

/// Each test spawns full simulated clusters; serialize to keep the
/// timing paths representative.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(r_max: usize) -> Option<(QuerySource, ModelSet)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP journal_mining: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    match latency::load_models(&m, 1, 2, r_max, false) {
        Ok(models) => Some((src, models)),
        Err(e) => {
            eprintln!("SKIP journal_mining: {e}");
            None
        }
    }
}

/// Step-paced driver (1ms+ per step; the step index paces the fault
/// script deterministically). Returns (accepted ids, rejected count,
/// resolutions so far).
fn drive_steps(
    clients: &[ShardedClient],
    src: &QuerySource,
    trace: &Trace,
    script: &mut FaultScript,
    surface: &FaultSurface,
) -> (HashSet<u64>, u64, Vec<Resolved>) {
    let mut submitted = HashSet::new();
    let mut rejected = 0u64;
    let mut got = Vec::new();
    for i in 0..trace.len() {
        script.apply(i as u64, surface);
        let ci = if trace.n_clients() > 1 { trace.client_of(i) as usize } else { i };
        let c = &clients[ci % clients.len()];
        match c.submit(src.queries[trace.query_idx[i] % src.len()].clone()) {
            Ok(id) => {
                assert!(submitted.insert(id), "tier ids must be unique");
            }
            Err(SubmitError::Rejected { .. } | SubmitError::SloShed { .. }) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        for c in clients {
            got.extend(c.poll());
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    (submitted, rejected, got)
}

/// Arrival-paced driver: submits each query at its trace offset (the
/// CLI `--trace` replay path's pacing), so the recorded journal's
/// `Submit` timestamps reproduce the trace's inter-arrival structure.
fn drive_paced(
    clients: &[ShardedClient],
    src: &QuerySource,
    trace: &Trace,
) -> (HashSet<u64>, Vec<Resolved>) {
    let start = Instant::now();
    let mut submitted = HashSet::new();
    let mut got = Vec::new();
    for i in 0..trace.len() {
        let target = start + Duration::from_secs_f64(trace.arrivals[i]);
        loop {
            let now = Instant::now();
            if now >= target {
                break;
            }
            std::thread::sleep(target - now);
        }
        let ci = if trace.n_clients() > 1 { trace.client_of(i) as usize } else { i };
        let c = &clients[ci % clients.len()];
        match c.submit(src.queries[trace.query_idx[i] % src.len()].clone()) {
            Ok(id) => {
                assert!(submitted.insert(id), "tier ids must be unique");
            }
            Err(e) => panic!("unbounded admission accepts everything: {e}"),
        }
        for c in clients {
            got.extend(c.poll());
        }
    }
    (submitted, got)
}

/// Sweep every client until `want` resolutions arrived (or timeout).
fn collect(clients: &[ShardedClient], got: &mut Vec<Resolved>, want: usize, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while got.len() < want && Instant::now() < deadline {
        let mut any = false;
        for c in clients {
            for r in c.poll() {
                got.push(r);
                any = true;
            }
        }
        if !any {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// The phase-accounting identity on a real mined run: every completed
/// span's four phases sum exactly to `complete - submit` on the
/// journal clock, which in turn tracks the session-measured latency;
/// and the trace-level outcome histogram equals the `End` footer.
fn assert_phase_and_footer_identities(a: &Analysis, ctx: &str) {
    assert!(!a.spans.is_empty(), "{ctx}: mined spans");
    for s in &a.spans {
        let Some(p) = s.phases() else {
            panic!("{ctx}: q{} of shard {} never completed", s.qid, s.shard)
        };
        assert_eq!(
            p.queue_us + p.seal_wait_us + p.decode_wait_us + p.tail_us,
            p.total_us,
            "{ctx}: phases sum exactly to end-to-end latency (q{} shard {})",
            s.qid,
            s.shard
        );
        assert_eq!(Some(p.total_us), s.total_us(), "{ctx}: total is complete - submit");
        // The recorded `Complete` payload is the session's own latency
        // measurement; the journal clock brackets the same interval
        // with only enqueue-path skew between them.
        let lat = s.latency_us.expect("completed span has a latency payload");
        let skew = p.total_us.abs_diff(lat);
        assert!(
            skew < 50_000,
            "{ctx}: journal-clock total {}us vs session latency {lat}us (skew {skew}us)",
            p.total_us
        );
    }
    let footer = a.footer.unwrap_or_else(|| panic!("{ctx}: clean run has an End footer"));
    let counts = a.outcome_counts();
    assert_eq!(counts.native, footer.native, "{ctx}: native totals");
    assert_eq!(counts.reconstructed, footer.reconstructed, "{ctx}: reconstructed totals");
    assert_eq!(counts.replica, footer.replica, "{ctx}: replica totals");
    assert_eq!(counts.defaulted, footer.defaulted, "{ctx}: defaulted totals");
    assert_eq!(a.rejected, footer.rejected, "{ctx}: rejected totals");
    assert_eq!(a.open_spans(), 0, "{ctx}: a drained run leaves no open spans");
}

/// Seeded truncation/corruption fuzz over real recorded bytes: every
/// mangled input must return — `Ok` for a clean prefix, a structured
/// `JournalError` otherwise — never panic, never hang.
fn fuzz_real_journal(bytes: &[u8], seed: u64, ctx: &str) {
    let mut state = seed | 1;
    let mut next = move |bound: u64| {
        // SplitMix64 step: deterministic, dependency-free.
        state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) % bound.max(1)
    };
    for round in 0..120 {
        let cut = next(bytes.len() as u64 + 1) as usize;
        let mut mangled = bytes[..cut].to_vec();
        if round % 3 == 0 && !mangled.is_empty() {
            // Flip a byte too: corruption, not just truncation.
            let at = next(mangled.len() as u64) as usize;
            mangled[at] ^= (1 + next(255)) as u8;
        }
        // Structured result either way; a panic or hang fails the test.
        let decoded = journal::decode(&mangled);
        let replayed = journal::replay(&mangled);
        if let Err(e) = &replayed {
            assert!(!format!("{e}").is_empty(), "{ctx}: error displays");
        }
        drop(decoded);
        drop(replayed);
    }
    // The unmangled journal still replays after the fuzz pass.
    journal::replay(bytes).unwrap_or_else(|e| panic!("{ctx}: pristine journal replays: {e}"));
}

/// ParM and Rateless sharded chaos runs mine into analyses that
/// satisfy the phase-accounting and footer identities, and the real
/// recorded bytes survive seeded truncation/corruption fuzzing.
#[test]
fn mined_phases_sum_and_outcomes_match_footer_for_parm_and_rateless() {
    let _guard = serial();
    const SHARDS: usize = 2;
    const M: usize = 2;
    const CLIENTS: usize = 4;
    const N: usize = 80;
    const SEED: u64 = 0x31A9;
    let Some((src, models)) = setup(2) else { return };
    let modes = [
        ("parm", Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }),
        (
            "rateless",
            Mode::Rateless { k: 2, r_min: 1, r_max: 2, halflife: Duration::from_millis(150) },
        ),
    ];
    for (name, mode) in modes {
        let mut cfg = ServiceConfig::defaults(mode, &GPU);
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = SEED;
        cfg.slo = Some(Duration::from_millis(1500));
        let recorder = Recorder::start(SEED, name, SHARDS as u64);
        cfg.recorder = recorder.clone();
        let spec = ShardSpec { shards: SHARDS, vnodes: 32, global_backlog: None };
        let tier = ShardedFrontend::start(cfg, spec, &models, &src.queries[0])
            .unwrap_or_else(|e| panic!("{name}: tier builds: {e}"));
        let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
        let surface =
            FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M);
        let mut script = FaultScript::builder(SEED)
            .kill_instance_at(12, 0, 0)
            .straggle_at(24, 1, 0, Duration::from_millis(200))
            .build();
        let trace =
            scenario::generate("zipf", SEED, N, 200.0, src.len()).expect("catalogue has zipf");

        let (submitted, rejected, mut got) =
            drive_steps(&clients, &src, &trace, &mut script, &surface);
        assert!(script.done(), "{name}: the scripted faults fired");
        assert_eq!(rejected, 0, "{name}: unbounded admission accepts everything");
        collect(&clients, &mut got, submitted.len(), Duration::from_secs(12));
        assert_eq!(got.len(), submitted.len(), "{name}: every accepted query resolves");
        let res = tier.shutdown().unwrap_or_else(|e| panic!("{name}: {e}"));
        let bytes = recorder.finish(&res.merged);

        let events = journal::decode(&bytes)
            .unwrap_or_else(|e| panic!("{name}: clean journal decodes: {e}"));
        let a = analyze(&events, &AnalyzeOpts::default());
        assert_eq!(a.mode, name);
        assert_eq!(a.spans.len(), submitted.len(), "{name}: one span per accepted query");
        assert_phase_and_footer_identities(&a, name);
        // Every span found its coding group through the dispatch FIFO.
        assert!(
            a.spans.iter().all(|s| s.group.is_some()),
            "{name}: every query attributed to a group"
        );
        assert!(!a.groups.is_empty(), "{name}: group fates mined");
        // The scripted kill and straggle produce chaos windows.
        assert_eq!(a.chaos.len(), 2, "{name}: both scripted faults journaled");
        assert!(!a.windows.is_empty(), "{name}: fault-impact windows computed");

        fuzz_real_journal(&bytes, SEED ^ 0xF022, name);
    }
}

/// The acceptance run: flash-crowd traffic through the cross-shard
/// tier with a whole-shard kill mid-run. The mined analysis must show
/// the killed shard's groups resolving by decode and a kill window
/// whose during-fault tail exceeds the pre-fault tail.
#[test]
fn whole_shard_kill_shows_decode_fates_and_inflated_during_window() {
    let _guard = serial();
    const SHARDS: usize = 3;
    const M: usize = 2;
    const CLIENTS: usize = 6;
    const N: usize = 200;
    const KILL_STEP: u64 = 80;
    const SEED: u64 = 0xFA11;
    let Some((src, models)) = setup(2) else { return };
    let mut cfg = ServiceConfig::defaults(
        Mode::CrossShard { k: 2, r_min: 1, r_max: 2, halflife: Duration::from_millis(150) },
        &GPU,
    );
    cfg.m = M;
    cfg.shuffles = 0;
    cfg.seed = SEED;
    cfg.slo = Some(Duration::from_millis(1500));
    let recorder = Recorder::start(SEED, "cross-shard", SHARDS as u64);
    cfg.recorder = recorder.clone();
    let spec = ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None };
    let tier = CrossShardFrontend::start(cfg, spec, &models, &src.queries[0])
        .expect("cross-shard tier builds");
    let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
    // Kill a shard that demonstrably carries traffic.
    let victim = tier.route_of(clients[0].id()).expect("live shard");
    let surface = FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M)
        .with_recorder(recorder.clone());
    let mut script = FaultScript::builder(SEED).kill_shard_at(KILL_STEP, victim).build();
    let trace = scenario::generate("flash-crowd", SEED, N, 400.0, src.len())
        .expect("catalogue has flash-crowd");

    let (submitted, rejected, mut got) =
        drive_steps(&clients, &src, &trace, &mut script, &surface);
    assert!(script.done(), "the shard kill fired");
    assert_eq!(rejected, 0, "unbounded admission accepts everything");
    tier.flush_open_groups();
    collect(&clients, &mut got, submitted.len(), Duration::from_secs(15));
    assert_eq!(got.len(), submitted.len(), "every accepted query resolves");
    let res = tier.shutdown().expect("clean shutdown");
    let bytes = recorder.finish(&res.fleet.merged);

    let events = journal::decode(&bytes).expect("clean journal decodes");
    let opts = AnalyzeOpts { window_us: 100_000, slow: 5 };
    let a = analyze(&events, &opts);
    assert_phase_and_footer_identities(&a, "cross-shard");

    // Group fates: fleet-scoped, and the killed shard's groups came
    // back via decode — at least one group both decoded and counts
    // reconstructed outcomes, with the kill inside its lifetime.
    assert!(a.groups.iter().all(|g| g.shard.is_none()), "cross-shard groups are fleet-scoped");
    let decoded: Vec<_> = a.groups.iter().filter(|g| g.decoded()).collect();
    assert!(!decoded.is_empty(), "the whole-shard kill forced decodes");
    assert!(
        decoded.iter().any(|g| g.outcomes.reconstructed > 0),
        "decoded groups resolved queries by reconstruction"
    );
    assert!(
        a.groups.iter().any(|g| g.faults_hit > 0),
        "some group's lifetime contains the kill"
    );
    assert!(
        a.outcome_counts().reconstructed > 0,
        "the killed shard's queries completed as recovered"
    );
    // Decoded spans carry the full marker chain: a strictly positive
    // decode-wait phase distinguishes them from native spans.
    assert!(
        a.spans
            .iter()
            .filter(|s| s.outcome_tag() == "recovered")
            .any(|s| s.phases().is_some_and(|p| p.decode_wait_us > 0)),
        "recovered spans show decode wait in their phase breakdown"
    );

    // The kill window: M coalesced kill events on the victim shard,
    // completions on both sides, and a fatter during-fault tail.
    let w = a
        .windows
        .iter()
        .find(|w| w.label.starts_with("kill") && w.shard == victim as u64)
        .expect("the shard kill has an impact window");
    assert_eq!(w.count, M as u64, "all instance kills coalesce into one window");
    assert!(w.pre.n > 0, "completions before the kill");
    assert!(w.during.n > 0, "completions during the kill");
    assert!(
        w.during.p99_us > w.pre.p99_us,
        "during-fault p99 ({}us over {} samples) exceeds pre-fault p99 ({}us over {})",
        w.during.p99_us,
        w.during.n,
        w.pre.p99_us,
        w.pre.n
    );
    assert!(
        w.during.outcomes.reconstructed + w.post.outcomes.reconstructed > 0,
        "recoveries land in the during/post windows"
    );
}

/// Mining fidelity: a flash-crowd run's journal mines into a
/// `workload::Trace` that reproduces the generating scenario's offered
/// load (count, mean gap, burstiness) and replays cleanly through a
/// fresh serving tier.
#[test]
fn mined_trace_matches_generating_scenario_and_replays() {
    let _guard = serial();
    const SHARDS: usize = 2;
    const M: usize = 2;
    const CLIENTS: usize = 4;
    const N: usize = 100;
    const SEED: u64 = 0x419E;
    let Some((src, models)) = setup(2) else { return };
    let scenario_trace = scenario::generate("flash-crowd", SEED, N, 100.0, src.len())
        .expect("catalogue has flash-crowd");

    let start_tier = |record: bool| {
        let mut cfg =
            ServiceConfig::defaults(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }, &GPU);
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = SEED;
        cfg.slo = Some(Duration::from_millis(1500));
        let recorder = if record {
            Recorder::start(SEED, "parm", SHARDS as u64)
        } else {
            Recorder::disabled()
        };
        cfg.recorder = recorder.clone();
        let spec = ShardSpec { shards: SHARDS, vnodes: 32, global_backlog: None };
        let tier = ShardedFrontend::start(cfg, spec, &models, &src.queries[0])
            .expect("tier builds");
        (tier, recorder)
    };

    // Record the scenario at its real arrival pacing.
    let (tier, recorder) = start_tier(true);
    let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
    let (submitted, mut got) = drive_paced(&clients, &src, &scenario_trace);
    collect(&clients, &mut got, submitted.len(), Duration::from_secs(12));
    assert_eq!(got.len(), submitted.len(), "every accepted query resolves");
    let res = tier.shutdown().expect("clean shutdown");
    let bytes = recorder.finish(&res.merged);

    // Mine it back.
    let events = journal::decode(&bytes).expect("clean journal decodes");
    let mined = Trace::from_journal(&events).expect("journal has submits to mine");
    assert_eq!(mined.len(), N, "one mined arrival per accepted query");
    assert_eq!(mined.query_idx.len(), N);

    let (want_gap, _) = scenario_trace.stats();
    let (got_gap, _) = mined.stats();
    let gap_err = (got_gap - want_gap).abs() / want_gap;
    assert!(
        gap_err < 0.30,
        "mined mean gap {got_gap:.5}s within 30% of scenario {want_gap:.5}s (err {gap_err:.2})"
    );
    let want_burst = scenario_trace.burst_ratio(20);
    let got_burst = mined.burst_ratio(20);
    assert!(want_burst > 2.0, "flash-crowd scenario is bursty ({want_burst:.2})");
    assert!(
        got_burst > 2.0 && got_burst > 0.5 * want_burst,
        "mined burstiness {got_burst:.2} preserves the flash crowd ({want_burst:.2})"
    );

    // File round trip, then replay the mined trace through a fresh
    // tier at its own pacing — the `parm serve --trace` path.
    let path = std::env::temp_dir().join(format!("parm-mined-{}.json", std::process::id()));
    mined.save(path.to_str().unwrap()).expect("mined trace saves");
    let loaded = Trace::load(path.to_str().unwrap()).expect("mined trace loads");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.len(), mined.len());
    assert_eq!(loaded.n_clients(), mined.n_clients());

    let (tier2, _) = start_tier(false);
    let clients2: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier2.client()).collect();
    let (submitted2, mut got2) = drive_paced(&clients2, &src, &loaded);
    assert_eq!(submitted2.len(), N, "the mined trace offers the same load");
    collect(&clients2, &mut got2, submitted2.len(), Duration::from_secs(12));
    assert_eq!(got2.len(), submitted2.len(), "the mined trace replays cleanly");
    let res2 = tier2.shutdown().expect("clean shutdown of the replay tier");
    assert_eq!(res2.merged.metrics.offered(), N as u64, "offered load conserved on replay");
}

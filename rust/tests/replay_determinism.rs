//! Record/replay regression suite for the serving-path journal
//! (`coordinator::journal`), plus observability checks for the
//! network-chaos primitives:
//!
//! - **per-mode round trip**: a scenario workload with scripted faults
//!   drives the sharded tier under ParM and Rateless; the recorded
//!   journal must replay cleanly, re-encode byte-identically, and
//!   reproduce the live `RunResult`'s outcome totals;
//! - **cross-shard chaos trial**: 200 queries through the cross-shard
//!   tier under a whole-shard kill plus link degradation, replayed
//!   twice — both replays byte-identical to the recording and to each
//!   other, totals matching the original run;
//! - **link degradation**: a `FaultScript` `DegradeLink` step pins
//!   phantom flows that inflate the serving tail as observed in the
//!   `WindowSnapshot`, conservation (offered = resolved + rejected)
//!   holds throughout, and `RestoreLink` clears the flows.
//!
//! Like the other cluster suites these spawn full simulated clusters,
//! run serialized, and skip with a message when artifacts are missing
//! under `--features pjrt`.

mod common;

use std::collections::HashSet;
use std::time::{Duration, Instant};

use common::{FaultScript, FaultSurface};
use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::SubmitError;
use parm::coordinator::journal::{self, EndTotals, Recorder};
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::{Resolved, ServiceBuilder};
use parm::coordinator::shards::{CrossShardFrontend, ShardSpec, ShardedClient, ShardedFrontend};
use parm::experiments::latency;
use parm::workload::scenario;
use parm::workload::trace::Trace;
use parm::workload::QuerySource;

/// Each test spawns full simulated clusters; serialize to keep the
/// timing paths representative.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(r_max: usize) -> Option<(QuerySource, ModelSet)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP replay_determinism: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    match latency::load_models(&m, 1, 2, r_max, false) {
        Ok(models) => Some((src, models)),
        Err(e) => {
            eprintln!("SKIP replay_determinism: {e}");
            None
        }
    }
}

/// Drive a scenario trace step-paced through the tier's clients: query
/// choice and tenant attribution come from the trace (its arrival
/// offsets pace the CLI replay path; here the step index paces the
/// fault script deterministically). Returns (accepted ids, rejected
/// count, resolutions collected so far).
fn drive_trace(
    clients: &[ShardedClient],
    src: &QuerySource,
    trace: &Trace,
    script: &mut FaultScript,
    surface: &FaultSurface,
) -> (HashSet<u64>, u64, Vec<Resolved>) {
    let mut submitted = HashSet::new();
    let mut rejected = 0u64;
    let mut got = Vec::new();
    for i in 0..trace.len() {
        script.apply(i as u64, surface);
        // Multi-tenant traces fan out by their client attribution;
        // single-client traces round-robin so traffic reaches every
        // shard.
        let ci = if trace.n_clients() > 1 { trace.client_of(i) as usize } else { i };
        let c = &clients[ci % clients.len()];
        match c.submit(src.queries[trace.query_idx[i] % src.len()].clone()) {
            Ok(id) => {
                assert!(submitted.insert(id), "tier ids must be unique");
            }
            Err(SubmitError::Rejected { .. } | SubmitError::SloShed { .. }) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        for c in clients {
            got.extend(c.poll());
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    (submitted, rejected, got)
}

/// Sweep every client until `want` resolutions arrived (or timeout).
fn collect(clients: &[ShardedClient], got: &mut Vec<Resolved>, want: usize, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    while got.len() < want && Instant::now() < deadline {
        let mut any = false;
        for c in clients {
            for r in c.poll() {
                got.push(r);
                any = true;
            }
        }
        if !any {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// A recorded sharded run under ParM and under Rateless replays
/// cleanly: byte-identical re-encode, totals equal to the live
/// `RunResult`, every scripted fault journaled.
#[test]
fn sharded_journal_records_and_replays_for_parm_and_rateless() {
    let _guard = serial();
    const SHARDS: usize = 2;
    const M: usize = 2;
    const CLIENTS: usize = 4;
    const N: usize = 80;
    const SEED: u64 = 0x5EA1;
    let Some((src, models)) = setup(2) else { return };
    let modes = [
        ("parm", Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }),
        (
            "rateless",
            Mode::Rateless { k: 2, r_min: 1, r_max: 2, halflife: Duration::from_millis(150) },
        ),
    ];
    for (name, mode) in modes {
        let mut cfg = ServiceConfig::defaults(mode, &GPU);
        cfg.m = M;
        cfg.shuffles = 0;
        cfg.seed = SEED;
        cfg.slo = Some(Duration::from_millis(1500));
        let recorder = Recorder::start(SEED, name, SHARDS as u64);
        cfg.recorder = recorder.clone();
        let spec = ShardSpec { shards: SHARDS, vnodes: 32, global_backlog: None };
        let tier = ShardedFrontend::start(cfg, spec, &models, &src.queries[0])
            .unwrap_or_else(|e| panic!("{name}: tier builds: {e}"));
        let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
        let surface =
            FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M);
        let mut script = FaultScript::builder(SEED)
            .kill_instance_at(12, 0, 0)
            .straggle_at(24, 1, 0, Duration::from_millis(200))
            .build();
        let trace =
            scenario::generate("zipf", SEED, N, 200.0, src.len()).expect("catalogue has zipf");

        let (submitted, rejected, mut got) =
            drive_trace(&clients, &src, &trace, &mut script, &surface);
        assert!(script.done(), "{name}: the scripted faults fired");
        assert_eq!(rejected, 0, "{name}: unbounded admission accepts everything");
        collect(&clients, &mut got, submitted.len(), Duration::from_secs(12));
        assert_eq!(got.len(), submitted.len(), "{name}: every accepted query resolves");
        let res = tier.shutdown().unwrap_or_else(|e| panic!("{name}: {e}"));

        let bytes = recorder.finish(&res.merged);
        let report =
            journal::replay(&bytes).unwrap_or_else(|e| panic!("{name}: journal replays: {e}"));
        assert_eq!(report.journal, bytes, "{name}: replay re-encodes byte-identically");
        assert_eq!(report.digest, journal::digest(&bytes), "{name}: digest agrees");
        assert_eq!(report.seed, SEED, "{name}");
        assert_eq!(report.mode, name, "{name}");
        assert_eq!(
            report.submits,
            submitted.len() as u64,
            "{name}: one Submit per accepted query"
        );
        assert_eq!(report.leaked, 0, "{name}: a drained run leaks no pending queries");
        assert_eq!(
            report.totals,
            EndTotals::of(&res.merged),
            "{name}: replayed totals reproduce the RunResult"
        );
        assert_eq!(report.faults, 2, "{name}: the kill and the straggle were journaled");
        assert!(report.seals > 0, "{name}: coding groups sealed");
    }
}

/// The ISSUE's regression: record a 200-query cross-shard chaos trial
/// (whole-shard kill plus link degradation), replay the journal twice,
/// and assert both replays are byte-identical to the recording and
/// reproduce the original run's totals.
#[test]
fn cross_shard_chaos_trial_replays_byte_identically_twice() {
    let _guard = serial();
    const SHARDS: usize = 3;
    const M: usize = 2;
    const CLIENTS: usize = 6;
    const N: usize = 200;
    const SEED: u64 = 0x2E9147;
    let Some((src, models)) = setup(2) else { return };
    let mut cfg = ServiceConfig::defaults(
        Mode::CrossShard { k: 2, r_min: 1, r_max: 2, halflife: Duration::from_millis(150) },
        &GPU,
    );
    cfg.m = M;
    cfg.shuffles = 0;
    cfg.seed = SEED;
    cfg.slo = Some(Duration::from_millis(1500));
    let recorder = Recorder::start(SEED, "cross-shard", SHARDS as u64);
    cfg.recorder = recorder.clone();
    let spec = ShardSpec { shards: SHARDS, vnodes: 64, global_backlog: None };
    let tier = CrossShardFrontend::start(cfg, spec, &models, &src.queries[0])
        .expect("cross-shard tier builds");
    let clients: Vec<ShardedClient> = (0..CLIENTS).map(|_| tier.client()).collect();
    // Kill a shard that demonstrably carries traffic (routing is
    // hash-based, so a hardcoded index might sit idle).
    let victim = tier.route_of(clients[0].id()).expect("live shard");
    let surface = FaultSurface::sharded((0..SHARDS).map(|s| tier.fault_plan(s)).collect(), M)
        .with_networks((0..SHARDS).map(|s| tier.network(s)).collect())
        .with_recorder(recorder.clone());
    // Production-flavoured chaos: degrade one link early, lose a whole
    // shard mid-run, restore the link late.
    let mut script = FaultScript::builder(SEED)
        .degrade_link_at(20, 0, 0, 8)
        .kill_shard_at(80, victim)
        .restore_link_at(160, 0, 0)
        .build();
    let trace = scenario::generate("flash-crowd", SEED, N, 400.0, src.len())
        .expect("catalogue has flash-crowd");

    let (submitted, rejected, mut got) =
        drive_trace(&clients, &src, &trace, &mut script, &surface);
    assert!(script.done(), "the scripted chaos fired");
    assert_eq!(rejected, 0, "unbounded admission accepts everything");
    tier.flush_open_groups();
    collect(&clients, &mut got, submitted.len(), Duration::from_secs(15));
    assert_eq!(got.len(), submitted.len(), "every accepted query resolves");
    let res = tier.shutdown().expect("clean shutdown");
    assert_eq!(res.fleet.merged.metrics.offered(), N as u64, "offered traffic conserved");

    let bytes = recorder.finish(&res.fleet.merged);
    let first = journal::replay(&bytes).expect("first replay");
    let second = journal::replay(&bytes).expect("second replay");
    assert_eq!(first.journal, bytes, "first replay re-encodes byte-identically");
    assert_eq!(second.journal, bytes, "second replay re-encodes byte-identically");
    assert_eq!(first.digest, second.digest, "replays agree with each other");
    assert_eq!(first.digest, journal::digest(&bytes), "and with the recording");
    let want = EndTotals::of(&res.fleet.merged);
    assert_eq!(first.totals, want, "replayed totals match the original RunResult");
    assert_eq!(second.totals, want);
    assert_eq!(first.submits, submitted.len() as u64);
    assert_eq!(first.leaked, 0, "a drained run leaks no pending queries");
    // The whole-shard kill (M instance kills) plus the degrade and the
    // restore all made it into the journal.
    assert_eq!(first.faults, (M + 2) as u64, "chaos actions journaled");
    assert!(first.seals > 0, "cross-shard groups sealed");
    assert!(first.decodes > 0, "the killed shard's queries came back via decode");
}

/// `FaultScript`-driven link degradation is observable end to end: the
/// phantom flows pin while the script holds them, the serving tail
/// inflates in the `WindowSnapshot`, conservation holds, and
/// `RestoreLink` clears the flows.
#[test]
fn link_degradation_inflates_the_window_tail_and_conserves() {
    let _guard = serial();
    const N: u64 = 60;
    let Some((src, models)) = setup(1) else { return };
    let run = |flows: u32| {
        // No redundancy: nothing rescues a query stuck behind the
        // degraded link, so the inflation lands squarely in the tail.
        let mut cfg = ServiceConfig::defaults(Mode::NoRedundancy, &GPU);
        cfg.m = 2;
        cfg.shuffles = 0;
        cfg.seed = 0xD316;
        let frontend =
            ServiceBuilder::new(cfg).serve(&models, &src.queries[0]).expect("frontend builds");
        let surface = FaultSurface::single(frontend.fault_plan(), 2)
            .with_networks(vec![Some(frontend.network())]);
        let mut script = if flows > 0 {
            FaultScript::builder(9)
                .degrade_link_at(0, 0, 0, flows)
                .degrade_link_at(0, 0, 1, flows)
                .restore_link_at(N - 1, 0, 0)
                .restore_link_at(N - 1, 0, 1)
                .build()
        } else {
            FaultScript::builder(9).build()
        };
        let client = frontend.client();
        let mut accepted = 0u64;
        for i in 0..N {
            script.apply(i, &surface);
            if i == 1 && flows > 0 {
                assert_eq!(frontend.network().degraded_flows(0), flows, "flows pinned");
                assert_eq!(frontend.network().degraded_flows(1), flows, "flows pinned");
            }
            if client.submit(src.queries[i as usize % src.len()].clone()).is_ok() {
                accepted += 1;
            }
            let _ = client.poll();
            std::thread::sleep(Duration::from_millis(2));
        }
        while client.stats().resolved < accepted {
            if client.next(Duration::from_secs(10)).is_none() {
                break;
            }
        }
        assert_eq!(client.stats().resolved, accepted, "every accepted query resolves");
        assert_eq!(frontend.network().degraded_flows(0), 0, "restore clears phantom flows");
        assert_eq!(frontend.network().degraded_flows(1), 0, "restore clears phantom flows");
        let w = frontend.window();
        let res = frontend.shutdown().expect("clean shutdown");
        (w, res)
    };
    let (clean_w, clean_res) = run(0);
    let (deg_w, deg_res) = run(16);
    // Conservation with and without chaos: offered = resolved + rejected.
    assert_eq!(clean_res.metrics.offered(), N);
    assert_eq!(deg_res.metrics.offered(), N);
    // 16 phantom flows add 2x-6x mean-service head-of-line delay *per
    // flow* to the unlucky quarter of queries — an order of magnitude of
    // tail inflation, far beyond run-to-run noise.
    assert!(
        deg_w.p99_ms > 2.0 * clean_w.p99_ms,
        "degraded tail must inflate: degraded p99 {:.3}ms vs clean p99 {:.3}ms",
        deg_w.p99_ms,
        clean_w.p99_ms
    );
}

//! End-to-end telemetry: a live serving frontend scraped over real TCP
//! while adversarial ("wedged") scrapers hold connections open, an
//! instance dies mid-run, and admission sheds load.
//!
//! The contract under test is the exporter's core promise: scraping is
//! strictly decoupled from serving. A scraper that connects and then
//! stalls — never sending a request, or reading one byte of the
//! response and then sitting on the socket — may wedge its own
//! connection thread until a timeout, but must never delay a `submit`,
//! drop a resolution, or skew the counters. Meanwhile a healthy scrape
//! taken during the fault must show the standard family catalogue, and
//! a second scrape must observe counters monotonically.
//!
//! Like the other cluster suites, this spawns a full simulated cluster:
//! it runs serialized and skips (with a message) if artifacts are
//! missing under `--features pjrt`.

mod common;

use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use common::{FaultScript, FaultSurface};
use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::frontend::{AdmissionPolicy, SubmitError};
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::telemetry::Exporter;
use parm::workload::QuerySource;

static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> Option<(Manifest, QuerySource)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP telemetry: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    Some((m, src))
}

fn models(m: &Manifest, k: usize) -> Option<ModelSet> {
    match latency::load_models(m, 1, k, 1, false) {
        Ok(ms) => Some(ms),
        Err(e) => {
            eprintln!("SKIP telemetry: {e}");
            None
        }
    }
}

/// One healthy scrape: full request, read to EOF, return the body.
fn scrape(addr: SocketAddr) -> String {
    let mut s = TcpStream::connect(addr).expect("connect to exporter");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read scrape response");
    out
}

/// The value of an *unlabelled* series in a Prometheus text rendering.
fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        l.strip_prefix(name)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse().ok())
    })
}

/// A scraper frozen mid-conversation. `request` controls how far it
/// gets before stalling: `false` connects and never speaks (wedging the
/// connection thread in its request read), `true` sends the request and
/// reads a single byte of the response, then sits on the socket.
struct WedgedScraper {
    stream: TcpStream,
}

impl WedgedScraper {
    fn new(addr: SocketAddr, request: bool) -> WedgedScraper {
        let mut stream = TcpStream::connect(addr).expect("connect to exporter");
        if request {
            stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut one = [0u8; 1];
            assert_eq!(stream.read(&mut one).expect("first response byte"), 1);
            assert_eq!(one[0], b'H', "response starts with the HTTP status line");
        }
        // From here on: total silence. The socket stays open until the
        // struct drops at the end of the test.
        WedgedScraper { stream }
    }
}

/// The wedged-scraper chaos drill. Open-loop load with an instance kill
/// mid-run while four stalled scrapers camp on the exporter; healthy
/// scrapes interleave. Asserts exactly-once delivery, full accounting
/// (offered = resolved + rejected), monotonic counters across scrapes,
/// and — the headline — that no submit call stalled on scraper state.
#[test]
fn wedged_scraper_never_stalls_the_serving_path() {
    let _guard = serial();
    const N: u64 = 300;
    const KILL_STEP: u64 = 120;
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2) else { return };

    let mut cfg =
        ServiceConfig::defaults(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }, &GPU);
    cfg.m = 4;
    cfg.shuffles = 0;
    cfg.seed = 0x7E1E;
    cfg.slo = Some(Duration::from_secs(3)); // backstop for doubly-lost groups
    cfg.admission = AdmissionPolicy::RejectAbove { backlog: 64 };

    let frontend = ServiceBuilder::new(cfg)
        .serve(&models, &src.queries[0])
        .expect("frontend builds");
    let exporter =
        Exporter::bind("127.0.0.1:0", frontend.registry()).expect("exporter binds");
    let addr = exporter.local_addr();

    // Camp four stalled scrapers on the endpoint before any load: two
    // silent connections (wedge the request read) and two that take one
    // byte of a response and freeze (wedge any further write).
    let _wedged: Vec<WedgedScraper> = vec![
        WedgedScraper::new(addr, false),
        WedgedScraper::new(addr, false),
        WedgedScraper::new(addr, true),
        WedgedScraper::new(addr, true),
    ];

    let surface = FaultSurface::single(frontend.fault_plan(), 4);
    let mut script = FaultScript::builder(0x7E1E).kill_instance_at(KILL_STEP, 0, 0).build();

    let client = frontend.client();
    let mut submitted = HashSet::new();
    let mut rejected = 0u64;
    let mut got = Vec::new();
    let mut max_submit = Duration::ZERO;
    let mut mid_scrape = String::new();
    for i in 0..N {
        script.apply(i, &surface);
        if i == KILL_STEP + 40 {
            // A healthy scrape mid-fault, concurrent with the wedged
            // four: the exporter answers each connection independently.
            mid_scrape = scrape(addr);
        }
        let t0 = Instant::now();
        match client.submit(src.queries[(i as usize) % src.len()].clone()) {
            Ok(id) => {
                assert!(submitted.insert(id), "frontend ids must be unique");
            }
            Err(SubmitError::Rejected { .. } | SubmitError::SloShed { .. }) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        max_submit = max_submit.max(t0.elapsed());
        got.extend(client.poll());
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(script.done(), "the scripted kill fired");

    // Exactly-once delivery: every accepted query resolves, to the
    // submitting client, with no duplicates — fault and wedges included.
    while got.len() < submitted.len() {
        match client.next(Duration::from_secs(10)) {
            Some(r) => got.push(r),
            None => break,
        }
    }
    assert_eq!(got.len(), submitted.len(), "every accepted query resolves");
    let ids: HashSet<u64> = got.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), got.len(), "no duplicate resolutions");
    assert_eq!(ids, submitted, "exactly the accepted ids");

    // The submit path never waits on scraper state. The exporter's
    // per-connection timeouts are 500 ms (read) and 2 s (write): if a
    // wedged connection could reach into `submit`, at least one call
    // would have absorbed a timeout-scale stall. The bound leaves
    // generous room for scheduler noise while staying well below the
    // smallest timeout.
    assert!(
        max_submit < Duration::from_millis(400),
        "submit stalled {max_submit:?} — serving path coupled to scraper state?"
    );

    // The mid-fault healthy scrape carries the catalogue's hot families.
    assert!(mid_scrape.starts_with("HTTP/1.0 200 OK"), "got: {mid_scrape}");
    for family in [
        "parm_queries_submitted_total",
        "parm_queries_resolved_total",
        "parm_queries_rejected_total",
        "parm_outcome_total",
        "parm_latency_ms",
        "parm_admission_total",
    ] {
        assert!(mid_scrape.contains(family), "mid-run scrape is missing {family}");
    }
    let mid_submitted = metric_value(&mid_scrape, "parm_queries_submitted_total")
        .expect("submitted counter is an unlabelled series");

    // Full accounting, client-side and scrape-side.
    let accepted = submitted.len() as u64;
    let st = client.stats();
    assert_eq!(st.submitted, accepted);
    assert_eq!(st.rejected, rejected);
    assert_eq!(st.resolved, accepted);

    let res = frontend.shutdown().expect("clean shutdown");
    assert_eq!(res.metrics.total(), accepted);
    assert_eq!(res.metrics.offered(), accepted + rejected, "offered = resolved + rejected");

    // The exporter outlives the session: a post-shutdown scrape still
    // answers, counters are monotonic and agree with the run record.
    let final_scrape = scrape(addr);
    let end_submitted = metric_value(&final_scrape, "parm_queries_submitted_total")
        .expect("submitted counter survives shutdown");
    let end_resolved = metric_value(&final_scrape, "parm_queries_resolved_total").unwrap();
    let end_rejected = metric_value(&final_scrape, "parm_queries_rejected_total").unwrap();
    assert!(
        end_submitted >= mid_submitted,
        "counters must be monotonic across scrapes ({mid_submitted} -> {end_submitted})"
    );
    assert_eq!(end_submitted, accepted as f64, "scrape agrees with the client");
    assert_eq!(end_resolved, accepted as f64);
    assert_eq!(end_rejected, rejected as f64);
    // Shutdown published the final window, so the series view an
    // operator (or `telemetry::series::Capture`) reads is present too.
    assert!(
        final_scrape.contains("parm_session_window_seconds"),
        "window gauges missing from the final scrape"
    );

    exporter.shutdown();
}

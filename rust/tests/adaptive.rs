//! End-to-end behavior of the adaptive redundancy subsystem
//! (`coordinator::adaptive`) against a real simulated cluster:
//!
//! - **ramp**: under an injected straggler burst (two deployed instances
//!   zombied together) the straggler predictor's unavailability estimate
//!   rises and the rateless scheme seals groups with more parities; after
//!   the burst clears, the evidence decays and `r` returns to the floor;
//! - **conservation**: a rateless session under a permanent instance
//!   failure still resolves every submitted query exactly once (natively,
//!   reconstructed, or — beyond the group's parities — by SLO default).
//!
//! Like the other cluster tests, these run serialized and skip with a
//! message if artifacts are missing under `--features pjrt`.

use std::time::{Duration, Instant};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::service::{Mode, ModelSet, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::util::rng::Pcg64;
use parm::workload::QuerySource;

/// Each test spawns a full simulated cluster; serialize to keep the
/// timing paths representative.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(r_max: usize) -> Option<(QuerySource, ModelSet)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP adaptive: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    match latency::load_models(&m, 1, 2, r_max, false) {
        Ok(models) => Some((src, models)),
        Err(e) => {
            eprintln!("SKIP adaptive: {e}");
            None
        }
    }
}

#[test]
fn predictor_ramps_r_through_a_straggler_burst_and_back() {
    let _guard = serial();
    let Some((src, models)) = setup(2) else { return };

    let halflife = Duration::from_millis(250);
    let mut cfg = ServiceConfig::defaults(
        Mode::Rateless { k: 2, r_min: 1, r_max: 2, halflife },
        &GPU,
    );
    cfg.m = 4;
    cfg.shuffles = 0;
    cfg.seed = 0xADA0;
    cfg.slo = Some(Duration::from_secs(2)); // backstop for >r-loss groups
    // Burst: instances 0 and 1 fail together from 0.9s to 2.1s — half
    // the deployed pool, so coding groups lose one or both slots.
    let burst_start = Duration::from_millis(900);
    let burst_len = Duration::from_millis(1200);
    cfg.fault_schedule = vec![(0, burst_start, burst_len), (1, burst_start, burst_len)];

    let mut handle = ServiceBuilder::new(cfg).build(&models, &src.queries[0]).unwrap();
    assert_eq!(handle.scheme_name(), "rateless");
    // Pace arrivals so the whole run spans ~4.2s: ~0.9s healthy lead-in,
    // the 1.2s burst, and a >= 2s healthy tail (8 half-lives) for decay.
    let run = Duration::from_millis(4200);
    let mean = handle.mean_service().as_secs_f64() * GPU.exec_scale.max(1.0);
    let capacity_rate = 0.4 * 4.0 / mean;
    let n = ((run.as_secs_f64() * capacity_rate) as u64).clamp(200, 4000);
    let interval = run.div_f64(n as f64);

    let start = Instant::now();
    let mut r_before_burst = 0usize;
    let mut max_r_burst = 0usize;
    for i in 0..n {
        let due = start + interval.mul_f64(i as f64);
        loop {
            let _ = handle.poll();
            let now = Instant::now();
            if now >= due {
                break;
            }
            std::thread::sleep((due - now).min(Duration::from_millis(2)));
        }
        handle.submit(src.queries[(i as usize) % src.len()].clone());
        let elapsed = start.elapsed();
        if let Some(t) = handle.scheme_telemetry() {
            if elapsed < burst_start {
                r_before_burst = r_before_burst.max(t.last_r);
            } else if elapsed < burst_start + burst_len + Duration::from_millis(300) {
                max_r_burst = max_r_burst.max(t.last_r);
            }
        }
    }
    let _ = handle.drain();
    let t = handle.scheme_telemetry().expect("rateless exposes telemetry");

    assert_eq!(r_before_burst, 1, "healthy lead-in stays at the floor");
    assert_eq!(
        max_r_burst, 2,
        "the burst must ramp r to the ceiling (unavailability {:.3})",
        t.unavailability
    );
    assert_eq!(
        t.last_r, 1,
        "r must decay back to the floor after the burst (unavailability {:.3})",
        t.unavailability
    );
    assert!(
        t.unavailability < 0.1,
        "evidence must decay within the healthy tail, got {:.3}",
        t.unavailability
    );
    assert!(
        t.parity_jobs > t.groups_sealed,
        "some groups carried extra parities ({} jobs over {} groups)",
        t.parity_jobs,
        t.groups_sealed
    );
    assert!(
        t.parity_jobs < 2 * t.groups_sealed,
        "not every group paid the ceiling ({} jobs over {} groups)",
        t.parity_jobs,
        t.groups_sealed
    );

    let res = handle.shutdown();
    assert!(
        res.reconstructions > 0,
        "the burst's lost predictions must be recovered by decode"
    );
    assert!(res.dropped_jobs > 0, "the zombied instances must have dropped jobs");
}

#[test]
fn rateless_session_conserves_queries_under_permanent_failure() {
    let _guard = serial();
    let Some((src, models)) = setup(2) else { return };

    let mut cfg = ServiceConfig::defaults(
        Mode::Rateless {
            k: 2,
            r_min: 1,
            r_max: 2,
            halflife: Duration::from_millis(200),
        },
        &GPU,
    );
    cfg.m = 2;
    cfg.shuffles = 0;
    cfg.seed = 0xADA1;
    cfg.slo = Some(Duration::from_secs(2));
    // One of two deployed instances is a zombie from 30ms on.
    cfg.fault_schedule = vec![(0, Duration::from_millis(30), Duration::ZERO)];

    let mut handle = ServiceBuilder::new(cfg).build(&models, &src.queries[0]).unwrap();
    let mut rng = Pcg64::new(0xC0FE);
    let n = 120u64;
    let mut ids = Vec::new();
    let mut resolved = Vec::new();
    for i in 0..n {
        ids.push(handle.submit(src.queries[(i as usize) % src.len()].clone()));
        if rng.next_f64() < 0.3 {
            resolved.extend(handle.poll());
        }
        std::thread::sleep(Duration::from_micros(500));
    }
    resolved.extend(handle.drain());
    let mut got: Vec<u64> = resolved.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids, "every query resolves exactly once");
    let res = handle.shutdown();
    assert_eq!(res.metrics.total(), n);
    assert!(
        res.metrics.native + res.metrics.reconstructed + res.metrics.defaulted == n,
        "outcomes partition the queries"
    );
}

//! Regression tests for the hot-path rearchitecture bugfix sweep.
//!
//! Three bugs rode along with the seed's serving loop and are pinned
//! here so they cannot regress:
//!
//! 1. `poll_timeout` stacked its waits — a batch-timeout wait followed
//!    by a full completion wait — so a caller asking for a 500 ms
//!    budget could block for roughly double that, and worse, return
//!    empty even though its query resolved the moment the batch flushed.
//!    The rewrite drives one shared deadline through the pump and wakes
//!    early for batcher/SLO deadlines.
//! 2. The open-loop drivers (`run_open_loop` / `run_trace_scaled`)
//!    duplicated a pacing loop that folded the *entire* completion
//!    backlog between due-checks, so a completion flood pushed arrival
//!    timestamps past their trace offsets. The shared `pace_until`
//!    bounds each fold and re-checks the deadline every pass.
//! 3. A panic on one thread while it held a coordinator or telemetry
//!    lock poisoned that lock for everyone (~194 `.unwrap()` sites) and
//!    cascaded a single fault into a fleet-wide crash. Locks now
//!    recover via `PoisonError::into_inner` and registry samplers run
//!    under `catch_unwind`.
//!
//! All three use the synthetic artifact backend (`Manifest::load_default`
//! fabricates a deterministic inventory), so they run anywhere.

use std::time::{Duration, Instant};

use parm::coordinator::encoder::Encoder;
use parm::coordinator::journal::{self, Event, Recorder};
use parm::coordinator::service::{Mode, ServiceConfig};
use parm::coordinator::session::{ServiceBuilder, ServiceHandle};
use parm::experiments::latency;
use parm::workload::trace::Trace;
use parm::workload::QuerySource;

/// Build a small ParM session against the synthetic backend, or `None`
/// when executables are unavailable (the suite-wide skip idiom).
fn build_session(
    tweak: &mut dyn FnMut(&mut ServiceConfig),
) -> Option<(ServiceHandle, QuerySource)> {
    let Ok(m) = parm::artifacts::Manifest::load_default() else { return None };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    let Ok(models) = latency::load_models(&m, 1, 2, 1, false) else {
        eprintln!("SKIP hotpath regression: no executables");
        return None;
    };
    let mut cfg = ServiceConfig::defaults(
        Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
        &parm::cluster::hardware::GPU,
    );
    cfg.m = 2;
    cfg.shuffles = 0;
    cfg.time_scale = 0.0;
    cfg.seed = 0x407;
    tweak(&mut cfg);
    let handle = ServiceBuilder::new(cfg).build(&models, &src.queries[0]).ok()?;
    Some((handle, src))
}

/// BUG 1 (`poll_timeout` double wait): with a batch that only seals by
/// timeout, the seed first slept out the batch deadline and then started
/// a *fresh* full-length completion wait — and in the worst case
/// returned nothing after ~2x the caller's budget because the flush
/// only happened on entry to the next call. One submit + one
/// `poll_timeout(500ms)` must return the resolved query in well under
/// half the budget: the pump wakes at the 5 ms batcher deadline,
/// flushes, and the modeled completion resolves immediately.
#[test]
fn poll_timeout_honors_a_single_shared_deadline() {
    let Some((mut h, src)) = build_session(&mut |cfg| {
        cfg.batch_size = 64; // never seals by count
        cfg.batch_timeout = Duration::from_millis(5);
    }) else {
        return;
    };
    let id = h.submit(src.queries[0].clone());
    let start = Instant::now();
    let got = h.poll_timeout(Duration::from_millis(500));
    let waited = start.elapsed();
    assert_eq!(got.len(), 1, "query must resolve within one poll_timeout");
    assert_eq!(got[0].id, id);
    assert!(
        waited < Duration::from_millis(250),
        "poll_timeout blocked {waited:?} for a query that resolved at the \
         5ms batch deadline — the wait is not honoring the shared deadline"
    );
    assert!(h.drain().is_empty());
    h.shutdown();
}

/// BUG 2 (pacing drift): trace replay must keep its arrival schedule
/// even when a deep completion backlog is draining underneath it. We
/// pile up a few thousand unharvested completions, then replay a trace
/// with 8 ms spacing and compare the journal's recorded submit
/// timestamps against the trace offsets. The bounded `pace_until` fold
/// keeps every arrival within tolerance; the seed's unbounded sweep let
/// the backlog push arrivals late.
#[test]
fn trace_pacing_stays_on_schedule_under_completion_flood() {
    let rec = Recorder::start(0xFEED, "parm", 1);
    let rec_cfg = rec.clone();
    let Some((mut h, src)) = build_session(&mut |cfg| {
        cfg.batch_size = 1;
        cfg.recorder = rec_cfg.clone();
    }) else {
        return;
    };
    // Flood: submit without polling so completions pile up on the bus.
    let flood: usize = 4_000;
    let mut ids = Vec::with_capacity(flood);
    for i in 0..flood {
        ids.push(h.submit(src.queries[i % src.len()].clone()));
    }
    // Let the (modeled, time_scale=0) workers finish into the bus.
    std::thread::sleep(Duration::from_millis(100));

    let step = Duration::from_millis(8);
    let n: usize = 12;
    let trace = Trace {
        arrivals: (0..n).map(|i| i as f64 * step.as_secs_f64()).collect(),
        query_idx: Vec::new(),
        client: Vec::new(),
        rate_qps: 1.0 / step.as_secs_f64(),
    };
    h.run_trace(&src.queries, &trace);

    let resolved = h.drain();
    let mut got: Vec<u64> = resolved.iter().map(|r| r.id).collect();
    got.sort_unstable();
    // Qids are sequential, so the trace arrivals follow the flood ids.
    let first = ids[0];
    let expect: Vec<u64> = (first..first + (flood + n) as u64).collect();
    assert_eq!(got, expect, "flood + trace queries each resolve exactly once");

    let res = h.shutdown();
    let bytes = rec.finish(&res);
    let evs = journal::decode(&bytes).expect("journal decodes");
    let trace_base = first + flood as u64;
    let ts: Vec<u64> = evs
        .iter()
        .filter_map(|te| match te.event {
            Event::Submit { qid } if qid >= trace_base => Some(te.ts_us),
            _ => None,
        })
        .collect();
    assert_eq!(ts.len(), n, "every trace arrival was journaled");
    // Compare inter-arrival schedule against the trace offsets, rebased
    // to the first trace submit. Tolerance is generous for noisy CI
    // hosts but far below the multi-step drift the unbounded sweep
    // produced under this flood.
    let tol_us: i64 = 40_000;
    for (i, &t) in ts.iter().enumerate() {
        let actual = (t - ts[0]) as i64;
        let expected = (i as u64 * step.as_micros() as u64) as i64;
        assert!(
            (actual - expected).abs() <= tol_us,
            "arrival {i}: {actual}us after first submit, trace offset {expected}us \
             — pacing drifted past tolerance ({tol_us}us) under completion flood"
        );
    }
}

/// BUG 3 (lock-poisoning cascade): a sampler hook that panics mid-scrape
/// used to unwind through the scrape, poison the registry's sampler
/// list, and turn every later lock `.unwrap()` into a panic — one
/// faulty hook took down telemetry and, through shared registry
/// handles, the serving path. Now the scrape contains the panic
/// (`catch_unwind`) and every lock recovers from poisoning, so the
/// session keeps serving with exactly-once conservation and the
/// registry stays scrapeable.
#[test]
fn panicking_sampler_neither_kills_scrapes_nor_breaks_conservation() {
    let Some((mut h, src)) = build_session(&mut |_| {}) else { return };
    let reg = h.registry();
    reg.sampler(|| panic!("sampler bomb"));

    // Scrape on another thread mid-run; it trips the bomb.
    let reg_scrape = reg.clone();
    let scraper = std::thread::spawn(move || reg_scrape.render());

    let mut ids = Vec::new();
    let mut resolved = Vec::new();
    for i in 0..200usize {
        ids.push(h.submit(src.queries[i % src.len()].clone()));
        if i % 16 == 0 {
            resolved.extend(h.poll());
            h.publish_telemetry();
        }
    }
    let rendered = scraper.join().expect("a panicking sampler must not kill the scraper thread");
    assert!(!rendered.is_empty());

    resolved.extend(h.drain());
    let mut got: Vec<u64> = resolved.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids, "exactly-once conservation with a poisoned-sampler scrape mid-run");

    // The registry must still be scrapeable afterward (the seed's
    // poisoned mutex panicked every subsequent render).
    assert!(!reg.render().is_empty());
    let res = h.shutdown();
    assert_eq!(res.metrics.total(), ids.len() as u64);
}

//! End-to-end service integration: the full threaded coordinator against
//! the simulated cluster, at small scale (fast enough for `cargo test`),
//! driven through the session API (`ServiceBuilder` + `ServiceHandle`).
//!
//! Under the default synthetic engine backend these run against the
//! fabricated artifact inventory (timing/shape semantics are real, trained
//! accuracy is not — which the serving-path assertions never rely on).
//! With `--features pjrt` they require `make artifacts` and skip with a
//! message otherwise. Mid-run faults are scripted through the
//! deterministic harness in `tests/common` (step-indexed, seeded).

mod common;

use std::collections::HashSet;
use std::time::Duration;

use common::{FaultScript, FaultSurface};

use parm::artifacts::Manifest;
use parm::cluster::hardware::GPU;
use parm::coordinator::encoder::Encoder;
use parm::coordinator::metrics::Outcome;
use parm::coordinator::service::{Mode, ModelSet, RunResult, Service, ServiceConfig};
use parm::coordinator::session::ServiceBuilder;
use parm::experiments::latency;
use parm::workload::QuerySource;

/// Each test spawns a full simulated cluster (many worker threads doing
/// real inference with precise-sleep pacing). Running them concurrently
/// oversubscribes the host and distorts/wedges the timing paths, so
/// serialize them.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup() -> Option<(Manifest, QuerySource)> {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP service_integration: {e}");
            return None;
        }
    };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    Some((m, src))
}

fn models(m: &Manifest, batch: usize, k: usize, r: usize, approx: bool) -> Option<ModelSet> {
    match latency::load_models(m, batch, k, r, approx) {
        Ok(ms) => Some(ms),
        Err(e) => {
            eprintln!("SKIP service_integration: {e}");
            None
        }
    }
}

fn quick_cfg(mode: Mode) -> ServiceConfig {
    let mut cfg = ServiceConfig::defaults(mode, &GPU);
    cfg.m = 4; // small cluster for test speed
    cfg.shuffles = 1;
    cfg.seed = 0x7E57;
    cfg
}

/// Build a session, drive the open-loop client, drain, shut down.
fn run_via_session(
    cfg: ServiceConfig,
    models: &ModelSet,
    src: &QuerySource,
    n: u64,
    rate: f64,
) -> RunResult {
    let mut handle = ServiceBuilder::new(cfg)
        .build(models, &src.queries[0])
        .expect("session builds");
    handle.run_open_loop(&src.queries, n, rate);
    let _ = handle.drain();
    handle.shutdown()
}

#[test]
fn parm_serves_all_queries() {
    let _guard = serial();
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let cfg = quick_cfg(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] });
    let res = run_via_session(cfg, &models, &src, 300, 120.0);
    let mut metrics = res.metrics;
    assert_eq!(metrics.total(), 300, "every query must resolve");
    assert_eq!(metrics.defaulted, 0, "no SLO configured, nothing defaults");
    assert!(metrics.latency.median() > 0.0);
}

#[test]
fn no_redundancy_serves_all_queries() {
    let _guard = serial();
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let cfg = quick_cfg(Mode::NoRedundancy);
    let res = run_via_session(cfg, &models, &src, 200, 100.0);
    assert_eq!(res.metrics.total(), 200);
    assert_eq!(res.reconstructions, 0);
}

#[test]
fn equal_resources_uses_extra_instances() {
    let _guard = serial();
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let mode = Mode::EqualResources { k: 2 };
    assert_eq!(mode.extra_instances(4), 2);
    let cfg = quick_cfg(mode);
    let res = run_via_session(cfg, &models, &src, 200, 100.0);
    assert_eq!(res.metrics.total(), 200);
}

#[test]
fn approx_backup_resolves_from_either_pool() {
    let _guard = serial();
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, true) else { return };
    let cfg = quick_cfg(Mode::ApproxBackup { k: 2 });
    let res = run_via_session(cfg, &models, &src, 200, 100.0);
    let metrics = res.metrics;
    assert_eq!(metrics.total(), 200);
    // With healthy instances the deployed pool usually wins, but both
    // paths must be live.
    assert!(metrics.native + metrics.replica == 200);
}

#[test]
fn parm_reconstructs_under_instance_failure() {
    let _guard = serial();
    // Kill one deployed instance permanently at t=0: every query the dead
    // instance swallows must come back via ParM reconstruction, and no
    // query may be lost (SLO backstop would mark stragglers Default —
    // there should be none while the group's siblings + parity survive).
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let mut cfg = quick_cfg(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] });
    cfg.shuffles = 0;
    cfg.slo = Some(Duration::from_secs(3));
    cfg.fault_schedule = vec![(0, Duration::ZERO, Duration::ZERO)];
    let res = run_via_session(cfg, &models, &src, 300, 150.0);
    let metrics = res.metrics;
    assert_eq!(metrics.total(), 300);
    assert!(
        res.reconstructions > 0,
        "a dead instance must trigger reconstructions (got {})",
        res.reconstructions
    );
    assert!(res.dropped_jobs > 0, "the fault plan must actually drop jobs");
    assert!(
        metrics.reconstructed > 0,
        "queries on the dead instance resolve via decode"
    );
}

#[test]
fn equal_resources_defaults_under_failure_where_parm_reconstructs() {
    let _guard = serial();
    // The qualitative contrast of §4: with an instance dead, the
    // Equal-Resources baseline can only miss SLOs (single-queue keeps
    // most queries off the dead instance, but whatever lands there is
    // lost), while ParM recovered those queries above.
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let mut cfg = quick_cfg(Mode::EqualResources { k: 2 });
    cfg.shuffles = 0;
    cfg.slo = Some(Duration::from_millis(400));
    cfg.fault_schedule = vec![(0, Duration::ZERO, Duration::ZERO)];
    let res = run_via_session(cfg, &models, &src, 300, 150.0);
    let metrics = res.metrics;
    assert_eq!(metrics.total(), 300);
    assert!(
        metrics.defaulted > 0,
        "queries swallowed by the dead instance must fall back to defaults"
    );
}

#[test]
fn replication_mode_halves_effective_capacity_but_serves() {
    let _guard = serial();
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let cfg = quick_cfg(Mode::Replication { copies: 2 });
    let res = run_via_session(cfg, &models, &src, 150, 60.0);
    assert_eq!(res.metrics.total(), 150);
}

#[test]
fn batched_service_works() {
    let _guard = serial();
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 2, 2, 1, false) else { return };
    let mut cfg = quick_cfg(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] });
    cfg.batch_size = 2;
    cfg.batch_timeout = Duration::from_millis(5);
    let res = run_via_session(cfg, &models, &src, 300, 150.0);
    assert_eq!(res.metrics.total(), 300);
}

#[test]
fn legacy_service_run_shim_still_works() {
    let _guard = serial();
    // Service::run survives as a compatibility shim over the session API.
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let cfg = quick_cfg(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] });
    let res = Service::run(&cfg, &models, &src.queries, 150, 100.0).unwrap();
    assert_eq!(res.metrics.total(), 150);
}

#[test]
fn live_handle_submit_drain_across_instance_failure() {
    let _guard = serial();
    // The new session surface end-to-end: a client submits queries against
    // a live handle, an instance dies mid-stream, and every submitted
    // query still comes back exactly once — stragglers via ParM decode.
    let Some((m, src)) = setup() else { return };
    let Some(models) = models(&m, 1, 2, 1, false) else { return };
    let mut cfg = quick_cfg(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] });
    cfg.shuffles = 0;
    cfg.slo = Some(Duration::from_secs(3)); // backstop for doubly-lost groups
    let mut handle = ServiceBuilder::new(cfg)
        .build(&models, &src.queries[0])
        .expect("session builds");

    // Undetected zombie from the 50th submit on: keeps taking jobs,
    // never answers — scripted through the deterministic fault harness
    // against the session's own fault plan.
    let surface = FaultSurface::single(handle.fault_plan(), 4);
    let mut script = FaultScript::builder(0x7E57).kill_instance_at(50, 0, 0).build();

    let mut submitted = HashSet::new();
    let mut resolved = Vec::new();
    for i in 0..200u64 {
        script.apply(i, &surface);
        let id = handle.submit(src.queries[(i as usize) % src.len()].clone());
        assert!(submitted.insert(id), "ids must be unique");
        resolved.extend(handle.poll());
        std::thread::sleep(Duration::from_millis(2));
    }
    resolved.extend(handle.drain());
    assert_eq!(handle.in_flight(), 0, "drain resolves everything");

    let ids: HashSet<u64> = resolved.iter().map(|r| r.id).collect();
    assert_eq!(ids, submitted, "every submitted query resolves");
    assert_eq!(resolved.len(), 200, "exactly once each");
    assert!(
        resolved.iter().any(|r| r.outcome == Outcome::Reconstructed),
        "queries swallowed by the dead instance come back via decode"
    );

    let res = handle.shutdown();
    assert_eq!(res.metrics.total(), 200);
    assert!(res.reconstructions > 0);
    assert!(res.dropped_jobs > 0, "the killed instance must drop jobs");
}

//! Property-based tests on coordinator invariants (hand-rolled generators
//! over our own PRNG — proptest is not in the build image; each property
//! runs hundreds of randomized cases with printable seeds).

use parm::coordinator::batcher::{Batcher, PendingQuery};
use parm::coordinator::coding::GroupTracker;
use parm::coordinator::decoder;
use parm::coordinator::encoder::Encoder;
use parm::tensor::{ops, Tensor};
use parm::util::json::Json;
use parm::util::rng::Pcg64;

fn rand_tensor(rng: &mut Pcg64, n: usize) -> Tensor {
    Tensor::new(vec![n], (0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect()).unwrap()
}

/// INVARIANT: whatever order completions arrive in, every slot of every
/// group resolves exactly once, and reconstructions only happen when the
/// group is decodable (k-1 data + parity for r=1).
#[test]
fn tracker_resolves_each_slot_exactly_once_any_order() {
    for seed in 0..200 {
        let mut rng = Pcg64::new(seed);
        let k = 2 + (seed as usize % 3); // k in 2..=4
        let mut tr = GroupTracker::new(k, &[Encoder::sum(k)]);
        let n = 8;

        // Build groups with known outputs; parity output = exact sum.
        let mut events = Vec::new();
        for g in 0..n {
            let ids: Vec<Vec<u64>> = (0..k).map(|s| vec![(g * k + s) as u64]).collect();
            tr.register(g as u64, ids);
            let outs: Vec<Tensor> = (0..k).map(|_| rand_tensor(&mut rng, 6)).collect();
            let mut parity = Tensor::zeros(vec![6]);
            for o in &outs {
                ops::add_assign(&mut parity, o).unwrap();
            }
            // Drop one random data completion per group (the straggler).
            let straggler = rng.below(k as u64) as usize;
            for (s, o) in outs.into_iter().enumerate() {
                if s != straggler {
                    events.push((g as u64, Some(s), o));
                }
            }
            events.push((g as u64, None, parity));
        }
        rng.shuffle(&mut events);

        let mut resolved = std::collections::HashMap::new();
        for (g, slot, t) in events {
            let res = match slot {
                Some(s) => tr.on_data(g, s, t),
                None => tr.on_parity(g, 0, t),
            };
            for sr in res.resolved {
                for id in sr.query_ids {
                    *resolved.entry(id).or_insert(0) += 1;
                }
            }
        }
        assert_eq!(resolved.len(), n * k, "seed {seed}: every query resolves");
        assert!(
            resolved.values().all(|&c| c == 1),
            "seed {seed}: no double resolution"
        );
        assert_eq!(tr.completed_groups, n as u64, "seed {seed}");
        assert_eq!(tr.reconstructions, n as u64, "seed {seed}: one straggler per group");
        assert_eq!(tr.open_groups(), 0, "seed {seed}: no leaked groups");
    }
}

/// INVARIANT (variable per-group r): in a tracker provisioned for r_max
/// parities, groups registered with any `r <= r_max` reconstruct any
/// `<= r` losses once their parities arrive; groups with `> r` losses
/// never decode (their queries are left to the session's SLO default)
/// and nothing panics — including parity completions beyond the group's
/// own r. Completion order is irrelevant.
#[test]
fn tracker_variable_r_recovers_up_to_r_losses_never_panics() {
    enum Ev {
        Data { slot: usize, out: Tensor },
        Parity { r_index: usize, out: Tensor },
    }
    for seed in 0..200 {
        let mut rng = Pcg64::new(7000 + seed);
        let k = 2 + (seed as usize % 3); // k in 2..=4
        let encoders: Vec<Encoder> = (0..k).map(|ri| Encoder::sum_r(k, ri)).collect();
        let weights: Vec<Vec<f32>> = (0..k)
            .map(|ri| (0..k).map(|i| ((i + 1) as f32).powi(ri as i32)).collect())
            .collect();
        let mut tr = GroupTracker::new(k, &encoders);
        let n_groups = 6;

        let mut events: Vec<(u64, Ev)> = Vec::new();
        let mut expect_recovered: Vec<(u64, Vec<Tensor>)> = Vec::new();
        let mut expect_stuck: Vec<(u64, Vec<usize>)> = Vec::new();
        for g in 0..n_groups as u64 {
            let r = 1 + (rng.below(k as u64) as usize); // r in 1..=k
            let ids: Vec<Vec<u64>> = (0..k).map(|s| vec![g * k as u64 + s as u64]).collect();
            tr.register_with_r(g, ids, r);
            assert_eq!(tr.group_r(g), Some(r));
            let outs: Vec<Tensor> = (0..k).map(|_| rand_tensor(&mut rng, 5)).collect();
            let losses = rng.below(k as u64 + 1) as usize; // 0..=k slots lost
            let lost = rng.choose_distinct(k, losses);
            for (s, o) in outs.iter().enumerate() {
                if !lost.contains(&s) {
                    events.push((g, Ev::Data { slot: s, out: o.clone() }));
                }
            }
            // Only the group's own r parities were dispatched...
            for (ri, ws) in weights.iter().take(r).enumerate() {
                let mut p = Tensor::zeros(vec![5]);
                for (o, &w) in outs.iter().zip(ws) {
                    ops::add_scaled_assign(&mut p, o, w).unwrap();
                }
                events.push((g, Ev::Parity { r_index: ri, out: p }));
            }
            // ...plus, occasionally, a stray parity beyond the group's r
            // (an adaptive scheme racing its own ramp): must be a
            // harmless no-op, never a panic.
            if r < k && rng.next_f64() < 0.5 {
                events.push((g, Ev::Parity { r_index: r, out: rand_tensor(&mut rng, 5) }));
            }
            if losses <= r {
                expect_recovered.push((g, outs));
            } else {
                expect_stuck.push((g, lost));
            }
        }
        rng.shuffle(&mut events);

        let mut resolved: std::collections::HashMap<u64, (u32, Tensor)> =
            std::collections::HashMap::new();
        for (g, ev) in events {
            let res = match ev {
                Ev::Data { slot, out } => tr.on_data(g, slot, out),
                Ev::Parity { r_index, out } => tr.on_parity(g, r_index, out),
            };
            for sr in res.resolved {
                for id in sr.query_ids {
                    resolved
                        .entry(id)
                        .and_modify(|e| e.0 += 1)
                        .or_insert((1, sr.output.clone()));
                }
            }
        }

        for (g, outs) in &expect_recovered {
            for s in 0..k {
                let qid = g * k as u64 + s as u64;
                let (count, out) = resolved
                    .get(&qid)
                    .unwrap_or_else(|| panic!("seed {seed} group {g} slot {s} must resolve"));
                assert_eq!(*count, 1, "seed {seed} group {g} slot {s}: exactly once");
                // Tolerance is looser than the r=2 decode tests: at
                // r=k=4 the §3.5 weight rows reach (i+1)^3 and the
                // 4x4 solve amplifies f32 rounding in the coded sums.
                for (a, b) in out.data().iter().zip(outs[s].data()) {
                    assert!(
                        (a - b).abs() < 0.1,
                        "seed {seed} group {g} slot {s}: {a} vs {b}"
                    );
                }
            }
            assert!(!tr.contains(*g), "seed {seed}: recovered group evicted");
        }
        for (g, lost) in &expect_stuck {
            assert!(tr.contains(*g), "seed {seed}: >r-loss group stays open");
            let unresolved = tr.unresolved_slots(*g);
            assert_eq!(
                unresolved.len(),
                lost.len(),
                "seed {seed} group {g}: exactly the lost slots stay unresolved"
            );
            for s in &unresolved {
                assert!(lost.contains(s), "seed {seed}: unresolved slot {s} was lost");
            }
            tr.abandon(*g);
        }
        assert_eq!(tr.open_groups(), 0, "seed {seed}: no leaked groups");
    }
}

/// INVARIANT: reconstruction through the real decoder equals the dropped
/// output exactly when the parity output is the exact coded sum — for any
/// k, any weights, any missing slot.
#[test]
fn decode_r1_exact_for_exact_parities() {
    for seed in 0..300 {
        let mut rng = Pcg64::new(1000 + seed);
        let k = 2 + (seed as usize % 4);
        let dim = 1 + (rng.below(40) as usize);
        let weights: Vec<f32> = (0..k).map(|_| 0.5 + rng.next_f32() * 2.0).collect();
        let outs: Vec<Tensor> = (0..k).map(|_| rand_tensor(&mut rng, dim)).collect();
        let mut parity = Tensor::zeros(vec![dim]);
        for (o, &w) in outs.iter().zip(&weights) {
            ops::add_scaled_assign(&mut parity, o, w).unwrap();
        }
        let j = rng.below(k as u64) as usize;
        let data: Vec<Option<Tensor>> = outs
            .iter()
            .enumerate()
            .map(|(i, o)| if i == j { None } else { Some(o.clone()) })
            .collect();
        let rec = decoder::decode_r1(&weights, &parity, &data, j).unwrap();
        for (r, e) in rec.data().iter().zip(outs[j].data()) {
            assert!(
                (r - e).abs() < 1e-3,
                "seed {seed} k={k} j={j}: {r} vs {e}"
            );
        }
    }
}

/// INVARIANT: general decode (r >= 2) recovers any u <= r missing slots.
#[test]
fn decode_general_recovers_any_missing_subset() {
    for seed in 0..150 {
        let mut rng = Pcg64::new(2000 + seed);
        let k = 2 + (seed as usize % 3);
        let r = 2;
        let dim = 5;
        let weights: Vec<Vec<f32>> = (0..r)
            .map(|ri| (0..k).map(|i| ((i + 1) as f32).powi(ri as i32)).collect())
            .collect();
        let outs: Vec<Tensor> = (0..k).map(|_| rand_tensor(&mut rng, dim)).collect();
        let parities: Vec<Option<Tensor>> = weights
            .iter()
            .map(|ws| {
                let mut p = Tensor::zeros(vec![dim]);
                for (o, &w) in outs.iter().zip(ws) {
                    ops::add_scaled_assign(&mut p, o, w).unwrap();
                }
                Some(p)
            })
            .collect();
        // Choose up to r missing slots.
        let miss = rng.choose_distinct(k, 1 + (seed as usize % 2).min(k - 1));
        let data: Vec<Option<Tensor>> = outs
            .iter()
            .enumerate()
            .map(|(i, o)| if miss.contains(&i) { None } else { Some(o.clone()) })
            .collect();
        let recs = decoder::decode_general(&weights, &data, &parities).unwrap();
        assert_eq!(recs.len(), miss.len(), "seed {seed}");
        for (slot, rec) in recs {
            for (a, b) in rec.data().iter().zip(outs[slot].data()) {
                assert!((a - b).abs() < 1e-2, "seed {seed} slot {slot}: {a} vs {b}");
            }
        }
    }
}

/// INVARIANT: with exactly one output missing, the general (r >= 1)
/// Gaussian-elimination decoder and the r = 1 subtraction fast path agree
/// exactly, for any k, any invertible weights, any missing slot, whichever
/// parity is available.
#[test]
fn decode_general_single_missing_agrees_with_fast_path() {
    for seed in 0..200 {
        let mut rng = Pcg64::new(6000 + seed);
        let k = 2 + (seed as usize % 3);
        let r = 1 + (seed as usize % 2);
        let dim = 1 + (rng.below(20) as usize);
        let weights: Vec<Vec<f32>> = (0..r)
            .map(|ri| (0..k).map(|i| ((i + 1) as f32).powi(ri as i32)).collect())
            .collect();
        let outs: Vec<Tensor> = (0..k).map(|_| rand_tensor(&mut rng, dim)).collect();
        let parities: Vec<Option<Tensor>> = weights
            .iter()
            .enumerate()
            .map(|(_ri, ws)| {
                // Randomly withhold parities when r = 2 (decode must use
                // whichever is available).
                if r == 2 && rng.next_f64() < 0.5 {
                    return None;
                }
                let mut p = Tensor::zeros(vec![dim]);
                for (o, &w) in outs.iter().zip(ws) {
                    ops::add_scaled_assign(&mut p, o, w).unwrap();
                }
                Some(p)
            })
            .collect();
        if parities.iter().all(Option::is_none) {
            continue;
        }
        let j = rng.below(k as u64) as usize;
        let data: Vec<Option<Tensor>> = outs
            .iter()
            .enumerate()
            .map(|(i, o)| if i == j { None } else { Some(o.clone()) })
            .collect();
        let general = decoder::decode_general(&weights, &data, &parities).unwrap();
        let pj = (0..parities.len()).find(|&x| parities[x].is_some()).unwrap();
        let fast =
            decoder::decode_r1(&weights[pj], parities[pj].as_ref().unwrap(), &data, j).unwrap();
        assert_eq!(general, vec![(j, fast)], "seed {seed} k={k} r={r} j={j}");
    }
}

/// INVARIANT (cross-shard decode): for random (k, r, shard-kill sets) a
/// fleet coding state whose groups stripe over k distinct shards
/// reconstructs any <= r unavailable slots once its parities arrive —
/// with each decoded slot routed to exactly the shard that owned it —
/// while > r losses never decode and never panic (stray parities beyond
/// the group's r included).
#[test]
fn cross_shard_decode_recovers_up_to_r_losses_for_random_kill_sets() {
    use parm::coordinator::cross_shard::{CrossShardConfig, CrossShardState};
    use std::time::{Duration, Instant};

    for seed in 0..120u64 {
        let mut rng = Pcg64::new(9000 + seed);
        let k = 2 + (seed as usize % 3); // k in 2..=4
        let r = 1 + (rng.below(k as u64) as usize); // r in 1..=k
        let shards = k + rng.below(3) as usize; // k..=k+2 fault domains
        // r_min == r_max pins the per-group redundancy for the trial.
        let st = CrossShardState::new(CrossShardConfig::new(
            k,
            r,
            r,
            shards,
            Duration::from_secs(5), // long horizon: no sweep interference
        ));
        let now = Instant::now();
        let dim = 4;

        // One group striped over k random distinct shards.
        let group_shards = rng.choose_distinct(shards, k);
        let mut placed = Vec::new(); // (group, slot, shard, qid)
        for (i, &shard) in group_shards.iter().enumerate() {
            let qid = 100 + i as u64;
            let (g, slot) = st.offer(shard, vec![qid], rand_tensor(&mut rng, dim), now);
            assert_eq!(g, 0, "seed {seed}: one group only");
            placed.push((g, slot, shard, qid));
        }
        assert_eq!(st.group_r(0), Some(r), "seed {seed}: pinned r");

        // Kill set: `losses` of the group's shards never answer.
        let losses = rng.below(k as u64 + 1) as usize; // 0..=k
        let killed: Vec<usize> = rng.choose_distinct(k, losses);
        for (i, &(g, slot, shard, _)) in placed.iter().enumerate() {
            if !killed.contains(&i) {
                st.on_data(shard, g, slot, 0, rand_tensor(&mut rng, dim), now);
            }
        }
        // All r parities arrive, plus a stray one beyond the group's r —
        // which must be a harmless no-op, never a panic.
        for ri in 0..r {
            st.on_parity(0, ri, rand_tensor(&mut rng, dim), now);
        }
        st.on_parity(0, r, rand_tensor(&mut rng, dim), now);

        if losses <= r {
            assert!(!st.contains(0), "seed {seed}: recoverable group fully resolved");
            for (i, &(_, _, shard, qid)) in placed.iter().enumerate() {
                let owed = st.drain_decoded(shard, now);
                if killed.contains(&i) {
                    assert_eq!(
                        owed.len(),
                        1,
                        "seed {seed}: killed shard {shard} owed its decoded slot"
                    );
                    assert_eq!(owed[0].0, vec![qid], "seed {seed}: routed to the owner");
                } else {
                    assert!(owed.is_empty(), "seed {seed}: native slots owe nothing");
                }
            }
            assert_eq!(st.reconstructions(), losses as u64, "seed {seed}");
        } else {
            assert!(st.contains(0), "seed {seed}: >r losses cannot decode");
            let unresolved = st.unresolved_slots(0);
            assert_eq!(unresolved.len(), losses, "seed {seed}: exactly the kills stuck");
            for &slot in &unresolved {
                assert!(killed.contains(&slot), "seed {seed}: stuck slot {slot} was killed");
            }
            for &(_, _, shard, _) in &placed {
                assert!(st.drain_decoded(shard, now).is_empty(), "seed {seed}");
            }
            assert_eq!(st.reconstructions(), 0, "seed {seed}");
        }
    }
}

/// INVARIANT: shard-tagged QueryIds never collide across legs — distinct
/// (shard, local id) pairs map to distinct fleet-wide ids, and the shard
/// always round-trips out of the tag.
#[test]
fn shard_tagged_query_ids_never_collide_across_legs() {
    use parm::coordinator::shards::{shard_of, tag_id, MAX_SHARDS};

    let mut rng = Pcg64::new(0x71D5);
    let mut seen = std::collections::HashMap::new();
    for _ in 0..20_000 {
        let shard = rng.below(MAX_SHARDS as u64 + 1) as usize;
        let fid = rng.below(1u64 << 56);
        let tagged = tag_id(shard, fid);
        assert_eq!(shard_of(tagged), shard, "shard survives tagging");
        assert_eq!(tagged & ((1u64 << 56) - 1), fid, "local id survives tagging");
        if let Some(prev) = seen.insert(tagged, (shard, fid)) {
            assert_eq!(prev, (shard, fid), "distinct legs must never share an id");
        }
    }
    // Exhaustive on the boundary: every shard with the same local id.
    let ids: std::collections::HashSet<u64> =
        (0..=MAX_SHARDS).map(|s| tag_id(s, 12_345)).collect();
    assert_eq!(ids.len(), MAX_SHARDS + 1);
}

/// INVARIANT: a live serving session conserves queries — across schemes
/// and seeds, submit/poll/drain returns every submitted id exactly once.
/// (Skips when no executables are loadable, e.g. `pjrt` without
/// artifacts.)
#[test]
fn session_conserves_queries_across_seeds() {
    use parm::coordinator::service::{Mode, ServiceConfig};
    use parm::coordinator::session::ServiceBuilder;
    use parm::experiments::latency;
    use parm::workload::QuerySource;

    let Ok(m) = parm::artifacts::Manifest::load_default() else { return };
    let ds = m.dataset(latency::LATENCY_DATASET).unwrap().clone();
    let src = QuerySource::from_dataset(&m, &ds).unwrap();
    let Ok(models) = latency::load_models(&m, 1, 2, 1, false) else {
        eprintln!("SKIP session_conserves_queries_across_seeds: no executables");
        return;
    };
    for seed in 0..3u64 {
        for mode in [
            Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
            Mode::Replication { copies: 2 },
        ] {
            let mut cfg =
                ServiceConfig::defaults(mode, &parm::cluster::hardware::GPU);
            cfg.m = 2;
            cfg.shuffles = 0;
            cfg.seed = 0x5E55 + seed;
            let mut handle =
                ServiceBuilder::new(cfg).build(&models, &src.queries[0]).unwrap();
            let mut rng = Pcg64::new(seed);
            let n = 40 + rng.below(40);
            let mut ids = Vec::new();
            let mut resolved = Vec::new();
            for i in 0..n {
                ids.push(handle.submit(src.queries[(i as usize) % src.len()].clone()));
                if rng.next_f64() < 0.3 {
                    resolved.extend(handle.poll());
                }
            }
            resolved.extend(handle.drain());
            let mut got: Vec<u64> = resolved.iter().map(|r| r.id).collect();
            got.sort_unstable();
            assert_eq!(got, ids, "seed {seed}: each id exactly once");
            let res = handle.shutdown();
            assert_eq!(res.metrics.total(), n);
        }
    }
}

/// INVARIANT: the batcher neither drops nor duplicates queries, and every
/// sealed batch is at most batch_size.
#[test]
fn batcher_conserves_queries() {
    for seed in 0..100 {
        let mut rng = Pcg64::new(3000 + seed);
        let bs = 1 + (rng.below(5) as usize);
        let mut b = Batcher::new(bs, std::time::Duration::from_millis(1));
        let n = 50 + rng.below(100);
        let mut seen = Vec::new();
        for id in 0..n {
            let sealed = b.offer(PendingQuery {
                id,
                input: Tensor::filled(vec![2], id as f32),
                arrived: std::time::Instant::now(),
            });
            if let Some(s) = sealed {
                assert!(s.query_ids.len() <= bs);
                seen.extend(s.query_ids);
            }
        }
        if let Some(s) = b.flush_all() {
            seen.extend(s.query_ids);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "seed {seed} bs={bs}");
    }
}

/// INVARIANT: sum-encode then per-slot subtract-decode round-trips the
/// encoder math itself (no model in the loop) for batched tensors too.
#[test]
fn encoder_batch_consistent_with_per_sample() {
    for seed in 0..60 {
        let mut rng = Pcg64::new(4000 + seed);
        let k = 2 + (seed as usize % 3);
        let bsz = 1 + (rng.below(4) as usize);
        let shape = vec![bsz, 6, 4, 3];
        let batches: Vec<Tensor> = (0..k)
            .map(|_| {
                let n: usize = shape.iter().product();
                Tensor::new(shape.clone(), (0..n).map(|_| rng.next_f32()).collect()).unwrap()
            })
            .collect();
        let refs: Vec<&Tensor> = batches.iter().collect();
        let enc = Encoder::sum(k);
        let whole = enc.encode_batches(&refs).unwrap();
        // Per-sample encode must agree.
        let split: Vec<Vec<Tensor>> = batches.iter().map(|b| b.unbatch()).collect();
        for i in 0..bsz {
            let stripe: Vec<&Tensor> = split.iter().map(|s| &s[i]).collect();
            let per = enc.encode(&stripe).unwrap();
            assert_eq!(per, whole.unbatch()[i], "seed {seed} sample {i}");
        }
    }
}

/// INVARIANT: JSON writer output always re-parses to the same value
/// (fuzzed over random nested documents).
#[test]
fn json_roundtrip_fuzz() {
    fn gen(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.below(12) as usize;
                Json::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(32 + rng.below(90) as u32).unwrap_or('x')
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(5) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for seed in 0..300 {
        let mut rng = Pcg64::new(5000 + seed);
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}: {text}");
    }
}

/// INVARIANT (minimal remap): adding one shard to an N-shard consistent-hash
/// ring moves at most ~1/(N+1) of a keyspace sample (we allow 2x the ideal
/// fraction for vnode placement variance), every moved key lands on the new
/// shard, and removing that shard restores the original routing exactly.
#[test]
fn ring_scale_out_remaps_minimally_and_scale_in_restores_exactly() {
    use parm::coordinator::shards::ShardRouter;

    const KEYS: u64 = 4000;
    for seed in 0..60u64 {
        let mut rng = Pcg64::new(6000 + seed);
        let n = 2 + (seed as usize % 6); // fleets of 2..=7 shards
        let mut router = ShardRouter::new(n, 64);
        let keys: Vec<u64> = (0..KEYS).map(|_| rng.next_u64()).collect();

        let before: Vec<usize> = keys
            .iter()
            .map(|&c| router.route(c).expect("all shards live"))
            .collect();
        let added = router.add_shard();
        assert_eq!(added, n, "seed {seed}: append-only indices");

        let mut moved = 0u64;
        for (&c, &old) in keys.iter().zip(&before) {
            let now = router.route(c).expect("all shards live");
            if now != old {
                assert_eq!(
                    now, added,
                    "seed {seed}: client {c:#x} moved {old}->{now}, but a grown \
                     ring may only hand keys to the new shard"
                );
                moved += 1;
            }
        }
        let frac = moved as f64 / KEYS as f64;
        let ideal = 1.0 / (n + 1) as f64;
        assert!(
            frac <= 2.0 * ideal,
            "seed {seed}: n={n} moved {frac:.4} of keys, > 2x the ideal {ideal:.4}"
        );
        // The new shard takes real load (vnodes make starvation astronomically
        // unlikely at 4000 keys).
        assert!(moved > 0, "seed {seed}: scale-out attracted no keys");

        // Scale back in: the ring must route exactly as it did before.
        router.remove_shard(added).expect("remove the shard we just added");
        for (&c, &old) in keys.iter().zip(&before) {
            assert_eq!(
                router.route(c),
                Some(old),
                "seed {seed}: removing shard {added} must restore the original route"
            );
        }
    }
}

/// INVARIANT (fleet window merge): for any set of per-shard snapshots —
/// including zero-resolved shards and snapshots poisoned with NaN or
/// infinite quantiles/rates — `WindowSnapshot::merge_all` yields all-finite
/// fields, exact counts, count-exact rates, and quantiles inside the hull
/// of the finite weighted inputs. An empty fleet merges to zero.
#[test]
fn window_merge_all_is_finite_exact_and_bounded() {
    use parm::coordinator::metrics::WindowSnapshot;
    use std::time::Duration;

    assert_eq!(WindowSnapshot::merge_all(&[]).resolved, 0);
    assert_eq!(WindowSnapshot::merge_all(&[]).p99_ms, 0.0);

    for seed in 0..200u64 {
        let mut rng = Pcg64::new(10_000 + seed);
        let shards = 1 + rng.below(8) as usize;
        let mut snaps = Vec::new();
        let (mut resolved_sum, mut rejected_sum) = (0u64, 0u64);
        let mut recovered_sum = 0.0f64;
        // Hull of the p99s that actually carry weight (finite, resolved > 0).
        let (mut p99_lo, mut p99_hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for _ in 0..shards {
            let mut s = WindowSnapshot::zero(Duration::from_secs(10));
            // Roughly a third of the shards are idle this window.
            s.resolved = if rng.next_f64() < 0.3 { 0 } else { rng.below(500) };
            s.rejected = rng.below(100);
            s.p50_ms = rng.next_f64() * 40.0;
            s.p99_ms = s.p50_ms + rng.next_f64() * 60.0;
            s.p999_ms = s.p99_ms * 1.2;
            s.recovery_rate = rng.next_f64();
            s.default_rate = rng.next_f64() * (1.0 - s.recovery_rate);
            s.qps = s.resolved as f64 / 10.0;
            // Poison ~1 in 4 snapshots with a non-finite field, as a
            // buggy or torn external producer would.
            if rng.next_f64() < 0.25 {
                match rng.below(4) {
                    0 => s.p99_ms = f64::NAN,
                    1 => s.qps = f64::INFINITY,
                    2 => s.recovery_rate = f64::NAN,
                    _ => s.p50_ms = f64::NEG_INFINITY,
                }
            }
            resolved_sum += s.resolved;
            rejected_sum += s.rejected;
            recovered_sum += if s.recovery_rate.is_finite() {
                s.recovery_rate * s.resolved as f64
            } else {
                0.0
            };
            if s.resolved > 0 {
                let p = if s.p99_ms.is_finite() { s.p99_ms } else { 0.0 };
                p99_lo = p99_lo.min(p);
                p99_hi = p99_hi.max(p);
            }
            snaps.push(s);
        }
        let m = WindowSnapshot::merge_all(&snaps);
        for (name, v) in [
            ("p50_ms", m.p50_ms),
            ("p99_ms", m.p99_ms),
            ("p999_ms", m.p999_ms),
            ("recovery_rate", m.recovery_rate),
            ("reject_rate", m.reject_rate),
            ("default_rate", m.default_rate),
            ("qps", m.qps),
        ] {
            assert!(v.is_finite(), "seed {seed}: merged {name} = {v} not finite");
        }
        assert_eq!(m.resolved, resolved_sum, "seed {seed}: counts exact");
        assert_eq!(m.rejected, rejected_sum, "seed {seed}: counts exact");
        let offered = resolved_sum + rejected_sum;
        let want_reject = if offered == 0 { 0.0 } else { rejected_sum as f64 / offered as f64 };
        assert!((m.reject_rate - want_reject).abs() < 1e-9, "seed {seed}");
        let want_recovery =
            if resolved_sum == 0 { 0.0 } else { recovered_sum / resolved_sum as f64 };
        assert!((m.recovery_rate - want_recovery).abs() < 1e-9, "seed {seed}");
        if resolved_sum == 0 {
            assert_eq!(m.p99_ms, 0.0, "seed {seed}: no weight, zero quantiles");
        } else {
            assert!(
                m.p99_ms >= p99_lo - 1e-9 && m.p99_ms <= p99_hi + 1e-9,
                "seed {seed}: p99 {} outside weighted hull [{p99_lo}, {p99_hi}]",
                m.p99_ms
            );
        }
    }
}

/// INVARIANT (reconfiguration contract): drain/restore/remove are idempotent
/// or clean errors under any operation sequence — never a panic, `remove`
/// never retires the last live shard, and `route` answers exactly when at
/// least one shard is live (drain alone may empty the ring; remove may not).
#[test]
fn ring_reconfiguration_never_panics_under_random_op_sequences() {
    use parm::coordinator::shards::{ReconfigError, ShardRouter};

    for seed in 0..120u64 {
        let mut rng = Pcg64::new(8000 + seed);
        let mut router = ShardRouter::new(1 + (seed as usize % 4), 16);
        for step in 0..200 {
            let shard = rng.below(router.shards() as u64 + 2) as usize; // often invalid
            match rng.below(4) {
                0 => {
                    let _ = router.drain_shard(shard);
                }
                1 => {
                    let _ = router.restore_shard(shard);
                }
                2 => {
                    if let Err(e) = router.remove_shard(shard) {
                        assert!(
                            matches!(
                                e,
                                ReconfigError::UnknownShard(_)
                                    | ReconfigError::RemovedShard(_)
                                    | ReconfigError::LastShard(_)
                            ),
                            "seed {seed} step {step}: unexpected {e}"
                        );
                    }
                }
                _ => {
                    if router.shards() < 12 {
                        router.add_shard();
                    }
                }
            }
            assert_eq!(
                router.route(rng.next_u64()).is_some(),
                router.live() >= 1,
                "seed {seed} step {step}: route answers iff a shard is live"
            );
            assert!(
                router.present() >= router.live(),
                "seed {seed} step {step}: drained shards are still present"
            );
            // Idempotency spot-check: a transition drains exactly once —
            // the retry is Ok(false), and restore undoes it; a no-op drain
            // leaves whatever state we found.
            if let Ok(first) = router.drain_shard(shard) {
                if first {
                    assert_eq!(router.drain_shard(shard), Ok(false), "seed {seed} step {step}");
                    assert_eq!(router.restore_shard(shard), Ok(true), "seed {seed} step {step}");
                } else {
                    let _ = router.restore_shard(shard);
                }
            }
        }
        // remove_shard's LastShard guard held throughout: something routable
        // can always be recovered by restoring every drained shard.
        for s in 0..router.shards() {
            let _ = router.restore_shard(s);
        }
        assert!(router.live() >= 1, "seed {seed}: fleet is recoverable");
        assert!(router.route(rng.next_u64()).is_some(), "seed {seed}");
    }
}

/// INVARIANT (slab vs map): the slab/arena-backed [`GroupTracker`] is
/// observationally identical to a plain `HashMap` reference model of its
/// bookkeeping rule — register (variable r, shard tags), data/parity
/// arrivals in any order (stale ids, out-of-range slots, and beyond-r
/// parities included), decode-when-missing <= parities-available, and
/// stale-group abandonment. Compared per step: the resolution stream
/// (slot, reconstructed flag, query ids, tag), the open-group id set,
/// per-group unresolved slots / r / tags, and both cumulative counters.
#[test]
fn slab_tracker_matches_hashmap_reference_under_group_churn() {
    struct RefGroup {
        query_ids: Vec<Vec<u64>>,
        tags: Vec<usize>,
        resolved: Vec<bool>,
        parity_have: Vec<bool>,
    }
    #[derive(Default)]
    struct RefModel {
        groups: std::collections::HashMap<u64, RefGroup>,
        completed: u64,
        reconstructions: u64,
    }
    // (slot, reconstructed, query_ids, tag) — the observable payload of a
    // SlotResolution minus the tensor (values are decode math, pinned by
    // the decoder properties above; this property pins the bookkeeping).
    type Obs = (usize, bool, Vec<u64>, usize);
    impl RefModel {
        fn settle(&mut self, g: u64, out: &mut Vec<Obs>) {
            let grp = self.groups.get_mut(&g).unwrap();
            let missing: Vec<usize> =
                (0..grp.resolved.len()).filter(|&i| !grp.resolved[i]).collect();
            let avail = grp.parity_have.iter().filter(|&&p| p).count();
            if !missing.is_empty() && missing.len() <= avail {
                for s in missing {
                    grp.resolved[s] = true;
                    self.reconstructions += 1;
                    out.push((s, true, grp.query_ids[s].clone(), grp.tags[s]));
                }
            }
            if grp.resolved.iter().all(|&r| r) {
                self.groups.remove(&g);
                self.completed += 1;
            }
        }
        fn on_data(&mut self, g: u64, slot: usize) -> Vec<Obs> {
            let mut out = Vec::new();
            let Some(grp) = self.groups.get_mut(&g) else { return out };
            if slot >= grp.resolved.len() {
                return out;
            }
            if !grp.resolved[slot] {
                grp.resolved[slot] = true;
                out.push((slot, false, grp.query_ids[slot].clone(), grp.tags[slot]));
            }
            self.settle(g, &mut out);
            out
        }
        fn on_parity(&mut self, g: u64, ri: usize) -> Vec<Obs> {
            let mut out = Vec::new();
            let Some(grp) = self.groups.get_mut(&g) else { return out };
            if ri >= grp.parity_have.len() {
                return out;
            }
            grp.parity_have[ri] = true;
            self.settle(g, &mut out);
            out
        }
    }

    for seed in 0..150u64 {
        let mut rng = Pcg64::new(12_000 + seed);
        let k = 2 + (seed as usize % 3); // k in 2..=4
        let r_max = 1 + (rng.below(k as u64) as usize);
        let encoders: Vec<Encoder> = (0..r_max).map(|ri| Encoder::sum_r(k, ri)).collect();
        let mut tr = GroupTracker::new(k, &encoders);
        let mut reference = RefModel::default();
        let mut next_group = 0u64;

        for step in 0..400 {
            match rng.below(10) {
                // Register a fresh group (variable r, random shard tags).
                0..=2 => {
                    let g = next_group;
                    next_group += 1;
                    let r = 1 + (rng.below(r_max as u64) as usize);
                    let ids: Vec<Vec<u64>> =
                        (0..k).map(|s| vec![g * k as u64 + s as u64]).collect();
                    let tags: Vec<usize> =
                        (0..k).map(|_| rng.below(8) as usize).collect();
                    tr.register_tagged(g, ids.clone(), r, tags.clone());
                    reference.groups.insert(
                        g,
                        RefGroup {
                            query_ids: ids,
                            tags,
                            resolved: vec![false; k],
                            parity_have: vec![false; r],
                        },
                    );
                }
                // Abandon a random known id (live or stale).
                3 => {
                    if next_group > 0 {
                        let g = rng.below(next_group);
                        tr.abandon(g);
                        reference.groups.remove(&g);
                    }
                }
                // Data completion: random (possibly stale/unknown) group,
                // random slot including one past the end.
                4..=6 => {
                    if next_group == 0 {
                        continue;
                    }
                    let g = rng.below(next_group + 1);
                    let slot = rng.below(k as u64 + 1) as usize;
                    let got: Vec<Obs> = tr
                        .on_data(g, slot, rand_tensor(&mut rng, 4))
                        .resolved
                        .into_iter()
                        .map(|s| (s.slot, s.reconstructed, s.query_ids, s.tag))
                        .collect();
                    assert_eq!(got, reference.on_data(g, slot), "seed {seed} step {step}");
                }
                // Parity completion: random r_index including beyond-r.
                _ => {
                    if next_group == 0 {
                        continue;
                    }
                    let g = rng.below(next_group + 1);
                    let ri = rng.below(r_max as u64 + 1) as usize;
                    let got: Vec<Obs> = tr
                        .on_parity(g, ri, rand_tensor(&mut rng, 4))
                        .resolved
                        .into_iter()
                        .map(|s| (s.slot, s.reconstructed, s.query_ids, s.tag))
                        .collect();
                    assert_eq!(got, reference.on_parity(g, ri), "seed {seed} step {step}");
                }
            }
            // Observable state equality after every step.
            assert_eq!(tr.open_groups(), reference.groups.len(), "seed {seed} step {step}");
            let mut live = tr.open_group_ids();
            live.sort_unstable();
            let mut want: Vec<u64> = reference.groups.keys().copied().collect();
            want.sort_unstable();
            assert_eq!(live, want, "seed {seed} step {step}: live id sets");
            for (&g, grp) in &reference.groups {
                assert!(tr.contains(g), "seed {seed} step {step}");
                assert_eq!(tr.group_r(g), Some(grp.parity_have.len()), "seed {seed}");
                let unresolved: Vec<usize> =
                    (0..k).filter(|&i| !grp.resolved[i]).collect();
                assert_eq!(tr.unresolved_slots(g), unresolved, "seed {seed} step {step}");
                for s in 0..k {
                    assert_eq!(tr.slot_tag(g, s), Some(grp.tags[s]), "seed {seed}");
                }
            }
            assert_eq!(tr.completed_groups, reference.completed, "seed {seed} step {step}");
            assert_eq!(
                tr.reconstructions, reference.reconstructions,
                "seed {seed} step {step}"
            );
        }
    }
}

/// INVARIANT (recycling safety): however many slab entries have been
/// freed and reused, traffic for a retired group id is inert — it emits
/// nothing and leaves every live group's unresolved slots, r, and query
/// routing untouched. Live ids always resolve to their *own* queries,
/// never a recycled predecessor's.
#[test]
fn recycled_group_ids_never_alias_inflight_groups() {
    for seed in 0..100u64 {
        let mut rng = Pcg64::new(13_000 + seed);
        let k = 2;
        let mut tr = GroupTracker::new(k, &[Encoder::sum(k)]);
        let mut retired: Vec<u64> = Vec::new();
        let mut live: Vec<u64> = Vec::new();
        let mut next_group = 0u64;

        for _ in 0..300 {
            match rng.below(4) {
                // Open a group (often recycling a freed slab body).
                0 | 1 => {
                    let g = next_group;
                    next_group += 1;
                    let ids: Vec<Vec<u64>> =
                        (0..k).map(|s| vec![g * 10 + s as u64]).collect();
                    tr.register(g, ids);
                    live.push(g);
                }
                // Fully resolve a live group, freeing its slab entry.
                2 if !live.is_empty() => {
                    let g = live.swap_remove(rng.below(live.len() as u64) as usize);
                    for s in 0..k {
                        tr.on_data(g, s, rand_tensor(&mut rng, 3));
                    }
                    assert!(!tr.contains(g), "seed {seed}: group {g} evicted");
                    retired.push(g);
                }
                // Replay stale traffic for a retired id.
                _ if !retired.is_empty() => {
                    let g = retired[rng.below(retired.len() as u64) as usize];
                    let before: Vec<(u64, Vec<usize>)> =
                        live.iter().map(|&l| (l, tr.unresolved_slots(l))).collect();
                    let r1 = tr.on_data(g, rng.below(k as u64) as usize, rand_tensor(&mut rng, 3));
                    let r2 = tr.on_parity(g, 0, rand_tensor(&mut rng, 3));
                    assert!(
                        r1.resolved.is_empty() && r2.resolved.is_empty(),
                        "seed {seed}: stale id {g} resolved something"
                    );
                    assert!(!tr.contains(g), "seed {seed}: stale id {g} revived");
                    for (l, unresolved) in before {
                        assert_eq!(
                            tr.unresolved_slots(l),
                            unresolved,
                            "seed {seed}: stale id {g} touched live group {l}"
                        );
                    }
                }
                _ => {}
            }
        }
        // Every live group still routes to its own query ids.
        for &g in &live {
            let res = tr.on_data(g, 0, rand_tensor(&mut rng, 3));
            if let Some(native) = res.resolved.iter().find(|s| !s.reconstructed) {
                assert_eq!(
                    native.query_ids,
                    vec![g * 10],
                    "seed {seed}: group {g} answers with a recycled predecessor's queries"
                );
            }
        }
        assert_eq!(tr.open_groups(), live.len(), "seed {seed}");
    }
}

//! Arrival-trace record / replay.
//!
//! Latency experiments default to live Poisson arrivals, but production
//! postmortems replay recorded traces. A trace is a JSON document of
//! arrival offsets (seconds) plus the query index each arrival drew —
//! replaying one reproduces a run's offered load exactly, independent of
//! the RNG, which also makes A/B comparisons across schemes noise-free.
//! Named production-shaped generators (diurnal curves, flash crowds,
//! Zipf tenants) live in [`crate::workload::scenario`]; they all produce
//! this type.
//!
//! Parsing is strict: a malformed document — missing arrays, non-numeric
//! entries, non-monotone offsets, length mismatches — is a
//! [`TraceError::Invalid`], never a silently truncated trace. A trace
//! that loads is a trace that replays.

use crate::util::json::Json;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Cumulative arrival offsets in seconds, non-decreasing.
    pub arrivals: Vec<f64>,
    /// Index into the query pool per arrival.
    pub query_idx: Vec<usize>,
    /// Client (tenant) attribution per arrival — empty for single-client
    /// traces; when present, the same length as `arrivals`. Multi-tenant
    /// scenario generators fill this so replays can fan arrivals out over
    /// per-tenant frontend clients.
    pub client: Vec<u32>,
    /// Nominal rate the trace was generated at (metadata).
    pub rate_qps: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum TraceError {
    #[error("trace io: {0}")]
    Io(#[from] std::io::Error),
    #[error("trace parse: {0}")]
    Parse(#[from] crate::util::json::ParseError),
    #[error("invalid trace: {0}")]
    Invalid(String),
}

impl Trace {
    /// Generate a Poisson trace (the paper's client behaviour).
    pub fn poisson(rng: &mut Pcg64, n: usize, rate: f64, pool_size: usize) -> Trace {
        let mut t = 0.0;
        let mut arrivals = Vec::with_capacity(n);
        let mut query_idx = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(rate);
            arrivals.push(t);
            query_idx.push(rng.below(pool_size as u64) as usize);
        }
        Trace { arrivals, query_idx, client: Vec::new(), rate_qps: rate }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Client attribution of arrival `i` (0 for single-client traces).
    pub fn client_of(&self, i: usize) -> u32 {
        self.client.get(i).copied().unwrap_or(0)
    }

    /// Number of distinct clients the trace attributes arrivals to (1
    /// for single-client traces).
    pub fn n_clients(&self) -> usize {
        self.client.iter().copied().max().map_or(1, |m| m as usize + 1)
    }

    /// Offered-load summary: mean inter-arrival gap and burstiness
    /// (CV², variance over squared mean of the gaps). A trace whose
    /// arrivals all land on the same instant has zero mean gap; its CV²
    /// is reported as 0 (perfectly regular), not NaN.
    pub fn stats(&self) -> (f64, f64) {
        if self.arrivals.len() < 2 {
            return (f64::NAN, f64::NAN);
        }
        let gaps: Vec<f64> = self.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        if mean <= 0.0 {
            // All gaps zero (or numerically so): var/mean² would be 0/0.
            return (mean, 0.0);
        }
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        (mean, var / (mean * mean))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("rate_qps", self.rate_qps)
            .set("arrivals", self.arrivals.clone())
            .set("query_idx", self.query_idx.iter().map(|&i| i as f64).collect::<Vec<_>>());
        if !self.client.is_empty() {
            j = j.set("client", self.client.iter().map(|&c| c as f64).collect::<Vec<_>>());
        }
        j
    }

    pub fn from_json_text(text: &str) -> Result<Trace, TraceError> {
        let j = Json::parse(text)?;
        let arrivals = float_array(&j, "arrivals")?;
        let query_idx: Vec<usize> = index_array(&j, "query_idx")?
            .into_iter()
            .map(|v| v as usize)
            .collect();
        if arrivals.len() != query_idx.len() {
            return Err(TraceError::Invalid(format!(
                "arrivals ({}) vs query_idx ({}) length mismatch",
                arrivals.len(),
                query_idx.len()
            )));
        }
        if arrivals.windows(2).any(|w| w[1] < w[0]) {
            return Err(TraceError::Invalid("arrivals must be non-decreasing".into()));
        }
        let client: Vec<u32> = if j.at(&["client"]).as_arr().is_some() {
            let c = index_array(&j, "client")?;
            if c.len() != arrivals.len() {
                return Err(TraceError::Invalid(format!(
                    "client ({}) vs arrivals ({}) length mismatch",
                    c.len(),
                    arrivals.len()
                )));
            }
            if let Some(&big) = c.iter().find(|&&v| v > u64::from(u32::MAX)) {
                return Err(TraceError::Invalid(format!("client id {big} out of range")));
            }
            c.into_iter().map(|v| v as u32).collect()
        } else {
            Vec::new()
        };
        Ok(Trace {
            arrivals,
            query_idx,
            client,
            rate_qps: j.at(&["rate_qps"]).as_f64().unwrap_or(f64::NAN),
        })
    }

    /// Mine a recorded serving-path journal back into a replayable
    /// trace: every `Submit` event becomes an arrival at its recorded
    /// offset (`ts_us`, recorder-epoch-relative, so the replay
    /// reproduces the run's inter-arrival pattern exactly). In sharded
    /// journals the submitting shard becomes the client attribution —
    /// replaying fans arrivals back over the same number of frontends.
    /// The journal does not record which pool tensor each query drew,
    /// so `query_idx` is sequential (replay paths index the pool
    /// modulo its size).
    ///
    /// A journal with no `Submit` events is [`TraceError::Invalid`]:
    /// there is no workload to replay.
    pub fn from_journal(
        events: &[crate::coordinator::journal::TimedEvent],
    ) -> Result<Trace, TraceError> {
        use crate::coordinator::journal::Event;
        let mut arrivals = Vec::new();
        let mut shards = Vec::new();
        for te in events {
            if let Event::Submit { .. } = te.event {
                arrivals.push(te.ts_us as f64 / 1e6);
                shards.push(te.shard);
            }
        }
        if arrivals.is_empty() {
            return Err(TraceError::Invalid("journal has no Submit events".into()));
        }
        // Journal timestamps are globally non-decreasing by
        // construction (delta encoding), so arrivals are already a
        // valid trace; assert the contract anyway against future codec
        // drift.
        debug_assert!(arrivals.windows(2).all(|w| w[1] >= w[0]));
        let n = arrivals.len();
        let span = arrivals[n - 1] - arrivals[0];
        let rate = if n > 1 && span > 0.0 { (n - 1) as f64 / span } else { 0.0 };
        // Only attribute clients when the run actually fanned over
        // shards; single-session journals stay single-client.
        let multi = shards.iter().any(|&s| s != shards[0]);
        let client: Vec<u32> =
            if multi { shards.into_iter().map(|s| s as u32).collect() } else { Vec::new() };
        Ok(Trace { arrivals, query_idx: (0..n).collect(), client, rate_qps: rate })
    }

    /// Burstiness as peak-to-mean arrivals per bin over `bins` equal
    /// time slices: 1.0 for perfectly uniform load, ≫1 for a flash
    /// crowd. Degenerate traces (fewer than two arrivals, zero span,
    /// `bins == 0`) report the all-in-one-bin ratio, `len` as f64, or
    /// 1.0 as appropriate.
    pub fn burst_ratio(&self, bins: usize) -> f64 {
        if self.arrivals.len() < 2 || bins == 0 {
            return 1.0;
        }
        let lo = self.arrivals[0];
        let span = self.arrivals[self.arrivals.len() - 1] - lo;
        if span <= 0.0 {
            // Everything on one instant: one bin holds it all.
            return self.arrivals.len() as f64;
        }
        let mut counts = vec![0u64; bins];
        for &a in &self.arrivals {
            let b = (((a - lo) / span) * bins as f64) as usize;
            counts[b.min(bins - 1)] += 1;
        }
        let mean = self.arrivals.len() as f64 / bins as f64;
        let peak = counts.iter().copied().max().unwrap_or(0) as f64;
        peak / mean
    }

    pub fn save(&self, path: &str) -> Result<(), TraceError> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<Trace, TraceError> {
        Self::from_json_text(&std::fs::read_to_string(path)?)
    }
}

/// `key` as an array of finite floats — any missing array or
/// non-numeric / non-finite entry is [`TraceError::Invalid`], never a
/// silent skip.
fn float_array(j: &Json, key: &str) -> Result<Vec<f64>, TraceError> {
    let arr = j
        .at(&[key])
        .as_arr()
        .ok_or_else(|| TraceError::Invalid(format!("missing {key}")))?;
    arr.iter()
        .enumerate()
        .map(|(i, v)| match v.as_f64() {
            Some(f) if f.is_finite() => Ok(f),
            Some(f) => Err(TraceError::Invalid(format!("{key}[{i}] is not finite ({f})"))),
            None => Err(TraceError::Invalid(format!("{key}[{i}] is not a number"))),
        })
        .collect()
}

/// `key` as an array of non-negative integers (rejects fractions and
/// negatives — `as usize` would silently saturate them).
fn index_array(j: &Json, key: &str) -> Result<Vec<u64>, TraceError> {
    let floats = float_array(j, key)?;
    floats
        .into_iter()
        .enumerate()
        .map(|(i, f)| {
            if f < 0.0 || f.fract() != 0.0 || f >= 9e15 {
                Err(TraceError::Invalid(format!(
                    "{key}[{i}] is not a non-negative integer ({f})"
                )))
            } else {
                Ok(f as u64)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_shape() {
        let mut rng = Pcg64::new(1);
        let t = Trace::poisson(&mut rng, 5000, 100.0, 32);
        assert_eq!(t.len(), 5000);
        assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]));
        assert!(t.query_idx.iter().all(|&i| i < 32));
        let (mean, cv2) = t.stats();
        assert!((mean - 0.01).abs() < 0.001, "{mean}");
        // Poisson gaps are exponential: CV² ≈ 1.
        assert!((cv2 - 1.0).abs() < 0.15, "{cv2}");
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Pcg64::new(2);
        let t = Trace::poisson(&mut rng, 50, 10.0, 4);
        let back = Trace::from_json_text(&t.to_json().to_string()).unwrap();
        assert_eq!(back.query_idx, t.query_idx);
        assert_eq!(back.arrivals.len(), t.arrivals.len());
        for (a, b) in back.arrivals.iter().zip(&t.arrivals) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn seeded_roundtrip_is_exact_for_100_random_traces() {
        // Json prints floats with Rust's shortest-round-trip Display, so
        // serialize → parse must reproduce every trace *exactly* (full
        // PartialEq, not approximate) — including the optional client
        // column.
        let mut rng = Pcg64::new(0xC0FFEE);
        for trial in 0..100 {
            let n = 1 + rng.below(200) as usize;
            let rate = 0.5 + rng.below(10_000) as f64 / 10.0;
            let pool = 1 + rng.below(64) as usize;
            let mut t = Trace::poisson(&mut rng, n, rate, pool);
            if trial % 2 == 1 {
                let tenants = 1 + rng.below(8) as u32;
                t.client = (0..n).map(|_| rng.below(u64::from(tenants)) as u32).collect();
            }
            let text = t.to_json().to_string();
            let back = Trace::from_json_text(&text)
                .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            assert_eq!(back, t, "trial {trial} round-trip not exact");
        }
    }

    #[test]
    fn rejects_malformed() {
        // Missing arrays.
        assert!(Trace::from_json_text("{}").is_err());
        assert!(Trace::from_json_text(r#"{"arrivals": [0.5]}"#).is_err());
        // Non-monotone offsets.
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1, 0], "query_idx": [0, 0]}"#
        )
        .is_err());
        // Length mismatches.
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1], "query_idx": [0, 1]}"#
        )
        .is_err());
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1, 2], "query_idx": [0, 1], "client": [0]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_non_numeric_entries_instead_of_truncating() {
        // filter_map-style parsing would silently drop the string and
        // yield a 1-entry trace; strict parsing must refuse.
        for bad in [
            r#"{"arrivals": [0.5, "x"], "query_idx": [0, 1]}"#,
            r#"{"arrivals": [0.5, null], "query_idx": [0, 1]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, "x"]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, -1]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, 1.5]}"#,
            r#"{"arrivals": [0.5, NaN], "query_idx": [0, 1]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, 1], "client": [0, true]}"#,
        ] {
            match Trace::from_json_text(bad) {
                Err(TraceError::Invalid(_)) => {}
                other => panic!("{bad} should be Invalid, got {other:?}"),
            }
        }
    }

    #[test]
    fn stats_zero_gap_trace_is_finite() {
        // Every arrival at the same instant: mean gap 0. CV² used to be
        // 0/0 = NaN; it must come back 0 (a perfectly regular burst).
        let t = Trace {
            arrivals: vec![2.0; 8],
            query_idx: vec![0; 8],
            client: Vec::new(),
            rate_qps: 1.0,
        };
        let (mean, cv2) = t.stats();
        assert_eq!(mean, 0.0);
        assert_eq!(cv2, 0.0);
        assert!(cv2.is_finite());
    }

    #[test]
    fn from_journal_mines_submits_into_a_replayable_trace() {
        use crate::coordinator::journal::{Event, TimedEvent};
        let te = |ts_us, shard, event| TimedEvent { ts_us, shard, event };
        let events = vec![
            te(0, 0, Event::Start { seed: 1, mode: "sharded".into(), shards: 2 }),
            te(10_000, 0, Event::Submit { qid: 0 }),
            te(20_000, 1, Event::Submit { qid: 0 }),
            te(25_000, 1, Event::Complete { qid: 0, outcome: 0, latency_us: 5000 }),
            te(30_000, 0, Event::Submit { qid: 1 }),
        ];
        let t = Trace::from_journal(&events).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.arrivals, vec![0.01, 0.02, 0.03]);
        assert_eq!(t.client, vec![0, 1, 0]);
        assert_eq!(t.n_clients(), 2);
        assert_eq!(t.query_idx, vec![0, 1, 2]);
        // 2 gaps over 20ms = 100 qps.
        assert!((t.rate_qps - 100.0).abs() < 1e-9, "{}", t.rate_qps);
        // Mined traces satisfy the strict save/load contract.
        let back = Trace::from_json_text(&t.to_json().to_string()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn from_journal_single_session_has_no_client_column() {
        use crate::coordinator::journal::{Event, TimedEvent};
        let events: Vec<TimedEvent> = (0..5)
            .map(|i| TimedEvent {
                ts_us: 1000 * (i + 1),
                shard: 0,
                event: Event::Submit { qid: i },
            })
            .collect();
        let t = Trace::from_journal(&events).unwrap();
        assert!(t.client.is_empty());
        assert_eq!(t.n_clients(), 1);
    }

    #[test]
    fn from_journal_rejects_empty() {
        use crate::coordinator::journal::{Event, TimedEvent};
        let events = vec![TimedEvent {
            ts_us: 0,
            shard: 0,
            event: Event::Start { seed: 1, mode: "parm".into(), shards: 1 },
        }];
        assert!(matches!(Trace::from_journal(&events), Err(TraceError::Invalid(_))));
        assert!(matches!(Trace::from_journal(&[]), Err(TraceError::Invalid(_))));
    }

    #[test]
    fn burst_ratio_separates_uniform_from_flash_crowd() {
        let uniform = Trace {
            arrivals: (0..1000).map(|i| i as f64 / 100.0).collect(),
            query_idx: vec![0; 1000],
            client: Vec::new(),
            rate_qps: 100.0,
        };
        let ratio = uniform.burst_ratio(10);
        assert!(ratio < 1.2, "uniform ratio {ratio}");

        // 90% of arrivals crammed into the last 10% of the window.
        let mut arrivals: Vec<f64> = (0..100).map(|i| i as f64 / 10.0).collect();
        arrivals.extend((0..900).map(|i| 9.0 + i as f64 / 900.0));
        arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let crowd = Trace {
            arrivals,
            query_idx: vec![0; 1000],
            client: Vec::new(),
            rate_qps: 100.0,
        };
        let ratio = crowd.burst_ratio(10);
        assert!(ratio > 5.0, "flash-crowd ratio {ratio}");

        // Degenerate shapes stay finite.
        assert_eq!(uniform.burst_ratio(0), 1.0);
        let point = Trace {
            arrivals: vec![1.0; 4],
            query_idx: vec![0; 4],
            client: Vec::new(),
            rate_qps: 1.0,
        };
        assert_eq!(point.burst_ratio(10), 4.0);
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Pcg64::new(3);
        let t = Trace::poisson(&mut rng, 10, 5.0, 2);
        let path = std::env::temp_dir().join(format!("parm-trace-{}.json", std::process::id()));
        t.save(path.to_str().unwrap()).unwrap();
        let back = Trace::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.query_idx, t.query_idx);
        std::fs::remove_file(path).unwrap();
    }
}

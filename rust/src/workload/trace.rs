//! Arrival-trace record / replay.
//!
//! Latency experiments default to live Poisson arrivals, but production
//! postmortems replay recorded traces. A trace is a JSON document of
//! arrival offsets (seconds) plus the query index each arrival drew —
//! replaying one reproduces a run's offered load exactly, independent of
//! the RNG, which also makes A/B comparisons across schemes noise-free.

use crate::util::json::Json;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Cumulative arrival offsets in seconds, non-decreasing.
    pub arrivals: Vec<f64>,
    /// Index into the query pool per arrival.
    pub query_idx: Vec<usize>,
    /// Nominal rate the trace was generated at (metadata).
    pub rate_qps: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum TraceError {
    #[error("trace io: {0}")]
    Io(#[from] std::io::Error),
    #[error("trace parse: {0}")]
    Parse(#[from] crate::util::json::ParseError),
    #[error("invalid trace: {0}")]
    Invalid(String),
}

impl Trace {
    /// Generate a Poisson trace (the paper's client behaviour).
    pub fn poisson(rng: &mut Pcg64, n: usize, rate: f64, pool_size: usize) -> Trace {
        let mut t = 0.0;
        let mut arrivals = Vec::with_capacity(n);
        let mut query_idx = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(rate);
            arrivals.push(t);
            query_idx.push(rng.below(pool_size as u64) as usize);
        }
        Trace { arrivals, query_idx, rate_qps: rate }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Offered-load summary: mean inter-arrival gap and burstiness (CV²).
    pub fn stats(&self) -> (f64, f64) {
        if self.arrivals.len() < 2 {
            return (f64::NAN, f64::NAN);
        }
        let gaps: Vec<f64> = self.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        (mean, var / (mean * mean))
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("rate_qps", self.rate_qps)
            .set("arrivals", self.arrivals.clone())
            .set("query_idx", self.query_idx.iter().map(|&i| i as f64).collect::<Vec<_>>())
    }

    pub fn from_json_text(text: &str) -> Result<Trace, TraceError> {
        let j = Json::parse(text)?;
        let arrivals: Vec<f64> = j
            .at(&["arrivals"])
            .as_arr()
            .ok_or_else(|| TraceError::Invalid("missing arrivals".into()))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let query_idx: Vec<usize> = j
            .at(&["query_idx"])
            .as_arr()
            .ok_or_else(|| TraceError::Invalid("missing query_idx".into()))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        if arrivals.len() != query_idx.len() {
            return Err(TraceError::Invalid(format!(
                "arrivals ({}) vs query_idx ({}) length mismatch",
                arrivals.len(),
                query_idx.len()
            )));
        }
        if arrivals.windows(2).any(|w| w[1] < w[0]) {
            return Err(TraceError::Invalid("arrivals must be non-decreasing".into()));
        }
        Ok(Trace {
            arrivals,
            query_idx,
            rate_qps: j.at(&["rate_qps"]).as_f64().unwrap_or(f64::NAN),
        })
    }

    pub fn save(&self, path: &str) -> Result<(), TraceError> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<Trace, TraceError> {
        Ok(Self::from_json_text(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_shape() {
        let mut rng = Pcg64::new(1);
        let t = Trace::poisson(&mut rng, 5000, 100.0, 32);
        assert_eq!(t.len(), 5000);
        assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]));
        assert!(t.query_idx.iter().all(|&i| i < 32));
        let (mean, cv2) = t.stats();
        assert!((mean - 0.01).abs() < 0.001, "{mean}");
        // Poisson gaps are exponential: CV² ≈ 1.
        assert!((cv2 - 1.0).abs() < 0.15, "{cv2}");
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Pcg64::new(2);
        let t = Trace::poisson(&mut rng, 50, 10.0, 4);
        let back = Trace::from_json_text(&t.to_json().to_string()).unwrap();
        assert_eq!(back.query_idx, t.query_idx);
        assert_eq!(back.arrivals.len(), t.arrivals.len());
        for (a, b) in back.arrivals.iter().zip(&t.arrivals) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(Trace::from_json_text("{}").is_err());
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1, 0], "query_idx": [0, 0]}"#
        )
        .is_err());
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1], "query_idx": [0, 1]}"#
        )
        .is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Pcg64::new(3);
        let t = Trace::poisson(&mut rng, 10, 5.0, 2);
        let path = std::env::temp_dir().join(format!("parm-trace-{}.json", std::process::id()));
        t.save(path.to_str().unwrap()).unwrap();
        let back = Trace::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.query_idx, t.query_idx);
        std::fs::remove_file(path).unwrap();
    }
}

//! Arrival-trace record / replay.
//!
//! Latency experiments default to live Poisson arrivals, but production
//! postmortems replay recorded traces. A trace is a JSON document of
//! arrival offsets (seconds) plus the query index each arrival drew —
//! replaying one reproduces a run's offered load exactly, independent of
//! the RNG, which also makes A/B comparisons across schemes noise-free.
//! Named production-shaped generators (diurnal curves, flash crowds,
//! Zipf tenants) live in [`crate::workload::scenario`]; they all produce
//! this type.
//!
//! Parsing is strict: a malformed document — missing arrays, non-numeric
//! entries, non-monotone offsets, length mismatches — is a
//! [`TraceError::Invalid`], never a silently truncated trace. A trace
//! that loads is a trace that replays.

use crate::util::json::Json;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Cumulative arrival offsets in seconds, non-decreasing.
    pub arrivals: Vec<f64>,
    /// Index into the query pool per arrival.
    pub query_idx: Vec<usize>,
    /// Client (tenant) attribution per arrival — empty for single-client
    /// traces; when present, the same length as `arrivals`. Multi-tenant
    /// scenario generators fill this so replays can fan arrivals out over
    /// per-tenant frontend clients.
    pub client: Vec<u32>,
    /// Nominal rate the trace was generated at (metadata).
    pub rate_qps: f64,
}

#[derive(Debug, thiserror::Error)]
pub enum TraceError {
    #[error("trace io: {0}")]
    Io(#[from] std::io::Error),
    #[error("trace parse: {0}")]
    Parse(#[from] crate::util::json::ParseError),
    #[error("invalid trace: {0}")]
    Invalid(String),
}

impl Trace {
    /// Generate a Poisson trace (the paper's client behaviour).
    pub fn poisson(rng: &mut Pcg64, n: usize, rate: f64, pool_size: usize) -> Trace {
        let mut t = 0.0;
        let mut arrivals = Vec::with_capacity(n);
        let mut query_idx = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(rate);
            arrivals.push(t);
            query_idx.push(rng.below(pool_size as u64) as usize);
        }
        Trace { arrivals, query_idx, client: Vec::new(), rate_qps: rate }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Client attribution of arrival `i` (0 for single-client traces).
    pub fn client_of(&self, i: usize) -> u32 {
        self.client.get(i).copied().unwrap_or(0)
    }

    /// Number of distinct clients the trace attributes arrivals to (1
    /// for single-client traces).
    pub fn n_clients(&self) -> usize {
        self.client.iter().copied().max().map_or(1, |m| m as usize + 1)
    }

    /// Offered-load summary: mean inter-arrival gap and burstiness
    /// (CV², variance over squared mean of the gaps). A trace whose
    /// arrivals all land on the same instant has zero mean gap; its CV²
    /// is reported as 0 (perfectly regular), not NaN.
    pub fn stats(&self) -> (f64, f64) {
        if self.arrivals.len() < 2 {
            return (f64::NAN, f64::NAN);
        }
        let gaps: Vec<f64> = self.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        if mean <= 0.0 {
            // All gaps zero (or numerically so): var/mean² would be 0/0.
            return (mean, 0.0);
        }
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        (mean, var / (mean * mean))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("rate_qps", self.rate_qps)
            .set("arrivals", self.arrivals.clone())
            .set("query_idx", self.query_idx.iter().map(|&i| i as f64).collect::<Vec<_>>());
        if !self.client.is_empty() {
            j = j.set("client", self.client.iter().map(|&c| c as f64).collect::<Vec<_>>());
        }
        j
    }

    pub fn from_json_text(text: &str) -> Result<Trace, TraceError> {
        let j = Json::parse(text)?;
        let arrivals = float_array(&j, "arrivals")?;
        let query_idx: Vec<usize> = index_array(&j, "query_idx")?
            .into_iter()
            .map(|v| v as usize)
            .collect();
        if arrivals.len() != query_idx.len() {
            return Err(TraceError::Invalid(format!(
                "arrivals ({}) vs query_idx ({}) length mismatch",
                arrivals.len(),
                query_idx.len()
            )));
        }
        if arrivals.windows(2).any(|w| w[1] < w[0]) {
            return Err(TraceError::Invalid("arrivals must be non-decreasing".into()));
        }
        let client: Vec<u32> = if j.at(&["client"]).as_arr().is_some() {
            let c = index_array(&j, "client")?;
            if c.len() != arrivals.len() {
                return Err(TraceError::Invalid(format!(
                    "client ({}) vs arrivals ({}) length mismatch",
                    c.len(),
                    arrivals.len()
                )));
            }
            if let Some(&big) = c.iter().find(|&&v| v > u64::from(u32::MAX)) {
                return Err(TraceError::Invalid(format!("client id {big} out of range")));
            }
            c.into_iter().map(|v| v as u32).collect()
        } else {
            Vec::new()
        };
        Ok(Trace {
            arrivals,
            query_idx,
            client,
            rate_qps: j.at(&["rate_qps"]).as_f64().unwrap_or(f64::NAN),
        })
    }

    pub fn save(&self, path: &str) -> Result<(), TraceError> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<Trace, TraceError> {
        Self::from_json_text(&std::fs::read_to_string(path)?)
    }
}

/// `key` as an array of finite floats — any missing array or
/// non-numeric / non-finite entry is [`TraceError::Invalid`], never a
/// silent skip.
fn float_array(j: &Json, key: &str) -> Result<Vec<f64>, TraceError> {
    let arr = j
        .at(&[key])
        .as_arr()
        .ok_or_else(|| TraceError::Invalid(format!("missing {key}")))?;
    arr.iter()
        .enumerate()
        .map(|(i, v)| match v.as_f64() {
            Some(f) if f.is_finite() => Ok(f),
            Some(f) => Err(TraceError::Invalid(format!("{key}[{i}] is not finite ({f})"))),
            None => Err(TraceError::Invalid(format!("{key}[{i}] is not a number"))),
        })
        .collect()
}

/// `key` as an array of non-negative integers (rejects fractions and
/// negatives — `as usize` would silently saturate them).
fn index_array(j: &Json, key: &str) -> Result<Vec<u64>, TraceError> {
    let floats = float_array(j, key)?;
    floats
        .into_iter()
        .enumerate()
        .map(|(i, f)| {
            if f < 0.0 || f.fract() != 0.0 || f >= 9e15 {
                Err(TraceError::Invalid(format!(
                    "{key}[{i}] is not a non-negative integer ({f})"
                )))
            } else {
                Ok(f as u64)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_shape() {
        let mut rng = Pcg64::new(1);
        let t = Trace::poisson(&mut rng, 5000, 100.0, 32);
        assert_eq!(t.len(), 5000);
        assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]));
        assert!(t.query_idx.iter().all(|&i| i < 32));
        let (mean, cv2) = t.stats();
        assert!((mean - 0.01).abs() < 0.001, "{mean}");
        // Poisson gaps are exponential: CV² ≈ 1.
        assert!((cv2 - 1.0).abs() < 0.15, "{cv2}");
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Pcg64::new(2);
        let t = Trace::poisson(&mut rng, 50, 10.0, 4);
        let back = Trace::from_json_text(&t.to_json().to_string()).unwrap();
        assert_eq!(back.query_idx, t.query_idx);
        assert_eq!(back.arrivals.len(), t.arrivals.len());
        for (a, b) in back.arrivals.iter().zip(&t.arrivals) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn seeded_roundtrip_is_exact_for_100_random_traces() {
        // Json prints floats with Rust's shortest-round-trip Display, so
        // serialize → parse must reproduce every trace *exactly* (full
        // PartialEq, not approximate) — including the optional client
        // column.
        let mut rng = Pcg64::new(0xC0FFEE);
        for trial in 0..100 {
            let n = 1 + rng.below(200) as usize;
            let rate = 0.5 + rng.below(10_000) as f64 / 10.0;
            let pool = 1 + rng.below(64) as usize;
            let mut t = Trace::poisson(&mut rng, n, rate, pool);
            if trial % 2 == 1 {
                let tenants = 1 + rng.below(8) as u32;
                t.client = (0..n).map(|_| rng.below(u64::from(tenants)) as u32).collect();
            }
            let text = t.to_json().to_string();
            let back = Trace::from_json_text(&text)
                .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
            assert_eq!(back, t, "trial {trial} round-trip not exact");
        }
    }

    #[test]
    fn rejects_malformed() {
        // Missing arrays.
        assert!(Trace::from_json_text("{}").is_err());
        assert!(Trace::from_json_text(r#"{"arrivals": [0.5]}"#).is_err());
        // Non-monotone offsets.
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1, 0], "query_idx": [0, 0]}"#
        )
        .is_err());
        // Length mismatches.
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1], "query_idx": [0, 1]}"#
        )
        .is_err());
        assert!(Trace::from_json_text(
            r#"{"arrivals": [1, 2], "query_idx": [0, 1], "client": [0]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_non_numeric_entries_instead_of_truncating() {
        // filter_map-style parsing would silently drop the string and
        // yield a 1-entry trace; strict parsing must refuse.
        for bad in [
            r#"{"arrivals": [0.5, "x"], "query_idx": [0, 1]}"#,
            r#"{"arrivals": [0.5, null], "query_idx": [0, 1]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, "x"]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, -1]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, 1.5]}"#,
            r#"{"arrivals": [0.5, NaN], "query_idx": [0, 1]}"#,
            r#"{"arrivals": [0.5, 1.0], "query_idx": [0, 1], "client": [0, true]}"#,
        ] {
            match Trace::from_json_text(bad) {
                Err(TraceError::Invalid(_)) => {}
                other => panic!("{bad} should be Invalid, got {other:?}"),
            }
        }
    }

    #[test]
    fn stats_zero_gap_trace_is_finite() {
        // Every arrival at the same instant: mean gap 0. CV² used to be
        // 0/0 = NaN; it must come back 0 (a perfectly regular burst).
        let t = Trace {
            arrivals: vec![2.0; 8],
            query_idx: vec![0; 8],
            client: Vec::new(),
            rate_qps: 1.0,
        };
        let (mean, cv2) = t.stats();
        assert_eq!(mean, 0.0);
        assert_eq!(cv2, 0.0);
        assert!(cv2.is_finite());
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Pcg64::new(3);
        let t = Trace::poisson(&mut rng, 10, 5.0, 2);
        let path = std::env::temp_dir().join(format!("parm-trace-{}.json", std::process::id()));
        t.save(path.to_str().unwrap()).unwrap();
        let back = Trace::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.query_idx, t.query_idx);
        std::fs::remove_file(path).unwrap();
    }
}

//! Production-shaped scenario library: named, seeded workload
//! generators.
//!
//! The paper's clients offer steady Poisson load (§5.1); production
//! traffic does not. Each generator here produces an ordinary
//! [`Trace`], so every scenario replays through the same machinery —
//! `ServiceHandle::run_trace`, `parm serve --scenario`, and the
//! record/replay journal — against every redundancy mode, with the
//! [`FaultScript`](crate::cluster::chaos::FaultScript) chaos harness and
//! link degradation layered on top.
//!
//! The catalogue:
//!
//! | name               | shape |
//! |--------------------|-------|
//! | `poisson`          | steady Poisson at the nominal rate (baseline) |
//! | `diurnal`          | sinusoidal rate over the trace horizon (day/night curve) |
//! | `flash-crowd`      | steady load with an 8x burst over the middle fifth |
//! | `zipf`             | 8 tenants with Zipf(1.1) heavy-tailed per-client rates |
//! | `multi-tenant-burst` | 4 equal tenants; twice, a correlated pair spikes 6x |
//!
//! All generators are pure functions of `(seed, n, rate, pool)`: the
//! same arguments produce the same trace on every host, which is what
//! lets the CI scenario lane smoke-run the catalogue and diff digests.
//! Time-varying shapes are sampled by Poisson thinning — candidate
//! arrivals at the peak rate, each kept with probability
//! `rate(t)/peak` — so gaps stay exactly exponential conditional on the
//! instantaneous rate.

use crate::util::rng::Pcg64;
use crate::workload::trace::Trace;

/// A named generator in the scenario catalogue.
pub struct Scenario {
    /// Catalogue key (`parm serve --scenario NAME`).
    pub name: &'static str,
    /// One-line operator-facing description.
    pub description: &'static str,
    generate: fn(&mut Pcg64, usize, f64, usize) -> Trace,
}

impl Scenario {
    /// Generate this scenario's trace: `n` arrivals at nominal `rate`
    /// qps drawing from a pool of `pool` query tensors.
    pub fn generate(&self, seed: u64, n: usize, rate: f64, pool: usize) -> Trace {
        assert!(n > 0 && rate > 0.0 && pool > 0, "scenario needs n, rate, pool > 0");
        let mut rng = Pcg64::new(seed);
        (self.generate)(&mut rng, n, rate, pool)
    }
}

/// The scenario catalogue, in documentation order.
pub const SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "poisson",
        description: "steady Poisson arrivals at the nominal rate (the paper's client)",
        generate: gen_poisson,
    },
    Scenario {
        name: "diurnal",
        description: "sinusoidal diurnal load curve: rate swings +/-60% over the horizon",
        generate: gen_diurnal,
    },
    Scenario {
        name: "flash-crowd",
        description: "flash crowd: steady load with an 8x burst over the middle fifth",
        generate: gen_flash_crowd,
    },
    Scenario {
        name: "zipf",
        description: "8 tenants with Zipf(1.1) heavy-tailed per-client request rates",
        generate: gen_zipf,
    },
    Scenario {
        name: "multi-tenant-burst",
        description: "4 equal tenants; twice, a correlated pair spikes 6x together",
        generate: gen_multi_tenant_burst,
    },
];

/// Look up a scenario by catalogue name.
pub fn scenario(name: &str) -> Option<&'static Scenario> {
    SCENARIOS.iter().find(|s| s.name == name)
}

/// Generate `name`'s trace, or `None` for an unknown name.
pub fn generate(name: &str, seed: u64, n: usize, rate: f64, pool: usize) -> Option<Trace> {
    scenario(name).map(|s| s.generate(seed, n, rate, pool))
}

/// The catalogue's names, for CLI help and error messages.
pub fn names() -> Vec<&'static str> {
    SCENARIOS.iter().map(|s| s.name).collect()
}

// ------------------------------------------------------------ generators

fn gen_poisson(rng: &mut Pcg64, n: usize, rate: f64, pool: usize) -> Trace {
    Trace::poisson(rng, n, rate, pool)
}

/// Nonhomogeneous Poisson arrivals by thinning: candidates at `peak`,
/// kept with probability `rate_at(t)/peak`. `rate_at` must never exceed
/// `peak`.
fn thinned_arrivals(
    rng: &mut Pcg64,
    n: usize,
    peak: f64,
    mut rate_at: impl FnMut(f64) -> f64,
) -> Vec<f64> {
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        t += rng.exponential(peak);
        let r = rate_at(t);
        debug_assert!(r <= peak * (1.0 + 1e-9));
        if r > 0.0 && rng.next_f64() < r / peak {
            out.push(t);
        }
    }
    out
}

fn uniform_query_idx(rng: &mut Pcg64, n: usize, pool: usize) -> Vec<usize> {
    (0..n).map(|_| rng.below(pool as u64) as usize).collect()
}

/// Sinusoidal day/night curve: `rate * (1 + 0.6 sin(2πt/horizon))`,
/// one full period over the expected trace horizon `n/rate`.
fn gen_diurnal(rng: &mut Pcg64, n: usize, rate: f64, pool: usize) -> Trace {
    const DEPTH: f64 = 0.6;
    let horizon = n as f64 / rate;
    let peak = rate * (1.0 + DEPTH);
    let arrivals = thinned_arrivals(rng, n, peak, |t| {
        rate * (1.0 + DEPTH * (2.0 * std::f64::consts::PI * t / horizon).sin())
    });
    let query_idx = uniform_query_idx(rng, n, pool);
    Trace { arrivals, query_idx, client: Vec::new(), rate_qps: rate }
}

/// Steady load with a burst: 8x the nominal rate across the middle
/// fifth of the horizon (the thundering herd after a push notification).
fn gen_flash_crowd(rng: &mut Pcg64, n: usize, rate: f64, pool: usize) -> Trace {
    const MULT: f64 = 8.0;
    let horizon = n as f64 / rate;
    let (burst_lo, burst_hi) = (0.4 * horizon, 0.6 * horizon);
    let arrivals = thinned_arrivals(rng, n, rate * MULT, |t| {
        if (burst_lo..burst_hi).contains(&t) {
            rate * MULT
        } else {
            rate
        }
    });
    let query_idx = uniform_query_idx(rng, n, pool);
    Trace { arrivals, query_idx, client: Vec::new(), rate_qps: rate }
}

/// Zipf(s) weights for `n` ranks: `w_i ∝ 1/(i+1)^s`, normalized.
fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    let raw: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|w| w / total).collect()
}

/// Draw an index from a normalized weight vector.
fn weighted_pick(rng: &mut Pcg64, weights: &[f64]) -> usize {
    let mut u = rng.next_f64();
    for (i, &w) in weights.iter().enumerate() {
        if u < w {
            return i;
        }
        u -= w;
    }
    weights.len() - 1
}

/// 8 tenants whose request rates follow Zipf(1.1): the heaviest tenant
/// offers ~6x the lightest's load. The superposition of per-tenant
/// Poisson streams is Poisson at the total rate with each arrival
/// attributed by weight, which is how it is sampled. Each tenant favors
/// its own slice of the query pool (hot-set locality).
fn gen_zipf(rng: &mut Pcg64, n: usize, rate: f64, pool: usize) -> Trace {
    const TENANTS: usize = 8;
    const SKEW: f64 = 1.1;
    let weights = zipf_weights(TENANTS, SKEW);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    let mut query_idx = Vec::with_capacity(n);
    let mut client = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exponential(rate);
        arrivals.push(t);
        let c = weighted_pick(rng, &weights);
        client.push(c as u32);
        // A tenant's queries cluster on its own eighth of the pool, with
        // a 1-in-4 spill to the whole pool.
        let idx = if pool >= TENANTS && rng.below(4) != 0 {
            let slice = pool / TENANTS;
            (c * slice + rng.below(slice as u64) as usize) % pool
        } else {
            rng.below(pool as u64) as usize
        };
        query_idx.push(idx);
    }
    Trace { arrivals, query_idx, client, rate_qps: rate }
}

/// 4 equal tenants; at two seeded instants a random pair of tenants
/// spikes to 6x its base rate for a tenth of the horizon — the
/// correlated burst case cross-shard coding sizes its r for.
fn gen_multi_tenant_burst(rng: &mut Pcg64, n: usize, rate: f64, pool: usize) -> Trace {
    const TENANTS: usize = 4;
    const MULT: f64 = 6.0;
    const BURSTS: usize = 2;
    let horizon = n as f64 / rate;
    let base = rate / TENANTS as f64;

    // Seeded burst windows: [start, start + horizon/10) each, and the
    // pair of tenants spiking in each.
    let mut windows = Vec::with_capacity(BURSTS);
    for b in 0..BURSTS {
        // Burst b starts somewhere in its own half of the horizon, so
        // the two bursts never merge into one long plateau.
        let half = horizon / BURSTS as f64;
        let start = b as f64 * half + rng.next_f64() * (half - horizon / 10.0).max(0.0);
        let pair = rng.choose_distinct(TENANTS, 2);
        windows.push((start, start + horizon / 10.0, pair));
    }

    let tenant_rate = |tenant: usize, t: f64| -> f64 {
        let bursting = windows
            .iter()
            .any(|(lo, hi, pair)| t >= *lo && t < *hi && pair.contains(&tenant));
        if bursting {
            base * MULT
        } else {
            base
        }
    };
    let total_rate =
        |t: f64| -> f64 { (0..TENANTS).map(|c| tenant_rate(c, t)).sum() };
    // Peak: both members of a pair bursting at once.
    let peak = base * (TENANTS as f64 - 2.0 + 2.0 * MULT);

    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity(n);
    let mut client = Vec::with_capacity(n);
    while arrivals.len() < n {
        t += rng.exponential(peak);
        let total = total_rate(t);
        if rng.next_f64() < total / peak {
            arrivals.push(t);
            // Attribute the arrival by instantaneous tenant rate.
            let mut u = rng.next_f64() * total;
            let mut picked = TENANTS - 1;
            for c in 0..TENANTS {
                let r = tenant_rate(c, t);
                if u < r {
                    picked = c;
                    break;
                }
                u -= r;
            }
            client.push(picked as u32);
        }
    }
    let query_idx = uniform_query_idx(rng, n, pool);
    Trace { arrivals, query_idx, client, rate_qps: rate }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_shape(t: &Trace, n: usize, pool: usize) {
        assert_eq!(t.len(), n);
        assert_eq!(t.query_idx.len(), n);
        assert!(t.arrivals.windows(2).all(|w| w[1] >= w[0]), "monotone");
        assert!(t.arrivals.iter().all(|a| a.is_finite() && *a >= 0.0));
        assert!(t.query_idx.iter().all(|&i| i < pool));
        if !t.client.is_empty() {
            assert_eq!(t.client.len(), n);
        }
    }

    #[test]
    fn every_scenario_generates_valid_deterministic_traces() {
        for s in SCENARIOS {
            let a = s.generate(7, 400, 200.0, 16);
            let b = s.generate(7, 400, 200.0, 16);
            assert_eq!(a, b, "{} must be pure in its seed", s.name);
            check_shape(&a, 400, 16);
            let c = s.generate(8, 400, 200.0, 16);
            assert_ne!(a.arrivals, c.arrivals, "{} must vary by seed", s.name);
            // Every scenario's trace must survive the strict JSON
            // round-trip exactly — that is what makes it replayable.
            let back = Trace::from_json_text(&a.to_json().to_string()).unwrap();
            assert_eq!(back, a, "{} round-trip", s.name);
        }
    }

    #[test]
    fn catalogue_lookup() {
        assert!(scenario("diurnal").is_some());
        assert!(scenario("no-such").is_none());
        assert!(generate("no-such", 1, 10, 10.0, 4).is_none());
        assert_eq!(names().len(), SCENARIOS.len());
        let t = generate("flash-crowd", 1, 100, 100.0, 8).unwrap();
        assert_eq!(t.len(), 100);
    }

    #[test]
    fn diurnal_rate_actually_varies() {
        let t = scenario("diurnal").unwrap().generate(3, 4000, 400.0, 8);
        // Quarter 1 rides the sine peak, quarter 3 the trough: the peak
        // quarter must hold substantially more arrivals.
        let horizon = t.arrivals.last().copied().unwrap();
        let q = |lo: f64, hi: f64| {
            t.arrivals
                .iter()
                .filter(|&&a| a >= lo * horizon && a < hi * horizon)
                .count() as f64
        };
        let peak_quarter = q(0.0, 0.25);
        let trough_quarter = q(0.5, 0.75);
        assert!(
            peak_quarter > 1.5 * trough_quarter,
            "peak {peak_quarter} vs trough {trough_quarter}"
        );
    }

    #[test]
    fn flash_crowd_is_burstier_than_poisson() {
        let flash = scenario("flash-crowd").unwrap().generate(5, 3000, 300.0, 8);
        let steady = scenario("poisson").unwrap().generate(5, 3000, 300.0, 8);
        let (_, cv2_flash) = flash.stats();
        let (_, cv2_steady) = steady.stats();
        assert!(
            cv2_flash > cv2_steady + 0.5,
            "flash CV² {cv2_flash} vs steady {cv2_steady}"
        );
    }

    #[test]
    fn zipf_tenants_are_heavy_tailed() {
        let t = scenario("zipf").unwrap().generate(11, 8000, 500.0, 64);
        assert_eq!(t.n_clients(), 8);
        let mut counts = vec![0usize; 8];
        for &c in &t.client {
            counts[c as usize] += 1;
        }
        // Zipf(1.1) over 8 ranks: tenant 0 carries ~32% of the load,
        // tenant 7 ~3%. Allow generous sampling slack.
        assert!(counts[0] > 4 * counts[7], "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn multi_tenant_bursts_are_correlated() {
        let t = scenario("multi-tenant-burst")
            .unwrap()
            .generate(13, 6000, 600.0, 8);
        assert_eq!(t.n_clients(), 4);
        // Sliding tenth-of-horizon windows: in the densest window, the
        // two bursting tenants together must dominate (correlated spike),
        // and that window must be denser than the sparsest by a wide
        // margin.
        let horizon = t.arrivals.last().copied().unwrap();
        let win = horizon / 10.0;
        let mut best: (usize, f64) = (0, 0.0);
        let mut worst = usize::MAX;
        for step in 0..90 {
            let lo = step as f64 * horizon / 100.0;
            let cnt = t
                .arrivals
                .iter()
                .filter(|&&a| a >= lo && a < lo + win)
                .count();
            if cnt > best.0 {
                best = (cnt, lo);
            }
            worst = worst.min(cnt);
        }
        assert!(best.0 as f64 > 2.0 * worst as f64, "{best:?} vs {worst}");
        // Inside the densest window, two tenants carry most arrivals.
        let (lo, hi) = (best.1, best.1 + win);
        let mut counts = vec![0usize; 4];
        for (i, &a) in t.arrivals.iter().enumerate() {
            if a >= lo && a < hi {
                counts[t.client[i] as usize] += 1;
            }
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top2: usize = sorted[..2].iter().sum();
        let total: usize = sorted.iter().sum();
        assert!(
            top2 as f64 > 0.7 * total as f64,
            "burst not concentrated on a tenant pair: {counts:?}"
        );
    }
}

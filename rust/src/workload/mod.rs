//! Workload generation: query sources and open-loop arrival schedules.
//!
//! The paper's clients send 100k queries at Poisson arrival rates (§5.1).
//! [`QuerySource`] cycles a dataset's test split (the latency experiments
//! draw from the Cat-v-Dog stand-in); arrival pacing itself lives in the
//! service generator loop (`coordinator::service`), which consumes
//! exponential inter-arrival gaps from the experiment RNG. Recorded or
//! generated arrival schedules are [`trace::Trace`]s; the named
//! production-shaped generators (diurnal curves, flash crowds, Zipf
//! tenants, correlated bursts) live in [`scenario`].

pub mod scenario;
pub mod trace;

use crate::artifacts::{DatasetEntry, Labels, Manifest};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// A pool of query tensors sampled or cycled by experiments.
pub struct QuerySource {
    pub queries: Vec<Tensor>,
    pub labels: Labels,
    pub dataset: String,
}

impl QuerySource {
    /// Load a dataset's full test split as the query pool.
    pub fn from_dataset(
        manifest: &Manifest,
        ds: &DatasetEntry,
    ) -> Result<QuerySource, crate::artifacts::ArtifactError> {
        let (queries, labels) = manifest.load_test_set(ds)?;
        Ok(QuerySource { queries, labels, dataset: ds.name.clone() })
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    /// A random permutation of indices, for stripe sampling.
    pub fn shuffled_indices(&self, rng: &mut Pcg64) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.queries.len()).collect();
        rng.shuffle(&mut idx);
        idx
    }

    /// Class label of sample i (classification datasets only).
    pub fn class_of(&self, i: usize) -> Option<i32> {
        match &self.labels {
            Labels::Classes(c) => c.get(i).copied(),
            _ => None,
        }
    }

    /// Bounding box of sample i (localization datasets only).
    pub fn box_of(&self, i: usize) -> Option<[f32; 4]> {
        match &self.labels {
            Labels::Boxes(b) => b.get(i).copied(),
            _ => None,
        }
    }
}

/// Deterministic Poisson arrival schedule: cumulative seconds for n events
/// at `rate` per second. Used by trace-replay tests; the live generator
/// draws incrementally instead.
pub fn poisson_schedule(rng: &mut Pcg64, n: usize, rate: f64) -> Vec<f64> {
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exponential(rate);
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_monotone_with_correct_mean_gap() {
        let mut rng = Pcg64::new(3);
        let s = poisson_schedule(&mut rng, 10_000, 200.0);
        assert!(s.windows(2).all(|w| w[1] >= w[0]));
        let mean_gap = s.last().unwrap() / s.len() as f64;
        assert!((mean_gap - 0.005).abs() < 0.0003, "{mean_gap}");
    }
}

//! Runtime layer: PJRT engine (AOT artifact loading + execution) and the
//! simulated-cluster worker/pool model built on top of it.

pub mod engine;
pub mod instance;
pub mod pool;

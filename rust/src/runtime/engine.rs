//! PJRT engine: loads AOT HLO-text artifacts and executes them.
//!
//! One process-wide `PjRtClient` (CPU) compiles each artifact once into a
//! `PjRtLoadedExecutable`; `Executable::run` then moves a query tensor in,
//! executes, and copies the prediction out. This is the only place the
//! request path touches XLA — everything above it deals in `Tensor`s.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax>=0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

use std::path::Path;
use std::sync::Arc;

use once_cell::sync::OnceCell;

use crate::tensor::Tensor;

#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    #[error("xla error: {0}")]
    Xla(String),
    #[error("executable expects input shape {expected:?}, got {actual:?}")]
    InputShape { expected: Vec<usize>, actual: Vec<usize> },
    #[error("artifact {0} not found")]
    NotFound(String),
}

impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

/// Process-wide PJRT CPU client.
///
/// SAFETY: the `xla` crate wraps the client handle in an `Rc`, which makes
/// it `!Send + !Sync` even though the underlying XLA `PjRtClient` (TFRT CPU)
/// is documented thread-safe (`Compile`/`Execute` may be called from any
/// thread). We never clone the inner `Rc` after construction — the wrapper
/// lives in a `'static` OnceCell and is only ever *borrowed* by worker
/// threads — so the non-atomic refcount is never mutated concurrently.
/// `runtime_smoke` integration tests exercise concurrent execution.
struct SharedClient(xla::PjRtClient);
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}

static CLIENT: OnceCell<SharedClient> = OnceCell::new();

pub fn client() -> Result<&'static xla::PjRtClient, EngineError> {
    CLIENT
        .get_or_try_init(|| xla::PjRtClient::cpu().map(SharedClient).map_err(EngineError::from))
        .map(|c| &c.0)
}

/// A compiled model program: fixed input shape (batch, ...), one output.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Full input shape including the batch dim.
    pub input_shape: Vec<usize>,
    /// Output vector length per sample.
    pub out_dim: usize,
    /// Batch size baked into the program.
    pub batch: usize,
    pub name: String,
}

// SAFETY: `PjRtLoadedExecutable::Execute` is thread-safe in XLA; the Rust
// wrapper is only `!Send` because of raw pointers and the `Rc` back to the
// client. We share `Executable` via `Arc` (so the inner `Rc` count is
// mutated only at construction and final drop, both single-threaded) and
// call `execute` concurrently, which XLA supports. Exercised by the
// `runtime_smoke` concurrent-execution test.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Compile an HLO-text artifact.
    pub fn load(
        path: impl AsRef<Path>,
        name: &str,
        input_shape: &[usize],
        batch: usize,
        out_dim: usize,
    ) -> Result<Arc<Executable>, EngineError> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(EngineError::NotFound(path.display().to_string()));
        }
        let client = client()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("non-utf8 artifact path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let mut full_shape = vec![batch];
        full_shape.extend_from_slice(input_shape);
        log::debug!("compiled {name} from {} (batch {batch})", path.display());
        Ok(Arc::new(Executable {
            exe,
            input_shape: full_shape,
            out_dim,
            batch,
            name: name.to_string(),
        }))
    }

    /// Execute on one batched input tensor; returns (batch, out_dim).
    pub fn run(&self, input: &Tensor) -> Result<Tensor, EngineError> {
        if input.shape() != self.input_shape.as_slice() {
            return Err(EngineError::InputShape {
                expected: self.input_shape.clone(),
                actual: input.shape().to_vec(),
            });
        }
        // Single-copy literal creation (vec1 + reshape would copy twice —
        // measured ~2x input-marshalling cost on the 64x64x3 workload;
        // see EXPERIMENTS.md §Perf).
        let bytes = unsafe {
            std::slice::from_raw_parts(
                input.data().as_ptr() as *const u8,
                input.data().len() * std::mem::size_of::<f32>(),
            )
        };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            input.shape(),
            bytes,
        )?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        Tensor::new(vec![self.batch, self.out_dim], data)
            .map_err(|e| EngineError::Xla(e.to_string()))
    }

    /// Execute and return the flat output regardless of declared out_dim
    /// (used by non-model programs such as the exported encoder kernel,
    /// whose output is a query tensor rather than (batch, out_dim)).
    pub fn run_raw(&self, input: &Tensor) -> Result<Tensor, EngineError> {
        if input.shape() != self.input_shape.as_slice() {
            return Err(EngineError::InputShape {
                expected: self.input_shape.clone(),
                actual: input.shape().to_vec(),
            });
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(
                input.data().as_ptr() as *const u8,
                input.data().len() * std::mem::size_of::<f32>(),
            )
        };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            input.shape(),
            bytes,
        )?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        let n = data.len();
        Tensor::new(vec![n], data).map_err(|e| EngineError::Xla(e.to_string()))
    }

    /// Execute on a single sample (pads/errors if batch != 1).
    pub fn run_one(&self, sample: &Tensor) -> Result<Tensor, EngineError> {
        let batched = Tensor::batch(std::slice::from_ref(sample))
            .map_err(|e| EngineError::Xla(e.to_string()))?;
        let out = self.run(&batched)?;
        Ok(out.unbatch().into_iter().next().unwrap())
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable")
            .field("name", &self.name)
            .field("input_shape", &self.input_shape)
            .field("out_dim", &self.out_dim)
            .finish()
    }
}

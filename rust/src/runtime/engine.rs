//! Execution engine: loads AOT artifacts and executes them.
//!
//! Two backends, selected at compile time:
//!
//! - **`pjrt` feature**: one process-wide `PjRtClient` (CPU) compiles each
//!   HLO-text artifact once into a `PjRtLoadedExecutable`; `Executable::run`
//!   moves a query tensor in, executes, and copies the prediction out. This
//!   is the only place the request path touches XLA — everything above it
//!   deals in `Tensor`s. Interchange is HLO **text** (see
//!   `python/compile/aot.py`): jax>=0.5 serialized protos use 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids and round-trips cleanly. The `xla` bindings are not on
//!   crates.io — see the `pjrt` feature note in `Cargo.toml`.
//!
//! - **default (synthetic)**: every `Executable` is a deterministic pure
//!   function of `(model name, input)` — a cheap hashed linear map. No
//!   artifact files are required, predictions carry no trained semantics
//!   (accuracy experiments are meaningless and skip), but service times,
//!   shapes, and the full coordinator/cluster machinery behave exactly as
//!   with the real backend, so the serving-path tests run everywhere.

use std::path::Path;
use std::sync::Arc;

use crate::tensor::Tensor;
use crate::util::sync::LockExt;

#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    #[error("xla error: {0}")]
    Xla(String),
    #[error("executable expects input shape {expected:?}, got {actual:?}")]
    InputShape { expected: Vec<usize>, actual: Vec<usize> },
    #[error("artifact {0} not found")]
    NotFound(String),
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

/// Process-wide PJRT CPU client.
///
/// SAFETY: the `xla` crate wraps the client handle in an `Rc`, which makes
/// it `!Send + !Sync` even though the underlying XLA `PjRtClient` (TFRT CPU)
/// is documented thread-safe (`Compile`/`Execute` may be called from any
/// thread). We never clone the inner `Rc` after construction — the wrapper
/// lives in a `'static` OnceLock and is only ever *borrowed* by worker
/// threads — so the non-atomic refcount is never mutated concurrently.
/// `runtime_smoke` integration tests exercise concurrent execution.
#[cfg(feature = "pjrt")]
struct SharedClient(xla::PjRtClient);
#[cfg(feature = "pjrt")]
unsafe impl Send for SharedClient {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for SharedClient {}

#[cfg(feature = "pjrt")]
static CLIENT: std::sync::OnceLock<SharedClient> = std::sync::OnceLock::new();
#[cfg(feature = "pjrt")]
static CLIENT_INIT: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(feature = "pjrt")]
pub fn client() -> Result<&'static xla::PjRtClient, EngineError> {
    if let Some(c) = CLIENT.get() {
        return Ok(&c.0);
    }
    // Serialize creation so only one client is ever constructed, without
    // caching transient failures (a failed attempt may be retried later).
    let _guard = CLIENT_INIT.plock();
    if let Some(c) = CLIENT.get() {
        return Ok(&c.0);
    }
    let made = xla::PjRtClient::cpu().map(SharedClient).map_err(EngineError::from)?;
    let _ = CLIENT.set(made);
    Ok(&CLIENT.get().expect("just set").0)
}

/// A compiled model program: fixed input shape (batch, ...), one output.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Seed for the synthetic backend (derived from the model name).
    #[cfg(not(feature = "pjrt"))]
    seed: u64,
    /// Full input shape including the batch dim.
    pub input_shape: Vec<usize>,
    /// Output vector length per sample.
    pub out_dim: usize,
    /// Batch size baked into the program.
    pub batch: usize,
    pub name: String,
}

// SAFETY (pjrt): `PjRtLoadedExecutable::Execute` is thread-safe in XLA; the
// Rust wrapper is only `!Send` because of raw pointers and the `Rc` back to
// the client. We share `Executable` via `Arc` (so the inner `Rc` count is
// mutated only at construction and final drop, both single-threaded) and
// call `execute` concurrently, which XLA supports. Exercised by the
// `runtime_smoke` concurrent-execution test. The synthetic backend is plain
// data and trivially thread-safe.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Compile an artifact. Under the synthetic backend the path is only
    /// recorded for diagnostics — no file is required.
    pub fn load(
        path: impl AsRef<Path>,
        name: &str,
        input_shape: &[usize],
        batch: usize,
        out_dim: usize,
    ) -> Result<Arc<Executable>, EngineError> {
        let path = path.as_ref();
        let mut full_shape = vec![batch];
        full_shape.extend_from_slice(input_shape);

        #[cfg(feature = "pjrt")]
        {
            if !path.exists() {
                return Err(EngineError::NotFound(path.display().to_string()));
            }
            let client = client()?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("non-utf8 artifact path"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            log::debug!("compiled {name} from {} (batch {batch})", path.display());
            Ok(Arc::new(Executable {
                exe,
                input_shape: full_shape,
                out_dim,
                batch,
                name: name.to_string(),
            }))
        }

        #[cfg(not(feature = "pjrt"))]
        {
            log::debug!(
                "synthetic executable {name} (batch {batch}, artifact {} ignored)",
                path.display()
            );
            Ok(Arc::new(Executable {
                seed: crate::util::rng::fnv1a(name.as_bytes()),
                input_shape: full_shape,
                out_dim,
                batch,
                name: name.to_string(),
            }))
        }
    }

    fn check_shape(&self, input: &Tensor) -> Result<(), EngineError> {
        if input.shape() != self.input_shape.as_slice() {
            return Err(EngineError::InputShape {
                expected: self.input_shape.clone(),
                actual: input.shape().to_vec(),
            });
        }
        Ok(())
    }

    /// Execute on one batched input tensor; returns (batch, out_dim).
    pub fn run(&self, input: &Tensor) -> Result<Tensor, EngineError> {
        self.check_shape(input)?;

        #[cfg(feature = "pjrt")]
        {
            let data = self.execute_pjrt(input)?;
            Tensor::new(vec![self.batch, self.out_dim], data)
                .map_err(|e| EngineError::Xla(e.to_string()))
        }

        #[cfg(not(feature = "pjrt"))]
        {
            let per = input.len() / self.batch;
            let mut out = Vec::with_capacity(self.batch * self.out_dim);
            for s in 0..self.batch {
                let xs = &input.data()[s * per..(s + 1) * per];
                synthetic_forward(self.seed, xs, self.out_dim, &mut out);
            }
            Tensor::new(vec![self.batch, self.out_dim], out)
                .map_err(|e| EngineError::Xla(e.to_string()))
        }
    }

    /// Execute and return the flat output regardless of declared out_dim
    /// (used by non-model programs such as the exported encoder kernel,
    /// whose output is a query tensor rather than (batch, out_dim)).
    pub fn run_raw(&self, input: &Tensor) -> Result<Tensor, EngineError> {
        self.check_shape(input)?;

        #[cfg(feature = "pjrt")]
        {
            let data = self.execute_pjrt(input)?;
            let n = data.len();
            Tensor::new(vec![n], data).map_err(|e| EngineError::Xla(e.to_string()))
        }

        #[cfg(not(feature = "pjrt"))]
        {
            let mut out = Vec::with_capacity(self.out_dim);
            synthetic_forward(self.seed, input.data(), self.out_dim, &mut out);
            let n = out.len();
            Tensor::new(vec![n], out).map_err(|e| EngineError::Xla(e.to_string()))
        }
    }

    #[cfg(feature = "pjrt")]
    fn execute_pjrt(&self, input: &Tensor) -> Result<Vec<f32>, EngineError> {
        // Single-copy literal creation (vec1 + reshape would copy twice —
        // measured ~2x input-marshalling cost on the 64x64x3 workload;
        // see EXPERIMENTS.md §Perf).
        let bytes = unsafe {
            std::slice::from_raw_parts(
                input.data().as_ptr() as *const u8,
                input.data().len() * std::mem::size_of::<f32>(),
            )
        };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            input.shape(),
            bytes,
        )?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute on a single sample (pads/errors if batch != 1).
    pub fn run_one(&self, sample: &Tensor) -> Result<Tensor, EngineError> {
        let batched = Tensor::batch(std::slice::from_ref(sample))
            .map_err(|e| EngineError::Xla(e.to_string()))?;
        let out = self.run(&batched)?;
        Ok(out.unbatch().into_iter().next().unwrap())
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable")
            .field("name", &self.name)
            .field("input_shape", &self.input_shape)
            .field("out_dim", &self.out_dim)
            .finish()
    }
}

/// Deterministic pseudo-model: each output is a sparse hashed linear
/// combination of the input (16 taps), so predictions depend on both the
/// model identity and the query while staying cheap enough to "serve" at
/// microsecond scale.
#[cfg(not(feature = "pjrt"))]
fn synthetic_forward(seed: u64, xs: &[f32], out_dim: usize, out: &mut Vec<f32>) {
    debug_assert!(!xs.is_empty());
    for j in 0..out_dim {
        let mut h = seed ^ (j as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut acc = 0.0f32;
        for _ in 0..16 {
            // splitmix64 step
            h = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = h;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^= z >> 31;
            let idx = (z as usize) % xs.len();
            let w = ((z >> 40) as f32) / (1u32 << 24) as f32 - 0.5;
            acc += xs[idx] * w;
        }
        out.push(acc);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_shaped() {
        let exe = Executable::load("no/such/file", "m.test", &[4, 4, 1], 2, 10).unwrap();
        let input = Tensor::new(vec![2, 4, 4, 1], (0..32).map(|i| i as f32 * 0.1).collect())
            .unwrap();
        let a = exe.run(&input).unwrap();
        let b = exe.run(&input).unwrap();
        assert_eq!(a, b, "pure function of input");
        assert_eq!(a.shape(), &[2, 10]);
        assert!(a.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn synthetic_differs_across_models_and_inputs() {
        let e1 = Executable::load("x", "model.a", &[4], 1, 8).unwrap();
        let e2 = Executable::load("x", "model.b", &[4], 1, 8).unwrap();
        let q1 = Tensor::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let q2 = Tensor::new(vec![1, 4], vec![4.0, 3.0, 2.0, 1.0]).unwrap();
        assert_ne!(e1.run(&q1).unwrap(), e2.run(&q1).unwrap());
        assert_ne!(e1.run(&q1).unwrap(), e1.run(&q2).unwrap());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let exe = Executable::load("x", "m", &[4], 1, 4).unwrap();
        let bad = Tensor::zeros(vec![1, 5]);
        assert!(matches!(exe.run(&bad), Err(EngineError::InputShape { .. })));
    }
}

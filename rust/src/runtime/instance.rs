//! Model-instance worker: one OS thread per simulated cluster instance.
//!
//! Each worker owns a compiled PJRT executable (its copy of the deployed
//! or parity model) and loops: pull a job from its queue (the shared
//! single queue, or a private queue under round-robin), simulate the
//! network transfer of the query under current link contention, run real
//! inference, apply the hardware profile's residual and any tenancy
//! slowdown, then send a completion back to the frontend.
//!
//! The *real* PJRT execution is always on the path — injected delays only
//! add to it — so the latency distributions inherit genuine execution
//! jitter rather than being fully synthetic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::cluster::hardware::Profile;
use crate::cluster::network::Network;
use crate::cluster::tenancy::Tenancy;
use crate::cluster::{precise_sleep, scaled};
use crate::runtime::engine::Executable;
use crate::tensor::Tensor;
use crate::util::bus::BusSender;
use crate::util::queue::Queue;
use crate::util::rng::Pcg64;

/// What a dispatched batch is for (drives the completion routing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// A batch of k consecutive query batches' worth of real queries.
    Data { group: u64, slot: usize },
    /// The parity batch of a coding group (slot = r_index).
    Parity { group: u64, r_index: usize },
    /// Replicated query batch (replication / approx-backup baselines).
    Replica { group: u64, slot: usize },
    /// Co-located tenant work (never routed back to clients).
    Background,
}

#[derive(Debug)]
pub struct Job {
    pub kind: JobKind,
    pub input: Tensor,
    /// Ids of the client queries in this batch (empty for background).
    pub query_ids: Vec<u64>,
    pub dispatched_at: Instant,
}

#[derive(Debug)]
pub struct Completion {
    pub kind: JobKind,
    pub instance: usize,
    pub query_ids: Vec<u64>,
    pub output: Tensor,
    pub finished_at: Instant,
    /// Pure PJRT execution time (for §Perf accounting).
    pub exec_time: Duration,
}

/// Knobs shared by all workers of a pool.
pub struct WorkerEnv {
    pub profile: &'static Profile,
    pub network: Arc<Network>,
    pub tenancy: Tenancy,
    pub faults: Arc<FaultPlan>,
    /// Multiplier on injected (non-PJRT) delays; < 1 compresses time.
    pub time_scale: f64,
    /// Extra head-of-line delay per active background flow, as a fraction
    /// of mean service time, sampled uniformly in [lo, hi] per query.
    /// Models transport-level interference beyond fair-share bandwidth
    /// (see DESIGN.md "Substitutions").
    pub hol_range: (f64, f64),
    /// Mean uncontended service time, measured at pool startup.
    pub mean_service: Duration,
    /// Jobs dropped by *this session's* failed instances. The global
    /// [`DROPPED_JOBS`] static spans every live session, so concurrent
    /// sessions (e.g. the shards of a
    /// [`crate::coordinator::shards::ShardedFrontend`]) would cross-count
    /// each other through it; per-shard accounting reads this counter.
    pub dropped: AtomicU64,
}

/// How workers produce predictions.
///
/// `Real` executes the PJRT program per query — ground truth, but on a
/// host with fewer cores than instances the instances contend for the
/// PJRT pool and the "cluster" stops being parallel (a 1-core CI image
/// serializes everything, so ParM's parity work would steal CPU from the
/// deployed pool — the opposite of the paper's extra-machines premise).
///
/// `Modeled` replays service times *measured from the real executable* at
/// startup (an empirical distribution, sampled per query and slept), with
/// a template output tensor from a real execution. Sleeps are truly
/// parallel on any host, so m instances behave like m servers. Latency
/// experiments default to Modeled; accuracy experiments and the
/// quickstart/localization examples always run Real inference.
#[derive(Clone)]
pub enum Execution {
    Real,
    Modeled(Arc<ServiceModel>),
}

/// Empirical service-time distribution + template output for one model.
pub struct ServiceModel {
    /// Measured per-execution times (seconds), sampled uniformly.
    pub samples: Vec<f64>,
    /// A real output of the executable (values irrelevant to timing paths).
    pub template_output: Tensor,
}

impl ServiceModel {
    /// Calibrate from real executions.
    pub fn measure(
        exe: &Executable,
        probe: &Tensor,
        n: usize,
    ) -> Result<ServiceModel, crate::runtime::engine::EngineError> {
        let mut samples = Vec::with_capacity(n);
        let mut out = None;
        for _ in 0..3 {
            let _ = exe.run(probe)?;
        }
        for _ in 0..n {
            let t0 = Instant::now();
            let o = exe.run(probe)?;
            samples.push(t0.elapsed().as_secs_f64());
            out.get_or_insert(o);
        }
        Ok(ServiceModel { samples, template_output: out.unwrap() })
    }

    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
    }

    fn sample(&self, rng: &mut Pcg64) -> Duration {
        Duration::from_secs_f64(self.samples[rng.below(self.samples.len() as u64) as usize])
    }
}

pub struct InstanceWorker {
    pub id: usize,
    handle: Option<JoinHandle<()>>,
}

/// Run inference per the execution mode: real PJRT, or calibrated sleep.
fn execute(
    exe: &Executable,
    execution: &Execution,
    input: &Tensor,
    rng: &mut Pcg64,
    time_scale: f64,
) -> Result<(Tensor, Duration), crate::runtime::engine::EngineError> {
    match execution {
        Execution::Real => {
            let t0 = Instant::now();
            let out = exe.run(input)?;
            Ok((out, t0.elapsed()))
        }
        Execution::Modeled(model) => {
            let d = model.sample(rng);
            precise_sleep(scaled(d, time_scale));
            Ok((model.template_output.clone(), d))
        }
    }
}

/// Count of jobs dropped because the instance was failed (observability).
pub static DROPPED_JOBS: AtomicU64 = AtomicU64::new(0);

impl InstanceWorker {
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        id: usize,
        exe: Arc<Executable>,
        execution: Execution,
        queue: Queue<Job>,
        completions: BusSender<Completion>,
        env: Arc<WorkerEnv>,
        seed: u64,
    ) -> InstanceWorker {
        let handle = std::thread::Builder::new()
            .name(format!("instance-{id}"))
            .spawn(move || worker_loop(id, exe, execution, queue, completions, env, seed))
            .expect("spawn instance worker");
        InstanceWorker { id, handle: Some(handle) }
    }

    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    id: usize,
    exe: Arc<Executable>,
    execution: Execution,
    queue: Queue<Job>,
    completions: BusSender<Completion>,
    env: Arc<WorkerEnv>,
    seed: u64,
) {
    let mut rng = Pcg64::new(seed ^ (id as u64) << 32);
    // Tenancy: schedule the co-located tenant's next arrival.
    let mut next_bg: Option<Instant> = if env.tenancy.enabled() && env.tenancy.is_tenant(id) {
        Some(Instant::now() + Duration::from_secs_f64(rng.exponential(env.tenancy.bg_rate)))
    } else {
        None
    };

    while let Some(job) = queue.pop() {
        // Failed instances are zombies: they keep accepting work at their
        // normal pace (an undetected dead backend in a push-RPC system)
        // but never respond — the limiting case of slowness. Pacing the
        // drops keeps a dead instance from draining the shared queue.
        if env.faults.is_failed(id) {
            DROPPED_JOBS.fetch_add(1, Ordering::Relaxed);
            env.dropped.fetch_add(1, Ordering::Relaxed);
            precise_sleep(scaled(env.mean_service, env.time_scale));
            continue;
        }

        // ---- network: query transfer under current link contention ----
        let bytes = job.input.len() * 4;
        let base = env.profile.transfer_time(bytes);
        let contended = env.network.transfer_time(id, bytes);
        let flows = env.network.active_flows(id);
        let mut delay = contended.max(base) + env.profile.dispatch_overhead;
        if flows > 0 && rng.next_f64() < 0.25 {
            // Head-of-line blocking behind shuffle bursts. Bursty by
            // nature: only a fraction of queries on a contended link land
            // behind a burst, so medians stay clean while the tail
            // inflates — the paper's Figure 11 shape.
            let (lo, hi) = env.hol_range;
            let frac = rng.range_f64(lo, hi) * flows as f64;
            delay += Duration::from_secs_f64(env.mean_service.as_secs_f64() * frac);
        }
        precise_sleep(scaled(delay, env.time_scale));

        // ---- co-located tenant work that arrived while we were away ----
        if let Some(due) = next_bg {
            let now = Instant::now();
            if now >= due {
                // Run the tenant's job first (it shares our accelerator).
                precise_sleep(scaled(env.tenancy.bg_service, env.time_scale));
                next_bg = Some(
                    now + Duration::from_secs_f64(rng.exponential(env.tenancy.bg_rate)),
                );
            }
        }

        // ---- inference (real PJRT or calibrated service-time model) ----
        let (output, exec_time) =
            match execute(&exe, &execution, &job.input, &mut rng, env.time_scale) {
                Ok(pair) => pair,
                Err(e) => {
                    log::error!("instance {id}: exec failed: {e}");
                    continue;
                }
            };

        // ---- hardware profile residual + tenant contention ----
        let mut residual = env.profile.residual(exec_time);
        if next_bg.is_some() && env.tenancy.slowdown > 1.0 && rng.next_f64() < 0.5 {
            // Probabilistic overlap with tenant activity.
            residual += Duration::from_secs_f64(
                exec_time.as_secs_f64() * (env.tenancy.slowdown - 1.0),
            );
        }
        precise_sleep(scaled(residual, env.time_scale));

        let done = Completion {
            kind: job.kind,
            instance: id,
            query_ids: job.query_ids,
            output,
            finished_at: Instant::now(),
            exec_time,
        };
        if completions.send(done).is_err() {
            return; // frontend gone; shut down
        }
    }
}

//! Instance pools: groups of workers sharing a load-balancing strategy.
//!
//! A `Pool` owns the queue(s) feeding a set of instance workers that all
//! serve the same executable — the paper's "m instances of the deployed
//! model" and "m/k instances of the parity model" are two pools. The
//! single-queue strategy is the paper's default (optimal for mean response
//! time [37]); round-robin is provided for the §5.1 comparison note.

use std::sync::Arc;

use crate::runtime::engine::Executable;
use crate::runtime::instance::{Completion, Execution, InstanceWorker, Job, WorkerEnv};
use crate::util::bus::BusSender;
use crate::util::queue::Queue;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Balancing {
    /// One shared queue; idle instances pull (paper default).
    SingleQueue,
    /// Per-instance queues; dispatcher assigns cyclically.
    RoundRobin,
}

pub struct Pool {
    pub name: String,
    balancing: Balancing,
    /// SingleQueue: one entry; RoundRobin: one per instance.
    queues: Vec<Queue<Job>>,
    workers: Vec<InstanceWorker>,
    rr_next: std::sync::atomic::AtomicUsize,
    /// Global instance ids (indices into the cluster-wide Network/FaultPlan).
    pub instance_ids: Vec<usize>,
}

impl Pool {
    /// Spawn `instance_ids.len()` workers for `exe`.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        name: &str,
        exe: Arc<Executable>,
        execution: Execution,
        instance_ids: Vec<usize>,
        balancing: Balancing,
        completions: BusSender<Completion>,
        env: Arc<WorkerEnv>,
        seed: u64,
    ) -> Pool {
        let queues: Vec<Queue<Job>> = match balancing {
            Balancing::SingleQueue => vec![Queue::new()],
            Balancing::RoundRobin => instance_ids.iter().map(|_| Queue::new()).collect(),
        };
        let workers = instance_ids
            .iter()
            .enumerate()
            .map(|(i, &gid)| {
                let q = match balancing {
                    Balancing::SingleQueue => queues[0].clone(),
                    Balancing::RoundRobin => queues[i].clone(),
                };
                InstanceWorker::spawn(
                    gid,
                    exe.clone(),
                    execution.clone(),
                    q,
                    completions.clone(),
                    env.clone(),
                    seed,
                )
            })
            .collect();
        Pool {
            name: name.to_string(),
            balancing,
            queues,
            workers,
            rr_next: std::sync::atomic::AtomicUsize::new(0),
            instance_ids,
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch a job according to the balancing strategy.
    pub fn dispatch(&self, job: Job) {
        match self.balancing {
            Balancing::SingleQueue => {
                let _ = self.queues[0].push(job);
            }
            Balancing::RoundRobin => {
                let i = self
                    .rr_next
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                    % self.queues.len();
                let _ = self.queues[i].push(job);
            }
        }
    }

    /// Total queued (not yet started) jobs — backpressure signal.
    pub fn backlog(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Close the queues without joining: workers exit once they drain.
    /// Used by best-effort teardown paths (session handle drop); orderly
    /// shutdown should prefer [`Pool::shutdown`].
    pub fn close(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    /// Close queues and join all workers.
    pub fn shutdown(self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers {
            w.join();
        }
    }
}

//! ParM encoders (§3.2, §4.2.3): run on the frontend for every coding
//! group, so they must be fast (the paper measures 93-193 us).
//!
//! - [`Encoder::Sum`]: the generic addition encoder, P = Σ w_i · X_i.
//!   Weights are all-ones for r = 1; for r > 1 each parity model gets its
//!   own weight vector (§3.5).
//! - [`Encoder::Concat`]: the image-classification-specific encoder:
//!   each query is area-downsampled and placed into a cell of the parity
//!   query, preserving the original feature count (Figure 10).
//!
//! Semantics are pinned to `python/compile/encoders.py` (which generated
//! the parity models' training data) — a mismatch would silently destroy
//! reconstruction accuracy, so the end-to-end accuracy experiments double
//! as integration tests of this equivalence.

use crate::tensor::{ops, Tensor, TensorError};

#[derive(Clone, Debug, PartialEq)]
pub enum Encoder {
    /// Weighted sum across the k queries of a group.
    Sum { weights: Vec<f32> },
    /// Downsample-and-tile (k = 2 stacks halves; square k tiles a grid).
    Concat { k: usize },
}

#[derive(Debug, thiserror::Error)]
pub enum EncodeError {
    #[error("expected {expected} queries, got {actual}")]
    WrongGroupSize { expected: usize, actual: usize },
    #[error("concat encoder needs k=2 or a perfect square, got {0}")]
    BadConcatK(usize),
    #[error(transparent)]
    Tensor(#[from] TensorError),
}

impl Encoder {
    /// The paper's generic addition encoder for a given k.
    pub fn sum(k: usize) -> Encoder {
        Encoder::Sum { weights: vec![1.0; k] }
    }

    /// Weights for the `r_index`-th parity model (§3.5): w_i = (i+1)^r_index.
    pub fn sum_r(k: usize, r_index: usize) -> Encoder {
        Encoder::Sum {
            weights: (0..k)
                .map(|i| ((i + 1) as f32).powi(r_index as i32))
                .collect(),
        }
    }

    pub fn concat(k: usize) -> Encoder {
        Encoder::Concat { k }
    }

    pub fn from_name(name: &str, k: usize, r_index: usize) -> Option<Encoder> {
        match name {
            "sum" => Some(Encoder::sum_r(k, r_index)),
            "concat" => Some(Encoder::concat(k)),
            _ => None,
        }
    }

    pub fn k(&self) -> usize {
        match self {
            Encoder::Sum { weights } => weights.len(),
            Encoder::Concat { k } => *k,
        }
    }

    /// Encode k same-shaped queries into one parity query.
    ///
    /// ```
    /// use parm::coordinator::encoder::Encoder;
    /// use parm::tensor::Tensor;
    ///
    /// // The paper's generic addition code: P = X1 + X2.
    /// let x1 = Tensor::filled(vec![4], 1.0);
    /// let x2 = Tensor::filled(vec![4], 2.0);
    /// let p = Encoder::sum(2).encode(&[&x1, &x2]).unwrap();
    /// assert_eq!(p.data(), &[3.0, 3.0, 3.0, 3.0][..]);
    ///
    /// // Group-size mismatches are rejected, not silently mis-encoded.
    /// assert!(Encoder::sum(3).encode(&[&x1, &x2]).is_err());
    /// ```
    pub fn encode(&self, queries: &[&Tensor]) -> Result<Tensor, EncodeError> {
        if queries.len() != self.k() {
            return Err(EncodeError::WrongGroupSize {
                expected: self.k(),
                actual: queries.len(),
            });
        }
        match self {
            Encoder::Sum { weights } => Ok(ops::weighted_sum(queries, weights)?),
            Encoder::Concat { k } => concat_encode(queries, *k),
        }
    }

    /// Encode batched queries elementwise: the i-th queries of each of the
    /// k batches form stripe i (§3.1 "Encoding takes place across
    /// individual queries of a coding group").
    pub fn encode_batches(&self, batches: &[&Tensor]) -> Result<Tensor, EncodeError> {
        if batches.len() != self.k() {
            return Err(EncodeError::WrongGroupSize {
                expected: self.k(),
                actual: batches.len(),
            });
        }
        match self {
            // Sum commutes with batching: sum whole batch tensors at once
            // (single pass, no per-sample splitting on the hot path).
            Encoder::Sum { weights } => Ok(ops::weighted_sum(batches, weights)?),
            Encoder::Concat { .. } => {
                let split: Vec<Vec<Tensor>> =
                    batches.iter().map(|b| b.unbatch()).collect();
                let bsz = split[0].len();
                let mut out = Vec::with_capacity(bsz);
                for i in 0..bsz {
                    let stripe: Vec<&Tensor> = split.iter().map(|s| &s[i]).collect();
                    out.push(self.encode(&stripe)?);
                }
                Ok(Tensor::batch(&out)?)
            }
        }
    }
}

fn concat_encode(queries: &[&Tensor], k: usize) -> Result<Tensor, EncodeError> {
    let shape = queries[0].shape();
    if shape.len() != 3 {
        return Err(EncodeError::Tensor(TensorError::Invalid {
            op: "concat_encode",
            msg: format!("need (H, W, C) queries, got {shape:?}"),
        }));
    }
    let (h, w) = (shape[0], shape[1]);
    if k == 2 {
        // Halve height, stack vertically (matches encoders.py k=2 branch).
        let halves: Vec<Tensor> = queries
            .iter()
            .map(|q| ops::resize_area(q, h / 2, w))
            .collect::<Result<_, _>>()?;
        return Ok(ops::concat_rows(&halves)?);
    }
    let g = (k as f64).sqrt() as usize;
    if g * g != k {
        return Err(EncodeError::BadConcatK(k));
    }
    let cells: Vec<Tensor> = queries
        .iter()
        .map(|q| ops::resize_area(q, h / g, w / g))
        .collect::<Result<_, _>>()?;
    let rows: Vec<Tensor> = (0..g)
        .map(|r| ops::concat_cols(&cells[r * g..(r + 1) * g]))
        .collect::<Result<_, _>>()?;
    Ok(ops::concat_rows(&rows)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::new(shape.to_vec(), data).unwrap()
    }

    #[test]
    fn sum_encoder_adds() {
        let a = t(&[2, 2, 1], vec![1., 2., 3., 4.]);
        let b = t(&[2, 2, 1], vec![10., 20., 30., 40.]);
        let enc = Encoder::sum(2);
        let p = enc.encode(&[&a, &b]).unwrap();
        assert_eq!(p.data(), &[11., 22., 33., 44.]);
    }

    #[test]
    fn sum_r_weights_match_35() {
        // §3.5 example: second parity for k=2 encodes X1 + 2*X2.
        let enc = Encoder::sum_r(2, 1);
        match &enc {
            Encoder::Sum { weights } => assert_eq!(weights, &vec![1.0, 2.0]),
            _ => unreachable!(),
        }
        let a = t(&[1], vec![3.0]);
        let b = t(&[1], vec![5.0]);
        assert_eq!(enc.encode(&[&a, &b]).unwrap().data(), &[13.0]);
    }

    #[test]
    fn wrong_group_size_rejected() {
        let a = t(&[1], vec![1.0]);
        assert!(matches!(
            Encoder::sum(2).encode(&[&a]),
            Err(EncodeError::WrongGroupSize { expected: 2, actual: 1 })
        ));
    }

    #[test]
    fn concat_k4_preserves_feature_count() {
        let qs: Vec<Tensor> = (0..4)
            .map(|i| Tensor::filled(vec![8, 8, 3], i as f32))
            .collect();
        let refs: Vec<&Tensor> = qs.iter().collect();
        let p = Encoder::concat(4).encode(&refs).unwrap();
        assert_eq!(p.shape(), &[8, 8, 3]);
        // top-left cell = query 0, top-right = query 1, etc.
        assert_eq!(p.data()[0], 0.0);
        assert_eq!(p.data()[4 * 3], 1.0); // (0, 4, 0)
        assert_eq!(p.data()[4 * 8 * 3], 2.0); // (4, 0, 0)
        assert_eq!(p.data()[(4 * 8 + 4) * 3], 3.0); // (4, 4, 0)
    }

    #[test]
    fn concat_k2_stacks_halves() {
        let a = Tensor::filled(vec![4, 4, 1], 1.0);
        let b = Tensor::filled(vec![4, 4, 1], 2.0);
        let p = Encoder::concat(2).encode(&[&a, &b]).unwrap();
        assert_eq!(p.shape(), &[4, 4, 1]);
        assert!(p.data()[..8].iter().all(|&v| v == 1.0));
        assert!(p.data()[8..].iter().all(|&v| v == 2.0));
    }

    #[test]
    fn concat_k3_rejected() {
        let qs: Vec<Tensor> = (0..3).map(|_| Tensor::zeros(vec![4, 4, 1])).collect();
        let refs: Vec<&Tensor> = qs.iter().collect();
        assert!(matches!(
            Encoder::concat(3).encode(&refs),
            Err(EncodeError::BadConcatK(3))
        ));
    }

    #[test]
    fn encode_batches_elementwise() {
        // Two batches of 2 samples each; stripe i = i-th sample of each.
        let b1 = t(&[2, 1], vec![1., 2.]);
        let b2 = t(&[2, 1], vec![10., 20.]);
        let p = Encoder::sum(2).encode_batches(&[&b1, &b2]).unwrap();
        assert_eq!(p.shape(), &[2, 1]);
        assert_eq!(p.data(), &[11., 22.]);
    }

    #[test]
    fn from_name_lookup() {
        assert_eq!(Encoder::from_name("sum", 3, 0), Some(Encoder::sum(3)));
        assert_eq!(Encoder::from_name("concat", 4, 0), Some(Encoder::concat(4)));
        assert_eq!(Encoder::from_name("fft", 2, 0), None);
    }
}

//! Pluggable redundancy schemes: the paper's framing made executable.
//!
//! ParM's contribution is a *general* coding-based resilience layer —
//! encoder, parity model, and decoder are interchangeable components, and
//! the evaluation's baselines differ from ParM only in how queries are
//! given redundancy and how completions resolve them. [`RedundancyScheme`]
//! is that seam: an object-safe strategy consulted by the serving session
//! at exactly two points —
//!
//! - [`RedundancyScheme::plan_dispatch`]: a sealed query batch arrives;
//!   the scheme decides which pools receive which jobs (and, for ParM,
//!   accumulates the coding group and emits the encoded parity job when
//!   the group seals);
//! - [`RedundancyScheme::on_completion`]: a worker finished a job; the
//!   scheme decides which queries that resolves and with what
//!   [`Outcome`] (for ParM this is where the decoder runs).
//!
//! The five schemes of the paper ship as implementations: [`ParmScheme`]
//! (§3), [`NoRedundancyScheme`], [`EqualResourcesScheme`] (§5.1),
//! [`ApproxBackupScheme`] (§5.2.6), and [`ReplicationScheme`] (§2.2). A
//! sixth — the adaptive rateless scheme, whose per-group redundancy
//! follows a learned straggler predictor — lives in
//! [`crate::coordinator::adaptive`] and is the worked example of a
//! *dynamic-topology* scheme (see below).
//!
//! # Adding a scheme
//!
//! To add a new scheme (an ApproxIFER-style rateless code, multi-group
//! striping, …) you answer three questions and the whole substrate —
//! pools, faults, shuffles, tenancy, batching, SLO handling, metrics,
//! and the multi-client frontend — comes for free:
//!
//! 1. **Topology** — [`RedundancyScheme::extra_instances`] and
//!    [`RedundancyScheme::layout`]: how many instances beyond the m
//!    deployed ones you need and how the global instance ids partition
//!    into pools. Layouts must partition `0..m + extra` exactly (pinned
//!    by a test below).
//! 2. **Dispatch** — [`RedundancyScheme::plan_dispatch`]: for each sealed
//!    query batch, which pools get which [`Job`]s. Stateful schemes (like
//!    ParM's coding groups) accumulate here and emit extra jobs when a
//!    group seals.
//! 3. **Resolution** — [`RedundancyScheme::on_completion`]: for each
//!    worker completion, which query ids now have predictions and with
//!    what [`Outcome`]. Duplicates are fine; the session deduplicates
//!    (first verdict wins).
//!
//! A minimal complete implementation — every batch to the deployed pool,
//! every completion resolves its queries:
//!
//! ```
//! use std::time::Instant;
//! use parm::coordinator::batcher::SealedBatch;
//! use parm::coordinator::metrics::Outcome;
//! use parm::coordinator::scheme::{
//!     DispatchPlan, PoolLayout, RedundancyScheme, Resolution, Target,
//! };
//! use parm::runtime::instance::{Completion, Job, JobKind};
//!
//! struct PassThrough {
//!     next_group: u64,
//! }
//!
//! impl RedundancyScheme for PassThrough {
//!     fn name(&self) -> &'static str {
//!         "pass-through"
//!     }
//!     fn extra_instances(&self, _m: usize) -> usize {
//!         0 // no redundancy: deployed instances only
//!     }
//!     fn layout(&self, m: usize) -> PoolLayout {
//!         PoolLayout { deployed: (0..m).collect(), parity: Vec::new(), approx: None }
//!     }
//!     fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
//!         let group = self.next_group;
//!         self.next_group += 1;
//!         DispatchPlan {
//!             jobs: vec![(
//!                 Target::Deployed,
//!                 Job {
//!                     kind: JobKind::Replica { group, slot: 0 },
//!                     input: batch.input,
//!                     query_ids: batch.query_ids,
//!                     dispatched_at: Instant::now(),
//!                 },
//!             )],
//!             resolutions: Vec::new(),
//!         }
//!     }
//!     fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
//!         vec![Resolution {
//!             query_ids: c.query_ids,
//!             at: c.finished_at,
//!             outcome: Outcome::Native,
//!         }]
//!     }
//! }
//!
//! // The session calls it exactly like this:
//! use parm::tensor::Tensor;
//! let mut s = PassThrough { next_group: 0 };
//! let plan = s.plan_dispatch(SealedBatch {
//!     query_ids: vec![0, 1],
//!     input: Tensor::filled(vec![2, 4], 1.0),
//!     oldest_arrival: Instant::now(),
//! });
//! assert_eq!(plan.jobs.len(), 1);
//! let resolved = s.on_completion(Completion {
//!     kind: JobKind::Replica { group: 0, slot: 0 },
//!     instance: 0,
//!     query_ids: vec![0, 1],
//!     output: Tensor::filled(vec![2, 4], 0.5),
//!     finished_at: Instant::now(),
//!     exec_time: std::time::Duration::ZERO,
//! });
//! assert_eq!(resolved[0].query_ids, vec![0, 1]);
//! ```
//!
//! To expose it declaratively (config files, CLI), also give [`Mode`] a
//! variant and an arm in [`Mode::scheme`]; for programmatic use, handing
//! the boxed scheme to a session directly works just as well.
//!
//! ## Dynamic-topology schemes
//!
//! Nothing above forces the three answers to be *constants*. A scheme
//! whose redundancy adapts at runtime — the rateless scheme in
//! [`crate::coordinator::adaptive`] is the shipped example — answers
//! them as follows:
//!
//! - **Topology is the ceiling, not the operating point.**
//!   [`RedundancyScheme::extra_instances`] / [`RedundancyScheme::layout`]
//!   are consulted once at build time, so provision pools for the
//!   *maximum* redundancy you may ever dispatch (`r_max` parity pools for
//!   rateless). Idle provisioned pools cost threads, not work.
//! - **Dispatch decides the fan-out per group.** `plan_dispatch` may emit
//!   any number of jobs: rateless consults its straggler predictor at
//!   group-seal time and emits `r ∈ [r_min, r_max]` parity jobs for that
//!   group only. Per-group bookkeeping must then carry the group's own
//!   `r` — [`crate::coordinator::coding::GroupTracker::register_with_r`]
//!   exists for exactly this.
//! - **Resolution must tolerate mixed generations.** Completions from
//!   groups sealed under a different `r` arrive interleaved; keying all
//!   state by group id (as `GroupTracker` does) makes this free. Feed
//!   your estimator from completions here — they carry the worker's
//!   timestamp and instance id.
//! - **Expose what you adapt.** Implement [`RedundancyScheme::telemetry`]
//!   so sessions ([`crate::coordinator::session::ServiceHandle::scheme_telemetry`])
//!   can surface the live operating point (last chosen `r`, the
//!   unavailability estimate) to examples, benches, and dashboards.

use std::time::Instant;

use crate::coordinator::batcher::SealedBatch;
use crate::coordinator::coding::GroupTracker;
use crate::coordinator::encoder::Encoder;
use crate::coordinator::metrics::Outcome;
use crate::coordinator::service::Mode;
use crate::runtime::instance::{Completion, Job, JobKind};
use crate::util::arena::ProbeMap;

/// Which pool a planned job goes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    Deployed,
    /// The r_index-th parity pool.
    Parity(usize),
    /// The approximate-backup pool.
    Approx,
}

/// Instance-id layout a scheme needs, consumed by the session builder.
/// Ids are global (indices into the cluster-wide Network/FaultPlan).
pub struct PoolLayout {
    pub deployed: Vec<usize>,
    /// One id set per parity pool (index = r_index).
    pub parity: Vec<Vec<usize>>,
    pub approx: Option<Vec<usize>>,
}

/// Live operating point of an adaptive scheme (see
/// [`RedundancyScheme::telemetry`]): what the scheme is *currently*
/// doing, as opposed to the cumulative [`RedundancyScheme::reconstructions`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeTelemetry {
    /// Redundancy chosen for the most recently sealed coding group.
    pub last_r: usize,
    /// The scheme's current estimate of per-slot unavailability.
    pub unavailability: f64,
    /// Coding groups sealed so far.
    pub groups_sealed: u64,
    /// Parity jobs dispatched so far (sum of per-group r); divided by
    /// `groups_sealed` this is the realized redundancy overhead.
    pub parity_jobs: u64,
}

/// A scheme's verdict that some queries now have predictions.
#[derive(Debug)]
pub struct Resolution {
    pub query_ids: Vec<u64>,
    /// When the resolving completion finished (latency accounting).
    pub at: Instant,
    pub outcome: Outcome,
}

/// What to do with one sealed batch.
#[derive(Debug, Default)]
pub struct DispatchPlan {
    pub jobs: Vec<(Target, Job)>,
    /// Resolutions surfaced as a side effect (e.g. buffered completions
    /// that became decodable when their coding group registered).
    pub resolutions: Vec<Resolution>,
}

/// A redundancy scheme: object-safe so sessions hold `Box<dyn ...>`.
///
/// A scheme instance is owned by one [`crate::coordinator::session::ServiceHandle`]
/// and called from its thread only — implementations keep plain mutable
/// state (coding groups, dedup maps) without locking.
pub trait RedundancyScheme: Send {
    fn name(&self) -> &'static str;

    /// Extra instances beyond the m deployed ones this scheme uses.
    fn extra_instances(&self, m: usize) -> usize;

    /// How the `m + extra_instances(m)` instance ids split into pools.
    fn layout(&self, m: usize) -> PoolLayout;

    /// Plan the dispatch of one sealed (already padded) query batch.
    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan;

    /// Fold in a worker completion; returns the queries it resolves.
    /// Duplicate resolutions for a query id are fine — the session
    /// resolves each query at most once (first verdict wins).
    fn on_completion(&mut self, c: Completion) -> Vec<Resolution>;

    /// Resolutions that originated *outside* this session's own dispatch
    /// and completion callbacks — e.g. a cross-shard decode performed by
    /// another session's parity leg
    /// ([`crate::coordinator::cross_shard`]). The session calls this at
    /// its pump cadence, so externally decoded queries resolve promptly
    /// even when this session's own cluster is entirely dead and no
    /// completion will ever fire again. The default is empty, which is
    /// correct for any scheme whose resolutions always ride a local
    /// callback.
    fn drain_external(&mut self) -> Vec<Resolution> {
        Vec::new()
    }

    /// Total decoder reconstructions performed so far.
    fn reconstructions(&self) -> u64 {
        0
    }

    /// Live telemetry for adaptive schemes; `None` (the default) for
    /// fixed-topology schemes whose dispatch never changes shape.
    fn telemetry(&self) -> Option<SchemeTelemetry> {
        None
    }

    /// Join the session's serving-path journal
    /// ([`crate::coordinator::journal`]): schemes that manage coding
    /// groups record their [`Seal`](crate::coordinator::journal::Event::Seal)
    /// and [`Decode`](crate::coordinator::journal::Event::Decode) events
    /// through the handed recorder. The default drops it — correct for
    /// schemes with no group state worth journaling (replication and the
    /// no-redundancy baselines).
    fn attach_recorder(&mut self, _recorder: crate::coordinator::journal::Recorder) {}
}

impl Mode {
    /// Instantiate the scheme this mode describes.
    pub fn scheme(&self) -> Box<dyn RedundancyScheme> {
        match self {
            Mode::Parm { k, encoders } => Box::new(ParmScheme::new(*k, encoders.clone())),
            Mode::NoRedundancy => Box::new(NoRedundancyScheme::default()),
            Mode::EqualResources { k } => Box::new(EqualResourcesScheme::new(*k)),
            Mode::ApproxBackup { k } => Box::new(ApproxBackupScheme::new(*k)),
            Mode::Replication { copies } => Box::new(ReplicationScheme::new(*copies)),
            Mode::Rateless { k, r_min, r_max, halflife } => {
                Box::new(crate::coordinator::adaptive::RatelessScheme::new(
                    crate::coordinator::adaptive::RatelessConfig::new(
                        *k, *r_min, *r_max, *halflife,
                    ),
                ))
            }
            // A cross-shard coding group spans sessions, so no single
            // session can instantiate it. ServiceBuilder::build rejects
            // the mode with a proper error before ever reaching here;
            // the sharded tier injects per-shard CrossShardScheme
            // instances via ServiceBuilder::with_scheme instead.
            Mode::CrossShard { .. } => unreachable!(
                "Mode::CrossShard is served through shards::CrossShardFrontend"
            ),
        }
    }
}

pub(crate) fn job(kind: JobKind, batch: &SealedBatch) -> Job {
    Job {
        kind,
        input: batch.input.clone(),
        query_ids: batch.query_ids.clone(),
        dispatched_at: Instant::now(),
    }
}

/// ceil(m / k): instances per parity/backup pool.
pub(crate) fn per_pool(m: usize, k: usize) -> usize {
    (m + k - 1) / k
}

// ------------------------------------------------------------------------
// ParM (§3)
// ------------------------------------------------------------------------

/// ParM: accumulate k data batches per coding group, dispatch one encoded
/// parity batch per parity model, decode stragglers on completion.
pub struct ParmScheme {
    k: usize,
    encoders: Vec<Encoder>,
    tracker: GroupTracker,
    /// The open (unsealed) coding group's batches, in slot order.
    accum: Vec<(Vec<u64>, crate::tensor::Tensor)>,
    /// Id of the open group; every id below it is sealed & registered, so
    /// "is this group registered?" is a comparison, not a set lookup.
    next_group: u64,
    /// Data completions that raced ahead of their group's registration.
    /// Only the open group can orphan (drained when it seals), so this
    /// holds at most one live entry — an association list beats a map:
    /// no hashing, and the retired `Vec` bodies recycle via `swap_remove`.
    orphans: Vec<(u64, Vec<Completion>)>,
    /// Serving-path journal (disabled unless the session attached one).
    recorder: crate::coordinator::journal::Recorder,
}

impl ParmScheme {
    pub fn new(k: usize, encoders: Vec<Encoder>) -> ParmScheme {
        assert!(k >= 1, "coding group size must be >= 1");
        assert!(!encoders.is_empty(), "ParM needs at least one encoder");
        ParmScheme {
            tracker: GroupTracker::new(k, &encoders),
            k,
            encoders,
            accum: Vec::new(),
            next_group: 0,
            orphans: Vec::new(),
            recorder: crate::coordinator::journal::Recorder::disabled(),
        }
    }

    fn registered(&self, group: u64) -> bool {
        group < self.next_group
    }

    /// Buffer a completion that raced ahead of its group's registration.
    fn orphan(&mut self, group: u64, c: Completion) {
        match self.orphans.iter_mut().find(|(g, _)| *g == group) {
            Some((_, cs)) => cs.push(c),
            None => self.orphans.push((group, vec![c])),
        }
    }

    fn apply_tracked(&mut self, c: Completion, out: &mut Vec<Resolution>) {
        let at = c.finished_at;
        let res = match c.kind {
            JobKind::Data { group, slot } => self.tracker.on_data(group, slot, c.output),
            JobKind::Parity { group, r_index } => {
                self.tracker.on_parity(group, r_index, c.output)
            }
            _ => return,
        };
        for sr in res.resolved {
            if sr.reconstructed {
                self.recorder.record(&crate::coordinator::journal::Event::Decode {
                    group: match c.kind {
                        JobKind::Data { group, .. } | JobKind::Parity { group, .. } => group,
                        _ => 0,
                    },
                    slot: sr.slot as u64,
                });
            }
            out.push(Resolution {
                query_ids: sr.query_ids,
                at,
                outcome: if sr.reconstructed {
                    Outcome::Reconstructed
                } else {
                    Outcome::Native
                },
            });
        }
    }
}

impl RedundancyScheme for ParmScheme {
    fn name(&self) -> &'static str {
        "parm"
    }

    fn extra_instances(&self, m: usize) -> usize {
        per_pool(m, self.k) * self.encoders.len().max(1)
    }

    fn layout(&self, m: usize) -> PoolLayout {
        let per = per_pool(m, self.k);
        PoolLayout {
            deployed: (0..m).collect(),
            parity: (0..self.encoders.len())
                .map(|ri| (m + ri * per..m + (ri + 1) * per).collect())
                .collect(),
            approx: None,
        }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let mut plan = DispatchPlan::default();
        let gid = self.next_group;
        let slot = self.accum.len();
        plan.jobs
            .push((Target::Deployed, job(JobKind::Data { group: gid, slot }, &batch)));
        self.accum.push((batch.query_ids, batch.input));

        if self.accum.len() == self.k {
            // Seal the coding group: register, encode, dispatch parities.
            let ids: Vec<Vec<u64>> = self.accum.iter().map(|(i, _)| i.clone()).collect();
            self.tracker.register(gid, ids);
            self.recorder.record(&crate::coordinator::journal::Event::Seal {
                group: gid,
                k: self.k as u64,
                r: self.encoders.len() as u64,
            });
            self.next_group += 1;
            let inputs: Vec<&crate::tensor::Tensor> =
                self.accum.iter().map(|(_, t)| t).collect();
            for (ri, enc) in self.encoders.iter().enumerate() {
                match enc.encode_batches(&inputs) {
                    Ok(parity) => plan.jobs.push((
                        Target::Parity(ri),
                        Job {
                            kind: JobKind::Parity { group: gid, r_index: ri },
                            input: parity,
                            query_ids: Vec::new(),
                            dispatched_at: Instant::now(),
                        },
                    )),
                    Err(e) => log::error!("encode failed: {e}"),
                }
            }
            self.accum.clear();
            // Completions that arrived before the group registered.
            if let Some(at) = self.orphans.iter().position(|(g, _)| *g == gid) {
                let (_, cs) = self.orphans.swap_remove(at);
                for c in cs {
                    self.apply_tracked(c, &mut plan.resolutions);
                }
            }
        }
        plan
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        let mut out = Vec::new();
        match c.kind {
            JobKind::Data { group, .. } => {
                // §3.1: predictions returned by model instances go straight
                // back to clients, independent of coding-group state.
                out.push(Resolution {
                    query_ids: c.query_ids.clone(),
                    at: c.finished_at,
                    outcome: Outcome::Native,
                });
                if self.registered(group) {
                    self.apply_tracked(c, &mut out);
                } else {
                    self.orphan(group, c);
                }
            }
            JobKind::Parity { group, .. } => {
                // Parities dispatch at seal time, so the group is always
                // registered; buffer defensively anyway.
                if self.registered(group) {
                    self.apply_tracked(c, &mut out);
                } else {
                    self.orphan(group, c);
                }
            }
            JobKind::Replica { .. } | JobKind::Background => {}
        }
        out
    }

    fn reconstructions(&self) -> u64 {
        self.tracker.reconstructions
    }

    fn attach_recorder(&mut self, recorder: crate::coordinator::journal::Recorder) {
        self.recorder = recorder;
    }
}

// ------------------------------------------------------------------------
// Replica-style schemes (baselines)
// ------------------------------------------------------------------------

/// First-copy-wins bookkeeping shared by every replica-style scheme.
/// Entries are removed once all copies of a group completed, so memory
/// stays bounded by in-flight work (plus any copies lost to failures).
/// Group ids are dense sequential u64s, so a [`ProbeMap`] replaces the
/// seed's `HashMap` on this per-completion path (ROADMAP item 2).
#[derive(Default)]
struct ReplicaTracker {
    /// group -> (resolved?, completions seen).
    inflight: ProbeMap<(bool, u32)>,
}

impl ReplicaTracker {
    /// Returns the outcome to resolve with, if this completion is first.
    fn on_completion(&mut self, c: &Completion, copies: usize) -> Option<Outcome> {
        let JobKind::Replica { group, slot } = c.kind else { return None };
        let (resolved, seen) = self.inflight.get(group).unwrap_or((false, 0));
        let seen = seen + 1;
        let first = !resolved;
        if (seen as usize) >= copies {
            self.inflight.remove(group);
        } else {
            self.inflight.insert(group, (true, seen));
        }
        if first {
            Some(if slot > 0 { Outcome::Replica } else { Outcome::Native })
        } else {
            None
        }
    }
}

fn replica_resolution(c: &Completion, outcome: Outcome) -> Resolution {
    Resolution { query_ids: c.query_ids.clone(), at: c.finished_at, outcome }
}

/// No redundancy: just the m deployed instances (§5.1 baseline floor).
#[derive(Default)]
pub struct NoRedundancyScheme {
    next_group: u64,
}

impl RedundancyScheme for NoRedundancyScheme {
    fn name(&self) -> &'static str {
        "none"
    }

    fn extra_instances(&self, _m: usize) -> usize {
        0
    }

    fn layout(&self, m: usize) -> PoolLayout {
        PoolLayout { deployed: (0..m).collect(), parity: Vec::new(), approx: None }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let gid = self.next_group;
        self.next_group += 1;
        DispatchPlan {
            jobs: vec![(
                Target::Deployed,
                job(JobKind::Replica { group: gid, slot: 0 }, &batch),
            )],
            resolutions: Vec::new(),
        }
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        match c.kind {
            // Single copy: every replica completion resolves its queries.
            JobKind::Replica { .. } => vec![replica_resolution(&c, Outcome::Native)],
            _ => Vec::new(),
        }
    }
}

/// Equal-Resources (§5.1): ParM's instance count, all serving the
/// deployed model behind one load balancer.
pub struct EqualResourcesScheme {
    k: usize,
    next_group: u64,
}

impl EqualResourcesScheme {
    pub fn new(k: usize) -> EqualResourcesScheme {
        EqualResourcesScheme { k, next_group: 0 }
    }
}

impl RedundancyScheme for EqualResourcesScheme {
    fn name(&self) -> &'static str {
        "equal-resources"
    }

    fn extra_instances(&self, m: usize) -> usize {
        per_pool(m, self.k)
    }

    fn layout(&self, m: usize) -> PoolLayout {
        // The extra instances join the deployed pool.
        PoolLayout {
            deployed: (0..m + self.extra_instances(m)).collect(),
            parity: Vec::new(),
            approx: None,
        }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let gid = self.next_group;
        self.next_group += 1;
        DispatchPlan {
            jobs: vec![(
                Target::Deployed,
                job(JobKind::Replica { group: gid, slot: 0 }, &batch),
            )],
            resolutions: Vec::new(),
        }
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        match c.kind {
            JobKind::Replica { .. } => vec![replica_resolution(&c, Outcome::Native)],
            _ => Vec::new(),
        }
    }
}

/// Approximate backup (§5.2.6): every batch also goes to a pool of m/k
/// cheaper models; whichever prediction arrives first wins.
pub struct ApproxBackupScheme {
    k: usize,
    next_group: u64,
    replicas: ReplicaTracker,
}

impl ApproxBackupScheme {
    pub fn new(k: usize) -> ApproxBackupScheme {
        ApproxBackupScheme { k, next_group: 0, replicas: ReplicaTracker::default() }
    }
}

impl RedundancyScheme for ApproxBackupScheme {
    fn name(&self) -> &'static str {
        "approx-backup"
    }

    fn extra_instances(&self, m: usize) -> usize {
        per_pool(m, self.k)
    }

    fn layout(&self, m: usize) -> PoolLayout {
        PoolLayout {
            deployed: (0..m).collect(),
            parity: Vec::new(),
            approx: Some((m..m + self.extra_instances(m)).collect()),
        }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let gid = self.next_group;
        self.next_group += 1;
        DispatchPlan {
            jobs: vec![
                (Target::Deployed, job(JobKind::Replica { group: gid, slot: 0 }, &batch)),
                (Target::Approx, job(JobKind::Replica { group: gid, slot: 1 }, &batch)),
            ],
            resolutions: Vec::new(),
        }
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        match self.replicas.on_completion(&c, 2) {
            Some(outcome) => vec![replica_resolution(&c, outcome)],
            None => Vec::new(),
        }
    }
}

/// Full replication (§2.2): every batch dispatched `copies` times to the
/// deployed pool; first copy wins.
pub struct ReplicationScheme {
    copies: usize,
    next_group: u64,
    replicas: ReplicaTracker,
}

impl ReplicationScheme {
    pub fn new(copies: usize) -> ReplicationScheme {
        assert!(copies >= 1);
        ReplicationScheme { copies, next_group: 0, replicas: ReplicaTracker::default() }
    }
}

impl RedundancyScheme for ReplicationScheme {
    fn name(&self) -> &'static str {
        "replication"
    }

    fn extra_instances(&self, _m: usize) -> usize {
        0
    }

    fn layout(&self, m: usize) -> PoolLayout {
        PoolLayout { deployed: (0..m).collect(), parity: Vec::new(), approx: None }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let gid = self.next_group;
        self.next_group += 1;
        DispatchPlan {
            jobs: (0..self.copies)
                .map(|c| {
                    (Target::Deployed, job(JobKind::Replica { group: gid, slot: c }, &batch))
                })
                .collect(),
            resolutions: Vec::new(),
        }
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        match self.replicas.on_completion(&c, self.copies) {
            Some(outcome) => vec![replica_resolution(&c, outcome)],
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn sealed(ids: Vec<u64>, v: f32) -> SealedBatch {
        SealedBatch {
            input: Tensor::filled(vec![ids.len().max(1), 2], v),
            query_ids: ids,
            oldest_arrival: Instant::now(),
        }
    }

    fn completion(kind: JobKind, ids: Vec<u64>, out: Tensor) -> Completion {
        Completion {
            kind,
            instance: 0,
            query_ids: ids,
            output: out,
            finished_at: Instant::now(),
            exec_time: std::time::Duration::ZERO,
        }
    }

    #[test]
    fn mode_scheme_names_and_extras_match_legacy_enum() {
        let modes = [
            Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
            Mode::NoRedundancy,
            Mode::EqualResources { k: 3 },
            Mode::ApproxBackup { k: 2 },
            Mode::Replication { copies: 2 },
            Mode::Rateless {
                k: 2,
                r_min: 1,
                r_max: 2,
                halflife: std::time::Duration::from_millis(500),
            },
        ];
        for m in &modes {
            let s = m.scheme();
            assert_eq!(s.name(), m.name());
            for inst in [1usize, 4, 12, 24] {
                assert_eq!(s.extra_instances(inst), m.extra_instances(inst), "{}", s.name());
            }
        }
    }

    #[test]
    fn layouts_partition_the_cluster() {
        for (mode, m) in [
            (Mode::Parm { k: 2, encoders: vec![Encoder::sum(2), Encoder::sum_r(2, 1)] }, 4),
            (Mode::NoRedundancy, 5),
            (Mode::EqualResources { k: 2 }, 4),
            (Mode::ApproxBackup { k: 2 }, 4),
            (Mode::Replication { copies: 3 }, 6),
            (
                Mode::Rateless {
                    k: 3,
                    r_min: 1,
                    r_max: 3,
                    halflife: std::time::Duration::from_millis(500),
                },
                7,
            ),
        ] {
            let s = mode.scheme();
            let total = m + s.extra_instances(m);
            let l = s.layout(m);
            let mut all: Vec<usize> = l.deployed.clone();
            for p in &l.parity {
                all.extend(p);
            }
            if let Some(a) = &l.approx {
                all.extend(a);
            }
            all.sort_unstable();
            assert_eq!(all, (0..total).collect::<Vec<_>>(), "{} m={m}", s.name());
        }
    }

    #[test]
    fn parm_seals_group_and_emits_parity() {
        let mut s = ParmScheme::new(2, vec![Encoder::sum(2)]);
        let p1 = s.plan_dispatch(sealed(vec![0], 1.0));
        assert_eq!(p1.jobs.len(), 1, "first batch: data only");
        assert!(matches!(p1.jobs[0].1.kind, JobKind::Data { group: 0, slot: 0 }));
        let p2 = s.plan_dispatch(sealed(vec![1], 2.0));
        assert_eq!(p2.jobs.len(), 2, "second batch seals: data + parity");
        assert!(matches!(p2.jobs[1].0, Target::Parity(0)));
        assert!(matches!(p2.jobs[1].1.kind, JobKind::Parity { group: 0, r_index: 0 }));
        // Parity input = sum of the two batches.
        assert_eq!(p2.jobs[1].1.input.data()[0], 3.0);
        // Next batch opens group 1.
        let p3 = s.plan_dispatch(sealed(vec![2], 0.0));
        assert!(matches!(p3.jobs[0].1.kind, JobKind::Data { group: 1, slot: 0 }));
    }

    #[test]
    fn parm_reconstructs_straggler_via_on_completion() {
        let mut s = ParmScheme::new(2, vec![Encoder::sum(2)]);
        let _ = s.plan_dispatch(sealed(vec![10], 0.0));
        let _ = s.plan_dispatch(sealed(vec![11], 0.0));
        // Data slot 0 arrives; slot 1 never does; parity decodes it.
        let f0 = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let fp = Tensor::new(vec![1, 2], vec![4.0, 6.0]).unwrap();
        let r0 = s.on_completion(completion(
            JobKind::Data { group: 0, slot: 0 },
            vec![10],
            f0,
        ));
        assert!(r0.iter().any(|r| r.outcome == Outcome::Native && r.query_ids == vec![10]));
        let r1 = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 0 },
            vec![],
            fp,
        ));
        let rec = r1.iter().find(|r| r.outcome == Outcome::Reconstructed).unwrap();
        assert_eq!(rec.query_ids, vec![11]);
        assert_eq!(s.reconstructions(), 1);
    }

    #[test]
    fn parm_buffers_orphan_completions_until_seal() {
        let mut s = ParmScheme::new(2, vec![Encoder::sum(2)]);
        let _ = s.plan_dispatch(sealed(vec![0], 0.0));
        // Completion for the open group's slot 0 before the group seals.
        let r = s.on_completion(completion(
            JobKind::Data { group: 0, slot: 0 },
            vec![0],
            Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap(),
        ));
        assert_eq!(r.len(), 1, "native resolution still immediate");
        // Sealing replays the orphan into the tracker; the parity can now
        // decode the other slot with no further data completions.
        let plan = s.plan_dispatch(sealed(vec![1], 0.0));
        assert!(plan.resolutions.iter().all(|x| x.outcome == Outcome::Native));
        let r = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 0 },
            vec![],
            Tensor::new(vec![1, 2], vec![3.0, 3.0]).unwrap(),
        ));
        let rec = r.iter().find(|x| x.outcome == Outcome::Reconstructed).unwrap();
        assert_eq!(rec.query_ids, vec![1]);
    }

    #[test]
    fn replication_first_copy_wins_and_state_is_pruned() {
        let mut s = ReplicationScheme::new(2);
        let plan = s.plan_dispatch(sealed(vec![5], 0.0));
        assert_eq!(plan.jobs.len(), 2);
        let out = Tensor::new(vec![1, 2], vec![0.0, 0.0]).unwrap();
        let r1 = s.on_completion(completion(
            JobKind::Replica { group: 0, slot: 1 },
            vec![5],
            out.clone(),
        ));
        assert_eq!(r1.len(), 1);
        assert_eq!(r1[0].outcome, Outcome::Replica, "backup copy answered first");
        let r2 = s.on_completion(completion(
            JobKind::Replica { group: 0, slot: 0 },
            vec![5],
            out,
        ));
        assert!(r2.is_empty(), "second copy deduplicated");
        assert!(s.replicas.inflight.is_empty(), "entry pruned after all copies");
    }

    #[test]
    fn approx_backup_dispatches_to_both_pools() {
        let mut s = ApproxBackupScheme::new(2);
        let plan = s.plan_dispatch(sealed(vec![7], 0.0));
        let targets: Vec<Target> = plan.jobs.iter().map(|(t, _)| *t).collect();
        assert_eq!(targets, vec![Target::Deployed, Target::Approx]);
        let out = Tensor::new(vec![1, 2], vec![0.0, 0.0]).unwrap();
        let r = s.on_completion(completion(
            JobKind::Replica { group: 0, slot: 0 },
            vec![7],
            out,
        ));
        assert_eq!(r[0].outcome, Outcome::Native);
    }
}

//! Long-lived serving sessions: [`ServiceBuilder`] assembles the cluster
//! substrate, [`ServiceHandle`] serves queries against it.
//!
//! The seed's `Service::run` was a one-shot batch experiment: it built the
//! cluster, generated a Poisson arrival stream, collected completions on a
//! dedicated thread (fed through a relay thread), and tore everything
//! down. This module splits that monolith along the paper's own seams:
//!
//! - [`ServiceBuilder::build`] constructs the substrate once — network,
//!   fault plan, tenancy, background shuffles, and one instance pool per
//!   [`crate::coordinator::scheme::PoolLayout`] entry — and calibrates the
//!   service-time model from the real executables;
//! - [`ServiceHandle`] is the client surface: [`ServiceHandle::submit`]
//!   enqueues a query and returns its [`QueryId`]; [`ServiceHandle::poll`]
//!   / [`ServiceHandle::drain`] return [`Resolved`] predictions;
//!   [`ServiceHandle::shutdown`] stops the cluster and yields the run's
//!   [`RunMetrics`]-bearing [`RunResult`].
//!
//! Threading: instance workers send [`Completion`]s on a sharded MPSC
//! bus ([`crate::util::bus`]) — each worker's sender is pinned to one of
//! N producer shards, so workers never contend on a single channel
//! mutex, and the handle sweeps whole shards per lock acquisition
//! instead of one `try_recv` per completion (ROADMAP item 2). The
//! handle owns the receiving end plus all coordination state — batcher,
//! scheme, pending table, metrics — and processes events on the
//! caller's thread. Completions are timestamped by the workers, so lazy
//! processing never distorts latency accounting. The handle is `Send`
//! but single-consumer: to serve many concurrent submitters, hand it to
//! [`crate::coordinator::frontend::ServingFrontend`], whose dispatcher
//! thread multiplexes [`crate::coordinator::frontend::ServiceClient`]s
//! onto it (see `docs/ARCHITECTURE.md` for the full thread/channel map).
//!
//! Live observability: the handle keeps a sliding [`LatencyWindow`]
//! alongside the cumulative [`RunMetrics`], so callers can
//! [`ServiceHandle::window_snapshot`] a running session at any time
//! instead of waiting for `shutdown`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::cluster::network::{Network, ShuffleGen};
use crate::cluster::tenancy::Tenancy;
use crate::coordinator::batcher::{Batcher, PendingQuery, SealedBatch};
use crate::coordinator::journal::{outcome_byte, Event, JobClass, Recorder};
use crate::coordinator::metrics::{LatencyWindow, Outcome, RunMetrics, WindowSnapshot};
use crate::coordinator::scheme::{RedundancyScheme, Resolution, SchemeTelemetry, Target};
use crate::coordinator::service::{measure_service, ModelSet, RunResult, ServiceConfig};
use crate::runtime::engine::Executable;
use crate::runtime::instance::{Completion, Execution, ServiceModel, WorkerEnv};
use crate::runtime::pool::Pool;
use crate::telemetry::{Counter, Registry, Summary};
use crate::tensor::Tensor;
use crate::util::bus::{self, BusReceiver, RecvStatus};
use crate::util::rng::Pcg64;
use crate::util::sync::{CondvarExt, LockExt};

/// Identifier handed back by [`ServiceHandle::submit`].
pub type QueryId = u64;

/// Max completions folded per pacing-loop pass (see
/// [`ServiceHandle::run_open_loop`]): small enough that the arrival
/// due-check runs at sub-millisecond cadence under a completion flood,
/// large enough that steady-state traffic clears in one pass.
const PACE_FOLD_BUDGET: usize = 256;

/// Pacing-loop hook for [`ServiceHandle::run_open_loop_observed`]: fire
/// `sink` when the sample cadence is due (catching up if the loop lagged
/// a tick) and report the next sample instant as an extra wake deadline.
fn maybe_sample(
    h: &mut ServiceHandle,
    now: Instant,
    start: Instant,
    sample_every: Option<Duration>,
    next_sample: &mut Option<Instant>,
    sink: &mut dyn FnMut(Duration, WindowSnapshot),
) -> Option<Instant> {
    if let (Some(every), Some(at)) = (sample_every, *next_sample) {
        if now >= at {
            sink(now - start, h.window.snapshot(now));
            // Fixed cadence; skip forward if we lagged a tick.
            let mut next = at + every;
            while next <= now {
                next += every;
            }
            *next_sample = Some(next);
        }
    }
    *next_sample
}

/// The session's publications into the fleet-wide metric registry
/// ([`crate::telemetry`]). Hot-path hooks (`on_submit`, `on_resolved`,
/// `on_rejected`) are wait-free atomic bumps on pre-registered handles;
/// the window/scheme gauges are folded in at `telemetry_every` cadence
/// from the pump loop (`maybe_publish`) — never from a scraper.
struct SessionTelemetry {
    registry: Registry,
    submitted: Counter,
    resolved: Counter,
    rejected: Counter,
    outcome_native: Counter,
    outcome_reconstructed: Counter,
    outcome_replica: Counter,
    outcome_default: Counter,
    latency_ms: Summary,
    /// Slowest-query exemplars seen so far, worst first — the live-path
    /// feed for the `parm_slow_query_*` gauge family, so operators see
    /// which queries hurt without a journal mining pass.
    slow: Vec<SlowExemplar>,
    every: Duration,
    next_publish: Instant,
}

/// One slowest-query exemplar: the same (qid, latency, outcome) triple
/// `parm trace` reconstructs from the journal, published live.
#[derive(Clone, Copy, Debug)]
struct SlowExemplar {
    qid: QueryId,
    latency_ms: f64,
    outcome: Outcome,
}

/// How many slowest-query exemplars the session keeps and publishes.
const SLOW_EXEMPLARS: usize = 5;

impl SessionTelemetry {
    fn new(registry: Registry, every: Duration) -> SessionTelemetry {
        let outcome = |o: &str| {
            registry.counter(
                "parm_outcome_total",
                "Resolved queries by outcome.",
                &[("outcome", o)],
            )
        };
        SessionTelemetry {
            submitted: registry.counter(
                "parm_queries_submitted_total",
                "Queries accepted into the session.",
                &[],
            ),
            resolved: registry.counter(
                "parm_queries_resolved_total",
                "Queries resolved (any outcome, defaults included).",
                &[],
            ),
            rejected: registry.counter(
                "parm_queries_rejected_total",
                "Queries turned away by admission control.",
                &[],
            ),
            outcome_native: outcome("native"),
            outcome_reconstructed: outcome("reconstructed"),
            outcome_replica: outcome("replica"),
            outcome_default: outcome("default"),
            latency_ms: registry.summary(
                "parm_latency_ms",
                "Frontend arrival to prediction available, milliseconds.",
                &[],
            ),
            slow: Vec::new(),
            every,
            next_publish: Instant::now() + every,
            registry,
        }
    }

    fn on_resolved(&mut self, qid: QueryId, outcome: Outcome, latency: Duration) {
        self.resolved.inc();
        match outcome {
            Outcome::Native => self.outcome_native.inc(),
            Outcome::Reconstructed => self.outcome_reconstructed.inc(),
            Outcome::Replica => self.outcome_replica.inc(),
            Outcome::Default => self.outcome_default.inc(),
        }
        let ms = latency.as_secs_f64() * 1e3;
        self.latency_ms.observe(ms);
        // Keep the worst SLOW_EXEMPLARS, sorted worst-first. Only
        // touched when the new latency beats the current floor, so the
        // steady-state cost is one comparison.
        if self.slow.len() < SLOW_EXEMPLARS
            || ms > self.slow.last().map_or(0.0, |e| e.latency_ms)
        {
            let at = self.slow.partition_point(|e| e.latency_ms >= ms);
            self.slow.insert(at, SlowExemplar { qid, latency_ms: ms, outcome });
            self.slow.truncate(SLOW_EXEMPLARS);
        }
    }

    /// Fold the live window and the scheme's operating point into
    /// gauges if the cadence is due. Runs on the session's own pump
    /// thread; cost is one window snapshot, same as any
    /// `window_snapshot` caller pays.
    fn maybe_publish(&mut self, window: &mut LatencyWindow, scheme: &dyn RedundancyScheme) {
        let now = Instant::now();
        if now < self.next_publish {
            return;
        }
        let mut next = self.next_publish + self.every;
        while next <= now {
            next += self.every;
        }
        self.next_publish = next;
        self.publish(window, scheme, now);
    }

    fn publish(&self, window: &mut LatencyWindow, scheme: &dyn RedundancyScheme, now: Instant) {
        let snap = window.snapshot(now);
        crate::telemetry::publish_window(&self.registry, "parm_session_window_", &[], &snap);
        for (i, e) in self.slow.iter().enumerate() {
            let rank = i.to_string();
            let labels = [("rank", rank.as_str())];
            self.registry
                .gauge(
                    "parm_slow_query_latency_ms",
                    "Latency of the rank-th slowest query so far.",
                    &labels,
                )
                .set(e.latency_ms);
            self.registry
                .gauge(
                    "parm_slow_query_qid",
                    "Session-local query id of the rank-th slowest query.",
                    &labels,
                )
                .set(e.qid as f64);
            self.registry
                .gauge(
                    "parm_slow_query_outcome",
                    "Outcome byte of the rank-th slowest query (0 native, 1 \
                     reconstructed, 2 replica, 3 default).",
                    &labels,
                )
                .set(f64::from(outcome_byte(e.outcome)));
        }
        if let Some(t) = scheme.telemetry() {
            self.registry
                .gauge("parm_scheme_last_r", "Redundancy chosen for the last sealed group.", &[])
                .set(t.last_r as f64);
            self.registry
                .gauge(
                    "parm_scheme_unavailability",
                    "Scheme's current per-slot unavailability estimate.",
                    &[],
                )
                .set(t.unavailability);
            self.registry
                .counter("parm_scheme_groups_sealed_total", "Coding groups sealed.", &[])
                .raise_to(t.groups_sealed);
            self.registry
                .counter(
                    "parm_scheme_parity_jobs_total",
                    "Parity jobs dispatched (sum of per-group r).",
                    &[],
                )
                .raise_to(t.parity_jobs);
            let overhead =
                if t.groups_sealed == 0 { 0.0 } else { t.parity_jobs as f64 / t.groups_sealed as f64 };
            self.registry
                .gauge(
                    "parm_scheme_parity_overhead",
                    "Realized redundancy overhead: parity jobs per sealed group.",
                    &[],
                )
                .set(overhead);
        }
    }
}

/// A query whose prediction is now available at the frontend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolved {
    pub id: QueryId,
    pub outcome: Outcome,
    /// Frontend arrival -> prediction available (SLO value for defaults).
    pub latency: Duration,
}

/// Builds the cluster substrate for a [`ServiceHandle`].
pub struct ServiceBuilder {
    cfg: ServiceConfig,
    /// Explicit strategy object overriding `cfg.mode` (see
    /// [`ServiceBuilder::with_scheme`]).
    scheme: Option<Box<dyn RedundancyScheme>>,
}

impl ServiceBuilder {
    pub fn new(cfg: ServiceConfig) -> ServiceBuilder {
        ServiceBuilder { cfg, scheme: None }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Tweak the configuration before building.
    pub fn config_mut(&mut self) -> &mut ServiceConfig {
        &mut self.cfg
    }

    /// Serve this session with an explicit scheme instance instead of
    /// instantiating `cfg.mode`. This is how schemes that share state
    /// *across* sessions are injected — the cross-shard tier hands each
    /// shard a [`crate::coordinator::cross_shard::CrossShardScheme`]
    /// bound to the fleet's shared coding state. The scheme's
    /// `extra_instances`/`layout` drive pool provisioning exactly as a
    /// mode-instantiated scheme's would.
    pub fn with_scheme(mut self, scheme: Box<dyn RedundancyScheme>) -> ServiceBuilder {
        self.scheme = Some(scheme);
        self
    }

    /// Assemble the cluster and start serving. `sample_query` calibrates
    /// the service-time model (any representative query tensor).
    pub fn build(self, models: &ModelSet, sample_query: &Tensor) -> anyhow::Result<ServiceHandle> {
        let ServiceBuilder { cfg, scheme } = self;
        let started = Instant::now();
        let mut rng = Pcg64::new(cfg.seed);
        let recorder = cfg.recorder.clone();
        let mut scheme = match scheme {
            Some(s) => s,
            None => {
                anyhow::ensure!(
                    !matches!(cfg.mode, crate::coordinator::service::Mode::CrossShard { .. }),
                    "Mode::CrossShard coding groups span sessions; serve it through \
                     shards::CrossShardFrontend (a bare session cannot host it)"
                );
                cfg.mode.scheme()
            }
        };

        // ---- cluster substrate ----
        // Mode-instantiated and injected schemes alike join the session's
        // journal; the default hook is a no-op for schemes that keep no
        // group state worth recording.
        scheme.attach_recorder(recorder.clone());
        let extra = scheme.extra_instances(cfg.m);
        let total_instances = cfg.m + extra;
        let network = Network::new(total_instances, cfg.profile);
        // Every fault lands in the journal regardless of who injected it
        // (scripted harness, scheduled injector, or a manual kill).
        let faults = FaultPlan::new_recorded(total_instances, recorder.clone());
        let sample = Tensor::batch(&vec![sample_query.clone(); cfg.batch_size.max(1)])?;

        // Per-pool execution mode: calibrate a service-time model from the
        // real executable, or run inference per query (see Execution docs).
        let make_execution = |exe: &Arc<Executable>| -> anyhow::Result<Execution> {
            if cfg.modeled_execution {
                let model = ServiceModel::measure(exe, &sample, 60)
                    .map_err(|e| anyhow::anyhow!("calibration failed: {e}"))?;
                Ok(Execution::Modeled(Arc::new(model)))
            } else {
                Ok(Execution::Real)
            }
        };
        let deployed_execution = make_execution(&models.deployed)?;
        let mean_service = match &deployed_execution {
            Execution::Modeled(m) => m.mean(),
            Execution::Real => measure_service(&models.deployed, &sample, 10),
        };
        let tenancy = if cfg.light_tenancy {
            Tenancy::light(total_instances, mean_service, &mut rng)
        } else {
            Tenancy::none()
        };
        let env = Arc::new(WorkerEnv {
            profile: cfg.profile,
            network: network.clone(),
            tenancy,
            faults: faults.clone(),
            time_scale: cfg.time_scale,
            hol_range: cfg.hol_range,
            mean_service,
            dropped: AtomicU64::new(0),
        });

        let shuffles = if cfg.shuffles > 0 {
            Some(ShuffleGen::start(network.clone(), cfg.shuffles, cfg.time_scale, rng.next_u64()))
        } else {
            None
        };
        let fault_injector = if cfg.fault_schedule.is_empty() {
            None
        } else {
            Some(FaultInjector::start(faults.clone(), cfg.fault_schedule.clone()))
        };

        // ---- pools (layout dictated by the scheme) ----
        let layout = scheme.layout(cfg.m);
        // One completion-bus shard per instance (capped): workers spread
        // round-robin across shards, so no two instances share a channel
        // lock on the completion path.
        let (done_tx, done_rx) = bus::channel::<Completion>(total_instances.min(16));
        let deployed = Pool::spawn(
            "deployed",
            models.deployed.clone(),
            deployed_execution,
            layout.deployed,
            cfg.balancing,
            done_tx.clone(),
            env.clone(),
            rng.next_u64(),
        );
        let mut parity = Vec::new();
        for (ri, ids) in layout.parity.into_iter().enumerate() {
            let exe = models.parities.get(ri).ok_or_else(|| {
                anyhow::anyhow!(
                    "scheme {:?} needs parity model {ri}, ModelSet has {}",
                    scheme.name(),
                    models.parities.len()
                )
            })?;
            parity.push(Pool::spawn(
                &format!("parity{ri}"),
                exe.clone(),
                make_execution(exe)?,
                ids,
                cfg.balancing,
                done_tx.clone(),
                env.clone(),
                rng.next_u64(),
            ));
        }
        let approx = match layout.approx {
            Some(ids) => {
                let exe = models
                    .approx
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("{} needs models.approx", scheme.name()))?;
                Some(Pool::spawn(
                    "approx",
                    exe.clone(),
                    make_execution(&exe)?,
                    ids,
                    cfg.balancing,
                    done_tx.clone(),
                    env.clone(),
                    rng.next_u64(),
                ))
            }
            None => None,
        };
        // Workers hold the only senders: the bus disconnects once all
        // pools shut down.
        drop(done_tx);

        log::debug!(
            "session up: scheme={} m={} extra={} batch={}",
            scheme.name(),
            cfg.m,
            extra,
            cfg.batch_size
        );
        Ok(ServiceHandle {
            batcher: Batcher::new(cfg.batch_size, cfg.batch_timeout),
            slo: cfg.slo,
            scheme,
            pools: Some(PoolSet { deployed, parity, approx }),
            rx: done_rx,
            faults,
            shuffles,
            fault_injector,
            pending: PendingTable::new(),
            sweep_buf: Vec::new(),
            resolved_out: VecDeque::new(),
            metrics: RunMetrics::default(),
            window: LatencyWindow::new(cfg.metrics_window),
            submitted: 0,
            resolved_count: 0,
            next_qid: 0,
            mean_service,
            started,
            env,
            // The handle inherits the builder's stream, so experiment
            // randomness (tenancy, shuffles, pools, then arrivals) stays
            // one continuous seeded sequence as in the seed's Service::run.
            rng,
            recorder,
            telemetry: SessionTelemetry::new(cfg.telemetry.clone(), cfg.telemetry_every),
        })
    }
}

struct PoolSet {
    deployed: Pool,
    parity: Vec<Pool>,
    approx: Option<Pool>,
}

impl PoolSet {
    fn dispatch(&self, target: Target, job: crate::runtime::instance::Job) {
        match target {
            Target::Deployed => self.deployed.dispatch(job),
            Target::Parity(ri) => match self.parity.get(ri) {
                Some(p) => p.dispatch(job),
                None => log::error!("dispatch to missing parity pool {ri}"),
            },
            Target::Approx => match &self.approx {
                Some(p) => p.dispatch(job),
                None => log::error!("dispatch to missing approx pool"),
            },
        }
    }

    fn close_all(&self) {
        self.deployed.close();
        for p in &self.parity {
            p.close();
        }
        if let Some(p) = &self.approx {
            p.close();
        }
    }

    fn shutdown_all(self) {
        self.deployed.shutdown();
        for p in self.parity {
            p.shutdown();
        }
        if let Some(p) = self.approx {
            p.shutdown();
        }
    }
}

/// A live serving session. Single consumer: all methods take `&mut self`;
/// the handle is `Send`, so a frontend can own it on a serving thread.
pub struct ServiceHandle {
    scheme: Box<dyn RedundancyScheme>,
    batcher: Batcher,
    slo: Option<Duration>,
    pools: Option<PoolSet>,
    rx: BusReceiver<Completion>,
    faults: Arc<FaultPlan>,
    shuffles: Option<ShuffleGen>,
    fault_injector: Option<FaultInjector>,
    /// query id -> frontend arrival (pending queries only).
    pending: PendingTable,
    /// Reusable buffer for completion sweeps (capacity persists across
    /// pumps, so a steady-state sweep allocates nothing).
    sweep_buf: Vec<Completion>,
    /// Resolved records not yet retrieved via poll()/drain().
    resolved_out: VecDeque<Resolved>,
    metrics: RunMetrics,
    /// Sliding window over recent resolutions (live observability).
    window: LatencyWindow,
    submitted: u64,
    resolved_count: u64,
    next_qid: u64,
    mean_service: Duration,
    started: Instant,
    /// Worker environment, kept for session-scoped observability (the
    /// per-session dropped-job counter; see [`WorkerEnv::dropped`]).
    env: Arc<WorkerEnv>,
    /// Continuation of the builder's seeded stream (open-loop arrivals).
    rng: Pcg64,
    /// Serving-path journal (disabled unless the config carried one).
    recorder: Recorder,
    /// Publications into the fleet-wide metric registry.
    telemetry: SessionTelemetry,
}

impl ServiceHandle {
    /// Scheme serving this session.
    pub fn scheme_name(&self) -> &'static str {
        self.scheme.name()
    }

    /// Measured uncontended mean service time of the deployed model.
    pub fn mean_service(&self) -> Duration {
        self.mean_service
    }

    /// Live telemetry from an adaptive scheme — the last chosen per-group
    /// redundancy, the straggler predictor's unavailability estimate, and
    /// the realized parity overhead. `None` for fixed-topology schemes.
    pub fn scheme_telemetry(&self) -> Option<SchemeTelemetry> {
        self.scheme.telemetry()
    }

    /// The metric registry this session publishes into (a clone of the
    /// config's handle — possibly shard-scoped by the sharded tier).
    /// Hand it to a [`crate::telemetry::Exporter`] to scrape it, or to a
    /// [`crate::telemetry::series::Capture`] to sample it.
    pub fn registry(&self) -> Registry {
        self.telemetry.registry.clone()
    }

    /// Fold the live window and scheme gauges into the registry *now*,
    /// regardless of the `telemetry_every` cadence — what `shutdown`
    /// and the sharded tier's drain path call so the last window state
    /// is visible to scrapers.
    pub fn publish_telemetry(&mut self) {
        self.telemetry.publish(&mut self.window, self.scheme.as_ref(), Instant::now());
    }

    /// Queries submitted so far.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Queries still awaiting a prediction.
    pub fn in_flight(&self) -> u64 {
        self.submitted - self.resolved_count
    }

    /// Queued-but-unstarted jobs across all pools (backpressure signal).
    pub fn backlog(&self) -> usize {
        self.pools.as_ref().map_or(0, |p| {
            p.deployed.backlog()
                + p.parity.iter().map(Pool::backlog).sum::<usize>()
                + p.approx.as_ref().map_or(0, Pool::backlog)
        })
    }

    /// Fault-injection surface for tests and chaos drills: permanently
    /// kill an instance (undetected zombie, the paper's failure model).
    pub fn kill_instance(&self, instance: usize) {
        self.faults.kill(instance);
    }

    /// Fail an instance for a bounded window.
    pub fn fail_instance_for(&self, instance: usize, dur: Duration) {
        self.faults.fail_for(instance, dur);
    }

    /// The session's shared fault-injection plan (the same one the
    /// instance workers consult). Lets a frontend keep chaos-drill access
    /// (`kill_instance` and friends) after the handle itself has moved
    /// onto a dispatcher thread.
    pub fn fault_plan(&self) -> Arc<FaultPlan> {
        self.faults.clone()
    }

    /// The session's link-contention model (the same instance the
    /// workers consult). Lets chaos harnesses degrade links
    /// ([`Network::degrade_link`]) with the same reach `fault_plan`
    /// gives them over hard failures.
    pub fn network(&self) -> Arc<Network> {
        self.env.network.clone()
    }

    /// Submit one query; returns its id. The query joins the current
    /// batch and is dispatched per the scheme when the batch seals (or on
    /// the batch timeout — serviced by `poll`/`drain`).
    pub fn submit(&mut self, input: Tensor) -> QueryId {
        let id = self.next_qid;
        self.next_qid += 1;
        self.submitted += 1;
        let arrived = Instant::now();
        self.pending.insert(id, arrived);
        self.telemetry.submitted.inc();
        self.recorder.record(&Event::Submit { qid: id });
        if let Some(sealed) = self.batcher.offer(PendingQuery { id, input, arrived }) {
            self.dispatch_sealed(sealed);
        }
        id
    }

    /// Earliest instant at which a partial batch becomes due (pacing aid
    /// for open-loop drivers).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.batcher.next_deadline()
    }

    /// Service the session without blocking: flush due batches, fold in
    /// completions, apply SLO defaults; returns newly resolved queries.
    pub fn poll(&mut self) -> Vec<Resolved> {
        self.service_pass(usize::MAX);
        self.take_resolved()
    }

    /// Like [`ServiceHandle::poll`], but block up to `wait` for the first
    /// *resolution* before folding in whatever else is ready. For
    /// single-consumer serving loops that would otherwise busy-poll
    /// between completions. The wait is a single deadline shared by
    /// every internal block — a completion sweep can never push total
    /// blocking past `wait` (the seed's version stacked a full
    /// `recv_timeout` on top of the drain and could block ~2×) — and
    /// the handle wakes early for batch-timeout and SLO deadlines, so a
    /// partial batch still seals mid-wait. (The multi-client frontend's
    /// dispatcher does *not* use this — it blocks on its submission
    /// channel instead and calls `poll` at its pump cadence.)
    pub fn poll_timeout(&mut self, wait: Duration) -> Vec<Resolved> {
        self.pump_until(Instant::now() + wait);
        self.take_resolved()
    }

    /// Live sliding-window metrics: tail percentiles, recovery rate, and
    /// reject rate over the most recent `metrics_window` (a
    /// [`ServiceConfig`] knob, default 10 s) of resolutions. Callable at
    /// any point in a session — the streamed counterpart of the
    /// cumulative [`RunResult`] metrics that [`ServiceHandle::shutdown`]
    /// returns.
    pub fn window_snapshot(&mut self) -> WindowSnapshot {
        self.window.snapshot(Instant::now())
    }

    /// Fold `n` admission-control rejects into this session's accounting
    /// (cumulative metrics and the live window). Rejections happen at the
    /// frontend, before a query ever reaches `submit` — this hook is how
    /// the frontend keeps the session's `RunResult` a complete record of
    /// the offered traffic.
    pub fn note_rejected(&mut self, n: u64) {
        if n == 0 {
            return;
        }
        self.metrics.record_rejected(n);
        self.window.record_rejects(n, Instant::now());
        self.telemetry.rejected.add(n);
        self.recorder.record(&Event::Reject { n });
    }

    /// Block until every submitted query has resolved (flushing any
    /// partial batch first); returns the newly resolved queries. With
    /// lost predictions and no SLO configured this waits forever — give
    /// the config an SLO when serving under failures.
    pub fn drain(&mut self) -> Vec<Resolved> {
        if let Some(sealed) = self.batcher.flush_all() {
            self.dispatch_sealed(sealed);
        }
        let mut out = Vec::new();
        while self.resolved_count < self.submitted {
            // 5 ms granularity bounds SLO-sweep latency, as in the seed.
            // pump_until returns early once anything resolves, so harvest
            // incrementally — waiting for the full set before draining
            // `resolved_out` would spin without ever blocking.
            self.pump_until(Instant::now() + Duration::from_millis(5));
            out.extend(self.resolved_out.drain(..));
        }
        out.extend(self.resolved_out.drain(..));
        out
    }

    /// Drain outstanding work, stop shuffles/fault injection, shut down
    /// every pool, and report the session's metrics.
    pub fn shutdown(mut self) -> RunResult {
        let _ = self.drain();
        self.publish_telemetry();
        if let Some(s) = self.shuffles.take() {
            s.stop();
        }
        if let Some(f) = self.fault_injector.take() {
            f.stop();
        }
        if let Some(pools) = self.pools.take() {
            pools.shutdown_all();
        }
        let metrics = std::mem::take(&mut self.metrics);
        RunResult {
            rejected: metrics.rejected,
            metrics,
            mean_service: self.mean_service,
            wall: self.started.elapsed(),
            // Session-scoped counter: concurrent sessions (shards) must
            // not cross-count each other's drops through the global
            // DROPPED_JOBS static.
            dropped_jobs: self.env.dropped.load(Ordering::Relaxed),
            reconstructions: self.scheme.reconstructions(),
        }
    }

    /// Drive the paper's open-loop Poisson client through this handle:
    /// `n_queries` arrivals at `rate` qps, drawn cyclically from
    /// `queries`. Arrivals never wait for completions (§5.1); completions
    /// are folded in opportunistically between arrivals. Inter-arrival
    /// gaps come from the session's own seeded stream (continuing the
    /// builder's draws, exactly like the pre-session `Service::run`).
    /// Does not drain.
    pub fn run_open_loop(&mut self, queries: &[Tensor], n_queries: u64, rate: f64) {
        self.run_open_loop_observed(queries, n_queries, rate, None, &mut |_, _| {});
    }

    /// [`ServiceHandle::run_open_loop`] with periodic live-metrics
    /// sampling: when `sample_every` is set, `sink(elapsed, snapshot)` is
    /// called at that cadence with the sliding-window snapshot — the
    /// time-series view behind Figure 11-style "p99 across a fault event"
    /// plots. Sampling shares the arrival loop's pacing, so it costs no
    /// extra thread and never distorts the offered load (snapshots are
    /// O(window events) and taken between arrivals).
    pub fn run_open_loop_observed(
        &mut self,
        queries: &[Tensor],
        n_queries: u64,
        rate: f64,
        sample_every: Option<Duration>,
        sink: &mut dyn FnMut(Duration, WindowSnapshot),
    ) {
        assert!(!queries.is_empty(), "open loop needs at least one query tensor");
        assert!(rate > 0.0, "open loop needs a positive rate");
        if let Some(every) = sample_every {
            assert!(!every.is_zero(), "sample cadence must be non-zero");
        }
        let start = Instant::now();
        let mut next_sample = sample_every.map(|every| start + every);
        let mut next_arrival = 0.0f64;
        for i in 0..n_queries {
            next_arrival += self.rng.exponential(rate);
            let due = start + Duration::from_secs_f64(next_arrival);
            self.pace_until(due, &mut |h, now| {
                maybe_sample(h, now, start, sample_every, &mut next_sample, sink)
            });
            self.submit(queries[(i as usize) % queries.len()].clone());
        }
    }

    /// Drive a recorded or generated [`Trace`] through this handle:
    /// arrivals at the trace's own offsets (scaled by `time_scale`, so
    /// compressed experiments replay compressed), query tensors drawn by
    /// the trace's `query_idx`. The open-loop contract matches
    /// [`ServiceHandle::run_open_loop`]: arrivals never wait for
    /// completions; completions fold in between arrivals. Does not
    /// drain.
    pub fn run_trace(&mut self, queries: &[Tensor], trace: &crate::workload::trace::Trace) {
        self.run_trace_scaled(queries, trace, 1.0);
    }

    /// [`ServiceHandle::run_trace`] with an explicit time-compression
    /// factor on the trace's arrival offsets (1.0 = as recorded).
    pub fn run_trace_scaled(
        &mut self,
        queries: &[Tensor],
        trace: &crate::workload::trace::Trace,
        time_scale: f64,
    ) {
        assert!(!queries.is_empty(), "trace replay needs at least one query tensor");
        let start = Instant::now();
        for (i, &offset) in trace.arrivals.iter().enumerate() {
            let due = start + Duration::from_secs_f64(offset.max(0.0) * time_scale);
            self.pace_until(due, &mut |_, _| None);
            let qi = trace.query_idx.get(i).copied().unwrap_or(i);
            self.submit(queries[qi % queries.len()].clone());
        }
    }

    /// Pace an open-loop driver to its next arrival: service the session
    /// in *bounded* passes until `due`, then return. `wake_hint` runs
    /// once per iteration with the current instant; it may do periodic
    /// side work (metrics sampling) and return an extra wake deadline to
    /// honor. Both open-loop drivers share this loop — the seed
    /// duplicated it, and both copies folded in an unbounded completion
    /// sweep *before* re-checking `due`, so a completion flood (tens of
    /// thousands of queued completions at saturation) could push
    /// arrivals milliseconds past their trace offsets. The
    /// [`PACE_FOLD_BUDGET`] cap keeps each pass short enough that the
    /// due-check runs at sub-millisecond cadence no matter how deep the
    /// completion backlog is; leftover completions are picked up by
    /// subsequent passes (or post-arrival slack) without distorting the
    /// offered load.
    fn pace_until(
        &mut self,
        due: Instant,
        wake_hint: &mut dyn FnMut(&mut ServiceHandle, Instant) -> Option<Instant>,
    ) {
        loop {
            let now = Instant::now();
            let extra = wake_hint(self, now);
            self.service_pass(PACE_FOLD_BUDGET);
            let now = Instant::now();
            if now >= due {
                return;
            }
            // Honor batch timeouts and the hint's cadence while pacing —
            // but never sleep if the bounded pass may have left backlog.
            if self.rx.pending() > 0 {
                continue;
            }
            let mut wake = due;
            if let Some(d) = self.batcher.next_deadline() {
                wake = wake.min(d);
            }
            if let Some(at) = extra {
                wake = wake.min(at);
            }
            if wake > now {
                std::thread::sleep(wake - now);
            }
        }
    }

    /// One non-blocking service pass: flush due batches, sweep up to
    /// `budget` completions off the bus in one batched drain, fold in
    /// external resolutions, apply SLO defaults. The budget is what lets
    /// latency-sensitive callers (the pacing loop) bound a single pass
    /// under a completion flood; control-path callers pass `usize::MAX`.
    fn service_pass(&mut self, budget: usize) {
        if let Some(sealed) = self.batcher.flush_due(Instant::now()) {
            self.dispatch_sealed(sealed);
        }
        let mut batch = std::mem::take(&mut self.sweep_buf);
        self.rx.try_drain(&mut batch, budget);
        for c in batch.drain(..) {
            self.on_completion(c);
        }
        self.sweep_buf = batch;
        // Resolutions decided outside this session's own completions
        // (cross-shard decodes performed by the shared parity leg).
        // Pump-driven, so they land even when this session's cluster is
        // entirely dead and no completion will ever arrive again.
        for r in self.scheme.drain_external() {
            self.apply_resolution(r);
        }
        self.sweep_slo();
        self.telemetry.maybe_publish(&mut self.window, self.scheme.as_ref());
        // Conservation: every submitted query is exactly one of pending
        // or resolved (the exactly-once invariant the journal replays).
        debug_assert_eq!(self.pending.len() as u64, self.submitted - self.resolved_count);
    }

    /// Block until `deadline`, servicing the session; returns early as
    /// soon as any query resolves. Wakes for batch-timeout and SLO
    /// deadlines, so time-driven transitions happen on time even with no
    /// completion traffic. Total blocking never exceeds `deadline`.
    fn pump_until(&mut self, deadline: Instant) {
        loop {
            self.service_pass(usize::MAX);
            if !self.resolved_out.is_empty() {
                return;
            }
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let mut wake = deadline;
            if let Some(d) = self.batcher.next_deadline() {
                wake = wake.min(d);
            }
            if let Some(slo) = self.slo {
                if let Some(arrived) = self.pending.earliest() {
                    wake = wake.min(arrived + slo);
                }
            }
            let mut batch = std::mem::take(&mut self.sweep_buf);
            match self.rx.recv_deadline(wake, &mut batch, usize::MAX) {
                RecvStatus::Items(_) => {
                    for c in batch.drain(..) {
                        self.on_completion(c);
                    }
                }
                RecvStatus::TimedOut => {}
                RecvStatus::Disconnected => {
                    // All workers gone: nothing will ever arrive on the
                    // bus again, so sleep out the wake interval instead
                    // of spinning (SLO sweeps still need the wakeups).
                    let now = Instant::now();
                    if wake > now {
                        std::thread::sleep(wake - now);
                    }
                }
            }
            self.sweep_buf = batch;
        }
    }

    fn dispatch_sealed(&mut self, mut sealed: SealedBatch) {
        // Executables are compiled for a fixed batch size: pad partial
        // batches (timeout / shutdown flushes) by repeating the last
        // sample. Padded rows' outputs are never routed to a query id,
        // and padding keeps data/parity tensor shapes aligned for the
        // decoder.
        let batch_size = self.batcher.batch_size();
        if sealed.input.shape()[0] < batch_size {
            let mut rows = sealed.input.unbatch();
            while rows.len() < batch_size {
                rows.push(rows.last().unwrap().clone());
            }
            sealed.input = Tensor::batch(&rows).expect("uniform rows");
        }
        let plan = self.scheme.plan_dispatch(sealed);
        for r in plan.resolutions {
            self.apply_resolution(r);
        }
        if let Some(pools) = &self.pools {
            for (target, job) in plan.jobs {
                if self.recorder.enabled() {
                    use crate::runtime::instance::JobKind;
                    let (group, kind, detail) = match job.kind {
                        JobKind::Data { group, slot } => (group, JobClass::Data, slot as u64),
                        JobKind::Parity { group, r_index } => {
                            (group, JobClass::Parity, r_index as u64)
                        }
                        JobKind::Replica { group, slot } => {
                            (group, JobClass::Replica, slot as u64)
                        }
                        JobKind::Background => (0, JobClass::Background, 0),
                    };
                    self.recorder.record(&Event::Dispatch {
                        group,
                        kind: kind as u8,
                        detail,
                        queries: job.query_ids.len() as u64,
                    });
                }
                pools.dispatch(target, job);
            }
        }
    }

    fn on_completion(&mut self, c: Completion) {
        for r in self.scheme.on_completion(c) {
            self.apply_resolution(r);
        }
    }

    /// First verdict per query wins; later ones are no-ops (the pending
    /// map is the dedup).
    fn apply_resolution(&mut self, r: Resolution) {
        for id in r.query_ids {
            if let Some(arrived) = self.pending.remove(id) {
                let latency = r.at.saturating_duration_since(arrived);
                self.metrics.record(arrived, r.at, r.outcome);
                self.window.record(r.outcome, latency, r.at);
                self.telemetry.on_resolved(id, r.outcome, latency);
                self.resolved_count += 1;
                // Inside the dedup branch: the journal sees exactly one
                // terminal event per query, the invariant replay checks.
                self.recorder.record(&Event::Complete {
                    qid: id,
                    outcome: outcome_byte(r.outcome),
                    latency_us: latency.as_micros() as u64,
                });
                self.resolved_out.push_back(Resolved { id, outcome: r.outcome, latency });
            }
        }
    }

    fn sweep_slo(&mut self) {
        let Some(slo) = self.slo else { return };
        let now = Instant::now();
        // Arrivals are monotone in query id, so expirations are a prefix
        // of the pending window: the sweep pops expired entries off the
        // front and stops at the first live one — O(expired), not
        // O(pending).
        let Some(cutoff) = now.checked_sub(slo) else { return };
        let mut expired = Vec::new();
        self.pending.take_expired(cutoff, &mut expired);
        for id in expired {
            self.metrics.record_default(slo);
            self.window.record(Outcome::Default, slo, now);
            self.telemetry.on_resolved(id, Outcome::Default, slo);
            self.resolved_count += 1;
            self.recorder.record(&Event::Complete {
                qid: id,
                outcome: outcome_byte(Outcome::Default),
                latency_us: slo.as_micros() as u64,
            });
            self.resolved_out.push_back(Resolved {
                id,
                outcome: Outcome::Default,
                latency: slo,
            });
        }
    }

    fn take_resolved(&mut self) -> Vec<Resolved> {
        self.resolved_out.drain(..).collect()
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        // Graceful best-effort teardown when dropped without shutdown():
        // closing the queues lets workers exit once drained; shuffle and
        // fault threads stop via their own Drop/stop.
        if let Some(pools) = self.pools.take() {
            pools.close_all();
        }
        if let Some(s) = self.shuffles.take() {
            s.stop();
        }
        if let Some(f) = self.fault_injector.take() {
            f.stop();
        }
    }
}

/// Scheduled hard failures: applies (instance, start, duration) triples,
/// interruptible so shutdown never waits out a long schedule.
struct FaultInjector {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl FaultInjector {
    fn start(plan: Arc<FaultPlan>, schedule: Vec<(usize, Duration, Duration)>) -> FaultInjector {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fault-injector".into())
            .spawn(move || {
                let start = Instant::now();
                let mut pending = schedule;
                pending.sort_by_key(|&(_, at, _)| at);
                let (lock, cv) = &*stop2;
                for (inst, at, dur) in pending {
                    let mut stopped = lock.plock();
                    loop {
                        if *stopped {
                            return;
                        }
                        let now = start.elapsed();
                        if now >= at {
                            break;
                        }
                        let (g, _) = cv.pwait_timeout(stopped, at - now);
                        stopped = g;
                    }
                    drop(stopped);
                    if dur.is_zero() {
                        plan.kill(inst);
                        log::info!("fault: instance {inst} killed");
                    } else {
                        plan.fail_for(inst, dur);
                        log::info!("fault: instance {inst} down for {dur:?}");
                    }
                }
            })
            .expect("spawn fault-injector");
        FaultInjector { stop, handle: Some(handle) }
    }

    fn stop(self) {}
}

impl Drop for FaultInjector {
    fn drop(&mut self) {
        *self.stop.0.plock() = true;
        self.stop.1.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Pending-query table exploiting the session's structure: query ids are
/// assigned sequentially and arrivals are timestamped in id order, so
/// the pending set is a contiguous id *window*. A ring of
/// `Option<Instant>` indexed by `id - base` gives O(1) insert/remove
/// with zero hashing, and — because arrival times are monotone in id —
/// SLO expirations are always a prefix, so the sweep is O(expired)
/// instead of a full scan of every in-flight query (ROADMAP item 2; the
/// seed used a `HashMap` and scanned it per pump).
struct PendingTable {
    /// Query id of `ring[0]`.
    base: QueryId,
    /// Arrival per id in `[base, base + ring.len())`; `None` = resolved.
    ring: VecDeque<Option<Instant>>,
    /// Number of `Some` entries.
    live: usize,
}

impl PendingTable {
    fn new() -> PendingTable {
        PendingTable { base: 0, ring: VecDeque::new(), live: 0 }
    }

    fn len(&self) -> usize {
        self.live
    }

    /// Record a new pending query. Ids arrive in submit order; gaps are
    /// tolerated (padded as already-resolved) but never produced by the
    /// session.
    fn insert(&mut self, id: QueryId, arrived: Instant) {
        if self.ring.is_empty() {
            self.base = id;
        }
        debug_assert!(id >= self.base + self.ring.len() as u64, "ids are sequential");
        while self.base + (self.ring.len() as u64) < id {
            self.ring.push_back(None);
        }
        self.ring.push_back(Some(arrived));
        self.live += 1;
    }

    /// Resolve `id`, returning its arrival if it was still pending
    /// (first-verdict-wins dedup relies on exactly this).
    fn remove(&mut self, id: QueryId) -> Option<Instant> {
        if id < self.base {
            return None;
        }
        let idx = (id - self.base) as usize;
        let arrived = self.ring.get_mut(idx)?.take();
        if arrived.is_some() {
            self.live -= 1;
            self.compact();
        }
        arrived
    }

    /// Pop every pending query that arrived at or before `cutoff` into
    /// `out`. Arrivals are monotone in id, so these are exactly the
    /// leading live entries of the window.
    fn take_expired(&mut self, cutoff: Instant, out: &mut Vec<QueryId>) {
        loop {
            match self.ring.front() {
                Some(None) => {
                    self.ring.pop_front();
                    self.base += 1;
                }
                Some(Some(t)) if *t <= cutoff => {
                    out.push(self.base);
                    self.ring.pop_front();
                    self.base += 1;
                    self.live -= 1;
                }
                _ => return,
            }
        }
    }

    /// Arrival of the oldest pending query (the next SLO deadline's
    /// anchor), if any.
    fn earliest(&self) -> Option<Instant> {
        self.ring.iter().find_map(|slot| *slot)
    }

    /// Drop resolved entries off the front so the window tracks the live
    /// span. Called after every remove: amortized O(1), and it keeps the
    /// ring from growing with session lifetime when queries resolve
    /// roughly in order (the common case).
    fn compact(&mut self) {
        while let Some(None) = self.ring.front() {
            self.ring.pop_front();
            self.base += 1;
        }
    }
}

#[cfg(test)]
mod pending_tests {
    use super::*;

    fn t(base: Instant, ms: u64) -> Instant {
        base + Duration::from_millis(ms)
    }

    #[test]
    fn insert_remove_roundtrip_in_and_out_of_order() {
        let now = Instant::now();
        let mut p = PendingTable::new();
        for id in 0..5u64 {
            p.insert(id, t(now, id * 10));
        }
        assert_eq!(p.len(), 5);
        // Out-of-order resolution.
        assert_eq!(p.remove(3), Some(t(now, 30)));
        assert_eq!(p.remove(3), None, "second verdict is a no-op");
        assert_eq!(p.remove(0), Some(t(now, 0)));
        assert_eq!(p.remove(4), Some(t(now, 40)));
        assert_eq!(p.len(), 2);
        assert_eq!(p.earliest(), Some(t(now, 10)));
        assert_eq!(p.remove(1), Some(t(now, 10)));
        assert_eq!(p.remove(2), Some(t(now, 20)));
        assert_eq!(p.len(), 0);
        assert!(p.earliest().is_none());
        // Window fully compacted: ring does not grow with history.
        assert!(p.ring.is_empty());
    }

    #[test]
    fn remove_below_base_is_none() {
        let now = Instant::now();
        let mut p = PendingTable::new();
        p.insert(10, now);
        assert_eq!(p.remove(3), None);
        assert_eq!(p.remove(10), Some(now));
    }

    #[test]
    fn take_expired_pops_exactly_the_prefix() {
        let now = Instant::now();
        let mut p = PendingTable::new();
        for id in 0..6u64 {
            p.insert(id, t(now, id * 10));
        }
        // Resolve one mid-window entry; it must not appear as expired.
        p.remove(1);
        let mut out = Vec::new();
        p.take_expired(t(now, 30), &mut out);
        assert_eq!(out, vec![0, 2, 3]);
        assert_eq!(p.len(), 2);
        assert_eq!(p.earliest(), Some(t(now, 40)));
        out.clear();
        p.take_expired(t(now, 30), &mut out);
        assert!(out.is_empty(), "sweep is idempotent below the cutoff");
        p.take_expired(t(now, 1000), &mut out);
        assert_eq!(out, vec![4, 5]);
        assert_eq!(p.len(), 0);
    }

    #[test]
    fn window_restarts_after_emptying() {
        let now = Instant::now();
        let mut p = PendingTable::new();
        p.insert(0, now);
        assert_eq!(p.remove(0), Some(now));
        // Much later id after the window emptied: base snaps forward.
        p.insert(1000, t(now, 5));
        assert_eq!(p.len(), 1);
        assert_eq!(p.remove(1000), Some(t(now, 5)));
    }
}

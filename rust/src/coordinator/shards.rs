//! Sharded serving tier: multi-session dispatch with consistent-hash
//! routing and merged cross-shard metrics.
//!
//! The paper's setting is a prediction-serving *cluster* absorbing high
//! query rates across many machines (§2.1, §6), but a single
//! [`ServingFrontend`] funnels every client through one dispatcher
//! thread driving one [`crate::coordinator::session::ServiceHandle`] — a
//! hard throughput ceiling. This module scales past it by running many
//! frontends side by side:
//!
//! ```text
//!  ShardedClient (id) ──▶ ShardRouter (hash ring, vnodes)
//!                             │ client id -> shard
//!         ┌───────────────────┼───────────────────┐
//!         ▼                   ▼                   ▼
//!   ServingFrontend 0   ServingFrontend 1  …  ServingFrontend N-1
//!   (dispatcher thread,  each with its own pools, scheme state,
//!    session, window)    fault plan, and admission accounting)
//! ```
//!
//! Each shard is a fully independent session — its own instance pools,
//! network/tenancy simulation, fault plan, dispatcher thread, and
//! sliding metrics window — so a fault or overload in one shard cannot
//! head-of-line-block another (its own *fault domain*). The
//! [`ShardRouter`] is a classic consistent-hash ring with virtual nodes:
//! client ids hash onto the ring and walk clockwise to the first live
//! shard, so draining one shard remaps only that shard's clients.
//!
//! [`ShardedClient`] keeps `submit`/`poll`/`next`/`stats`/`window`
//! shard-transparent: submissions go to the routed shard, returned
//! [`QueryId`]s carry the shard in their top byte (unique fleet-wide),
//! and deliveries are swept from every shard the client ever touched.
//! Admission composes: each shard enforces the per-session
//! [`crate::coordinator::frontend::AdmissionPolicy`], and the tier adds
//! an optional fleet-wide offered-load cap ([`ShardSpec::global_backlog`])
//! checked before the per-shard policy.
//!
//! [`ShardedFrontend::shutdown`] merges the per-shard
//! [`RunResult`]s into one fleet record (exact — raw latency samples
//! concatenate), and [`ShardedFrontend::window`] merges the live
//! per-shard [`WindowSnapshot`]s for fleet-wide p50/p99/p99.9.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::coordinator::frontend::{ClientStats, ServiceClient, ServingFrontend, SubmitError};
use crate::coordinator::metrics::WindowSnapshot;
use crate::coordinator::service::{ModelSet, RunResult, ServiceConfig};
use crate::coordinator::session::{QueryId, Resolved, ServiceBuilder};
use crate::tensor::Tensor;

/// Shard index lives in the top byte of a sharded [`QueryId`], so ids
/// stay unique fleet-wide even though every shard numbers its own
/// queries from zero.
const SHARD_SHIFT: u32 = 56;

/// Hard cap on shard count (the id tag is one byte).
pub const MAX_SHARDS: usize = 255;

/// SplitMix64: cheap, well-mixed 64-bit hash for ring points and client
/// placement (also used to decorrelate per-shard seeds).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn tag(shard: usize, fid: QueryId) -> QueryId {
    ((shard as u64) << SHARD_SHIFT) | fid
}

/// The shard a sharded [`QueryId`] was served by.
pub fn shard_of(id: QueryId) -> usize {
    (id >> SHARD_SHIFT) as usize
}

/// Sizing and policy knobs of the sharded tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Number of independent sessions (1..=[`MAX_SHARDS`]).
    pub shards: usize,
    /// Virtual nodes per shard on the hash ring. More vnodes smooth the
    /// client distribution (64 keeps the max/min shard population within
    /// a few tens of percent for large client counts).
    pub vnodes: usize,
    /// Fleet-wide offered-load cap composed *over* the per-shard
    /// admission policies: a submit first checks the summed load of all
    /// shards against this, then the routed shard's own policy.
    /// `None` = per-shard admission only.
    pub global_backlog: Option<usize>,
}

impl Default for ShardSpec {
    fn default() -> ShardSpec {
        ShardSpec { shards: 1, vnodes: 64, global_backlog: None }
    }
}

impl ShardSpec {
    pub fn new(shards: usize) -> ShardSpec {
        ShardSpec { shards, ..ShardSpec::default() }
    }
}

/// Consistent-hash ring with virtual nodes mapping client ids to shards.
///
/// Each shard owns `vnodes` pseudo-random points on a 64-bit ring; a
/// client hashes to a point and is served by the first *live* shard
/// clockwise from it. Marking a shard down therefore remaps only the
/// clients whose first point belonged to that shard — everyone else
/// keeps their routing (the property the rerouting tests pin down).
pub struct ShardRouter {
    /// (ring point, shard), sorted by point.
    ring: Vec<(u64, usize)>,
    down: Vec<bool>,
    vnodes: usize,
}

impl ShardRouter {
    pub fn new(shards: usize, vnodes: usize) -> ShardRouter {
        assert!(shards >= 1, "router needs at least one shard");
        assert!(vnodes >= 1, "router needs at least one vnode per shard");
        let mut ring = Vec::with_capacity(shards * vnodes);
        for s in 0..shards {
            for v in 0..vnodes {
                // Distinct, well-spread point per (shard, vnode).
                ring.push((splitmix64(((s as u64) << 32) | v as u64), s));
            }
        }
        ring.sort_unstable();
        ShardRouter { ring, down: vec![false; shards], vnodes }
    }

    pub fn shards(&self) -> usize {
        self.down.len()
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// Shards currently accepting new routes.
    pub fn live(&self) -> usize {
        self.down.iter().filter(|d| !**d).count()
    }

    pub fn is_down(&self, shard: usize) -> bool {
        self.down[shard]
    }

    /// Mark a shard down (drained: new routes skip it) or back up.
    pub fn set_down(&mut self, shard: usize, down: bool) {
        self.down[shard] = down;
    }

    /// Route a client id to a live shard, or `None` if every shard is
    /// down. O(log ring) in the common case; the clockwise walk only
    /// lengthens while consecutive points belong to down shards.
    pub fn route(&self, client: u64) -> Option<usize> {
        let h = splitmix64(client ^ 0xC11E_17D0_57ED);
        let start = self.ring.partition_point(|&(p, _)| p < h);
        for i in 0..self.ring.len() {
            let (_, s) = self.ring[(start + i) % self.ring.len()];
            if !self.down[s] {
                return Some(s);
            }
        }
        None
    }
}

/// State shared by the tier's frontend handle and every client.
struct ShardShared {
    router: RwLock<ShardRouter>,
    global_backlog: Option<usize>,
    next_client: AtomicU64,
}

/// N independent serving sessions behind one consistent-hash router.
///
/// Build with [`ShardedFrontend::start`], mint [`ShardedClient`]s with
/// [`ShardedFrontend::client`], degrade shards with
/// [`ShardedFrontend::kill_instance`] / [`ShardedFrontend::drain_shard`],
/// observe the fleet with [`ShardedFrontend::window`], and finish with
/// [`ShardedFrontend::shutdown`] for the merged run record.
pub struct ShardedFrontend {
    frontends: Vec<ServingFrontend>,
    shared: Arc<ShardShared>,
}

/// What [`ShardedFrontend::shutdown`] returns: the fleet-wide merged
/// record plus each shard's own, so callers can audit that the merge
/// conserved every count.
pub struct ShardedRunResult {
    /// All shards folded together ([`RunResult::merged`]).
    pub merged: RunResult,
    /// Per-shard results, in shard order.
    pub per_shard: Vec<RunResult>,
}

impl ShardedFrontend {
    /// Stand up `spec.shards` independent sessions from one config.
    ///
    /// Shard 0 keeps `cfg.seed` unchanged (so `--shards 1` reproduces the
    /// unsharded run exactly); later shards get decorrelated seeds, since
    /// N copies of one seed would fail, shuffle, and pace in lockstep —
    /// the opposite of independent fault domains. For the same reason a
    /// configured `fault_schedule` applies to **shard 0 only** (the
    /// scenario "degrade one shard while the others keep their latency
    /// profile"); use [`ShardedFrontend::kill_instance`] /
    /// [`ShardedFrontend::fail_instance_for`] to target other shards.
    pub fn start(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
    ) -> anyhow::Result<ShardedFrontend> {
        anyhow::ensure!(
            (1..=MAX_SHARDS).contains(&spec.shards),
            "shards must be in 1..={MAX_SHARDS}, got {}",
            spec.shards
        );
        anyhow::ensure!(spec.vnodes >= 1, "vnodes must be >= 1");
        let mut frontends = Vec::with_capacity(spec.shards);
        for s in 0..spec.shards {
            let mut shard_cfg = cfg.clone();
            if s > 0 {
                shard_cfg.seed = splitmix64(cfg.seed ^ ((s as u64) << 40));
                // One scheduled fault must not fire in lockstep across
                // the whole fleet — that would erase the healthy-shard
                // baseline the tier exists to preserve.
                shard_cfg.fault_schedule.clear();
            }
            frontends.push(ServiceBuilder::new(shard_cfg).serve(models, sample_query)?);
        }
        Ok(ShardedFrontend {
            frontends,
            shared: Arc::new(ShardShared {
                router: RwLock::new(ShardRouter::new(spec.shards, spec.vnodes)),
                global_backlog: spec.global_backlog,
                next_client: AtomicU64::new(0),
            }),
        })
    }

    pub fn shards(&self) -> usize {
        self.frontends.len()
    }

    /// Mint a shard-transparent client (a fresh identity on every shard,
    /// routed by its id).
    ///
    /// Note on admission fairness: each leg registers the default weight
    /// on *every* shard, so a shard's fair-share denominator counts the
    /// whole fleet of tier clients, not just the ones routed to it —
    /// weighted shares are diluted by the shard count (the per-client
    /// one-slot floor and the 2x-limit ceiling still apply). Per-routed-
    /// shard weight accounting is an open item (see ROADMAP).
    pub fn client(&self) -> ShardedClient {
        ShardedClient {
            id: self.shared.next_client.fetch_add(1, Ordering::Relaxed),
            legs: self.frontends.iter().map(ServingFrontend::client).collect(),
            shared: self.shared.clone(),
        }
    }

    /// The shard the router currently assigns to `client_id` (`None` if
    /// every shard is drained).
    pub fn route_of(&self, client_id: u64) -> Option<usize> {
        self.shared.router.read().unwrap().route(client_id)
    }

    /// Take a shard out of the routing ring: *subsequent* submits from
    /// its clients walk clockwise to the next live shard, while queries
    /// already in the shard keep resolving and its session still shows
    /// up (and is drained) in [`ShardedFrontend::shutdown`].
    pub fn drain_shard(&self, shard: usize) {
        self.shared.router.write().unwrap().set_down(shard, true);
    }

    /// Put a drained shard back into the ring.
    pub fn restore_shard(&self, shard: usize) {
        self.shared.router.write().unwrap().set_down(shard, false);
    }

    /// Live shard count (shards not drained).
    pub fn live_shards(&self) -> usize {
        self.shared.router.read().unwrap().live()
    }

    /// Permanently kill one instance *of one shard* (the paper's
    /// undetected-zombie failure model, scoped to a fault domain): that
    /// shard degrades to its redundancy scheme while the others keep
    /// their latency profile.
    pub fn kill_instance(&self, shard: usize, instance: usize) {
        self.frontends[shard].kill_instance(instance);
    }

    /// Fail one instance of one shard for a bounded window.
    pub fn fail_instance_for(&self, shard: usize, instance: usize, dur: Duration) {
        self.frontends[shard].fail_instance_for(instance, dur);
    }

    /// Summed admission-load estimate across every shard (what the
    /// global offered-load cap bounds).
    pub fn load(&self) -> usize {
        self.frontends.iter().map(ServingFrontend::load).sum()
    }

    /// Total admission rejects across every shard (including global-cap
    /// rejects, which are tallied against the routed shard).
    pub fn rejected(&self) -> u64 {
        self.frontends.iter().map(ServingFrontend::rejected).sum()
    }

    /// One shard's live window.
    pub fn shard_window(&self, shard: usize) -> WindowSnapshot {
        self.frontends[shard].window()
    }

    /// Fleet-wide live metrics: every shard's window merged
    /// ([`WindowSnapshot::merge`] — counts exact, quantiles
    /// resolved-weighted).
    pub fn window(&self) -> WindowSnapshot {
        let snaps: Vec<WindowSnapshot> =
            self.frontends.iter().map(ServingFrontend::window).collect();
        WindowSnapshot::merge_all(&snaps)
    }

    /// Shut every shard down (each drains its in-flight queries) and
    /// merge the per-shard [`RunResult`]s into one fleet record. The
    /// merged `submitted`/`resolved`/`rejected` totals equal the
    /// per-shard sums by construction — `per_shard` is returned so tests
    /// and reports can verify exactly that.
    pub fn shutdown(self) -> anyhow::Result<ShardedRunResult> {
        let mut per_shard = Vec::with_capacity(self.frontends.len());
        for f in self.frontends {
            per_shard.push(f.shutdown()?);
        }
        Ok(ShardedRunResult { merged: RunResult::merged(&per_shard), per_shard })
    }
}

/// A shard-transparent client of a [`ShardedFrontend`].
///
/// Cheap to clone (clones share this client's identity and inboxes, like
/// [`ServiceClient`]); `Send + Sync`, so one client can be driven from
/// several threads. Submissions route to the client's current shard;
/// completions are swept from every shard, so rerouting mid-run (a
/// drained shard) never strands a delivery.
#[derive(Clone)]
pub struct ShardedClient {
    id: u64,
    /// One per-shard identity, indexed by shard.
    legs: Vec<ServiceClient>,
    shared: Arc<ShardShared>,
}

impl ShardedClient {
    /// This client's tier-assigned id (the consistent-hash key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The shard the router currently assigns this client to.
    pub fn shard(&self) -> Option<usize> {
        self.shared.router.read().unwrap().route(self.id)
    }

    /// Submit one query through the routed shard's admission control
    /// (after the fleet-wide cap, when configured). The returned id
    /// carries the serving shard in its top byte ([`shard_of`]).
    pub fn submit(&self, input: Tensor) -> Result<QueryId, SubmitError> {
        let Some(shard) = self.shared.router.read().unwrap().route(self.id) else {
            return Err(SubmitError::Closed);
        };
        if let Some(cap) = self.shared.global_backlog {
            let load: usize = self.legs.iter().map(ServiceClient::load).sum();
            if load >= cap {
                // Tally against the shard that would have served it, so
                // the fleet's merged RunResult still covers offered load.
                self.legs[shard].note_reject();
                return Err(SubmitError::Rejected { load, limit: cap });
            }
        }
        let fid = self.legs[shard].submit(input)?;
        Ok(tag(shard, fid))
    }

    /// Non-blocking: take every prediction delivered to this client on
    /// any shard, ids re-tagged fleet-wide.
    pub fn poll(&self) -> Vec<Resolved> {
        let mut out = Vec::new();
        for (s, leg) in self.legs.iter().enumerate() {
            for r in leg.poll() {
                out.push(Resolved { id: tag(s, r.id), ..r });
            }
        }
        out
    }

    /// Block up to `timeout` for the next prediction from any shard.
    /// Sweeps every leg, parking briefly on the currently-routed shard
    /// (where new deliveries land) between sweeps.
    pub fn next(&self, timeout: Duration) -> Option<Resolved> {
        let deadline = Instant::now() + timeout;
        loop {
            for (s, leg) in self.legs.iter().enumerate() {
                if let Some(r) = leg.try_next() {
                    return Some(Resolved { id: tag(s, r.id), ..r });
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let primary = self.shared.router.read().unwrap().route(self.id).unwrap_or(0);
            let park = (deadline - now).min(Duration::from_millis(2));
            if let Some(r) = self.legs[primary].next(park) {
                return Some(Resolved { id: tag(primary, r.id), ..r });
            }
        }
    }

    /// This client's counters summed across every shard it touched.
    pub fn stats(&self) -> ClientStats {
        let mut total = ClientStats::default();
        for leg in &self.legs {
            let s = leg.stats();
            total.submitted += s.submitted;
            total.resolved += s.resolved;
            total.rejected += s.rejected;
            total.native += s.native;
            total.recovered += s.recovered;
            total.defaulted += s.defaulted;
        }
        total
    }

    /// This client's live window merged across shards.
    pub fn window(&self) -> WindowSnapshot {
        let snaps: Vec<WindowSnapshot> = self.legs.iter().map(ServiceClient::window).collect();
        WindowSnapshot::merge_all(&snaps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_client_is_send_sync_clone() {
        fn assert_traits<T: Send + Sync + Clone>() {}
        assert_traits::<ShardedClient>();
    }

    #[test]
    fn id_tagging_roundtrips() {
        for shard in [0usize, 1, 3, 254] {
            let id = tag(shard, 12_345);
            assert_eq!(shard_of(id), shard);
            assert_eq!(id & ((1u64 << SHARD_SHIFT) - 1), 12_345);
        }
    }

    #[test]
    fn ring_covers_all_shards_reasonably_evenly() {
        let router = ShardRouter::new(4, 64);
        let mut counts = [0usize; 4];
        for client in 0..10_000u64 {
            counts[router.route(client).unwrap()] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // 10k clients over 4 shards with 64 vnodes: every shard gets
            // a solid chunk (loose bound — the ring is hash-balanced, not
            // perfectly uniform).
            assert!(c > 500, "shard {s} nearly starved: {counts:?}");
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let a = ShardRouter::new(8, 32);
        let b = ShardRouter::new(8, 32);
        for client in 0..500u64 {
            assert_eq!(a.route(client), b.route(client));
        }
    }

    #[test]
    fn downing_a_shard_remaps_only_its_clients() {
        let mut router = ShardRouter::new(4, 64);
        let before: Vec<usize> =
            (0..2_000u64).map(|c| router.route(c).unwrap()).collect();
        router.set_down(2, true);
        assert_eq!(router.live(), 3);
        for (c, &was) in before.iter().enumerate() {
            let now = router.route(c as u64).unwrap();
            if was == 2 {
                assert_ne!(now, 2, "client {c} still routed to the down shard");
            } else {
                assert_eq!(now, was, "client {c} remapped without its shard going down");
            }
        }
        // Restoring brings every original route back.
        router.set_down(2, false);
        for (c, &was) in before.iter().enumerate() {
            assert_eq!(router.route(c as u64).unwrap(), was);
        }
    }

    #[test]
    fn all_shards_down_routes_none() {
        let mut router = ShardRouter::new(2, 8);
        router.set_down(0, true);
        router.set_down(1, true);
        assert_eq!(router.route(7), None);
        assert_eq!(router.live(), 0);
    }
}

//! Sharded serving tier: multi-session dispatch with consistent-hash
//! routing, merged cross-shard metrics, and *runtime elasticity*.
//!
//! The paper's setting is a prediction-serving *cluster* absorbing high
//! query rates across many machines (§2.1, §6), but a single
//! [`ServingFrontend`] funnels every client through one dispatcher
//! thread driving one [`crate::coordinator::session::ServiceHandle`] — a
//! hard throughput ceiling. This module scales past it by running many
//! frontends side by side:
//!
//! ```text
//!  ShardedClient (id) ──▶ ShardRouter (hash ring, vnodes)
//!                             │ client id -> shard
//!         ┌───────────────────┼───────────────────┐
//!         ▼                   ▼                   ▼
//!   ServingFrontend 0   ServingFrontend 1  …  ServingFrontend N-1
//!   (dispatcher thread,  each with its own pools, scheme state,
//!    session, window)    fault plan, and admission accounting)
//! ```
//!
//! Each shard is a fully independent session — its own instance pools,
//! network/tenancy simulation, fault plan, dispatcher thread, and
//! sliding metrics window — so a fault or overload in one shard cannot
//! head-of-line-block another (its own *fault domain*). The
//! [`ShardRouter`] is a classic consistent-hash ring with virtual nodes:
//! client ids hash onto the ring and walk clockwise to the first live
//! shard, so draining one shard remaps only that shard's clients.
//!
//! [`ShardedClient`] keeps `submit`/`poll`/`next`/`stats`/`window`
//! shard-transparent: submissions go to the routed shard, returned
//! [`QueryId`]s carry the shard in their top byte (unique fleet-wide),
//! and deliveries are swept from every shard the client ever touched.
//! Admission composes: each shard enforces the per-session
//! [`crate::coordinator::frontend::AdmissionPolicy`], and the tier adds
//! an optional fleet-wide offered-load cap ([`ShardSpec::global_backlog`])
//! checked before the per-shard policy.
//!
//! # Elasticity
//!
//! The fleet is no longer fixed at construction.
//! [`ShardedFrontend::add_shard`] stands up a fresh session at runtime
//! and splices it into the ring with the minimal-remap guarantee of
//! consistent hashing; [`ShardedFrontend::remove_shard`] reroutes its
//! clients and tears the session down (draining in-flight queries into
//! their owners' inboxes — nothing accepted is lost). Shard indices are
//! **append-only**: a removed shard retires its slot forever, so
//! [`QueryId`] tags never alias across fleet generations. The
//! reconfiguration contract (see [`ShardRouter::drain_shard`]) is
//! idempotency without panics: double-drain and restore-of-live are
//! `Ok(false)` no-ops, remove-while-draining succeeds, and every invalid
//! op (unknown index, removed shard, last live shard) is a clean
//! [`ReconfigError`]. The embedded control plane
//! ([`crate::coordinator::control`]) builds its admin surface directly
//! on these primitives.
//!
//! [`ShardedFrontend::shutdown`] merges the per-shard
//! [`RunResult`]s into one fleet record (exact — raw latency samples
//! concatenate, and retired shards' final records are folded back in),
//! and [`ShardedFrontend::window`] merges the live per-shard
//! [`WindowSnapshot`]s for fleet-wide p50/p99/p99.9.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::coordinator::cross_shard::{
    CrossShardConfig, CrossShardScheme, CrossShardState, CrossShardTelemetry, ParityLeg,
};
use crate::coordinator::frontend::{
    AdmissionPolicy, ClientStats, ServiceClient, ServingFrontend, SubmitError,
};
use crate::coordinator::metrics::WindowSnapshot;
use crate::coordinator::scheme::RedundancyScheme;
use crate::coordinator::service::{Mode, ModelSet, RunResult, ServiceConfig};
use crate::coordinator::session::{QueryId, Resolved, ServiceBuilder};
use crate::tensor::Tensor;
use crate::util::sync::{LockExt, RwLockExt};

/// Shard index lives in the top byte of a sharded [`QueryId`], so ids
/// stay unique fleet-wide even though every shard numbers its own
/// queries from zero.
const SHARD_SHIFT: u32 = 56;

/// Hard cap on shard count (the id tag is one byte). Because shard
/// indices are append-only across add/remove, this bounds the number of
/// shards ever *created* over a fleet's lifetime, not just the live set.
pub const MAX_SHARDS: usize = 255;

/// SplitMix64: cheap, well-mixed 64-bit hash for ring points and client
/// placement (also used to decorrelate per-shard seeds).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Tag a shard-local query id with its serving shard (the top byte), so
/// ids stay unique across every leg of the tier. Public so property
/// suites can pin the no-collision invariant directly.
pub fn tag_id(shard: usize, fid: QueryId) -> QueryId {
    ((shard as u64) << SHARD_SHIFT) | fid
}

fn tag(shard: usize, fid: QueryId) -> QueryId {
    tag_id(shard, fid)
}

/// The shard a sharded [`QueryId`] was served by.
pub fn shard_of(id: QueryId) -> usize {
    (id >> SHARD_SHIFT) as usize
}

/// The shard-local query id under the tag — inverse of [`tag_id`]
/// together with [`shard_of`]. Journal mining uses this to bind a
/// router-observed `Route` event back to the leg session's span.
pub fn fid_of(id: QueryId) -> QueryId {
    id & ((1u64 << SHARD_SHIFT) - 1)
}

/// Errors from runtime fleet reconfiguration. Every reconfiguration
/// entry point — on [`ShardRouter`], [`ShardedFrontend`],
/// [`CrossShardFrontend`], and the control plane — returns these
/// instead of panicking, so an operator fat-fingering a shard index
/// over the admin socket can never take the data path down.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum ReconfigError {
    /// The shard index was never allocated.
    #[error("shard {0} does not exist")]
    UnknownShard(usize),
    /// The shard was removed from the fleet (slots retire forever; the
    /// index is not reusable).
    #[error("shard {0} was removed from the fleet")]
    RemovedShard(usize),
    /// The op would leave the ring with zero live shards.
    #[error("removing shard {0} would leave no live shard in the ring")]
    LastShard(usize),
    /// The fleet has exhausted its [`MAX_SHARDS`] lifetime slot budget.
    #[error("fleet at capacity: {0} shard slots already allocated (max {MAX_SHARDS})")]
    AtCapacity(usize),
    /// The fleet was shut down; no further reconfiguration is possible.
    #[error("the fleet is shut down")]
    Closed,
}

/// Sizing and policy knobs of the sharded tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Number of independent sessions (1..=[`MAX_SHARDS`]).
    pub shards: usize,
    /// Virtual nodes per shard on the hash ring. More vnodes smooth the
    /// client distribution (64 keeps the max/min shard population within
    /// a few tens of percent for large client counts).
    pub vnodes: usize,
    /// Fleet-wide offered-load cap composed *over* the per-shard
    /// admission policies: a submit first checks the summed load of all
    /// shards against this, then the routed shard's own policy.
    /// `None` = per-shard admission only.
    pub global_backlog: Option<usize>,
}

impl Default for ShardSpec {
    fn default() -> ShardSpec {
        ShardSpec { shards: 1, vnodes: 64, global_backlog: None }
    }
}

impl ShardSpec {
    pub fn new(shards: usize) -> ShardSpec {
        ShardSpec { shards, ..ShardSpec::default() }
    }
}

/// Consistent-hash ring with virtual nodes mapping client ids to shards.
///
/// Each shard owns `vnodes` pseudo-random points on a 64-bit ring; a
/// client hashes to a point and is served by the first *live* shard
/// clockwise from it. Marking a shard down therefore remaps only the
/// clients whose first point belonged to that shard — everyone else
/// keeps their routing (the property the rerouting tests pin down).
///
/// The ring is elastic: [`ShardRouter::add_shard`] appends a new index
/// whose vnode points are a pure function of `(shard, vnode)`, so
/// growing N→N+1 produces exactly the ring a fresh (N+1)-shard router
/// would have — the minimal-remap and exact-restore properties the
/// seeded suite in `tests/coordinator_props.rs` pins. Removed shards
/// retire their index forever (see [`ReconfigError::RemovedShard`]).
pub struct ShardRouter {
    /// (ring point, shard), sorted by point. Removed shards own no
    /// points.
    ring: Vec<(u64, usize)>,
    down: Vec<bool>,
    removed: Vec<bool>,
    vnodes: usize,
}

impl ShardRouter {
    pub fn new(shards: usize, vnodes: usize) -> ShardRouter {
        assert!(shards >= 1, "router needs at least one shard");
        assert!(vnodes >= 1, "router needs at least one vnode per shard");
        let mut ring = Vec::with_capacity(shards * vnodes);
        for s in 0..shards {
            for v in 0..vnodes {
                // Distinct, well-spread point per (shard, vnode).
                ring.push((splitmix64(((s as u64) << 32) | v as u64), s));
            }
        }
        ring.sort_unstable();
        ShardRouter {
            ring,
            down: vec![false; shards],
            removed: vec![false; shards],
            vnodes,
        }
    }

    /// Total shard slots ever allocated, including retired ones (the
    /// exclusive upper bound for shard indices).
    pub fn shards(&self) -> usize {
        self.down.len()
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// Shards still provisioned (not removed), drained or not.
    pub fn present(&self) -> usize {
        self.removed.iter().filter(|r| !**r).count()
    }

    /// Shards currently accepting new routes.
    pub fn live(&self) -> usize {
        (0..self.down.len())
            .filter(|&s| !self.down[s] && !self.removed[s])
            .count()
    }

    pub fn is_down(&self, shard: usize) -> bool {
        self.down[shard]
    }

    pub fn is_removed(&self, shard: usize) -> bool {
        self.removed[shard]
    }

    /// Mark a shard down (drained: new routes skip it) or back up.
    /// Unchecked primitive kept for tests and callers that manage their
    /// own validity; operational paths use the checked, idempotent
    /// [`ShardRouter::drain_shard`] / [`ShardRouter::restore_shard`].
    pub fn set_down(&mut self, shard: usize, down: bool) {
        self.down[shard] = down;
    }

    /// Take a shard out of the ring.
    ///
    /// Idempotency contract (shared by every reconfiguration op in this
    /// module): `Ok(true)` means the state changed, `Ok(false)` means it
    /// was already drained (a no-op, *not* an error — retried operator
    /// commands must converge), and invalid targets (unknown index,
    /// removed shard) are clean [`ReconfigError`]s. Never panics.
    pub fn drain_shard(&mut self, shard: usize) -> Result<bool, ReconfigError> {
        if shard >= self.down.len() {
            return Err(ReconfigError::UnknownShard(shard));
        }
        if self.removed[shard] {
            return Err(ReconfigError::RemovedShard(shard));
        }
        if self.down[shard] {
            return Ok(false);
        }
        self.down[shard] = true;
        Ok(true)
    }

    /// Put a drained shard back into the ring. `Ok(false)` if it was
    /// already live (restore-of-live is a no-op); errors mirror
    /// [`ShardRouter::drain_shard`].
    pub fn restore_shard(&mut self, shard: usize) -> Result<bool, ReconfigError> {
        if shard >= self.down.len() {
            return Err(ReconfigError::UnknownShard(shard));
        }
        if self.removed[shard] {
            return Err(ReconfigError::RemovedShard(shard));
        }
        if !self.down[shard] {
            return Ok(false);
        }
        self.down[shard] = false;
        Ok(true)
    }

    /// Allocate the next shard index and splice its vnode points into
    /// the ring. Points depend only on `(shard, vnode)`, so the grown
    /// ring equals a fresh router of the larger size: only keys whose
    /// first point now belongs to the new shard remap (≈1/(N+1) of the
    /// keyspace), and a subsequent [`ShardRouter::remove_shard`] of the
    /// same index restores the original routing exactly.
    pub fn add_shard(&mut self) -> usize {
        let s = self.down.len();
        for v in 0..self.vnodes {
            self.ring.push((splitmix64(((s as u64) << 32) | v as u64), s));
        }
        self.ring.sort_unstable();
        self.down.push(false);
        self.removed.push(false);
        s
    }

    /// Retire a shard: its vnode points leave the ring and its index is
    /// never reused (so [`QueryId`] shard tags stay unique across the
    /// fleet's whole history). Remove-while-draining is allowed — a
    /// drained shard is the normal removal candidate. Errors: unknown
    /// index, double-remove ([`ReconfigError::RemovedShard`]), or a
    /// removal that would leave zero live shards
    /// ([`ReconfigError::LastShard`]).
    pub fn remove_shard(&mut self, shard: usize) -> Result<(), ReconfigError> {
        if shard >= self.down.len() {
            return Err(ReconfigError::UnknownShard(shard));
        }
        if self.removed[shard] {
            return Err(ReconfigError::RemovedShard(shard));
        }
        let live_after = (0..self.down.len())
            .filter(|&s| s != shard && !self.down[s] && !self.removed[s])
            .count();
        if live_after == 0 {
            return Err(ReconfigError::LastShard(shard));
        }
        self.removed[shard] = true;
        self.ring.retain(|&(_, s)| s != shard);
        Ok(())
    }

    /// Route a client id to a live shard, or `None` if every shard is
    /// down. O(log ring) in the common case; the clockwise walk only
    /// lengthens while consecutive points belong to down shards.
    pub fn route(&self, client: u64) -> Option<usize> {
        let h = splitmix64(client ^ 0xC11E_17D0_57ED);
        let start = self.ring.partition_point(|&(p, _)| p < h);
        for i in 0..self.ring.len() {
            let (_, s) = self.ring[(start + i) % self.ring.len()];
            if !self.down[s] && !self.removed[s] {
                return Some(s);
            }
        }
        None
    }
}

/// Sentinel for "no shard holds this client's weight".
const NO_SHARD: usize = usize::MAX;

/// One tier client's per-shard legs plus which shard currently holds
/// its admission-fairness weight. Weights *follow the router*: a leg
/// registers its weight only on the shard the router assigns, and
/// drain/restore moves it — so a shard's fair-share denominator counts
/// exactly the clients it actually serves (the ROADMAP dilution fix).
///
/// Legs are **grow-only**: `add_shard` appends a leg for the new shard
/// to every registered home, and retirement never takes a leg away from
/// a client that already holds it — a retiring session drains its
/// in-flight queries into that leg's inbox, so dropping it would strand
/// deliveries. Slots retired before this client was minted are `None`.
struct ClientHome {
    client_id: u64,
    /// Fairness weight, remembered so late-added shards can mint this
    /// client's passive leg with the same carve-out.
    weight: f64,
    /// One per-shard identity, indexed by shard slot.
    legs: RwLock<Vec<Option<ServiceClient>>>,
    /// Shard whose frontend currently holds the weight ([`NO_SHARD`]
    /// before first routing or when every shard is down).
    active: AtomicUsize,
}

impl ClientHome {
    fn rehome(&self, router: &ShardRouter) {
        let next = router.route(self.client_id).unwrap_or(NO_SHARD);
        let prev = self.active.swap(next, Ordering::SeqCst);
        if prev == next {
            return;
        }
        let legs = self.legs.pread();
        if prev != NO_SHARD {
            if let Some(Some(leg)) = legs.get(prev) {
                leg.deactivate_weight();
            }
        }
        if next != NO_SHARD {
            if let Some(Some(leg)) = legs.get(next) {
                leg.activate_weight();
            }
        }
    }
}

impl Drop for ClientHome {
    fn drop(&mut self) {
        // The last clone of this client is gone: give its weight back to
        // whatever shard currently holds it, so transient clients never
        // permanently inflate a shard's fair-share denominator.
        let active = self.active.load(Ordering::SeqCst);
        if active != NO_SHARD {
            if let Some(Some(leg)) = self.legs.get_mut().unwrap().get(active) {
                leg.deactivate_weight();
            }
        }
    }
}

/// State shared by the tier's frontend handle and every client.
struct ShardShared {
    router: RwLock<ShardRouter>,
    global_backlog: Option<usize>,
    next_client: AtomicU64,
    /// Base (untagged) serving-path journal handle; clients record
    /// routing decisions through it, shards record through per-shard
    /// tagged clones.
    recorder: crate::coordinator::journal::Recorder,
    /// Every live client's weight home (weights move on drain/restore,
    /// legs grow on add_shard). Weak: the strong references live in the
    /// `ShardedClient` clones, so a dropped client's home is pruned on
    /// the next sweep instead of accumulating forever.
    homes: Mutex<Vec<std::sync::Weak<ClientHome>>>,
}

impl ShardShared {
    /// Re-derive every live client's weight placement from the current
    /// ring, pruning dropped clients (lock order: router before homes,
    /// everywhere — including the mint path, so a client minted
    /// concurrently with a drain is either swept here or sees the
    /// updated ring itself).
    fn rehome_all(&self) {
        let router = self.router.pread();
        let mut homes = self.homes.plock();
        homes.retain(|w| match w.upgrade() {
            Some(home) => {
                home.rehome(&router);
                true
            }
            None => false,
        });
    }
}

/// One shard slot of the elastic tier: a live session, or the record of
/// a session removed at runtime.
enum ShardSlot {
    Live(ServingFrontend),
    /// Torn down by [`ShardedFrontend::remove_shard`]. Keeps the fault
    /// plan (so the harness surface stays total over history) and the
    /// session's final record for the shutdown merge — conservation
    /// audits must still see the queries it served before retiring.
    Retired {
        faults: Arc<FaultPlan>,
        result: Option<RunResult>,
    },
}

impl ShardSlot {
    fn live(&self) -> Option<&ServingFrontend> {
        match self {
            ShardSlot::Live(f) => Some(f),
            ShardSlot::Retired { .. } => None,
        }
    }
}

/// Everything needed to stand up one more shard session at runtime:
/// the base config, the model set, and the per-shard scheme factory the
/// tier was started with. Guarded by a mutex that doubles as the
/// reconfiguration serializer — the data path never takes it.
struct ShardSpawner {
    cfg: ServiceConfig,
    /// `cfg.seed` as configured, before any per-shard decorrelation.
    base_seed: u64,
    models: ModelSet,
    sample: Tensor,
    scheme_for_shard: Box<dyn FnMut(usize) -> Option<Box<dyn RedundancyScheme>> + Send>,
}

impl ShardSpawner {
    fn spawn(&mut self, s: usize) -> anyhow::Result<ServingFrontend> {
        let mut shard_cfg = self.cfg.clone();
        // Session-local query ids restart at zero in every shard: the
        // per-shard tag is what keeps them distinct in the journal.
        shard_cfg.recorder = self.cfg.recorder.tagged(s as u64);
        // Same discipline for metrics: every shard session publishes
        // into the one fleet registry under its own `shard` label.
        shard_cfg.telemetry = self.cfg.telemetry.scoped("shard", s);
        if s > 0 {
            shard_cfg.seed = splitmix64(self.base_seed ^ ((s as u64) << 40));
            // One scheduled fault must not fire in lockstep across
            // the whole fleet — that would erase the healthy-shard
            // baseline the tier exists to preserve.
            shard_cfg.fault_schedule.clear();
        }
        let mut builder = ServiceBuilder::new(shard_cfg);
        if let Some(scheme) = (self.scheme_for_shard)(s) {
            builder = builder.with_scheme(scheme);
        }
        builder.serve(&self.models, &self.sample)
    }
}

/// N independent serving sessions behind one consistent-hash router.
///
/// Build with [`ShardedFrontend::start`], mint [`ShardedClient`]s with
/// [`ShardedFrontend::client`], degrade shards with
/// [`ShardedFrontend::kill_instance`] / [`ShardedFrontend::drain_shard`],
/// resize the fleet at runtime with [`ShardedFrontend::add_shard`] /
/// [`ShardedFrontend::remove_shard`], observe the fleet with
/// [`ShardedFrontend::window`], and finish with
/// [`ShardedFrontend::shutdown`] for the merged run record.
pub struct ShardedFrontend {
    /// Indexed by shard; retired slots keep their index forever.
    slots: RwLock<Vec<ShardSlot>>,
    /// Runtime shard factory; its mutex serializes reconfiguration
    /// (lock order: spawner → slots → router → homes → legs).
    spawner: Mutex<ShardSpawner>,
    shared: Arc<ShardShared>,
}

/// What [`ShardedFrontend::shutdown`] returns: the fleet-wide merged
/// record plus each shard's own, so callers can audit that the merge
/// conserved every count.
pub struct ShardedRunResult {
    /// All shards folded together ([`RunResult::merged`]), including
    /// shards removed at runtime.
    pub merged: RunResult,
    /// Per-shard results, in shard order (removed shards contribute the
    /// record they had at teardown).
    pub per_shard: Vec<RunResult>,
}

impl ShardedFrontend {
    /// Stand up `spec.shards` independent sessions from one config.
    ///
    /// Shard 0 keeps `cfg.seed` unchanged (so `--shards 1` reproduces the
    /// unsharded run exactly); later shards get decorrelated seeds, since
    /// N copies of one seed would fail, shuffle, and pace in lockstep —
    /// the opposite of independent fault domains. For the same reason a
    /// configured `fault_schedule` applies to **shard 0 only** (the
    /// scenario "degrade one shard while the others keep their latency
    /// profile"); use [`ShardedFrontend::kill_instance`] /
    /// [`ShardedFrontend::fail_instance_for`] to target other shards.
    pub fn start(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
    ) -> anyhow::Result<ShardedFrontend> {
        anyhow::ensure!(
            !matches!(cfg.mode, Mode::CrossShard { .. }),
            "Mode::CrossShard coding groups span shards; serve it through \
             CrossShardFrontend::start"
        );
        ShardedFrontend::start_with(cfg, spec, models, sample_query, |_| None)
    }

    /// [`ShardedFrontend::start`] with an optional per-shard scheme
    /// override: `scheme_for_shard(s)` returning `Some` injects that
    /// strategy into shard s's session (how the cross-shard tier binds
    /// every shard to one fleet-shared coding state); `None` falls back
    /// to instantiating `cfg.mode` as usual. The factory is retained so
    /// [`ShardedFrontend::add_shard`] can stamp out late shards the
    /// same way.
    pub(crate) fn start_with(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
        scheme_for_shard: impl FnMut(usize) -> Option<Box<dyn RedundancyScheme>> + Send + 'static,
    ) -> anyhow::Result<ShardedFrontend> {
        anyhow::ensure!(
            (1..=MAX_SHARDS).contains(&spec.shards),
            "shards must be in 1..={MAX_SHARDS}, got {}",
            spec.shards
        );
        anyhow::ensure!(spec.vnodes >= 1, "vnodes must be >= 1");
        let mut spawner = ShardSpawner {
            base_seed: cfg.seed,
            cfg,
            models: models.clone(),
            sample: sample_query.clone(),
            scheme_for_shard: Box::new(scheme_for_shard),
        };
        let mut slots = Vec::with_capacity(spec.shards);
        for s in 0..spec.shards {
            slots.push(ShardSlot::Live(spawner.spawn(s)?));
        }
        let recorder = spawner.cfg.recorder.clone();
        Ok(ShardedFrontend {
            slots: RwLock::new(slots),
            spawner: Mutex::new(spawner),
            shared: Arc::new(ShardShared {
                router: RwLock::new(ShardRouter::new(spec.shards, spec.vnodes)),
                global_backlog: spec.global_backlog,
                next_client: AtomicU64::new(0),
                recorder,
                homes: Mutex::new(Vec::new()),
            }),
        })
    }

    /// Total shard slots ever allocated (the exclusive upper bound for
    /// shard indices), including slots retired by
    /// [`ShardedFrontend::remove_shard`].
    pub fn shards(&self) -> usize {
        self.slots.pread().len()
    }

    /// Shards still provisioned (sessions running), drained or not.
    pub fn provisioned_shards(&self) -> usize {
        self.slots
            .read()
            .unwrap()
            .iter()
            .filter(|s| s.live().is_some())
            .count()
    }

    /// Mint a shard-transparent client (a fresh identity on every shard,
    /// routed by its id) with the default fairness weight of 1.
    ///
    /// Admission fairness follows the routing: the client's weight is
    /// registered only on the shard the router currently assigns it,
    /// and moves when drain/restore remaps the client — so a shard's
    /// weighted fair shares are computed over exactly the clients it
    /// serves, undiluted by the rest of the fleet.
    pub fn client(&self) -> ShardedClient {
        self.client_with_weight(1.0)
    }

    /// [`ShardedFrontend::client`] with an explicit admission-fairness
    /// weight (see [`ServingFrontend::client_with_weight`] for the
    /// carve-out semantics on the routed shard).
    pub fn client_with_weight(&self, weight: f64) -> ShardedClient {
        let id = self.shared.next_client.fetch_add(1, Ordering::Relaxed);
        // Hold slots (read) across leg minting AND home registration, so
        // a concurrent add_shard — which pushes new legs into registered
        // homes under slots (write) — is ordered entirely before this
        // mint (we see its slot) or entirely after (it sees our home).
        // Either way the legs vector covers every shard the router can
        // return. Lock order: slots → router → homes.
        let slots = self.slots.pread();
        let legs: Vec<Option<ServiceClient>> = slots
            .iter()
            .map(|slot| slot.live().map(|f| f.passive_client_with_weight(weight)))
            .collect();
        let home = Arc::new(ClientHome {
            client_id: id,
            weight,
            legs: RwLock::new(legs),
            active: AtomicUsize::new(NO_SHARD),
        });
        {
            // Hold router (read) + homes across rehome AND registration
            // — same order as rehome_all — so a concurrent drain/restore
            // cannot slip between them and leave this client's weight on
            // a shard the router no longer assigns it.
            let router = self.shared.router.pread();
            let mut homes = self.shared.homes.plock();
            home.rehome(&router);
            homes.push(Arc::downgrade(&home));
        }
        drop(slots);
        ShardedClient { id, home, shared: self.shared.clone() }
    }

    /// Stand up one more shard session and splice it into the ring.
    ///
    /// The new shard is stamped from the same config/models/scheme
    /// factory as the originals (with a decorrelated seed), every
    /// existing client grows a passive leg on it before it can receive
    /// a route, and consistent hashing guarantees only ≈1/(N+1) of the
    /// client population remaps onto it. Returns the new shard's index.
    /// Serialized with every other reconfiguration op; the data path
    /// never blocks on it beyond brief slot/ring lock windows.
    pub fn add_shard(&self) -> anyhow::Result<usize> {
        let mut spawner = self.spawner.plock();
        let s = self.slots.pread().len();
        if s >= MAX_SHARDS {
            return Err(ReconfigError::AtCapacity(s).into());
        }
        let fe = spawner.spawn(s)?;
        {
            let mut slots = self.slots.pwrite();
            debug_assert_eq!(slots.len(), s, "reconfiguration must be serialized");
            let mut homes = self.shared.homes.plock();
            homes.retain(|w| match w.upgrade() {
                Some(home) => {
                    home.legs
                        .write()
                        .unwrap()
                        .push(Some(fe.passive_client_with_weight(home.weight)));
                    true
                }
                None => false,
            });
            slots.push(ShardSlot::Live(fe));
        }
        self.shared.router.pwrite().add_shard();
        self.shared.rehome_all();
        Ok(s)
    }

    /// Tear a shard down at runtime: retire it from the ring (rerouting
    /// its clients with their weights), then shut its session down —
    /// in-flight queries drain into their owners' inboxes, so accepted
    /// work is never lost. The teardown runs outside every tier lock
    /// (draining can take a while; the data path must not stall behind
    /// it). The slot's final [`RunResult`] is folded into
    /// [`ShardedFrontend::shutdown`]'s merge. Errors are the
    /// [`ShardRouter::remove_shard`] contract: clean, never panicking.
    pub fn remove_shard(&self, shard: usize) -> anyhow::Result<()> {
        let _reconfig = self.spawner.plock();
        self.shared.router.pwrite().remove_shard(shard)?;
        self.shared.rehome_all();
        let fe = {
            let mut slots = self.slots.pwrite();
            let slot = &mut slots[shard];
            let faults = match slot.live() {
                Some(f) => f.fault_plan(),
                // Router bookkeeping and slots move in lockstep under
                // the spawner lock, so a routable shard is always live.
                None => return Ok(()),
            };
            match std::mem::replace(slot, ShardSlot::Retired { faults, result: None }) {
                ShardSlot::Live(f) => f,
                ShardSlot::Retired { .. } => unreachable!(),
            }
        };
        let result = fe.shutdown()?;
        if let ShardSlot::Retired { result: stash, .. } =
            &mut self.slots.pwrite()[shard]
        {
            *stash = Some(result);
        }
        Ok(())
    }

    /// Swap the admission policy on every live shard (and on the
    /// spawner, so late-added shards inherit it). Takes effect on the
    /// next admission decision; in-flight queries are untouched.
    pub fn set_admission(&self, policy: AdmissionPolicy) {
        let mut spawner = self.spawner.plock();
        spawner.cfg.admission = policy;
        let slots = self.slots.pread();
        for slot in slots.iter() {
            if let Some(f) = slot.live() {
                f.set_policy(policy);
            }
        }
    }

    /// Fairness weight currently registered with one shard's frontend
    /// (observability for the weight-follows-router invariant). Retired
    /// shards hold no weight.
    pub fn shard_total_weight(&self, shard: usize) -> f64 {
        self.slots.pread()[shard]
            .live()
            .map_or(0.0, ServingFrontend::total_weight)
    }

    /// The shard the router currently assigns to `client_id` (`None` if
    /// every shard is drained).
    pub fn route_of(&self, client_id: u64) -> Option<usize> {
        self.shared.router.pread().route(client_id)
    }

    /// Take a shard out of the routing ring: *subsequent* submits from
    /// its clients walk clockwise to the next live shard, while queries
    /// already in the shard keep resolving and its session still shows
    /// up (and is drained) in [`ShardedFrontend::shutdown`]. Remapped
    /// clients' fairness weights move with them. Idempotent: `Ok(true)`
    /// if the shard transitioned, `Ok(false)` if it was already drained.
    pub fn drain_shard(&self, shard: usize) -> Result<bool, ReconfigError> {
        let changed = self.shared.router.pwrite().drain_shard(shard)?;
        if changed {
            self.shared.rehome_all();
        }
        Ok(changed)
    }

    /// Put a drained shard back into the ring (its original clients'
    /// weights return with their routes). Idempotent: `Ok(false)` if it
    /// was already live.
    pub fn restore_shard(&self, shard: usize) -> Result<bool, ReconfigError> {
        let changed = self.shared.router.pwrite().restore_shard(shard)?;
        if changed {
            self.shared.rehome_all();
        }
        Ok(changed)
    }

    /// Live shard count (shards not drained and not removed).
    pub fn live_shards(&self) -> usize {
        self.shared.router.pread().live()
    }

    /// One shard's ring state: `"live"`, `"drained"`, `"retired"`, or
    /// `"unknown"` for an index never allocated (total, for operator
    /// surfaces that must not panic on bad input).
    pub fn shard_state(&self, shard: usize) -> &'static str {
        let router = self.shared.router.pread();
        if shard >= router.shards() {
            "unknown"
        } else if router.is_removed(shard) {
            "retired"
        } else if router.is_down(shard) {
            "drained"
        } else {
            "live"
        }
    }

    /// Permanently kill one instance *of one shard* (the paper's
    /// undetected-zombie failure model, scoped to a fault domain): that
    /// shard degrades to its redundancy scheme while the others keep
    /// their latency profile. A no-op (with a warning) on retired
    /// shards.
    pub fn kill_instance(&self, shard: usize, instance: usize) {
        if let Some(f) = self.slots.pread()[shard].live() {
            f.kill_instance(instance);
        } else {
            log::warn!("kill_instance: shard {shard} is retired");
        }
    }

    /// Fail one instance of one shard for a bounded window.
    pub fn fail_instance_for(&self, shard: usize, instance: usize, dur: Duration) {
        if let Some(f) = self.slots.pread()[shard].live() {
            f.fail_instance_for(instance, dur);
        } else {
            log::warn!("fail_instance_for: shard {shard} is retired");
        }
    }

    /// One shard's cluster fault plan (the surface the deterministic
    /// fault-injection harness in `tests/common` scripts against).
    /// Total over the fleet's history: retired shards keep their plan.
    pub fn fault_plan(&self, shard: usize) -> Arc<FaultPlan> {
        match &self.slots.pread()[shard] {
            ShardSlot::Live(f) => f.fault_plan(),
            ShardSlot::Retired { faults, .. } => faults.clone(),
        }
    }

    /// One live shard's link-contention model (`None` for retired
    /// shards) — the scriptable network-chaos surface.
    pub fn network(&self, shard: usize) -> Option<Arc<crate::cluster::network::Network>> {
        self.slots.pread()[shard].live().map(ServingFrontend::network)
    }

    /// The tier's base journal handle (what the control plane records
    /// reconfiguration events through).
    pub fn recorder(&self) -> crate::coordinator::journal::Recorder {
        self.shared.recorder.clone()
    }

    /// The fleet-wide metric registry (unscoped base handle; every shard
    /// session publishes into it under its `shard` label).
    pub fn registry(&self) -> crate::telemetry::Registry {
        self.spawner.plock().cfg.telemetry.clone()
    }

    /// Summed admission-load estimate across every live shard (what the
    /// global offered-load cap bounds).
    pub fn load(&self) -> usize {
        self.slots
            .read()
            .unwrap()
            .iter()
            .filter_map(ShardSlot::live)
            .map(ServingFrontend::load)
            .sum()
    }

    /// Total admission rejects across every shard (including global-cap
    /// rejects, which are tallied against the routed shard, and rejects
    /// recorded by shards since removed).
    pub fn rejected(&self) -> u64 {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|slot| match slot {
                ShardSlot::Live(f) => f.rejected(),
                ShardSlot::Retired { result, .. } => {
                    result.as_ref().map_or(0, |r| r.rejected)
                }
            })
            .sum()
    }

    /// One shard's live window (zero for retired shards).
    pub fn shard_window(&self, shard: usize) -> WindowSnapshot {
        self.slots.pread()[shard]
            .live()
            .map_or_else(|| WindowSnapshot::zero(Duration::ZERO), ServingFrontend::window)
    }

    /// Fleet-wide live metrics: every live shard's window merged
    /// ([`WindowSnapshot::merge`] — counts exact, quantiles
    /// resolved-weighted).
    pub fn window(&self) -> WindowSnapshot {
        let slots = self.slots.pread();
        let snaps: Vec<WindowSnapshot> = slots
            .iter()
            .filter_map(ShardSlot::live)
            .map(ServingFrontend::window)
            .collect();
        WindowSnapshot::merge_all(&snaps)
    }

    /// Shut every shard down (each drains its in-flight queries) and
    /// merge the per-shard [`RunResult`]s into one fleet record —
    /// including shards removed at runtime, whose final records were
    /// stashed at teardown. The merged `submitted`/`resolved`/`rejected`
    /// totals equal the per-shard sums by construction — `per_shard` is
    /// returned so tests and reports can verify exactly that.
    pub fn shutdown(self) -> anyhow::Result<ShardedRunResult> {
        let slots = self.slots.into_inner().unwrap();
        let mut per_shard = Vec::with_capacity(slots.len());
        for (s, slot) in slots.into_iter().enumerate() {
            match slot {
                ShardSlot::Live(f) => per_shard.push(f.shutdown()?),
                ShardSlot::Retired { result: Some(r), .. } => per_shard.push(r),
                ShardSlot::Retired { result: None, .. } => {
                    log::warn!("shard {s}: retired without a run record (teardown failed)");
                }
            }
        }
        Ok(ShardedRunResult { merged: RunResult::merged(&per_shard), per_shard })
    }
}

/// A shard-transparent client of a [`ShardedFrontend`].
///
/// Cheap to clone (clones share this client's identity and inboxes, like
/// [`ServiceClient`]); `Send + Sync`, so one client can be driven from
/// several threads. Submissions route to the client's current shard;
/// completions are swept from every shard, so rerouting mid-run (a
/// drained shard) never strands a delivery. Legs live behind the home's
/// lock so the tier can grow them when shards are added at runtime.
#[derive(Clone)]
pub struct ShardedClient {
    id: u64,
    /// Keeps this client's weight home (and per-shard legs) alive; when
    /// the last clone drops, the home's Drop releases the weight and
    /// the tier prunes it.
    home: Arc<ClientHome>,
    shared: Arc<ShardShared>,
}

impl ShardedClient {
    /// This client's tier-assigned id (the consistent-hash key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The shard the router currently assigns this client to.
    pub fn shard(&self) -> Option<usize> {
        self.shared.router.pread().route(self.id)
    }

    /// The shard currently holding this client's admission weight
    /// (`None` when every shard is down). Equal to
    /// [`ShardedClient::shard`] except in the instant between a
    /// drain/restore and its rehome sweep.
    pub fn weight_shard(&self) -> Option<usize> {
        match self.home.active.load(Ordering::SeqCst) {
            NO_SHARD => None,
            s => Some(s),
        }
    }

    /// Submit one query through the routed shard's admission control
    /// (after the fleet-wide cap, when configured). The returned id
    /// carries the serving shard in its top byte ([`shard_of`]).
    pub fn submit(&self, input: Tensor) -> Result<QueryId, SubmitError> {
        let Some(shard) = self.shared.router.pread().route(self.id) else {
            return Err(SubmitError::Closed);
        };
        let legs = self.home.legs.pread();
        if let Some(cap) = self.shared.global_backlog {
            let load: usize = legs.iter().flatten().map(ServiceClient::load).sum();
            if load >= cap {
                // Tally against the shard that would have served it, so
                // the fleet's merged RunResult still covers offered load.
                if let Some(Some(leg)) = legs.get(shard) {
                    leg.note_reject();
                }
                return Err(SubmitError::Rejected { load, limit: cap });
            }
        }
        let Some(Some(leg)) = legs.get(shard) else {
            return Err(SubmitError::Closed);
        };
        let fid = leg.submit(input)?;
        if self.shared.recorder.enabled() {
            self.shared.recorder.record(&crate::coordinator::journal::Event::Route {
                qid: tag(shard, fid),
                shard: shard as u64,
            });
        }
        Ok(tag(shard, fid))
    }

    /// Non-blocking: take every prediction delivered to this client on
    /// any shard, ids re-tagged fleet-wide.
    pub fn poll(&self) -> Vec<Resolved> {
        let legs = self.home.legs.pread();
        let mut out = Vec::new();
        for (s, leg) in legs.iter().enumerate() {
            let Some(leg) = leg else { continue };
            for r in leg.poll() {
                out.push(Resolved { id: tag(s, r.id), ..r });
            }
        }
        out
    }

    /// Block up to `timeout` for the next prediction from any shard.
    /// Sweeps every leg, parking briefly on the currently-routed shard
    /// (where new deliveries land) between sweeps. The park happens on
    /// a leg clone with the legs lock released, so a concurrent
    /// add_shard never waits on a parked client.
    pub fn next(&self, timeout: Duration) -> Option<Resolved> {
        let deadline = Instant::now() + timeout;
        loop {
            let primary = {
                let legs = self.home.legs.pread();
                for (s, leg) in legs.iter().enumerate() {
                    let Some(leg) = leg else { continue };
                    if let Some(r) = leg.try_next() {
                        return Some(Resolved { id: tag(s, r.id), ..r });
                    }
                }
                let p = self.shared.router.pread().route(self.id).unwrap_or(0);
                legs.get(p).and_then(|l| l.clone()).map(|leg| (p, leg))
            };
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let park = (deadline - now).min(Duration::from_millis(2));
            match primary {
                Some((p, leg)) => {
                    if let Some(r) = leg.next(park) {
                        return Some(Resolved { id: tag(p, r.id), ..r });
                    }
                }
                None => std::thread::sleep(park),
            }
        }
    }

    /// This client's counters summed across every shard it touched.
    pub fn stats(&self) -> ClientStats {
        let legs = self.home.legs.pread();
        let mut total = ClientStats::default();
        for leg in legs.iter().flatten() {
            let s = leg.stats();
            total.submitted += s.submitted;
            total.resolved += s.resolved;
            total.rejected += s.rejected;
            total.native += s.native;
            total.recovered += s.recovered;
            total.defaulted += s.defaulted;
        }
        total
    }

    /// This client's live window merged across shards.
    pub fn window(&self) -> WindowSnapshot {
        let legs = self.home.legs.pread();
        let snaps: Vec<WindowSnapshot> =
            legs.iter().flatten().map(ServiceClient::window).collect();
        WindowSnapshot::merge_all(&snaps)
    }
}

// ------------------------------------------------------------------------
// Cross-shard coding tier
// ------------------------------------------------------------------------

/// The sharded tier with coding groups that *span* the shards
/// ([`Mode::CrossShard`]): every group stripes its k data batches over k
/// distinct shards and sends its parities to a shared cross-shard pool,
/// so killing an entire shard costs each group at most one slot — which
/// decodes like any single-instance loss. Group redundancy is sized by
/// a fleet-level straggler predictor that merges per-shard estimates
/// (see [`crate::coordinator::cross_shard`] for the data flow).
///
/// The client surface is identical to [`ShardedFrontend`]'s — the same
/// [`ShardedClient`] type, routing, admission, weight-follows-router
/// fairness, windows, and merged shutdown — plus the parity pool's own
/// run records and the fleet coding telemetry.
///
/// The tier is elastic end to end: [`CrossShardFrontend::add_shard`] /
/// [`CrossShardFrontend::remove_shard`] resize the data fleet *and*
/// re-provision the shared parity pool toward `ceil(shards·m/k)`
/// instances per r_index (asynchronously — in-flight parity jobs finish
/// on the outgoing sessions before they retire, so no open group loses
/// its protection mid-resize).
pub struct CrossShardFrontend {
    tier: ShardedFrontend,
    parity: ParityLeg,
    state: Arc<CrossShardState>,
    /// Deployed instances per data shard ([`CrossShardFrontend::kill_shard`]).
    shard_m: usize,
    /// Coding-group width (parity pool provisioning divisor).
    k: usize,
}

/// What [`CrossShardFrontend::shutdown`] returns.
pub struct CrossShardRunResult {
    /// The data shards' merged + per-shard records (client traffic).
    pub fleet: ShardedRunResult,
    /// The shared parity pool's session records, in r_index order
    /// (sessions rotated out by a runtime resize are merged into their
    /// r_index's record). These count *parity* queries, deliberately
    /// kept out of the fleet record so client-traffic conservation
    /// stays auditable.
    pub parity: Vec<RunResult>,
    /// Final fleet coding telemetry (sealed groups, parity jobs,
    /// reconstructions, per-shard unavailability).
    pub telemetry: CrossShardTelemetry,
}

impl CrossShardFrontend {
    /// Stand up the cross-shard tier: `spec.shards` data shards (each an
    /// independent session running [`CrossShardScheme`] against one
    /// fleet-shared coding state) plus `r_max` shared parity sessions of
    /// `ceil(shards·m / k)` instances each (ParM's m/k provisioning at
    /// fleet scale). Requires `cfg.mode` to be [`Mode::CrossShard`] and
    /// `spec.shards >= k`; `models` must carry `r_max` parity
    /// executables.
    pub fn start(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
    ) -> anyhow::Result<CrossShardFrontend> {
        let Mode::CrossShard { k, r_min, r_max, halflife } = cfg.mode else {
            anyhow::bail!(
                "CrossShardFrontend needs Mode::CrossShard, got mode {:?}",
                cfg.mode.name()
            );
        };
        anyhow::ensure!(
            spec.shards >= k,
            "cross-shard groups stripe k={k} slots over distinct shards; \
             need shards >= k, got {}",
            spec.shards
        );
        let state = Arc::new(CrossShardState::new(CrossShardConfig::new(
            k,
            r_min,
            r_max,
            spec.shards,
            halflife,
        )));
        // Wire the parity channel before any shard can seal a group.
        let (ptx, prx) = mpsc::channel();
        state.set_parity_sender(ptx.clone());
        // Fleet-level Seal/Decode events carry the base (untagged)
        // journal handle; per-shard events are tagged by the spawner.
        state.set_recorder(cfg.recorder.clone());
        let tier = {
            let st = state.clone();
            ShardedFrontend::start_with(cfg.clone(), spec, models, sample_query, move |s| {
                Some(Box::new(CrossShardScheme::new(s, st.clone())) as Box<dyn RedundancyScheme>)
            })?
        };
        let per = (spec.shards * cfg.m + k - 1) / k;
        let parity =
            ParityLeg::start(&cfg, &state, models, sample_query, per, r_max, ptx, prx)?;
        Ok(CrossShardFrontend { tier, parity, state, shard_m: cfg.m, k })
    }

    /// Total shard slots ever allocated (including retired ones).
    pub fn shards(&self) -> usize {
        self.tier.shards()
    }

    /// Data shards still provisioned (sessions running).
    pub fn provisioned_shards(&self) -> usize {
        self.tier.provisioned_shards()
    }

    /// Instances in each per-r_index shared parity pool (the currently
    /// *active* generation; resizes apply asynchronously).
    pub fn parity_pool_size(&self) -> usize {
        self.parity.pool_size()
    }

    /// The parity pool size the current fleet calls for:
    /// `ceil(provisioned·m / k)`, ParM's m/k provisioning at fleet
    /// scale. [`CrossShardFrontend::parity_pool_size`] converges to
    /// this after a resize.
    pub fn parity_pool_target(&self) -> usize {
        ((self.tier.provisioned_shards() * self.shard_m + self.k - 1) / self.k).max(1)
    }

    /// Stand up one more data shard at runtime. The shared coding state
    /// grows first (so the new shard can offer batches the moment
    /// traffic reaches it), then the tier adds the session, then the
    /// parity pool is re-provisioned toward the new
    /// [`CrossShardFrontend::parity_pool_target`]. Returns the new
    /// shard's index.
    pub fn add_shard(&self) -> anyhow::Result<usize> {
        self.state.grow_to(self.tier.shards() + 1);
        let s = self.tier.add_shard()?;
        // A concurrent add could have raced the pre-grow; make sure the
        // state covers the index the tier actually allocated.
        self.state.grow_to(s + 1);
        self.parity.resize(self.parity_pool_target());
        Ok(s)
    }

    /// Tear one data shard down at runtime: reroute its clients, drain
    /// its session, retire its coding-state lane, and shrink the parity
    /// pool toward the new target. Refuses to shrink the fleet below k
    /// provisioned shards (groups must still stripe over k distinct
    /// shards).
    pub fn remove_shard(&self, shard: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.tier.provisioned_shards() > self.k,
            "cross-shard groups stripe over k={} distinct shards; cannot \
             shrink the fleet below that",
            self.k
        );
        self.tier.remove_shard(shard)?;
        self.state.retire_shard(shard);
        self.parity.resize(self.parity_pool_target());
        Ok(())
    }

    /// Swap the admission policy on every live data shard.
    pub fn set_admission(&self, policy: AdmissionPolicy) {
        self.tier.set_admission(policy);
    }

    /// Mint a shard-transparent client (same surface as
    /// [`ShardedFrontend::client`]).
    pub fn client(&self) -> ShardedClient {
        self.tier.client()
    }

    /// Mint a client with an explicit admission-fairness weight.
    pub fn client_with_weight(&self, weight: f64) -> ShardedClient {
        self.tier.client_with_weight(weight)
    }

    /// The shard the router currently assigns to `client_id`.
    pub fn route_of(&self, client_id: u64) -> Option<usize> {
        self.tier.route_of(client_id)
    }

    /// Take a data shard out of the routing ring (in-flight queries keep
    /// resolving; stranded open groups short-seal at the loss horizon).
    /// Idempotent — see [`ShardedFrontend::drain_shard`].
    pub fn drain_shard(&self, shard: usize) -> Result<bool, ReconfigError> {
        self.tier.drain_shard(shard)
    }

    /// Put a drained shard back into the ring. Idempotent — see
    /// [`ShardedFrontend::restore_shard`].
    pub fn restore_shard(&self, shard: usize) -> Result<bool, ReconfigError> {
        self.tier.restore_shard(shard)
    }

    pub fn live_shards(&self) -> usize {
        self.tier.live_shards()
    }

    /// One shard's ring state (see [`ShardedFrontend::shard_state`]).
    pub fn shard_state(&self, shard: usize) -> &'static str {
        self.tier.shard_state(shard)
    }

    /// One live data shard's link-contention model (see
    /// [`ShardedFrontend::network`]).
    pub fn network(&self, shard: usize) -> Option<Arc<crate::cluster::network::Network>> {
        self.tier.network(shard)
    }

    /// The fleet's base journal handle (see [`ShardedFrontend::recorder`]).
    pub fn recorder(&self) -> crate::coordinator::journal::Recorder {
        self.tier.recorder()
    }

    /// The fleet-wide metric registry (see [`ShardedFrontend::registry`]).
    pub fn registry(&self) -> crate::telemetry::Registry {
        self.tier.registry()
    }

    /// Permanently kill one deployed instance of one data shard.
    pub fn kill_instance(&self, shard: usize, instance: usize) {
        self.tier.kill_instance(shard, instance);
    }

    /// Kill *every* deployed instance of one data shard — the
    /// whole-fault-domain loss this tier exists to absorb: each coding
    /// group loses at most its one slot there and decodes from the
    /// shared parity pool.
    pub fn kill_shard(&self, shard: usize) {
        for i in 0..self.shard_m {
            self.tier.kill_instance(shard, i);
        }
    }

    /// Fail one instance of one data shard for a bounded window.
    pub fn fail_instance_for(&self, shard: usize, instance: usize, dur: Duration) {
        self.tier.fail_instance_for(shard, instance, dur);
    }

    /// One data shard's fault plan (harness surface).
    pub fn fault_plan(&self, shard: usize) -> Arc<FaultPlan> {
        self.tier.fault_plan(shard)
    }

    /// The r_index-th parity pool's fault plan (harness surface).
    pub fn parity_fault_plan(&self, r_index: usize) -> Arc<FaultPlan> {
        self.parity.fault_plan(r_index)
    }

    /// Permanently kill one instance of the r_index-th parity pool.
    pub fn kill_parity_instance(&self, r_index: usize, instance: usize) {
        self.parity.kill(r_index, instance);
    }

    /// Summed admission-load estimate across the data shards.
    pub fn load(&self) -> usize {
        self.tier.load()
    }

    /// Total admission rejects across the data shards.
    pub fn rejected(&self) -> u64 {
        self.tier.rejected()
    }

    /// One data shard's live window.
    pub fn shard_window(&self, shard: usize) -> WindowSnapshot {
        self.tier.shard_window(shard)
    }

    /// Fleet-wide live metrics (data shards merged).
    pub fn window(&self) -> WindowSnapshot {
        self.tier.window()
    }

    /// Fairness weight currently registered on one shard.
    pub fn shard_total_weight(&self, shard: usize) -> f64 {
        self.tier.shard_total_weight(shard)
    }

    /// Live fleet coding telemetry: last chosen r, per-shard and fleet
    /// unavailability, groups sealed, parity jobs, reconstructions.
    pub fn telemetry(&self) -> CrossShardTelemetry {
        self.state.fleet_telemetry()
    }

    /// Short-seal every open coding group now. Call when offered load
    /// pauses (end of a drive phase) so tail queries get their parity
    /// protection immediately instead of at the loss horizon.
    pub fn flush_open_groups(&self) {
        self.state.flush_open(Instant::now());
    }

    /// Shut the tier down: short-seal the tail, drain the data shards
    /// (decodes keep landing while they drain), then stop the parity
    /// pool, returning the fleet record, the parity records, and the
    /// final telemetry. As with every drain in this stack, resolution
    /// of queries that lost both their data and their decode path needs
    /// an SLO in the config — set one when serving under failures.
    pub fn shutdown(self) -> anyhow::Result<CrossShardRunResult> {
        self.state.flush_open(Instant::now());
        let fleet = self.tier.shutdown()?;
        let telemetry = self.state.fleet_telemetry();
        let parity = self.parity.stop();
        Ok(CrossShardRunResult { fleet, parity, telemetry })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_client_is_send_sync_clone() {
        fn assert_traits<T: Send + Sync + Clone>() {}
        assert_traits::<ShardedClient>();
    }

    #[test]
    fn id_tagging_roundtrips() {
        for shard in [0usize, 1, 3, 254] {
            let id = tag(shard, 12_345);
            assert_eq!(shard_of(id), shard);
            assert_eq!(id & ((1u64 << SHARD_SHIFT) - 1), 12_345);
        }
    }

    #[test]
    fn ring_covers_all_shards_reasonably_evenly() {
        let router = ShardRouter::new(4, 64);
        let mut counts = [0usize; 4];
        for client in 0..10_000u64 {
            counts[router.route(client).unwrap()] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // 10k clients over 4 shards with 64 vnodes: every shard gets
            // a solid chunk (loose bound — the ring is hash-balanced, not
            // perfectly uniform).
            assert!(c > 500, "shard {s} nearly starved: {counts:?}");
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let a = ShardRouter::new(8, 32);
        let b = ShardRouter::new(8, 32);
        for client in 0..500u64 {
            assert_eq!(a.route(client), b.route(client));
        }
    }

    #[test]
    fn downing_a_shard_remaps_only_its_clients() {
        let mut router = ShardRouter::new(4, 64);
        let before: Vec<usize> =
            (0..2_000u64).map(|c| router.route(c).unwrap()).collect();
        router.set_down(2, true);
        assert_eq!(router.live(), 3);
        for (c, &was) in before.iter().enumerate() {
            let now = router.route(c as u64).unwrap();
            if was == 2 {
                assert_ne!(now, 2, "client {c} still routed to the down shard");
            } else {
                assert_eq!(now, was, "client {c} remapped without its shard going down");
            }
        }
        // Restoring brings every original route back.
        router.set_down(2, false);
        for (c, &was) in before.iter().enumerate() {
            assert_eq!(router.route(c as u64).unwrap(), was);
        }
    }

    #[test]
    fn all_shards_down_routes_none() {
        let mut router = ShardRouter::new(2, 8);
        router.set_down(0, true);
        router.set_down(1, true);
        assert_eq!(router.route(7), None);
        assert_eq!(router.live(), 0);
    }

    #[test]
    fn grown_ring_equals_fresh_ring_of_same_size() {
        let mut grown = ShardRouter::new(3, 32);
        assert_eq!(grown.add_shard(), 3);
        let fresh = ShardRouter::new(4, 32);
        for client in 0..2_000u64 {
            assert_eq!(grown.route(client), fresh.route(client));
        }
    }

    #[test]
    fn remove_restores_prior_routing_exactly() {
        let mut router = ShardRouter::new(3, 32);
        let before: Vec<usize> =
            (0..2_000u64).map(|c| router.route(c).unwrap()).collect();
        let s = router.add_shard();
        router.remove_shard(s).unwrap();
        for (c, &was) in before.iter().enumerate() {
            assert_eq!(router.route(c as u64).unwrap(), was, "client {c} moved");
        }
    }

    #[test]
    fn reconfig_ops_are_idempotent_and_never_panic() {
        let mut router = ShardRouter::new(3, 16);
        // Double drain: transition then no-op.
        assert_eq!(router.drain_shard(1), Ok(true));
        assert_eq!(router.drain_shard(1), Ok(false));
        // Restore of live shard: no-op.
        assert_eq!(router.restore_shard(0), Ok(false));
        assert_eq!(router.restore_shard(1), Ok(true));
        // Remove-while-draining is allowed.
        assert_eq!(router.drain_shard(2), Ok(true));
        assert_eq!(router.remove_shard(2), Ok(()));
        // Double remove, and ops on a removed shard, are clean errors.
        assert_eq!(router.remove_shard(2), Err(ReconfigError::RemovedShard(2)));
        assert_eq!(router.drain_shard(2), Err(ReconfigError::RemovedShard(2)));
        assert_eq!(router.restore_shard(2), Err(ReconfigError::RemovedShard(2)));
        // Unknown indices are clean errors.
        assert_eq!(router.drain_shard(9), Err(ReconfigError::UnknownShard(9)));
        assert_eq!(router.remove_shard(9), Err(ReconfigError::UnknownShard(9)));
        // Cannot remove the last live shard (shard 1 is drained: with 0
        // gone, nothing live would remain).
        assert_eq!(router.remove_shard(0), Err(ReconfigError::LastShard(0)));
        assert_eq!(router.present(), 2);
        assert_eq!(router.live(), 1);
    }
}

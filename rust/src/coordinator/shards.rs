//! Sharded serving tier: multi-session dispatch with consistent-hash
//! routing and merged cross-shard metrics.
//!
//! The paper's setting is a prediction-serving *cluster* absorbing high
//! query rates across many machines (§2.1, §6), but a single
//! [`ServingFrontend`] funnels every client through one dispatcher
//! thread driving one [`crate::coordinator::session::ServiceHandle`] — a
//! hard throughput ceiling. This module scales past it by running many
//! frontends side by side:
//!
//! ```text
//!  ShardedClient (id) ──▶ ShardRouter (hash ring, vnodes)
//!                             │ client id -> shard
//!         ┌───────────────────┼───────────────────┐
//!         ▼                   ▼                   ▼
//!   ServingFrontend 0   ServingFrontend 1  …  ServingFrontend N-1
//!   (dispatcher thread,  each with its own pools, scheme state,
//!    session, window)    fault plan, and admission accounting)
//! ```
//!
//! Each shard is a fully independent session — its own instance pools,
//! network/tenancy simulation, fault plan, dispatcher thread, and
//! sliding metrics window — so a fault or overload in one shard cannot
//! head-of-line-block another (its own *fault domain*). The
//! [`ShardRouter`] is a classic consistent-hash ring with virtual nodes:
//! client ids hash onto the ring and walk clockwise to the first live
//! shard, so draining one shard remaps only that shard's clients.
//!
//! [`ShardedClient`] keeps `submit`/`poll`/`next`/`stats`/`window`
//! shard-transparent: submissions go to the routed shard, returned
//! [`QueryId`]s carry the shard in their top byte (unique fleet-wide),
//! and deliveries are swept from every shard the client ever touched.
//! Admission composes: each shard enforces the per-session
//! [`crate::coordinator::frontend::AdmissionPolicy`], and the tier adds
//! an optional fleet-wide offered-load cap ([`ShardSpec::global_backlog`])
//! checked before the per-shard policy.
//!
//! [`ShardedFrontend::shutdown`] merges the per-shard
//! [`RunResult`]s into one fleet record (exact — raw latency samples
//! concatenate), and [`ShardedFrontend::window`] merges the live
//! per-shard [`WindowSnapshot`]s for fleet-wide p50/p99/p99.9.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::coordinator::cross_shard::{
    CrossShardConfig, CrossShardScheme, CrossShardState, CrossShardTelemetry, ParityLeg,
};
use crate::coordinator::frontend::{ClientStats, ServiceClient, ServingFrontend, SubmitError};
use crate::coordinator::metrics::WindowSnapshot;
use crate::coordinator::scheme::RedundancyScheme;
use crate::coordinator::service::{Mode, ModelSet, RunResult, ServiceConfig};
use crate::coordinator::session::{QueryId, Resolved, ServiceBuilder};
use crate::tensor::Tensor;

/// Shard index lives in the top byte of a sharded [`QueryId`], so ids
/// stay unique fleet-wide even though every shard numbers its own
/// queries from zero.
const SHARD_SHIFT: u32 = 56;

/// Hard cap on shard count (the id tag is one byte).
pub const MAX_SHARDS: usize = 255;

/// SplitMix64: cheap, well-mixed 64-bit hash for ring points and client
/// placement (also used to decorrelate per-shard seeds).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Tag a shard-local query id with its serving shard (the top byte), so
/// ids stay unique across every leg of the tier. Public so property
/// suites can pin the no-collision invariant directly.
pub fn tag_id(shard: usize, fid: QueryId) -> QueryId {
    ((shard as u64) << SHARD_SHIFT) | fid
}

fn tag(shard: usize, fid: QueryId) -> QueryId {
    tag_id(shard, fid)
}

/// The shard a sharded [`QueryId`] was served by.
pub fn shard_of(id: QueryId) -> usize {
    (id >> SHARD_SHIFT) as usize
}

/// Sizing and policy knobs of the sharded tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Number of independent sessions (1..=[`MAX_SHARDS`]).
    pub shards: usize,
    /// Virtual nodes per shard on the hash ring. More vnodes smooth the
    /// client distribution (64 keeps the max/min shard population within
    /// a few tens of percent for large client counts).
    pub vnodes: usize,
    /// Fleet-wide offered-load cap composed *over* the per-shard
    /// admission policies: a submit first checks the summed load of all
    /// shards against this, then the routed shard's own policy.
    /// `None` = per-shard admission only.
    pub global_backlog: Option<usize>,
}

impl Default for ShardSpec {
    fn default() -> ShardSpec {
        ShardSpec { shards: 1, vnodes: 64, global_backlog: None }
    }
}

impl ShardSpec {
    pub fn new(shards: usize) -> ShardSpec {
        ShardSpec { shards, ..ShardSpec::default() }
    }
}

/// Consistent-hash ring with virtual nodes mapping client ids to shards.
///
/// Each shard owns `vnodes` pseudo-random points on a 64-bit ring; a
/// client hashes to a point and is served by the first *live* shard
/// clockwise from it. Marking a shard down therefore remaps only the
/// clients whose first point belonged to that shard — everyone else
/// keeps their routing (the property the rerouting tests pin down).
pub struct ShardRouter {
    /// (ring point, shard), sorted by point.
    ring: Vec<(u64, usize)>,
    down: Vec<bool>,
    vnodes: usize,
}

impl ShardRouter {
    pub fn new(shards: usize, vnodes: usize) -> ShardRouter {
        assert!(shards >= 1, "router needs at least one shard");
        assert!(vnodes >= 1, "router needs at least one vnode per shard");
        let mut ring = Vec::with_capacity(shards * vnodes);
        for s in 0..shards {
            for v in 0..vnodes {
                // Distinct, well-spread point per (shard, vnode).
                ring.push((splitmix64(((s as u64) << 32) | v as u64), s));
            }
        }
        ring.sort_unstable();
        ShardRouter { ring, down: vec![false; shards], vnodes }
    }

    pub fn shards(&self) -> usize {
        self.down.len()
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// Shards currently accepting new routes.
    pub fn live(&self) -> usize {
        self.down.iter().filter(|d| !**d).count()
    }

    pub fn is_down(&self, shard: usize) -> bool {
        self.down[shard]
    }

    /// Mark a shard down (drained: new routes skip it) or back up.
    pub fn set_down(&mut self, shard: usize, down: bool) {
        self.down[shard] = down;
    }

    /// Route a client id to a live shard, or `None` if every shard is
    /// down. O(log ring) in the common case; the clockwise walk only
    /// lengthens while consecutive points belong to down shards.
    pub fn route(&self, client: u64) -> Option<usize> {
        let h = splitmix64(client ^ 0xC11E_17D0_57ED);
        let start = self.ring.partition_point(|&(p, _)| p < h);
        for i in 0..self.ring.len() {
            let (_, s) = self.ring[(start + i) % self.ring.len()];
            if !self.down[s] {
                return Some(s);
            }
        }
        None
    }
}

/// Sentinel for "no shard holds this client's weight".
const NO_SHARD: usize = usize::MAX;

/// One tier client's per-shard legs plus which shard currently holds
/// its admission-fairness weight. Weights *follow the router*: a leg
/// registers its weight only on the shard the router assigns, and
/// drain/restore moves it — so a shard's fair-share denominator counts
/// exactly the clients it actually serves (the ROADMAP dilution fix).
struct WeightHome {
    client_id: u64,
    legs: Vec<ServiceClient>,
    /// Shard whose frontend currently holds the weight ([`NO_SHARD`]
    /// before first routing or when every shard is down).
    active: AtomicUsize,
}

impl WeightHome {
    fn rehome(&self, router: &ShardRouter) {
        let next = router.route(self.client_id).unwrap_or(NO_SHARD);
        let prev = self.active.swap(next, Ordering::SeqCst);
        if prev == next {
            return;
        }
        if prev != NO_SHARD {
            self.legs[prev].deactivate_weight();
        }
        if next != NO_SHARD {
            self.legs[next].activate_weight();
        }
    }
}

impl Drop for WeightHome {
    fn drop(&mut self) {
        // The last clone of this client is gone: give its weight back to
        // whatever shard currently holds it, so transient clients never
        // permanently inflate a shard's fair-share denominator.
        let active = self.active.load(Ordering::SeqCst);
        if active != NO_SHARD {
            self.legs[active].deactivate_weight();
        }
    }
}

/// State shared by the tier's frontend handle and every client.
struct ShardShared {
    router: RwLock<ShardRouter>,
    global_backlog: Option<usize>,
    next_client: AtomicU64,
    /// Every live client's weight home (weights move on drain/restore).
    /// Weak: the strong references live in the `ShardedClient` clones,
    /// so a dropped client's home is pruned on the next sweep instead
    /// of accumulating forever.
    homes: Mutex<Vec<std::sync::Weak<WeightHome>>>,
}

impl ShardShared {
    /// Re-derive every live client's weight placement from the current
    /// ring, pruning dropped clients (lock order: router before homes,
    /// everywhere — including the mint path, so a client minted
    /// concurrently with a drain is either swept here or sees the
    /// updated ring itself).
    fn rehome_all(&self) {
        let router = self.router.read().unwrap();
        let mut homes = self.homes.lock().unwrap();
        homes.retain(|w| match w.upgrade() {
            Some(home) => {
                home.rehome(&router);
                true
            }
            None => false,
        });
    }
}

/// N independent serving sessions behind one consistent-hash router.
///
/// Build with [`ShardedFrontend::start`], mint [`ShardedClient`]s with
/// [`ShardedFrontend::client`], degrade shards with
/// [`ShardedFrontend::kill_instance`] / [`ShardedFrontend::drain_shard`],
/// observe the fleet with [`ShardedFrontend::window`], and finish with
/// [`ShardedFrontend::shutdown`] for the merged run record.
pub struct ShardedFrontend {
    frontends: Vec<ServingFrontend>,
    shared: Arc<ShardShared>,
}

/// What [`ShardedFrontend::shutdown`] returns: the fleet-wide merged
/// record plus each shard's own, so callers can audit that the merge
/// conserved every count.
pub struct ShardedRunResult {
    /// All shards folded together ([`RunResult::merged`]).
    pub merged: RunResult,
    /// Per-shard results, in shard order.
    pub per_shard: Vec<RunResult>,
}

impl ShardedFrontend {
    /// Stand up `spec.shards` independent sessions from one config.
    ///
    /// Shard 0 keeps `cfg.seed` unchanged (so `--shards 1` reproduces the
    /// unsharded run exactly); later shards get decorrelated seeds, since
    /// N copies of one seed would fail, shuffle, and pace in lockstep —
    /// the opposite of independent fault domains. For the same reason a
    /// configured `fault_schedule` applies to **shard 0 only** (the
    /// scenario "degrade one shard while the others keep their latency
    /// profile"); use [`ShardedFrontend::kill_instance`] /
    /// [`ShardedFrontend::fail_instance_for`] to target other shards.
    pub fn start(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
    ) -> anyhow::Result<ShardedFrontend> {
        anyhow::ensure!(
            !matches!(cfg.mode, Mode::CrossShard { .. }),
            "Mode::CrossShard coding groups span shards; serve it through \
             CrossShardFrontend::start"
        );
        ShardedFrontend::start_with(cfg, spec, models, sample_query, |_| None)
    }

    /// [`ShardedFrontend::start`] with an optional per-shard scheme
    /// override: `scheme_for_shard(s)` returning `Some` injects that
    /// strategy into shard s's session (how the cross-shard tier binds
    /// every shard to one fleet-shared coding state); `None` falls back
    /// to instantiating `cfg.mode` as usual.
    pub(crate) fn start_with(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
        mut scheme_for_shard: impl FnMut(usize) -> Option<Box<dyn RedundancyScheme>>,
    ) -> anyhow::Result<ShardedFrontend> {
        anyhow::ensure!(
            (1..=MAX_SHARDS).contains(&spec.shards),
            "shards must be in 1..={MAX_SHARDS}, got {}",
            spec.shards
        );
        anyhow::ensure!(spec.vnodes >= 1, "vnodes must be >= 1");
        let mut frontends = Vec::with_capacity(spec.shards);
        for s in 0..spec.shards {
            let mut shard_cfg = cfg.clone();
            if s > 0 {
                shard_cfg.seed = splitmix64(cfg.seed ^ ((s as u64) << 40));
                // One scheduled fault must not fire in lockstep across
                // the whole fleet — that would erase the healthy-shard
                // baseline the tier exists to preserve.
                shard_cfg.fault_schedule.clear();
            }
            let mut builder = ServiceBuilder::new(shard_cfg);
            if let Some(scheme) = scheme_for_shard(s) {
                builder = builder.with_scheme(scheme);
            }
            frontends.push(builder.serve(models, sample_query)?);
        }
        Ok(ShardedFrontend {
            frontends,
            shared: Arc::new(ShardShared {
                router: RwLock::new(ShardRouter::new(spec.shards, spec.vnodes)),
                global_backlog: spec.global_backlog,
                next_client: AtomicU64::new(0),
                homes: Mutex::new(Vec::new()),
            }),
        })
    }

    pub fn shards(&self) -> usize {
        self.frontends.len()
    }

    /// Mint a shard-transparent client (a fresh identity on every shard,
    /// routed by its id) with the default fairness weight of 1.
    ///
    /// Admission fairness follows the routing: the client's weight is
    /// registered only on the shard the router currently assigns it,
    /// and moves when drain/restore remaps the client — so a shard's
    /// weighted fair shares are computed over exactly the clients it
    /// serves, undiluted by the rest of the fleet.
    pub fn client(&self) -> ShardedClient {
        self.client_with_weight(1.0)
    }

    /// [`ShardedFrontend::client`] with an explicit admission-fairness
    /// weight (see [`ServingFrontend::client_with_weight`] for the
    /// carve-out semantics on the routed shard).
    pub fn client_with_weight(&self, weight: f64) -> ShardedClient {
        let id = self.shared.next_client.fetch_add(1, Ordering::Relaxed);
        let legs: Vec<ServiceClient> = self
            .frontends
            .iter()
            .map(|f| f.passive_client_with_weight(weight))
            .collect();
        let home = Arc::new(WeightHome {
            client_id: id,
            legs: legs.clone(),
            active: AtomicUsize::new(NO_SHARD),
        });
        {
            // Hold router (read) + homes across rehome AND registration
            // — same order as rehome_all — so a concurrent drain/restore
            // cannot slip between them and leave this client's weight on
            // a shard the router no longer assigns it.
            let router = self.shared.router.read().unwrap();
            let mut homes = self.shared.homes.lock().unwrap();
            home.rehome(&router);
            homes.push(Arc::downgrade(&home));
        }
        ShardedClient { id, legs, home, shared: self.shared.clone() }
    }

    /// Fairness weight currently registered with one shard's frontend
    /// (observability for the weight-follows-router invariant).
    pub fn shard_total_weight(&self, shard: usize) -> f64 {
        self.frontends[shard].total_weight()
    }

    /// The shard the router currently assigns to `client_id` (`None` if
    /// every shard is drained).
    pub fn route_of(&self, client_id: u64) -> Option<usize> {
        self.shared.router.read().unwrap().route(client_id)
    }

    /// Take a shard out of the routing ring: *subsequent* submits from
    /// its clients walk clockwise to the next live shard, while queries
    /// already in the shard keep resolving and its session still shows
    /// up (and is drained) in [`ShardedFrontend::shutdown`]. Remapped
    /// clients' fairness weights move with them.
    pub fn drain_shard(&self, shard: usize) {
        self.shared.router.write().unwrap().set_down(shard, true);
        self.shared.rehome_all();
    }

    /// Put a drained shard back into the ring (its original clients'
    /// weights return with their routes).
    pub fn restore_shard(&self, shard: usize) {
        self.shared.router.write().unwrap().set_down(shard, false);
        self.shared.rehome_all();
    }

    /// Live shard count (shards not drained).
    pub fn live_shards(&self) -> usize {
        self.shared.router.read().unwrap().live()
    }

    /// Permanently kill one instance *of one shard* (the paper's
    /// undetected-zombie failure model, scoped to a fault domain): that
    /// shard degrades to its redundancy scheme while the others keep
    /// their latency profile.
    pub fn kill_instance(&self, shard: usize, instance: usize) {
        self.frontends[shard].kill_instance(instance);
    }

    /// Fail one instance of one shard for a bounded window.
    pub fn fail_instance_for(&self, shard: usize, instance: usize, dur: Duration) {
        self.frontends[shard].fail_instance_for(instance, dur);
    }

    /// One shard's cluster fault plan (the surface the deterministic
    /// fault-injection harness in `tests/common` scripts against).
    pub fn fault_plan(&self, shard: usize) -> Arc<FaultPlan> {
        self.frontends[shard].fault_plan()
    }

    /// Summed admission-load estimate across every shard (what the
    /// global offered-load cap bounds).
    pub fn load(&self) -> usize {
        self.frontends.iter().map(ServingFrontend::load).sum()
    }

    /// Total admission rejects across every shard (including global-cap
    /// rejects, which are tallied against the routed shard).
    pub fn rejected(&self) -> u64 {
        self.frontends.iter().map(ServingFrontend::rejected).sum()
    }

    /// One shard's live window.
    pub fn shard_window(&self, shard: usize) -> WindowSnapshot {
        self.frontends[shard].window()
    }

    /// Fleet-wide live metrics: every shard's window merged
    /// ([`WindowSnapshot::merge`] — counts exact, quantiles
    /// resolved-weighted).
    pub fn window(&self) -> WindowSnapshot {
        let snaps: Vec<WindowSnapshot> =
            self.frontends.iter().map(ServingFrontend::window).collect();
        WindowSnapshot::merge_all(&snaps)
    }

    /// Shut every shard down (each drains its in-flight queries) and
    /// merge the per-shard [`RunResult`]s into one fleet record. The
    /// merged `submitted`/`resolved`/`rejected` totals equal the
    /// per-shard sums by construction — `per_shard` is returned so tests
    /// and reports can verify exactly that.
    pub fn shutdown(self) -> anyhow::Result<ShardedRunResult> {
        let mut per_shard = Vec::with_capacity(self.frontends.len());
        for f in self.frontends {
            per_shard.push(f.shutdown()?);
        }
        Ok(ShardedRunResult { merged: RunResult::merged(&per_shard), per_shard })
    }
}

/// A shard-transparent client of a [`ShardedFrontend`].
///
/// Cheap to clone (clones share this client's identity and inboxes, like
/// [`ServiceClient`]); `Send + Sync`, so one client can be driven from
/// several threads. Submissions route to the client's current shard;
/// completions are swept from every shard, so rerouting mid-run (a
/// drained shard) never strands a delivery.
#[derive(Clone)]
pub struct ShardedClient {
    id: u64,
    /// One per-shard identity, indexed by shard.
    legs: Vec<ServiceClient>,
    /// Keeps this client's weight home alive; when the last clone drops,
    /// the home's Drop releases the weight and the tier prunes it.
    home: Arc<WeightHome>,
    shared: Arc<ShardShared>,
}

impl ShardedClient {
    /// This client's tier-assigned id (the consistent-hash key).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The shard the router currently assigns this client to.
    pub fn shard(&self) -> Option<usize> {
        self.shared.router.read().unwrap().route(self.id)
    }

    /// The shard currently holding this client's admission weight
    /// (`None` when every shard is down). Equal to
    /// [`ShardedClient::shard`] except in the instant between a
    /// drain/restore and its rehome sweep.
    pub fn weight_shard(&self) -> Option<usize> {
        match self.home.active.load(Ordering::SeqCst) {
            NO_SHARD => None,
            s => Some(s),
        }
    }

    /// Submit one query through the routed shard's admission control
    /// (after the fleet-wide cap, when configured). The returned id
    /// carries the serving shard in its top byte ([`shard_of`]).
    pub fn submit(&self, input: Tensor) -> Result<QueryId, SubmitError> {
        let Some(shard) = self.shared.router.read().unwrap().route(self.id) else {
            return Err(SubmitError::Closed);
        };
        if let Some(cap) = self.shared.global_backlog {
            let load: usize = self.legs.iter().map(ServiceClient::load).sum();
            if load >= cap {
                // Tally against the shard that would have served it, so
                // the fleet's merged RunResult still covers offered load.
                self.legs[shard].note_reject();
                return Err(SubmitError::Rejected { load, limit: cap });
            }
        }
        let fid = self.legs[shard].submit(input)?;
        Ok(tag(shard, fid))
    }

    /// Non-blocking: take every prediction delivered to this client on
    /// any shard, ids re-tagged fleet-wide.
    pub fn poll(&self) -> Vec<Resolved> {
        let mut out = Vec::new();
        for (s, leg) in self.legs.iter().enumerate() {
            for r in leg.poll() {
                out.push(Resolved { id: tag(s, r.id), ..r });
            }
        }
        out
    }

    /// Block up to `timeout` for the next prediction from any shard.
    /// Sweeps every leg, parking briefly on the currently-routed shard
    /// (where new deliveries land) between sweeps.
    pub fn next(&self, timeout: Duration) -> Option<Resolved> {
        let deadline = Instant::now() + timeout;
        loop {
            for (s, leg) in self.legs.iter().enumerate() {
                if let Some(r) = leg.try_next() {
                    return Some(Resolved { id: tag(s, r.id), ..r });
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let primary = self.shared.router.read().unwrap().route(self.id).unwrap_or(0);
            let park = (deadline - now).min(Duration::from_millis(2));
            if let Some(r) = self.legs[primary].next(park) {
                return Some(Resolved { id: tag(primary, r.id), ..r });
            }
        }
    }

    /// This client's counters summed across every shard it touched.
    pub fn stats(&self) -> ClientStats {
        let mut total = ClientStats::default();
        for leg in &self.legs {
            let s = leg.stats();
            total.submitted += s.submitted;
            total.resolved += s.resolved;
            total.rejected += s.rejected;
            total.native += s.native;
            total.recovered += s.recovered;
            total.defaulted += s.defaulted;
        }
        total
    }

    /// This client's live window merged across shards.
    pub fn window(&self) -> WindowSnapshot {
        let snaps: Vec<WindowSnapshot> = self.legs.iter().map(ServiceClient::window).collect();
        WindowSnapshot::merge_all(&snaps)
    }
}

// ------------------------------------------------------------------------
// Cross-shard coding tier
// ------------------------------------------------------------------------

/// The sharded tier with coding groups that *span* the shards
/// ([`Mode::CrossShard`]): every group stripes its k data batches over k
/// distinct shards and sends its parities to a shared cross-shard pool,
/// so killing an entire shard costs each group at most one slot — which
/// decodes like any single-instance loss. Group redundancy is sized by
/// a fleet-level straggler predictor that merges per-shard estimates
/// (see [`crate::coordinator::cross_shard`] for the data flow).
///
/// The client surface is identical to [`ShardedFrontend`]'s — the same
/// [`ShardedClient`] type, routing, admission, weight-follows-router
/// fairness, windows, and merged shutdown — plus the parity pool's own
/// run records and the fleet coding telemetry.
pub struct CrossShardFrontend {
    tier: ShardedFrontend,
    parity: ParityLeg,
    state: Arc<CrossShardState>,
    /// Deployed instances per data shard ([`CrossShardFrontend::kill_shard`]).
    shard_m: usize,
}

/// What [`CrossShardFrontend::shutdown`] returns.
pub struct CrossShardRunResult {
    /// The data shards' merged + per-shard records (client traffic).
    pub fleet: ShardedRunResult,
    /// The shared parity pool's session records, in r_index order.
    /// These count *parity* queries, deliberately kept out of the fleet
    /// record so client-traffic conservation stays auditable.
    pub parity: Vec<RunResult>,
    /// Final fleet coding telemetry (sealed groups, parity jobs,
    /// reconstructions, per-shard unavailability).
    pub telemetry: CrossShardTelemetry,
}

impl CrossShardFrontend {
    /// Stand up the cross-shard tier: `spec.shards` data shards (each an
    /// independent session running [`CrossShardScheme`] against one
    /// fleet-shared coding state) plus `r_max` shared parity sessions of
    /// `ceil(shards·m / k)` instances each (ParM's m/k provisioning at
    /// fleet scale). Requires `cfg.mode` to be [`Mode::CrossShard`] and
    /// `spec.shards >= k`; `models` must carry `r_max` parity
    /// executables.
    pub fn start(
        cfg: ServiceConfig,
        spec: ShardSpec,
        models: &ModelSet,
        sample_query: &Tensor,
    ) -> anyhow::Result<CrossShardFrontend> {
        let Mode::CrossShard { k, r_min, r_max, halflife } = cfg.mode else {
            anyhow::bail!(
                "CrossShardFrontend needs Mode::CrossShard, got mode {:?}",
                cfg.mode.name()
            );
        };
        anyhow::ensure!(
            spec.shards >= k,
            "cross-shard groups stripe k={k} slots over distinct shards; \
             need shards >= k, got {}",
            spec.shards
        );
        let state = Arc::new(CrossShardState::new(CrossShardConfig::new(
            k,
            r_min,
            r_max,
            spec.shards,
            halflife,
        )));
        // Wire the parity channel before any shard can seal a group.
        let (ptx, prx) = mpsc::channel();
        state.set_parity_sender(ptx.clone());
        let tier = {
            let st = state.clone();
            ShardedFrontend::start_with(cfg.clone(), spec, models, sample_query, move |s| {
                Some(Box::new(CrossShardScheme::new(s, st.clone())) as Box<dyn RedundancyScheme>)
            })?
        };
        let per = (spec.shards * cfg.m + k - 1) / k;
        let parity =
            ParityLeg::start(&cfg, &state, models, sample_query, per, r_max, ptx, prx)?;
        Ok(CrossShardFrontend { tier, parity, state, shard_m: cfg.m })
    }

    pub fn shards(&self) -> usize {
        self.tier.shards()
    }

    /// Instances in each per-r_index shared parity pool.
    pub fn parity_pool_size(&self) -> usize {
        self.parity.pool_size()
    }

    /// Mint a shard-transparent client (same surface as
    /// [`ShardedFrontend::client`]).
    pub fn client(&self) -> ShardedClient {
        self.tier.client()
    }

    /// Mint a client with an explicit admission-fairness weight.
    pub fn client_with_weight(&self, weight: f64) -> ShardedClient {
        self.tier.client_with_weight(weight)
    }

    /// The shard the router currently assigns to `client_id`.
    pub fn route_of(&self, client_id: u64) -> Option<usize> {
        self.tier.route_of(client_id)
    }

    /// Take a data shard out of the routing ring (in-flight queries keep
    /// resolving; stranded open groups short-seal at the loss horizon).
    pub fn drain_shard(&self, shard: usize) {
        self.tier.drain_shard(shard);
    }

    /// Put a drained shard back into the ring.
    pub fn restore_shard(&self, shard: usize) {
        self.tier.restore_shard(shard);
    }

    pub fn live_shards(&self) -> usize {
        self.tier.live_shards()
    }

    /// Permanently kill one deployed instance of one data shard.
    pub fn kill_instance(&self, shard: usize, instance: usize) {
        self.tier.kill_instance(shard, instance);
    }

    /// Kill *every* deployed instance of one data shard — the
    /// whole-fault-domain loss this tier exists to absorb: each coding
    /// group loses at most its one slot there and decodes from the
    /// shared parity pool.
    pub fn kill_shard(&self, shard: usize) {
        for i in 0..self.shard_m {
            self.tier.kill_instance(shard, i);
        }
    }

    /// Fail one instance of one data shard for a bounded window.
    pub fn fail_instance_for(&self, shard: usize, instance: usize, dur: Duration) {
        self.tier.fail_instance_for(shard, instance, dur);
    }

    /// One data shard's fault plan (harness surface).
    pub fn fault_plan(&self, shard: usize) -> Arc<FaultPlan> {
        self.tier.fault_plan(shard)
    }

    /// The r_index-th parity pool's fault plan (harness surface).
    pub fn parity_fault_plan(&self, r_index: usize) -> Arc<FaultPlan> {
        self.parity.fault_plan(r_index)
    }

    /// Permanently kill one instance of the r_index-th parity pool.
    pub fn kill_parity_instance(&self, r_index: usize, instance: usize) {
        self.parity.kill(r_index, instance);
    }

    /// Summed admission-load estimate across the data shards.
    pub fn load(&self) -> usize {
        self.tier.load()
    }

    /// Total admission rejects across the data shards.
    pub fn rejected(&self) -> u64 {
        self.tier.rejected()
    }

    /// One data shard's live window.
    pub fn shard_window(&self, shard: usize) -> WindowSnapshot {
        self.tier.shard_window(shard)
    }

    /// Fleet-wide live metrics (data shards merged).
    pub fn window(&self) -> WindowSnapshot {
        self.tier.window()
    }

    /// Fairness weight currently registered on one shard.
    pub fn shard_total_weight(&self, shard: usize) -> f64 {
        self.tier.shard_total_weight(shard)
    }

    /// Live fleet coding telemetry: last chosen r, per-shard and fleet
    /// unavailability, groups sealed, parity jobs, reconstructions.
    pub fn telemetry(&self) -> CrossShardTelemetry {
        self.state.fleet_telemetry()
    }

    /// Short-seal every open coding group now. Call when offered load
    /// pauses (end of a drive phase) so tail queries get their parity
    /// protection immediately instead of at the loss horizon.
    pub fn flush_open_groups(&self) {
        self.state.flush_open(Instant::now());
    }

    /// Shut the tier down: short-seal the tail, drain the data shards
    /// (decodes keep landing while they drain), then stop the parity
    /// pool, returning the fleet record, the parity records, and the
    /// final telemetry. As with every drain in this stack, resolution
    /// of queries that lost both their data and their decode path needs
    /// an SLO in the config — set one when serving under failures.
    pub fn shutdown(self) -> anyhow::Result<CrossShardRunResult> {
        self.state.flush_open(Instant::now());
        let fleet = self.tier.shutdown()?;
        let telemetry = self.state.fleet_telemetry();
        let parity = self.parity.stop();
        Ok(CrossShardRunResult { fleet, parity, telemetry })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_client_is_send_sync_clone() {
        fn assert_traits<T: Send + Sync + Clone>() {}
        assert_traits::<ShardedClient>();
    }

    #[test]
    fn id_tagging_roundtrips() {
        for shard in [0usize, 1, 3, 254] {
            let id = tag(shard, 12_345);
            assert_eq!(shard_of(id), shard);
            assert_eq!(id & ((1u64 << SHARD_SHIFT) - 1), 12_345);
        }
    }

    #[test]
    fn ring_covers_all_shards_reasonably_evenly() {
        let router = ShardRouter::new(4, 64);
        let mut counts = [0usize; 4];
        for client in 0..10_000u64 {
            counts[router.route(client).unwrap()] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // 10k clients over 4 shards with 64 vnodes: every shard gets
            // a solid chunk (loose bound — the ring is hash-balanced, not
            // perfectly uniform).
            assert!(c > 500, "shard {s} nearly starved: {counts:?}");
        }
    }

    #[test]
    fn routing_is_deterministic() {
        let a = ShardRouter::new(8, 32);
        let b = ShardRouter::new(8, 32);
        for client in 0..500u64 {
            assert_eq!(a.route(client), b.route(client));
        }
    }

    #[test]
    fn downing_a_shard_remaps_only_its_clients() {
        let mut router = ShardRouter::new(4, 64);
        let before: Vec<usize> =
            (0..2_000u64).map(|c| router.route(c).unwrap()).collect();
        router.set_down(2, true);
        assert_eq!(router.live(), 3);
        for (c, &was) in before.iter().enumerate() {
            let now = router.route(c as u64).unwrap();
            if was == 2 {
                assert_ne!(now, 2, "client {c} still routed to the down shard");
            } else {
                assert_eq!(now, was, "client {c} remapped without its shard going down");
            }
        }
        // Restoring brings every original route back.
        router.set_down(2, false);
        for (c, &was) in before.iter().enumerate() {
            assert_eq!(router.route(c as u64).unwrap(), was);
        }
    }

    #[test]
    fn all_shards_down_routes_none() {
        let mut router = ShardRouter::new(2, 8);
        router.set_down(0, true);
        router.set_down(1, true);
        assert_eq!(router.route(7), None);
        assert_eq!(router.live(), 0);
    }
}

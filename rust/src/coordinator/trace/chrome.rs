//! Chrome trace-event export (`parm trace --chrome OUT.json`): the span
//! trees rendered as a [Trace Event Format] document that
//! `chrome://tracing` and Perfetto open directly.
//!
//! One **process** per shard (`pid` = shard tag), queries packed
//! greedily onto **lanes** (`tid`) so overlapping spans stack instead
//! of colliding, each completed span a complete (`X`) event with its
//! non-zero phases as nested child slices, and every chaos event an
//! instant (`i`) marker on its shard's track.
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::collections::HashMap;

use crate::coordinator::trace::{Analysis, QuerySpan};
use crate::util::json::Json;

fn x_event(name: String, cat: &str, ts: u64, dur: u64, pid: u64, tid: u64) -> Json {
    Json::obj()
        .set("name", name)
        .set("cat", cat)
        .set("ph", "X")
        .set("ts", ts)
        .set("dur", dur)
        .set("pid", pid)
        .set("tid", tid)
}

/// Greedy lane packer: first lane whose last span ended by `start`,
/// else a new lane. Returns the 1-based lane id.
struct Lanes(Vec<u64>);

impl Lanes {
    fn assign(&mut self, start: u64, end: u64) -> u64 {
        for (i, lane_end) in self.0.iter_mut().enumerate() {
            if *lane_end <= start {
                *lane_end = end;
                return i as u64 + 1;
            }
        }
        self.0.push(end);
        self.0.len() as u64
    }
}

fn span_events(s: &QuerySpan, tid: u64, out: &mut Vec<Json>) {
    let Some(p) = s.phases() else { return };
    let total = p.total_us.max(1);
    out.push(
        x_event(
            format!("q{} [{}]", s.qid, s.outcome_tag()),
            "query",
            s.submit_us,
            total,
            s.shard,
            tid,
        )
        .set(
            "args",
            Json::obj()
                .set("qid", s.qid)
                .set("group", s.group.map(Json::from).unwrap_or(Json::Null))
                .set("outcome", s.outcome_tag())
                .set("latency_us", s.latency_us.map(Json::from).unwrap_or(Json::Null)),
        ),
    );
    // Nested phase slices: children must sit strictly inside the
    // parent for the viewers to nest them, which the clamped markers
    // guarantee.
    let m0 = s.submit_us;
    let m1 = m0 + p.queue_us;
    let m2 = m1 + p.seal_wait_us;
    let m3 = m2 + p.decode_wait_us;
    for (name, lo, dur) in [
        ("queue", m0, p.queue_us),
        ("seal-wait", m1, p.seal_wait_us),
        ("decode-wait", m2, p.decode_wait_us),
        ("tail", m3, p.tail_us),
    ] {
        if dur > 0 {
            out.push(x_event(name.to_string(), "phase", lo, dur, s.shard, tid));
        }
    }
}

/// Render the analysis as a Trace Event Format JSON document.
pub fn chrome_trace(a: &Analysis) -> String {
    let mut events: Vec<Json> = Vec::new();
    let mut lanes: HashMap<u64, Lanes> = HashMap::new();

    // Spans in submit order per shard: the greedy packer needs starts
    // non-decreasing, which submit order gives within a shard.
    let mut ordered: Vec<&QuerySpan> = a.spans.iter().filter(|s| s.complete_us.is_some()).collect();
    ordered.sort_by_key(|s| (s.shard, s.submit_us));
    for s in ordered {
        let end = s.complete_us.unwrap_or(s.submit_us).max(s.submit_us + 1);
        let tid = lanes.entry(s.shard).or_insert_with(|| Lanes(Vec::new())).assign(s.submit_us, end);
        span_events(s, tid, &mut events);
    }

    for c in &a.chaos {
        events.push(
            Json::obj()
                .set("name", c.label())
                .set("cat", "chaos")
                .set("ph", "i")
                .set("s", "g")
                .set("ts", c.ts_us)
                .set("pid", c.shard)
                .set("tid", 0u64),
        );
    }

    // Process metadata so the viewer names each shard's track.
    let mut pids: Vec<u64> = lanes.keys().copied().collect();
    for c in &a.chaos {
        if !pids.contains(&c.shard) {
            pids.push(c.shard);
        }
    }
    pids.sort_unstable();
    for pid in pids {
        events.push(
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", pid)
                .set("tid", 0u64)
                .set("args", Json::obj().set("name", format!("shard {pid}"))),
        );
    }

    Json::obj()
        .set("traceEvents", events)
        .set("displayTimeUnit", "ms")
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::journal::{Event, TimedEvent};
    use crate::coordinator::trace::{analyze, AnalyzeOpts};

    #[test]
    fn export_is_valid_json_with_nested_phases_and_instants() {
        let te = |ts_us, shard, event| TimedEvent { ts_us, shard, event };
        let events = vec![
            te(0, 0, Event::Start { seed: 1, mode: "parm".into(), shards: 1 }),
            te(10, 0, Event::Submit { qid: 0 }),
            te(12, 0, Event::Submit { qid: 1 }),
            te(20, 0, Event::Dispatch { group: 1, kind: 0, detail: 0, queries: 2 }),
            te(25, 0, Event::Seal { group: 1, k: 2, r: 1 }),
            te(40, 0, Event::Fault { instance: 0, kind: 1, arg: 0 }),
            te(80, 0, Event::Complete { qid: 0, outcome: 0, latency_us: 70 }),
            te(95, 0, Event::Complete { qid: 1, outcome: 0, latency_us: 83 }),
        ];
        let a = analyze(&events, &AnalyzeOpts::default());
        let doc = chrome_trace(&a);
        let parsed = Json::parse(&doc).expect("valid trace json");
        let evs = parsed.at(&["traceEvents"]).as_arr().expect("events array");
        // 2 query slices + their phase children + 1 instant + 1 metadata.
        assert!(evs.len() >= 4, "got {} events", evs.len());
        let phases = evs
            .iter()
            .filter(|e| e.at(&["cat"]).as_str() == Some("phase"))
            .count();
        assert!(phases >= 2, "expected nested phase slices");
        assert!(evs.iter().any(|e| e.at(&["ph"]).as_str() == Some("i")));
        assert!(evs.iter().any(|e| e.at(&["ph"]).as_str() == Some("M")));
        // Overlapping spans landed on distinct lanes.
        let tids: Vec<usize> = evs
            .iter()
            .filter(|e| e.at(&["cat"]).as_str() == Some("query"))
            .filter_map(|e| e.at(&["tid"]).as_usize())
            .collect();
        assert_eq!(tids.len(), 2);
        assert_ne!(tids[0], tids[1]);
    }
}

//! Group-fate timelines: one [`GroupFate`] per coding group the journal
//! saw, tracking when it sealed, which slots the decoder reconstructed,
//! how its queries ultimately resolved, and which faults landed inside
//! its lifetime.
//!
//! # Scoping
//!
//! Per-shard schemes (ParM, rateless, replication) allocate group ids
//! session-locally, so two shards can both own a "group 3" — those
//! groups are keyed `(shard, group)`. The cross-shard tier allocates
//! group ids from fleet-shared state and records seals/decodes through
//! the untagged fleet recorder, so its groups are keyed fleet-wide
//! (`shard == None`). [`crate::coordinator::trace::analyze`] picks the
//! keying from the journal's `Start.mode`.

use crate::coordinator::trace::span::OutcomeCounts;

/// Everything the journal tells us about one coding group's life.
#[derive(Clone, Debug)]
pub struct GroupFate {
    /// Owning shard tag for per-shard schemes; `None` for fleet-scoped
    /// (cross-shard) groups.
    pub shard: Option<u64>,
    /// Group id, unique within its scope.
    pub group: u64,
    /// Data slots / parity count from the `Seal` event (0 until sealed).
    pub k: u64,
    pub r: u64,
    /// First `Dispatch` into the group — when it started accumulating.
    pub first_dispatch_us: Option<u64>,
    /// `Seal` timestamp.
    pub sealed_us: Option<u64>,
    /// Latest terminal event among the group's attributed queries.
    pub settled_us: Option<u64>,
    /// Dispatch counts by job class (`Background` jobs are not groups).
    pub data_jobs: u64,
    pub parity_jobs: u64,
    pub replica_jobs: u64,
    /// Query ids attributed to the group via data dispatches.
    pub queries: u64,
    /// Decoder reconstructions: `(ts_us, slot)` per `Decode` event.
    pub decodes: Vec<(u64, u64)>,
    /// Terminal outcomes of the attributed queries.
    pub outcomes: OutcomeCounts,
    /// Fault events that landed on the group's dispatch shards between
    /// its first dispatch and its settlement.
    pub faults_hit: u64,
    /// Distinct recorder tags that dispatched jobs into the group (for
    /// cross-shard groups: the stripe).
    pub dispatch_shards: Vec<u64>,
}

impl GroupFate {
    pub(crate) fn new(shard: Option<u64>, group: u64) -> GroupFate {
        GroupFate {
            shard,
            group,
            k: 0,
            r: 0,
            first_dispatch_us: None,
            sealed_us: None,
            settled_us: None,
            data_jobs: 0,
            parity_jobs: 0,
            replica_jobs: 0,
            queries: 0,
            decodes: Vec::new(),
            outcomes: OutcomeCounts::default(),
            faults_hit: 0,
            dispatch_shards: Vec::new(),
        }
    }

    pub(crate) fn note_dispatch_shard(&mut self, tag: u64) {
        if !self.dispatch_shards.contains(&tag) {
            self.dispatch_shards.push(tag);
        }
    }

    /// Did the decoder have to step in for this group?
    pub fn decoded(&self) -> bool {
        !self.decodes.is_empty()
    }

    /// Seal → settle duration, when both ends were observed.
    pub fn settle_us(&self) -> Option<u64> {
        match (self.sealed_us, self.settled_us) {
            (Some(s), Some(e)) => Some(e.saturating_sub(s)),
            _ => None,
        }
    }

    /// Parity actually used: reconstructions per parity dispatched.
    pub fn parity_used(&self) -> bool {
        self.decoded() && self.parity_jobs > 0
    }
}

//! Per-query span reconstruction: one [`QuerySpan`] per submitted query,
//! carrying every serving-path marker the journal recorded for it and
//! the derived per-phase durations.
//!
//! # The clamped-marker model
//!
//! A span's markers are `submit → route → dispatch → seal → decode →
//! complete`. Not every query has every marker: a natively-served query
//! never decodes, a single-session run never routes, and a query the
//! SLO sweep defaulted may complete before its group ever dispatches
//! (the session applies resolutions *before* recording the batch's
//! `Dispatch` events). [`QuerySpan::phases`] therefore clamps each
//! marker into `[previous marker, complete]` — a missing or out-of-order
//! marker inherits its predecessor, contributing a zero-width phase —
//! so the four phase durations **sum exactly** to the end-to-end
//! latency by construction. That identity is the property test's
//! anchor: no phase accounting ever leaks or double-counts time.

use crate::coordinator::metrics::Outcome;

/// One query's reconstructed serving-path timeline. All timestamps are
/// absolute microseconds since the recorder epoch.
#[derive(Clone, Debug)]
pub struct QuerySpan {
    /// Recorder tag of the session that accepted the submit (the shard
    /// index in sharded runs, 0 in single-session runs).
    pub shard: u64,
    /// Session-local query id; `(shard, qid)` is unique run-wide.
    pub qid: u64,
    /// The shard-tagged id the routing client observed, when a `Route`
    /// event matched this span.
    pub tagged_qid: Option<u64>,
    /// Coding group this query rode, once a data dispatch claimed it.
    pub group: Option<u64>,
    pub submit_us: u64,
    pub route_us: Option<u64>,
    pub dispatch_us: Option<u64>,
    pub seal_us: Option<u64>,
    pub decode_us: Option<u64>,
    /// Terminal timestamp; `None` for queries leaked by a run cut short.
    pub complete_us: Option<u64>,
    /// Terminal outcome; `None` while incomplete.
    pub outcome: Option<Outcome>,
    /// The latency the live session measured (the `Complete` payload);
    /// may differ from `complete_us - submit_us` by recorder-clock skew
    /// of the enqueue path, usually by well under a millisecond.
    pub latency_us: Option<u64>,
}

impl QuerySpan {
    pub(crate) fn new(shard: u64, qid: u64, submit_us: u64) -> QuerySpan {
        QuerySpan {
            shard,
            qid,
            tagged_qid: None,
            group: None,
            submit_us,
            route_us: None,
            dispatch_us: None,
            seal_us: None,
            decode_us: None,
            complete_us: None,
            outcome: None,
            latency_us: None,
        }
    }

    /// Total journal-clock latency: `complete - submit`.
    pub fn total_us(&self) -> Option<u64> {
        self.complete_us.map(|c| c.saturating_sub(self.submit_us))
    }

    /// Per-phase durations under the clamped-marker model (see module
    /// docs). `None` until the span completes. The four phases sum to
    /// [`Phases::total_us`] exactly.
    pub fn phases(&self) -> Option<Phases> {
        let complete = self.complete_us?;
        let m0 = self.submit_us.min(complete);
        let m1 = self.dispatch_us.unwrap_or(m0).max(m0).min(complete);
        let m2 = self.seal_us.unwrap_or(m1).max(m1).min(complete);
        let m3 = self.decode_us.unwrap_or(m2).max(m2).min(complete);
        Some(Phases {
            queue_us: m1 - m0,
            seal_wait_us: m2 - m1,
            decode_wait_us: m3 - m2,
            tail_us: complete - m3,
            total_us: complete - m0,
        })
    }

    /// Short outcome tag for reports: `native` / `recovered` /
    /// `replica` / `defaulted`, or `open` while incomplete.
    pub fn outcome_tag(&self) -> &'static str {
        match self.outcome {
            Some(Outcome::Native) => "native",
            Some(Outcome::Reconstructed) => "recovered",
            Some(Outcome::Replica) => "replica",
            Some(Outcome::Default) => "defaulted",
            None => "open",
        }
    }
}

/// Per-phase durations of a completed span. Invariant:
/// `queue + seal_wait + decode_wait + tail == total`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Phases {
    /// Submit → first data dispatch (batching/queueing delay).
    pub queue_us: u64,
    /// Dispatch → group seal (waiting for the group to fill).
    pub seal_wait_us: u64,
    /// Seal → decoder reconstruction (zero for natively-served spans).
    pub decode_wait_us: u64,
    /// Last marker → terminal event (worker execution + completion
    /// fan-out).
    pub tail_us: u64,
    /// End-to-end: submit → complete.
    pub total_us: u64,
}

/// Outcome histogram used by spans, groups, and fault windows alike.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutcomeCounts {
    pub native: u64,
    pub reconstructed: u64,
    pub replica: u64,
    pub defaulted: u64,
}

impl OutcomeCounts {
    pub fn add(&mut self, o: Outcome) {
        match o {
            Outcome::Native => self.native += 1,
            Outcome::Reconstructed => self.reconstructed += 1,
            Outcome::Replica => self.replica += 1,
            Outcome::Default => self.defaulted += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.native + self.reconstructed + self.replica + self.defaulted
    }
}

/// Nearest-rank percentile over an **already sorted** slice; 0 when
/// empty. `p` in [0, 100].
pub fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = (p / 100.0 * sorted_us.len() as f64).ceil() as usize;
    sorted_us[rank.clamp(1, sorted_us.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_sum_with_all_markers() {
        let mut s = QuerySpan::new(0, 1, 100);
        s.dispatch_us = Some(140);
        s.seal_us = Some(150);
        s.decode_us = Some(300);
        s.complete_us = Some(420);
        let p = s.phases().unwrap();
        assert_eq!(p.queue_us, 40);
        assert_eq!(p.seal_wait_us, 10);
        assert_eq!(p.decode_wait_us, 150);
        assert_eq!(p.tail_us, 120);
        assert_eq!(
            p.queue_us + p.seal_wait_us + p.decode_wait_us + p.tail_us,
            p.total_us
        );
    }

    #[test]
    fn missing_and_out_of_order_markers_clamp_to_zero_width() {
        // Complete precedes dispatch (the SLO-sweep race) and there is
        // no seal/decode: everything clamps, phases still sum.
        let mut s = QuerySpan::new(0, 1, 100);
        s.dispatch_us = Some(900);
        s.complete_us = Some(400);
        let p = s.phases().unwrap();
        assert_eq!(p.total_us, 300);
        assert_eq!(p.queue_us + p.seal_wait_us + p.decode_wait_us + p.tail_us, 300);
        assert_eq!(p.queue_us, 300); // dispatch clamped onto complete
        assert_eq!(p.tail_us, 0);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 50.0), 50);
        assert_eq!(percentile(&v, 99.0), 99);
        assert_eq!(percentile(&v, 100.0), 100);
        assert_eq!(percentile(&[], 99.0), 0);
        assert_eq!(percentile(&[7], 50.0), 7);
    }
}

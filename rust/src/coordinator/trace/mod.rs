//! Journal mining: re-execute a recorded serving-path journal into
//! per-query **span trees**, per-group **fate timelines**, and
//! **fault-impact windows** — the diagnostics layer over
//! [`crate::coordinator::journal`].
//!
//! ```text
//!   journal bytes ──decode()──▶ [TimedEvent]
//!        │                          │
//!        ▼                          ▼
//!   replay() verifies          analyze() mines
//!   (causal invariants,        ├─ QuerySpan per submit: submit → route →
//!    byte-identity)            │    dispatch → seal → decode → complete,
//!                              │    phases summing exactly to latency
//!                              ├─ GroupFate per coding group: seal time,
//!                              │    slot reconstructions, parity usage,
//!                              │    faults that landed inside its life
//!                              └─ FaultWindow per chaos burst: latency /
//!                                   outcome distribution before, during,
//!                                   and after the event
//! ```
//!
//! [`analyze`] is *tolerant* where [`crate::coordinator::journal::replay`]
//! is strict: it never fails — a truncated or partially-corrupt stream
//! yields spans for whatever prefix decoded, with missing markers
//! clamped (see [`span::QuerySpan::phases`]). Verification is replay's
//! job; mining answers "what happened to query 17".
//!
//! Surfaced as `parm trace <journal>` (report / `--json` /
//! `--chrome` Perfetto export), `parm replay --report`, and
//! `parm mine <journal>` (reconstruct a replayable
//! [`crate::workload::Trace`] — see [`crate::workload::Trace::from_journal`]).

pub mod chrome;
pub mod groups;
pub mod report;
pub mod span;
pub mod windows;

use std::collections::{HashMap, VecDeque};

use crate::coordinator::journal::{byte_outcome, EndTotals, Event, JobClass, TimedEvent};
use crate::coordinator::metrics::Outcome;
use crate::coordinator::shards::{fid_of, shard_of};

pub use groups::GroupFate;
pub use span::{OutcomeCounts, Phases, QuerySpan};
pub use windows::{ChaosEvent, ChaosKind, FaultWindow, WindowStats};

/// Knobs for [`analyze`].
#[derive(Clone, Copy, Debug)]
pub struct AnalyzeOpts {
    /// Half-width W of each fault-impact window (pre `[T-W,T)`, during
    /// `[T,T+W)`, post `[T+W,T+2W)`).
    pub window_us: u64,
    /// How many slowest-query exemplars the reports show.
    pub slow: usize,
}

impl Default for AnalyzeOpts {
    fn default() -> AnalyzeOpts {
        AnalyzeOpts { window_us: 250_000, slow: 5 }
    }
}

/// Everything [`analyze`] mined out of one journal.
#[derive(Clone, Debug)]
pub struct Analysis {
    /// Run header fields (zero / empty when the journal lacks `Start`).
    pub seed: u64,
    pub mode: String,
    pub shards: u64,
    /// Records walked.
    pub events: u64,
    /// Wall-clock from the `End` footer (0 when absent).
    pub wall_us: u64,
    /// Admission rejections summed from `Reject` events.
    pub rejected: u64,
    /// The recorded `End` footer, when the run terminated cleanly.
    pub footer: Option<EndTotals>,
    /// One span per `Submit`, in submit order.
    pub spans: Vec<QuerySpan>,
    /// One fate per coding group, in first-appearance order.
    pub groups: Vec<GroupFate>,
    /// Impact windows per coalesced chaos burst.
    pub windows: Vec<FaultWindow>,
    /// The raw (uncoalesced) chaos stream.
    pub chaos: Vec<ChaosEvent>,
}

impl Analysis {
    /// Outcome histogram over completed spans — the totals the property
    /// tests check against the `End` footer.
    pub fn outcome_counts(&self) -> OutcomeCounts {
        let mut c = OutcomeCounts::default();
        for s in &self.spans {
            if let Some(o) = s.outcome {
                c.add(o);
            }
        }
        c
    }

    /// The `n` slowest completed spans, worst first.
    pub fn slowest(&self, n: usize) -> Vec<&QuerySpan> {
        let mut done: Vec<&QuerySpan> =
            self.spans.iter().filter(|s| s.complete_us.is_some()).collect();
        done.sort_by_key(|s| std::cmp::Reverse(s.total_us().unwrap_or(0)));
        done.truncate(n);
        done
    }

    /// Submitted spans with no terminal event (a run cut short).
    pub fn open_spans(&self) -> u64 {
        self.spans.iter().filter(|s| s.complete_us.is_none()).count() as u64
    }
}

/// Group-map key: per-shard schemes scope group ids by recorder tag;
/// the cross-shard tier allocates fleet-wide ids (recorded under tag 0
/// by the shared recorder, but dispatched under per-shard tags), so its
/// groups key on the id alone.
const FLEET: u64 = u64::MAX;

/// Mine a decoded event stream. Never fails: any prefix of a valid
/// journal — including one cut mid-run — produces a best-effort
/// analysis (spans without terminal events stay open; see
/// [`Analysis::open_spans`]).
pub fn analyze(events: &[TimedEvent], opts: &AnalyzeOpts) -> Analysis {
    let mut a = Analysis {
        seed: 0,
        mode: String::new(),
        shards: 0,
        events: events.len() as u64,
        wall_us: 0,
        rejected: 0,
        footer: None,
        spans: Vec::new(),
        groups: Vec::new(),
        windows: Vec::new(),
        chaos: Vec::new(),
    };
    let mut fleet_groups = false;
    // (tag, qid) -> span index. Session-local qids restart per shard,
    // so the recorder tag scopes them — same keying replay verifies.
    let mut span_ix: HashMap<(u64, u64), usize> = HashMap::new();
    // Per-tag FIFO of submitted-but-not-yet-dispatched spans: sessions
    // drain submissions in order, so the i-th data dispatch claims the
    // i-th unclaimed submit. `queries` on the Dispatch says how many.
    let mut fifo: HashMap<u64, VecDeque<usize>> = HashMap::new();
    let mut group_ix: HashMap<(u64, u64), usize> = HashMap::new();
    let mut completions: Vec<windows::CompletionSample> = Vec::new();

    let group_scope = |fleet: bool, tag: u64| if fleet { FLEET } else { tag };

    for te in events {
        let tag = te.shard;
        let ts = te.ts_us;
        match &te.event {
            Event::Start { seed, mode, shards } => {
                a.seed = *seed;
                a.mode = mode.clone();
                a.shards = *shards;
                fleet_groups = mode.contains("cross");
            }
            Event::Submit { qid } => {
                let ix = a.spans.len();
                a.spans.push(QuerySpan::new(tag, *qid, ts));
                span_ix.insert((tag, *qid), ix);
                fifo.entry(tag).or_default().push_back(ix);
            }
            Event::Route { qid, .. } => {
                // Recorded by the router after the leg accepted, under
                // the fleet tag; the tagged qid names the leg's span.
                let key = (shard_of(*qid) as u64, fid_of(*qid));
                if let Some(&ix) = span_ix.get(&key) {
                    a.spans[ix].route_us = Some(ts);
                    a.spans[ix].tagged_qid = Some(*qid);
                }
            }
            Event::Dispatch { group, kind, queries, .. } => {
                if *kind == JobClass::Background as u8 {
                    continue;
                }
                let scope = group_scope(fleet_groups, tag);
                let gi = *group_ix.entry((scope, *group)).or_insert_with(|| {
                    let shard = if fleet_groups { None } else { Some(tag) };
                    a.groups.push(GroupFate::new(shard, *group));
                    a.groups.len() - 1
                });
                let fate = &mut a.groups[gi];
                fate.first_dispatch_us.get_or_insert(ts);
                fate.note_dispatch_shard(tag);
                if *kind == JobClass::Parity as u8 {
                    fate.parity_jobs += 1;
                } else if *kind == JobClass::Replica as u8 {
                    fate.replica_jobs += 1;
                } else {
                    fate.data_jobs += 1;
                    // Claim the batch's queries off the submit FIFO.
                    // (A query the SLO sweep already defaulted is still
                    // claimed here — its span just completed first.)
                    let q = fifo.entry(tag).or_default();
                    for _ in 0..*queries {
                        let Some(ix) = q.pop_front() else { break };
                        let span = &mut a.spans[ix];
                        span.group = Some(*group);
                        span.dispatch_us.get_or_insert(ts);
                        fate.queries += 1;
                    }
                }
            }
            Event::Seal { group, k, r } => {
                let scope = group_scope(fleet_groups, tag);
                let gi = *group_ix.entry((scope, *group)).or_insert_with(|| {
                    let shard = if fleet_groups { None } else { Some(tag) };
                    a.groups.push(GroupFate::new(shard, *group));
                    a.groups.len() - 1
                });
                let fate = &mut a.groups[gi];
                fate.k = *k;
                fate.r = *r;
                fate.sealed_us = Some(ts);
            }
            Event::Decode { group, slot } => {
                let scope = group_scope(fleet_groups, tag);
                if let Some(&gi) = group_ix.get(&(scope, *group)) {
                    a.groups[gi].decodes.push((ts, *slot));
                }
            }
            Event::Complete { qid, outcome, latency_us } => {
                if let Some(&ix) = span_ix.get(&(tag, *qid)) {
                    let span = &mut a.spans[ix];
                    span.complete_us = Some(ts);
                    span.latency_us = Some(*latency_us);
                    span.outcome = byte_outcome(*outcome);
                    if let Some(o) = span.outcome {
                        completions.push((ts, *latency_us, o));
                    }
                }
            }
            Event::Fault { instance, kind, arg } => {
                a.chaos.push(ChaosEvent {
                    ts_us: ts,
                    shard: tag,
                    kind: ChaosKind::Fault { kind: *kind, instance: *instance, arg: *arg },
                });
            }
            Event::Reconfig { verb, shard } => {
                a.chaos.push(ChaosEvent {
                    ts_us: ts,
                    shard: tag,
                    kind: ChaosKind::Reconfig { verb: *verb, target: *shard },
                });
            }
            Event::Reject { n } => a.rejected += *n,
            Event::End {
                native,
                reconstructed,
                replica,
                defaulted,
                rejected,
                reconstructions,
                wall_us,
            } => {
                a.wall_us = *wall_us;
                a.footer = Some(EndTotals {
                    native: *native,
                    reconstructed: *reconstructed,
                    replica: *replica,
                    defaulted: *defaulted,
                    rejected: *rejected,
                    reconstructions: *reconstructions,
                    wall_us: *wall_us,
                });
            }
        }
    }

    // Finalize: fold group state into spans (seal/decode markers — a
    // span learns its seal time from its group) and span terminals into
    // groups (outcome histogram, settle time).
    for span in &mut a.spans {
        let Some(g) = span.group else { continue };
        let scope = group_scope(fleet_groups, span.shard);
        let Some(&gi) = group_ix.get(&(scope, g)) else { continue };
        let fate = &mut a.groups[gi];
        if span.seal_us.is_none() {
            span.seal_us = fate.sealed_us;
        }
        if span.outcome == Some(Outcome::Reconstructed) && span.decode_us.is_none() {
            span.decode_us = fate.decodes.first().map(|&(ts, _)| ts);
        }
        if let Some(c) = span.complete_us {
            fate.settled_us = Some(fate.settled_us.map_or(c, |s| s.max(c)));
            if let Some(o) = span.outcome {
                fate.outcomes.add(o);
            }
        }
    }
    for fate in &mut a.groups {
        let Some(start) = fate.first_dispatch_us else { continue };
        let end = fate.settled_us.or(fate.sealed_us).unwrap_or(start);
        fate.faults_hit = a
            .chaos
            .iter()
            .filter(|c| {
                c.is_fault()
                    && c.ts_us >= start
                    && c.ts_us <= end
                    && fate.dispatch_shards.contains(&c.shard)
            })
            .count() as u64;
    }
    a.windows = windows::fault_windows(&a.chaos, &completions, opts.window_us);
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn te(ts_us: u64, shard: u64, event: Event) -> TimedEvent {
        TimedEvent { ts_us, shard, event }
    }

    fn dispatch(group: u64, kind: JobClass, detail: u64, queries: u64) -> Event {
        Event::Dispatch { group, kind: kind as u8, detail, queries }
    }

    #[test]
    fn analyze_reconstructs_a_parm_run_end_to_end() {
        let events = vec![
            te(0, 0, Event::Start { seed: 7, mode: "parm".into(), shards: 1 }),
            te(10, 0, Event::Submit { qid: 0 }),
            te(20, 0, Event::Submit { qid: 1 }),
            te(30, 0, Event::Seal { group: 1, k: 2, r: 1 }),
            te(31, 0, dispatch(1, JobClass::Data, 0, 1)),
            te(32, 0, dispatch(1, JobClass::Data, 1, 1)),
            te(33, 0, dispatch(1, JobClass::Parity, 0, 0)),
            te(100, 0, Event::Complete { qid: 0, outcome: 0, latency_us: 90 }),
            te(120, 0, Event::Fault { instance: 1, kind: 1, arg: 0 }),
            te(150, 0, Event::Decode { group: 1, slot: 1 }),
            te(160, 0, Event::Complete { qid: 1, outcome: 1, latency_us: 140 }),
            te(200, 0, Event::Reject { n: 3 }),
            te(
                210,
                0,
                Event::End {
                    native: 1,
                    reconstructed: 1,
                    replica: 0,
                    defaulted: 0,
                    rejected: 3,
                    reconstructions: 1,
                    wall_us: 210,
                },
            ),
        ];
        let a = analyze(&events, &AnalyzeOpts::default());

        assert_eq!(a.mode, "parm");
        assert_eq!(a.seed, 7);
        assert_eq!(a.rejected, 3);
        assert_eq!(a.spans.len(), 2);
        assert_eq!(a.open_spans(), 0);

        // Phase identity: durations sum exactly to end-to-end latency,
        // and the journal-clock total matches the recorded payload.
        for s in &a.spans {
            let p = s.phases().unwrap();
            assert_eq!(
                p.queue_us + p.seal_wait_us + p.decode_wait_us + p.tail_us,
                p.total_us
            );
            assert_eq!(Some(p.total_us), s.latency_us);
        }

        // The recovered span picked up its group's seal and decode.
        let s1 = &a.spans[1];
        assert_eq!(s1.outcome_tag(), "recovered");
        assert_eq!(s1.group, Some(1));
        assert_eq!(s1.dispatch_us, Some(32));
        assert_eq!(s1.decode_us, Some(150));

        // Trace-level outcomes equal the footer totals.
        let footer = a.footer.expect("footer");
        let counts = a.outcome_counts();
        assert_eq!(counts.native, footer.native);
        assert_eq!(counts.reconstructed, footer.reconstructed);
        assert_eq!(counts.defaulted, footer.defaulted);
        assert_eq!(a.rejected, footer.rejected);

        // Group fate: sealed at 30, two data slots + one parity, both
        // queries attributed, one reconstruction, the kill landed
        // inside its lifetime.
        assert_eq!(a.groups.len(), 1);
        let g = &a.groups[0];
        assert_eq!((g.k, g.r), (2, 1));
        assert_eq!((g.data_jobs, g.parity_jobs, g.queries), (2, 1, 2));
        assert_eq!(g.sealed_us, Some(30));
        assert_eq!(g.settled_us, Some(160));
        assert_eq!(g.decodes, vec![(150, 1)]);
        assert!(g.parity_used());
        assert_eq!(g.outcomes.total(), 2);
        assert_eq!(g.faults_hit, 1);

        // One chaos burst, one impact window.
        assert_eq!(a.windows.len(), 1);
        assert_eq!(a.chaos.len(), 1);
    }

    #[test]
    fn complete_before_dispatch_still_attributes_via_fifo() {
        // The session applies resolutions before recording the batch's
        // Dispatch events, so a swept query terminates first. The FIFO
        // claim must still bind it to its group, and the clamped phase
        // model must still sum.
        let events = vec![
            te(0, 0, Event::Start { seed: 1, mode: "parm".into(), shards: 1 }),
            te(10, 0, Event::Submit { qid: 0 }),
            te(50, 0, Event::Complete { qid: 0, outcome: 3, latency_us: 40 }),
            te(80, 0, dispatch(2, JobClass::Data, 0, 1)),
        ];
        let a = analyze(&events, &AnalyzeOpts::default());
        let s = &a.spans[0];
        assert_eq!(s.group, Some(2));
        assert_eq!(s.dispatch_us, Some(80));
        let p = s.phases().unwrap();
        assert_eq!(p.total_us, 40);
        assert_eq!(p.queue_us + p.seal_wait_us + p.decode_wait_us + p.tail_us, 40);
        assert_eq!(a.groups[0].queries, 1);
        assert_eq!(a.groups[0].outcomes.defaulted, 1);
    }

    #[test]
    fn cross_shard_groups_are_fleet_scoped() {
        // Two shards dispatch into the same fleet-level group id; the
        // seal arrives under the untagged fleet recorder. One group,
        // striped over both shards.
        let events = vec![
            te(0, 0, Event::Start { seed: 1, mode: "cross-shard".into(), shards: 2 }),
            te(10, 0, Event::Submit { qid: 0 }),
            te(11, 1, Event::Submit { qid: 0 }),
            te(20, 0, dispatch(5, JobClass::Data, 0, 1)),
            te(21, 1, dispatch(5, JobClass::Data, 1, 1)),
            te(22, 0, Event::Seal { group: 5, k: 2, r: 1 }),
            te(90, 0, Event::Complete { qid: 0, outcome: 0, latency_us: 80 }),
            te(95, 1, Event::Complete { qid: 0, outcome: 0, latency_us: 84 }),
        ];
        let a = analyze(&events, &AnalyzeOpts::default());
        assert_eq!(a.groups.len(), 1);
        let g = &a.groups[0];
        assert_eq!(g.shard, None);
        assert_eq!(g.dispatch_shards, vec![0, 1]);
        assert_eq!(g.queries, 2);
        assert_eq!(g.outcomes.native, 2);
        // Both spans exist independently under their shard tags.
        assert_eq!(a.spans.len(), 2);
        assert!(a.spans.iter().all(|s| s.seal_us == Some(22)));
    }

    #[test]
    fn truncated_stream_yields_open_spans_not_errors() {
        let events = vec![
            te(0, 0, Event::Start { seed: 1, mode: "parm".into(), shards: 1 }),
            te(10, 0, Event::Submit { qid: 0 }),
            te(20, 0, Event::Submit { qid: 1 }),
            te(30, 0, Event::Complete { qid: 0, outcome: 0, latency_us: 20 }),
        ];
        let a = analyze(&events, &AnalyzeOpts::default());
        assert_eq!(a.open_spans(), 1);
        assert!(a.footer.is_none());
        assert_eq!(a.outcome_counts().total(), 1);
    }

    #[test]
    fn route_events_bind_to_the_tagged_span() {
        let tagged = crate::coordinator::shards::tag_id(1, 4);
        let events = vec![
            te(0, 0, Event::Start { seed: 1, mode: "sharded".into(), shards: 2 }),
            te(10, 1, Event::Submit { qid: 4 }),
            te(12, 0, Event::Route { qid: tagged, shard: 1 }),
            te(90, 1, Event::Complete { qid: 4, outcome: 0, latency_us: 80 }),
        ];
        let a = analyze(&events, &AnalyzeOpts::default());
        assert_eq!(a.spans.len(), 1);
        assert_eq!(a.spans[0].route_us, Some(12));
        assert_eq!(a.spans[0].tagged_qid, Some(tagged));
    }
}

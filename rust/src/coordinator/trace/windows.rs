//! Fault-impact windows: for every chaos event (fault-plan mutation or
//! control-plane reconfiguration) in the journal, the latency and
//! outcome distribution of completions in the intervals **before**
//! `[T-W, T)`, **during** `[T, T+W)`, and **after** `[T+W, T+2W)` the
//! event, computed straight from the `Complete` stream.
//!
//! Chaos often arrives in bursts — a whole-shard kill is `m` instance
//! kills recorded microseconds apart — so events of the same kind on
//! the same shard within [`COALESCE_US`] collapse into one window with
//! a `count`, anchored at the first event's timestamp.

use crate::coordinator::metrics::Outcome;
use crate::coordinator::trace::span::{percentile, OutcomeCounts};

/// Chaos events closer than this (same shard, same kind) merge into one
/// fault window.
pub const COALESCE_US: u64 = 10_000;

/// A `Fault` or `Reconfig` record, decoded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    pub ts_us: u64,
    /// Recorder tag (shard index for per-shard fault plans).
    pub shard: u64,
    pub kind: ChaosKind,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// A fault-plan mutation ([`crate::coordinator::journal::FaultKind`]
    /// byte).
    Fault { kind: u8, instance: u64, arg: u64 },
    /// A control-plane verb
    /// ([`crate::coordinator::journal::ReconfigVerb`] byte).
    Reconfig { verb: u8, target: u64 },
}

impl ChaosEvent {
    /// Human label, e.g. `kill instance 2` or `reconfig drain shard 1`.
    pub fn label(&self) -> String {
        match &self.kind {
            ChaosKind::Fault { kind, instance, arg } => match kind {
                0 => format!("fail instance {instance} for {arg}us"),
                1 => format!("kill instance {instance}"),
                2 => format!("heal instance {instance}"),
                3 => format!("degrade link {instance} ({arg} flows)"),
                4 => format!("restore link {instance}"),
                other => format!("fault kind {other} instance {instance}"),
            },
            ChaosKind::Reconfig { verb, target } => match verb {
                0 => "reconfig add-shard".to_string(),
                1 => format!("reconfig remove shard {target}"),
                2 => format!("reconfig drain shard {target}"),
                3 => format!("reconfig restore shard {target}"),
                4 => "reconfig set-admission".to_string(),
                other => format!("reconfig verb {other} shard {target}"),
            },
        }
    }

    /// Coalescing identity: events merge when shard and kind class
    /// match (instance/arg may differ — a shard kill hits every
    /// instance).
    fn coalesce_key(&self) -> (u64, u8, bool) {
        match &self.kind {
            ChaosKind::Fault { kind, .. } => (self.shard, *kind, false),
            ChaosKind::Reconfig { verb, .. } => (self.shard, *verb, true),
        }
    }

    /// Is this a `Fault` (as opposed to a `Reconfig`)?
    pub fn is_fault(&self) -> bool {
        matches!(self.kind, ChaosKind::Fault { .. })
    }
}

/// Latency/outcome distribution of one window interval.
#[derive(Clone, Debug, Default)]
pub struct WindowStats {
    pub n: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub outcomes: OutcomeCounts,
}

impl WindowStats {
    fn of(lat_us: &mut Vec<u64>, outcomes: OutcomeCounts) -> WindowStats {
        lat_us.sort_unstable();
        let n = lat_us.len() as u64;
        let mean = if n == 0 {
            0.0
        } else {
            lat_us.iter().sum::<u64>() as f64 / n as f64
        };
        WindowStats {
            n,
            mean_us: mean,
            p50_us: percentile(lat_us, 50.0),
            p99_us: percentile(lat_us, 99.0),
            outcomes,
        }
    }
}

/// One chaos event (possibly coalesced) with its before/during/after
/// completion distributions.
#[derive(Clone, Debug)]
pub struct FaultWindow {
    /// Anchor timestamp (first event of the coalesced burst).
    pub at_us: u64,
    pub shard: u64,
    pub label: String,
    /// Raw events folded into this window (1 unless coalesced).
    pub count: u64,
    /// Half-window width W.
    pub width_us: u64,
    pub pre: WindowStats,
    pub during: WindowStats,
    pub post: WindowStats,
}

/// A terminal event as the window pass consumes it: completion
/// timestamp, session-measured latency, outcome.
pub type CompletionSample = (u64, u64, Outcome);

fn stats_in(completions: &[CompletionSample], lo: u64, hi: u64) -> WindowStats {
    let mut lats = Vec::new();
    let mut outcomes = OutcomeCounts::default();
    for &(ts, lat, out) in completions {
        if ts >= lo && ts < hi {
            lats.push(lat);
            outcomes.add(out);
        }
    }
    WindowStats::of(&mut lats, outcomes)
}

/// Coalesce a time-ordered chaos stream and compute the impact window
/// around each burst. `completions` need not be sorted.
pub fn fault_windows(
    chaos: &[ChaosEvent],
    completions: &[CompletionSample],
    width_us: u64,
) -> Vec<FaultWindow> {
    let mut out: Vec<FaultWindow> = Vec::new();
    let mut anchors: Vec<(ChaosEvent, u64)> = Vec::new();
    for ev in chaos {
        match anchors.last_mut() {
            Some((first, count))
                if first.coalesce_key() == ev.coalesce_key()
                    && ev.ts_us.saturating_sub(first.ts_us) <= COALESCE_US =>
            {
                *count += 1;
            }
            _ => anchors.push((ev.clone(), 1)),
        }
    }
    for (ev, count) in anchors {
        let t = ev.ts_us;
        out.push(FaultWindow {
            at_us: t,
            shard: ev.shard,
            label: ev.label(),
            count,
            width_us,
            pre: stats_in(completions, t.saturating_sub(width_us), t),
            during: stats_in(completions, t, t.saturating_add(width_us)),
            post: stats_in(
                completions,
                t.saturating_add(width_us),
                t.saturating_add(2 * width_us),
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kill(ts: u64, shard: u64, instance: u64) -> ChaosEvent {
        ChaosEvent {
            ts_us: ts,
            shard,
            kind: ChaosKind::Fault { kind: 1, instance, arg: 0 },
        }
    }

    #[test]
    fn burst_of_kills_coalesces_into_one_window() {
        let chaos =
            vec![kill(1000, 2, 0), kill(1005, 2, 1), kill(1010, 2, 2), kill(400_000, 2, 0)];
        let w = fault_windows(&chaos, &[], 50_000);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].count, 3);
        assert_eq!(w[0].at_us, 1000);
        assert_eq!(w[1].count, 1);
    }

    #[test]
    fn different_shards_or_kinds_do_not_coalesce() {
        let heal = ChaosEvent {
            ts_us: 1002,
            shard: 2,
            kind: ChaosKind::Fault { kind: 2, instance: 0, arg: 0 },
        };
        let w = fault_windows(&[kill(1000, 2, 0), heal, kill(1004, 3, 0)], &[], 1000);
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn windows_split_completions_and_show_latency_shift() {
        // 10 fast completions before the fault, 10 slow during, 10
        // fast after; W = 100ms.
        let mut completions = Vec::new();
        for i in 0..10u64 {
            completions.push((900_000 + i * 1000, 2_000, Outcome::Native));
            completions.push((1_000_000 + i * 1000, 90_000, Outcome::Reconstructed));
            completions.push((1_100_000 + i * 1000, 2_500, Outcome::Native));
        }
        let w = fault_windows(&[kill(1_000_000, 0, 1)], &completions, 100_000);
        assert_eq!(w.len(), 1);
        let w = &w[0];
        assert_eq!((w.pre.n, w.during.n, w.post.n), (10, 10, 10));
        assert!(w.during.p99_us > w.pre.p99_us);
        assert_eq!(w.during.outcomes.reconstructed, 10);
        assert_eq!(w.pre.outcomes.native, 10);
        assert!(w.during.mean_us > w.pre.mean_us);
    }
}

//! Renderers for [`Analysis`](crate::coordinator::trace::Analysis):
//! the human-readable `parm trace` report and the `--json` machine
//! output the CI schema lane validates.

use crate::coordinator::trace::span::percentile;
use crate::coordinator::trace::{Analysis, AnalyzeOpts, FaultWindow, OutcomeCounts, QuerySpan};
use crate::util::json::Json;

/// Microseconds, humanized (`850us`, `12.3ms`, `1.20s`).
pub fn fmt_us(us: u64) -> String {
    if us >= 1_000_000 {
        format!("{:.2}s", us as f64 / 1e6)
    } else if us >= 1_000 {
        format!("{:.1}ms", us as f64 / 1e3)
    } else {
        format!("{us}us")
    }
}

struct PhaseDist {
    p50: u64,
    p99: u64,
    max: u64,
}

fn dist(mut v: Vec<u64>) -> PhaseDist {
    v.sort_unstable();
    PhaseDist {
        p50: percentile(&v, 50.0),
        p99: percentile(&v, 99.0),
        max: v.last().copied().unwrap_or(0),
    }
}

/// Per-phase latency distributions over completed spans, in the order
/// queue / seal-wait / decode-wait / tail / total.
fn phase_dists(a: &Analysis) -> Vec<(&'static str, PhaseDist)> {
    let mut cols: [Vec<u64>; 5] = Default::default();
    for s in &a.spans {
        if let Some(p) = s.phases() {
            cols[0].push(p.queue_us);
            cols[1].push(p.seal_wait_us);
            cols[2].push(p.decode_wait_us);
            cols[3].push(p.tail_us);
            cols[4].push(p.total_us);
        }
    }
    let names = ["queue", "seal-wait", "decode-wait", "tail", "total"];
    names.into_iter().zip(cols.into_iter().map(dist)).collect()
}

fn outcome_line(c: &OutcomeCounts) -> String {
    format!(
        "native {} recovered {} replica {} defaulted {}",
        c.native, c.reconstructed, c.replica, c.defaulted
    )
}

// ---------------------------------------------------------------- text

/// The `parm trace` human report.
pub fn render_text(a: &Analysis, opts: &AnalyzeOpts) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let w = &mut out;

    let _ = writeln!(
        w,
        "journal: mode={} seed={:#x} shards={} events={} wall={}",
        a.mode,
        a.seed,
        a.shards,
        a.events,
        fmt_us(a.wall_us)
    );
    let counts = a.outcome_counts();
    let _ = writeln!(
        w,
        "queries: {} submitted, {} open | {} | rejected {}",
        a.spans.len(),
        a.open_spans(),
        outcome_line(&counts),
        a.rejected
    );
    if a.footer.is_none() {
        let _ = writeln!(w, "note: no End footer — journal cut mid-run");
    }

    let _ = writeln!(w, "\nphase latency (completed spans):");
    let _ = writeln!(w, "  {:<12} {:>10} {:>10} {:>10}", "phase", "p50", "p99", "max");
    for (name, d) in phase_dists(a) {
        let _ = writeln!(
            w,
            "  {:<12} {:>10} {:>10} {:>10}",
            name,
            fmt_us(d.p50),
            fmt_us(d.p99),
            fmt_us(d.max)
        );
    }

    let slow = a.slowest(opts.slow);
    if !slow.is_empty() {
        let _ = writeln!(w, "\nslowest queries:");
        for s in slow {
            let _ = writeln!(w, "  {}", span_line(s));
        }
    }

    let decoded = a.groups.iter().filter(|g| g.decoded()).count();
    let faulted = a.groups.iter().filter(|g| g.faults_hit > 0).count();
    let _ = writeln!(
        w,
        "\ngroup fates: {} groups, {} decoded, {} hit by faults",
        a.groups.len(),
        decoded,
        faulted
    );
    let interesting = a.groups.iter().filter(|g| g.decoded() || g.faults_hit > 0).count();
    let mut shown = 0usize;
    for g in a.groups.iter().filter(|g| g.decoded() || g.faults_hit > 0) {
        if shown == 20 {
            let _ = writeln!(w, "  ... ({} more)", interesting - shown);
            break;
        }
        shown += 1;
        let scope = match g.shard {
            Some(s) => format!("shard {s}"),
            None => format!("shards {:?}", g.dispatch_shards),
        };
        let slots: Vec<String> =
            g.decodes.iter().map(|&(ts, slot)| format!("slot {slot}@{}", fmt_us(ts))).collect();
        let _ = writeln!(
            w,
            "  group {} ({scope}): k={} r={} sealed@{} settle={} queries={} decodes=[{}] {} faults={}",
            g.group,
            g.k,
            g.r,
            g.sealed_us.map(fmt_us).unwrap_or_else(|| "-".into()),
            g.settle_us().map(fmt_us).unwrap_or_else(|| "-".into()),
            g.queries,
            slots.join(", "),
            outcome_line(&g.outcomes),
            g.faults_hit
        );
    }

    if a.windows.is_empty() {
        let _ = writeln!(w, "\nfault-impact windows: none (no chaos events)");
    } else {
        let _ = writeln!(
            w,
            "\nfault-impact windows (W={}):",
            fmt_us(a.windows[0].width_us)
        );
        for fw in &a.windows {
            let _ = writeln!(w, "  {}", window_line(fw));
        }
    }
    out
}

fn span_line(s: &QuerySpan) -> String {
    let p = s.phases();
    let total = s.total_us().unwrap_or(0);
    match p {
        Some(p) => format!(
            "shard {} qid {} [{}] total={} queue={} seal-wait={} decode-wait={} tail={}",
            s.shard,
            s.qid,
            s.outcome_tag(),
            fmt_us(total),
            fmt_us(p.queue_us),
            fmt_us(p.seal_wait_us),
            fmt_us(p.decode_wait_us),
            fmt_us(p.tail_us)
        ),
        None => format!("shard {} qid {} [open]", s.shard, s.qid),
    }
}

fn window_line(fw: &FaultWindow) -> String {
    let seg = |name: &str, s: &crate::coordinator::trace::WindowStats| {
        format!("{name} n={} p50={} p99={}", s.n, fmt_us(s.p50_us), fmt_us(s.p99_us))
    };
    format!(
        "@{} shard {} {}{}: {} | {} | {}",
        fmt_us(fw.at_us),
        fw.shard,
        fw.label,
        if fw.count > 1 { format!(" (x{})", fw.count) } else { String::new() },
        seg("pre", &fw.pre),
        seg("during", &fw.during),
        seg("post", &fw.post)
    )
}

// ---------------------------------------------------------------- json

fn opt_u64(v: Option<u64>) -> Json {
    v.map(Json::from).unwrap_or(Json::Null)
}

fn outcomes_json(c: &OutcomeCounts) -> Json {
    Json::obj()
        .set("native", c.native)
        .set("recovered", c.reconstructed)
        .set("replica", c.replica)
        .set("defaulted", c.defaulted)
}

fn span_json(s: &QuerySpan) -> Json {
    let mut j = Json::obj()
        .set("shard", s.shard)
        .set("qid", s.qid)
        .set("outcome", s.outcome_tag())
        .set("submit_us", s.submit_us)
        .set("route_us", opt_u64(s.route_us))
        .set("dispatch_us", opt_u64(s.dispatch_us))
        .set("seal_us", opt_u64(s.seal_us))
        .set("decode_us", opt_u64(s.decode_us))
        .set("complete_us", opt_u64(s.complete_us))
        .set("latency_us", opt_u64(s.latency_us))
        .set("group", opt_u64(s.group));
    if let Some(p) = s.phases() {
        j = j.set(
            "phases",
            Json::obj()
                .set("queue_us", p.queue_us)
                .set("seal_wait_us", p.seal_wait_us)
                .set("decode_wait_us", p.decode_wait_us)
                .set("tail_us", p.tail_us)
                .set("total_us", p.total_us),
        );
    }
    j
}

fn window_stats_json(s: &crate::coordinator::trace::WindowStats) -> Json {
    Json::obj()
        .set("n", s.n)
        .set("mean_us", s.mean_us)
        .set("p50_us", s.p50_us)
        .set("p99_us", s.p99_us)
        .set("outcomes", outcomes_json(&s.outcomes))
}

/// The `parm trace --json` document. Spans are complete; the group
/// timeline is capped to the interesting (decoded or fault-hit) groups
/// with `groups_truncated` flagging the cap.
pub fn render_json(a: &Analysis) -> Json {
    const GROUP_CAP: usize = 500;
    let footer = match &a.footer {
        Some(f) => Json::obj()
            .set("native", f.native)
            .set("reconstructed", f.reconstructed)
            .set("replica", f.replica)
            .set("defaulted", f.defaulted)
            .set("rejected", f.rejected)
            .set("reconstructions", f.reconstructions)
            .set("wall_us", f.wall_us),
        None => Json::Null,
    };
    let phase_json: Vec<Json> = phase_dists(a)
        .into_iter()
        .map(|(name, d)| {
            Json::obj()
                .set("phase", name)
                .set("p50_us", d.p50)
                .set("p99_us", d.p99)
                .set("max_us", d.max)
        })
        .collect();
    let interesting: Vec<&crate::coordinator::trace::GroupFate> =
        a.groups.iter().filter(|g| g.decoded() || g.faults_hit > 0).collect();
    let truncated = interesting.len() > GROUP_CAP;
    let groups: Vec<Json> = interesting
        .into_iter()
        .take(GROUP_CAP)
        .map(|g| {
            Json::obj()
                .set("group", g.group)
                .set("shard", opt_u64(g.shard))
                .set("k", g.k)
                .set("r", g.r)
                .set("first_dispatch_us", opt_u64(g.first_dispatch_us))
                .set("sealed_us", opt_u64(g.sealed_us))
                .set("settled_us", opt_u64(g.settled_us))
                .set("queries", g.queries)
                .set("data_jobs", g.data_jobs)
                .set("parity_jobs", g.parity_jobs)
                .set("replica_jobs", g.replica_jobs)
                .set(
                    "decodes",
                    g.decodes
                        .iter()
                        .map(|&(ts, slot)| Json::obj().set("ts_us", ts).set("slot", slot))
                        .collect::<Vec<Json>>(),
                )
                .set("outcomes", outcomes_json(&g.outcomes))
                .set("faults_hit", g.faults_hit)
                .set("dispatch_shards", g.dispatch_shards.clone())
        })
        .collect();
    let windows: Vec<Json> = a
        .windows
        .iter()
        .map(|fw| {
            Json::obj()
                .set("at_us", fw.at_us)
                .set("shard", fw.shard)
                .set("label", fw.label.as_str())
                .set("count", fw.count)
                .set("width_us", fw.width_us)
                .set("pre", window_stats_json(&fw.pre))
                .set("during", window_stats_json(&fw.during))
                .set("post", window_stats_json(&fw.post))
        })
        .collect();
    Json::obj()
        .set("seed", a.seed)
        .set("mode", a.mode.as_str())
        .set("shards", a.shards)
        .set("events", a.events)
        .set("wall_us", a.wall_us)
        .set("rejected", a.rejected)
        .set("footer", footer)
        .set("outcomes", outcomes_json(&a.outcome_counts()))
        .set("open_spans", a.open_spans())
        .set("phase_latency", phase_json)
        .set("spans", a.spans.iter().map(span_json).collect::<Vec<Json>>())
        .set("groups_total", a.groups.len())
        .set("groups_truncated", truncated)
        .set("groups", groups)
        .set("windows", windows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::journal::{Event, TimedEvent};
    use crate::coordinator::trace::analyze;

    fn sample() -> Analysis {
        let te = |ts_us, shard, event| TimedEvent { ts_us, shard, event };
        let events = vec![
            te(0, 0, Event::Start { seed: 9, mode: "parm".into(), shards: 1 }),
            te(10, 0, Event::Submit { qid: 0 }),
            te(
                20,
                0,
                Event::Dispatch { group: 1, kind: 0, detail: 0, queries: 1 },
            ),
            te(25, 0, Event::Seal { group: 1, k: 1, r: 1 }),
            te(60, 0, Event::Fault { instance: 0, kind: 1, arg: 0 }),
            te(80, 0, Event::Decode { group: 1, slot: 0 }),
            te(90, 0, Event::Complete { qid: 0, outcome: 1, latency_us: 80 }),
            te(
                100,
                0,
                Event::End {
                    native: 0,
                    reconstructed: 1,
                    replica: 0,
                    defaulted: 0,
                    rejected: 0,
                    reconstructions: 1,
                    wall_us: 100,
                },
            ),
        ];
        analyze(&events, &AnalyzeOpts::default())
    }

    #[test]
    fn text_report_mentions_every_section() {
        let text = render_text(&sample(), &AnalyzeOpts::default());
        for needle in
            ["journal: mode=parm", "phase latency", "slowest queries", "group fates", "fault-impact"]
        {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn json_report_round_trips_and_carries_the_schema() {
        let doc = render_json(&sample()).to_string();
        let parsed = Json::parse(&doc).expect("valid json");
        assert_eq!(parsed.at(&["mode"]).as_str(), Some("parm"));
        assert_eq!(parsed.at(&["spans"]).as_arr().map(<[Json]>::len), Some(1));
        let span = &parsed.at(&["spans"]).as_arr().unwrap()[0];
        assert_eq!(span.at(&["outcome"]).as_str(), Some("recovered"));
        assert_eq!(span.at(&["phases", "total_us"]).as_usize(), Some(80));
        assert_eq!(parsed.at(&["windows"]).as_arr().map(<[Json]>::len), Some(1));
        assert_eq!(parsed.at(&["groups"]).as_arr().map(<[Json]>::len), Some(1));
        assert_eq!(parsed.at(&["footer", "reconstructed"]).as_usize(), Some(1));
    }

    #[test]
    fn fmt_us_humanizes() {
        assert_eq!(fmt_us(850), "850us");
        assert_eq!(fmt_us(12_345), "12.3ms");
        assert_eq!(fmt_us(1_200_000), "1.20s");
    }
}

//! ParM decoder (§3.2, §3.5): reconstructs unavailable predictions from
//! the parity model's output plus the available predictions.
//!
//! r = 1 (the common case, fast path): a single subtraction pass,
//!   Fhat(X_j) = (F_P(P) - Σ_{i≠j} w_i·F(X_i)) / w_j.
//!
//! r > 1: each parity model was trained for a different weight vector
//! (§3.5); with u ≤ r data outputs missing we solve the u×u linear system
//! given by any u parity outputs via Gaussian elimination with partial
//! pivoting (coefficients are the parity weights; the right-hand sides
//! are whole prediction vectors).
//!
//! The decoder runs on the frontend collector thread; the paper measures
//! 8-19 us for it, so the r = 1 path is a single allocation + one fused
//! subtract loop.

use crate::tensor::{ops, Tensor};

#[derive(Debug, thiserror::Error)]
pub enum DecodeError {
    #[error("need {need} available of k={k} data outputs for r=1 decode, have {have}")]
    NotEnoughData { k: usize, need: usize, have: usize },
    #[error("cannot decode {missing} missing outputs with {parities} parity outputs")]
    TooManyMissing { missing: usize, parities: usize },
    #[error("singular decode system (weights not independent)")]
    Singular,
    #[error("tensor error: {0}")]
    Tensor(#[from] crate::tensor::TensorError),
}

/// r = 1 subtraction decode: reconstruct slot `j` from the parity output
/// and the other k-1 data outputs.
///
/// ```
/// use parm::coordinator::decoder::decode_r1;
/// use parm::tensor::Tensor;
///
/// // F(X1) = [1, 2] is unavailable; F(X2) = [3, 4] arrived, and the
/// // parity model produced F_P(P) ~ F(X1) + F(X2) = [4, 6].
/// let f2 = Tensor::new(vec![1, 2], vec![3.0, 4.0]).unwrap();
/// let fp = Tensor::new(vec![1, 2], vec![4.0, 6.0]).unwrap();
/// let rec = decode_r1(&[1.0, 1.0], &fp, &[None, Some(f2)], 0).unwrap();
/// assert_eq!(rec.data(), &[1.0, 2.0][..]);
/// ```
pub fn decode_r1(
    weights: &[f32],
    parity_out: &Tensor,
    data_outs: &[Option<Tensor>],
    j: usize,
) -> Result<Tensor, DecodeError> {
    let k = weights.len();
    debug_assert_eq!(data_outs.len(), k);
    let have = data_outs.iter().filter(|d| d.is_some()).count();
    if have < k - 1 || data_outs[j].is_some() && have < k {
        // (if slot j itself is present this is a no-op decode; still allow)
    }
    let mut acc = parity_out.clone();
    let mut missing_weight = None;
    for (i, (d, &w)) in data_outs.iter().zip(weights).enumerate() {
        if i == j {
            missing_weight = Some(w);
            continue;
        }
        match d {
            Some(t) => ops::add_scaled_assign(&mut acc, t, -w)?,
            None => {
                return Err(DecodeError::NotEnoughData { k, need: k - 1, have })
            }
        }
    }
    let w = missing_weight.expect("slot index in range");
    if (w - 1.0).abs() > f32::EPSILON {
        for v in acc.data_mut() {
            *v /= w;
        }
    }
    Ok(acc)
}

/// General decode: given per-parity weight vectors (r x k), the available
/// data outputs, and the available parity outputs, reconstruct all missing
/// data slots. Returns (slot, reconstruction) pairs.
pub fn decode_general(
    weights: &[Vec<f32>],
    data_outs: &[Option<Tensor>],
    parity_outs: &[Option<Tensor>],
) -> Result<Vec<(usize, Tensor)>, DecodeError> {
    let k = data_outs.len();
    let missing: Vec<usize> = (0..k).filter(|&i| data_outs[i].is_none()).collect();
    if missing.is_empty() {
        return Ok(Vec::new());
    }
    let avail_parities: Vec<usize> = (0..parity_outs.len())
        .filter(|&j| parity_outs[j].is_some())
        .collect();
    let u = missing.len();
    if u > avail_parities.len() {
        return Err(DecodeError::TooManyMissing {
            missing: u,
            parities: avail_parities.len(),
        });
    }

    // Fast path: one missing, first available parity.
    if u == 1 {
        let pj = avail_parities[0];
        let rec = decode_r1(
            &weights[pj],
            parity_outs[pj].as_ref().unwrap(),
            data_outs,
            missing[0],
        )?;
        return Ok(vec![(missing[0], rec)]);
    }

    // Build the u x u system: rows = first u available parities,
    // cols = missing slots. RHS_j = P_j - sum_{i available} w_ji F(X_i).
    let rows: Vec<usize> = avail_parities[..u].to_vec();
    let mut a = vec![vec![0.0f64; u]; u];
    let mut rhs: Vec<Tensor> = Vec::with_capacity(u);
    for (ri, &pj) in rows.iter().enumerate() {
        for (ci, &m) in missing.iter().enumerate() {
            a[ri][ci] = weights[pj][m] as f64;
        }
        let mut b = parity_outs[pj].as_ref().unwrap().clone();
        for (i, d) in data_outs.iter().enumerate() {
            if let Some(t) = d {
                ops::add_scaled_assign(&mut b, t, -weights[pj][i])?;
            }
        }
        rhs.push(b);
    }

    // Gaussian elimination with partial pivoting; the RHS entries are
    // whole tensors, so row ops apply to prediction vectors.
    for col in 0..u {
        let (pivot, pv) = (col..u)
            .map(|r| (r, a[r][col].abs()))
            .max_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
            .unwrap();
        if pv < 1e-9 {
            return Err(DecodeError::Singular);
        }
        a.swap(col, pivot);
        rhs.swap(col, pivot);
        for r in (col + 1)..u {
            let f = a[r][col] / a[col][col];
            if f == 0.0 {
                continue;
            }
            for c in col..u {
                a[r][c] -= f * a[col][c];
            }
            let (lo, hi) = rhs.split_at_mut(r);
            ops::add_scaled_assign(&mut hi[0], &lo[col], -(f as f32))?;
        }
    }
    // Back substitution.
    let mut out: Vec<Option<Tensor>> = vec![None; u];
    for col in (0..u).rev() {
        let mut x = rhs[col].clone();
        for c in (col + 1)..u {
            let coeff = a[col][c];
            let solved = out[c].as_ref().unwrap();
            ops::add_scaled_assign(&mut x, solved, -(coeff as f32))?;
        }
        let diag = a[col][col] as f32;
        for v in x.data_mut() {
            *v /= diag;
        }
        out[col] = Some(x);
    }
    Ok(missing
        .into_iter()
        .zip(out.into_iter().map(Option::unwrap))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: Vec<f32>) -> Tensor {
        Tensor::new(vec![data.len()], data).unwrap()
    }

    #[test]
    fn r1_subtraction_roundtrip() {
        // F(X1)=[1,2], F(X2)=[3,4]; parity model output = their sum.
        let f1 = t(vec![1., 2.]);
        let f2 = t(vec![3., 4.]);
        let fp = t(vec![4., 6.]);
        let w = vec![1.0, 1.0];
        let rec = decode_r1(&w, &fp, &[Some(f1.clone()), None], 1).unwrap();
        assert_eq!(rec.data(), f2.data());
        let rec = decode_r1(&w, &fp, &[None, Some(f2)], 0).unwrap();
        assert_eq!(rec.data(), f1.data());
    }

    #[test]
    fn r1_weighted_divides() {
        // P encodes X1 + 2*X2 => F_P approximates F(X1) + 2 F(X2).
        let f1 = t(vec![1., 1.]);
        let fp = t(vec![7., 9.]); // 1 + 2*3, 1 + 2*4
        let w = vec![1.0, 2.0];
        let rec = decode_r1(&w, &fp, &[Some(f1), None], 1).unwrap();
        assert_eq!(rec.data(), &[3., 4.]);
    }

    #[test]
    fn r1_insufficient_data_errors() {
        let fp = t(vec![0.]);
        let err = decode_r1(&[1., 1., 1.], &fp, &[Some(t(vec![1.])), None, None], 1);
        assert!(matches!(err, Err(DecodeError::NotEnoughData { .. })));
    }

    #[test]
    fn general_two_missing_two_parities() {
        // k=2, r=2; weights rows: [1,1] and [1,2] (§3.5).
        let f1 = t(vec![2., 0.]);
        let f2 = t(vec![1., 5.]);
        let p0 = t(vec![3., 5.]); // f1 + f2
        let p1 = t(vec![4., 10.]); // f1 + 2 f2
        let w = vec![vec![1., 1.], vec![1., 2.]];
        let rec =
            decode_general(&w, &[None, None], &[Some(p0), Some(p1)]).unwrap();
        assert_eq!(rec.len(), 2);
        assert_eq!(rec[0].0, 0);
        for (v, e) in rec[0].1.data().iter().zip(f1.data()) {
            assert!((v - e).abs() < 1e-5);
        }
        for (v, e) in rec[1].1.data().iter().zip(f2.data()) {
            assert!((v - e).abs() < 1e-5);
        }
    }

    #[test]
    fn general_one_missing_uses_fast_path() {
        let f1 = t(vec![2.]);
        let p0 = t(vec![5.]);
        let w = vec![vec![1., 1.]];
        let rec = decode_general(&w, &[Some(f1), None], &[Some(p0)]).unwrap();
        assert_eq!(rec, vec![(1, t(vec![3.]))]);
    }

    #[test]
    fn general_too_many_missing() {
        let w = vec![vec![1., 1.]];
        let err = decode_general(&w, &[None, None], &[Some(t(vec![1.]))]);
        assert!(matches!(err, Err(DecodeError::TooManyMissing { .. })));
    }

    #[test]
    fn general_singular_weights_error() {
        // Two missing slots but linearly dependent parity weights: the
        // 2x2 system [[1,1],[2,2]] has no solution set to pick from.
        let w = vec![vec![1., 1.], vec![2., 2.]];
        let err = decode_general(&w, &[None, None], &[Some(t(vec![3.])), Some(t(vec![6.]))]);
        assert!(matches!(err, Err(DecodeError::Singular)), "{err:?}");
    }

    #[test]
    fn general_near_zero_pivot_forces_row_swap() {
        // First parity's weight on the first missing slot is ~0: naive
        // elimination would divide by 1e-12 and destroy precision; partial
        // pivoting swaps rows and recovers both slots exactly.
        let f0 = t(vec![3., -1.]);
        let f1 = t(vec![2., 5.]);
        let w = vec![vec![1e-12, 1.], vec![1., 1.]];
        let p0 = t(vec![
            1e-12 * 3. + 2.,
            1e-12 * -1. + 5.,
        ]);
        let p1 = t(vec![5., 4.]);
        let rec = decode_general(&w, &[None, None], &[Some(p0), Some(p1)]).unwrap();
        assert_eq!(rec.len(), 2);
        for (slot, tensor) in rec {
            let truth = if slot == 0 { &f0 } else { &f1 };
            for (a, b) in tensor.data().iter().zip(truth.data()) {
                assert!((a - b).abs() < 1e-4, "slot {slot}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn general_single_missing_matches_r1_fast_path() {
        // With exactly one output missing the general decoder must agree
        // with the r=1 subtraction fast path bit-for-bit (it delegates).
        let f0 = t(vec![1.5, -2.0, 0.25]);
        let f2 = t(vec![0.5, 4.0, -1.0]);
        let weights = vec![vec![1.0f32, 2.0, 3.0], vec![1.0, 4.0, 9.0]];
        // Parity 0 output for F(X1) = [2, 7, 1]: p = f0 + 2*f1 + 3*f2.
        let f1 = t(vec![2., 7., 1.]);
        let mut p0 = t(vec![0.; 3]);
        for (i, f) in [&f0, &f1, &f2].into_iter().enumerate() {
            crate::tensor::ops::add_scaled_assign(&mut p0, f, weights[0][i]).unwrap();
        }
        let data = [Some(f0.clone()), None, Some(f2.clone())];
        let general =
            decode_general(&weights, &data, &[Some(p0.clone()), None]).unwrap();
        let fast = decode_r1(&weights[0], &p0, &data, 1).unwrap();
        assert_eq!(general, vec![(1, fast)]);
    }

    #[test]
    fn general_none_missing_is_empty() {
        let w = vec![vec![1., 1.]];
        let rec = decode_general(
            &w,
            &[Some(t(vec![1.])), Some(t(vec![2.]))],
            &[Some(t(vec![3.]))],
        )
        .unwrap();
        assert!(rec.is_empty());
    }

    #[test]
    fn general_k3_r2_various_missing_pairs() {
        // k=3, r=2; weights [1,1,1] and [1,2,3].
        let fs = [t(vec![1.]), t(vec![4.]), t(vec![9.])];
        let p0 = t(vec![14.]);
        let p1 = t(vec![1. + 8. + 27.]);
        let w = vec![vec![1., 1., 1.], vec![1., 2., 3.]];
        for (m1, m2) in [(0usize, 1usize), (0, 2), (1, 2)] {
            let mut data: Vec<Option<Tensor>> =
                fs.iter().map(|f| Some(f.clone())).collect();
            data[m1] = None;
            data[m2] = None;
            let rec = decode_general(&w, &data, &[Some(p0.clone()), Some(p1.clone())])
                .unwrap();
            assert_eq!(rec.len(), 2);
            for (slot, tensor) in rec {
                assert!(
                    (tensor.data()[0] - fs[slot].data()[0]).abs() < 1e-4,
                    "slot {slot}: {} vs {}",
                    tensor.data()[0],
                    fs[slot].data()[0]
                );
            }
        }
    }
}

//! The ParM coordinator (the paper's system contribution): encoders,
//! decoders, coding groups, batching, SLO handling, metrics, and the
//! serving frontend that wires them to instance pools.

pub mod batcher;
pub mod coding;
pub mod decoder;
pub mod encoder;
pub mod metrics;
pub mod service;

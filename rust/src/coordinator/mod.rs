//! The ParM coordinator (the paper's system contribution): encoders,
//! decoders, coding groups, batching, SLO handling, metrics, and the
//! serving sessions that wire them to instance pools.
//!
//! Architecture (post service-API redesign):
//!
//! - [`service`] holds the declarative surface: [`service::Mode`],
//!   [`service::ServiceConfig`], [`service::ModelSet`], plus the one-shot
//!   [`service::Service::run`] experiment shim.
//! - [`session`] is the serving engine: [`session::ServiceBuilder`]
//!   assembles the cluster substrate (network, faults, tenancy, shuffles,
//!   instance pools) from a config; [`session::ServiceHandle`] is the
//!   long-lived client surface — `submit(query) -> QueryId`,
//!   `poll()`/`drain() -> Vec<Resolved>`, `shutdown() -> RunResult`.
//! - [`scheme`] is the extension seam: an object-safe
//!   [`scheme::RedundancyScheme`] trait consulted at dispatch and
//!   completion time, with ParM and the paper's four baselines as
//!   implementations. **To add a new redundancy scheme**, implement the
//!   trait (pool layout, dispatch plan, completion→resolution rule) and
//!   expose it via a [`service::Mode`] variant; batching, pools, faults,
//!   shuffles, tenancy, SLO handling, and metrics all come for free. See
//!   the `scheme` module docs for the walk-through.
//! - [`adaptive`] is the first *dynamic-topology* scheme: a learned
//!   straggler predictor ([`adaptive::StragglerPredictor`]) feeding an
//!   ApproxIFER-style rateless code ([`adaptive::RatelessScheme`]) whose
//!   per-group parity count is chosen at group-seal time.
//! - [`frontend`] is the multi-client surface: a dispatcher thread owns
//!   the single-consumer handle, [`frontend::ServiceClient`]s submit
//!   concurrently through admission control
//!   ([`frontend::AdmissionPolicy`]) and get completions routed back to
//!   per-client inboxes with per-client accounting.
//! - [`shards`] is the scale-out tier: [`shards::ShardedFrontend`] runs
//!   N independent frontends (one session per shard, each its own fault
//!   domain) behind a consistent-hash [`shards::ShardRouter`], with
//!   shard-transparent [`shards::ShardedClient`]s, an optional fleet-wide
//!   offered-load cap, per-shard fault injection, and shutdown that
//!   merges per-shard results into one run record.
//! - [`cross_shard`] makes the coding groups themselves span those fault
//!   domains: [`shards::CrossShardFrontend`] stripes each group's k data
//!   batches over k distinct shards and serves parities from a shared
//!   cross-shard pool, with per-group r sized by a fleet-level
//!   straggler predictor ([`adaptive::FleetPredictor`]) — a whole-shard
//!   kill costs each group at most one slot and decodes like any
//!   single-instance loss.
//! - [`control`] is the embedded control plane: [`control::ControlPlane`]
//!   owns runtime reconfiguration of a live fleet (add/remove/drain/
//!   restore shards, swap admission policy, re-provision the cross-shard
//!   parity pool as the fleet resizes) and serves a line-oriented JSON
//!   admin protocol over a local Unix socket
//!   ([`control::AdminServer`]; `parm admin` is the client).
//! - [`metrics`] carries both aggregation surfaces: cumulative
//!   [`metrics::RunMetrics`] for a whole run and the sliding
//!   [`metrics::LatencyWindow`] behind every live snapshot.
//! - [`journal`] is the deterministic record/replay substrate: a binary,
//!   delta-encoded event log ([`journal::Recorder`]) every surface above
//!   can write into, replayable byte-identically with
//!   [`journal::replay`] (`parm replay` on the CLI).
//! - [`trace`] mines that journal into diagnostics: per-query span
//!   trees with exact phase accounting ([`trace::QuerySpan`]),
//!   group-fate timelines ([`trace::GroupFate`]), and fault-impact
//!   windows ([`trace::FaultWindow`]) — surfaced as `parm trace`
//!   (text / JSON / Chrome trace-event export), `parm replay --report`,
//!   and `parm mine` (journal → replayable [`crate::workload::Trace`]).
//! - Every tier above also publishes into the fleet-wide telemetry
//!   registry ([`crate::telemetry::Registry`], carried by
//!   [`service::ServiceConfig::telemetry`]): sessions count
//!   submits/resolutions/outcomes, schemes publish their operating
//!   point, the frontend publishes admission verdicts and client
//!   weights, and the control plane publishes reconfig verbs plus the
//!   merged fleet/per-shard windows — scraped via
//!   [`crate::telemetry::Exporter`] and the `parm admin telemetry`
//!   command, which read the same families.
//!
//! The thread-and-channel map of the whole stack is drawn in
//! `docs/ARCHITECTURE.md`.

pub mod adaptive;
pub mod batcher;
pub mod coding;
pub mod control;
pub mod cross_shard;
pub mod decoder;
pub mod encoder;
pub mod frontend;
pub mod journal;
pub mod metrics;
pub mod scheme;
pub mod service;
pub mod session;
pub mod shards;
pub mod trace;

//! Per-query latency accounting and serving metrics.
//!
//! Latency is measured exactly as in the paper (§5.1): from frontend
//! arrival to the moment a prediction for the query is available at the
//! frontend — from the deployed model, from a reconstruction, from a
//! replica, or (failing all by the SLO) a default prediction.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Deployed model's own prediction arrived first.
    Native,
    /// ParM reconstruction arrived first.
    Reconstructed,
    /// A replica / approximate backup answered first.
    Replica,
    /// Nothing by the SLO: default prediction returned.
    Default,
}

#[derive(Debug)]
pub struct QueryRecord {
    pub id: u64,
    pub arrived: Instant,
    pub resolved: Option<(Instant, Outcome)>,
}

/// Aggregates a full run.
#[derive(Default)]
pub struct RunMetrics {
    pub latency: Summary,
    pub native: u64,
    pub reconstructed: u64,
    pub replica: u64,
    pub defaulted: u64,
    /// Encode / decode time accounting (§5.2.5).
    pub encode_us: Summary,
    pub decode_us: Summary,
}

impl RunMetrics {
    pub fn record(&mut self, arrived: Instant, resolved: Instant, outcome: Outcome) {
        self.latency
            .record(resolved.duration_since(arrived).as_secs_f64() * 1e3);
        match outcome {
            Outcome::Native => self.native += 1,
            Outcome::Reconstructed => self.reconstructed += 1,
            Outcome::Replica => self.replica += 1,
            Outcome::Default => self.defaulted += 1,
        }
    }

    pub fn record_default(&mut self, slo: Duration) {
        self.latency.record(slo.as_secs_f64() * 1e3);
        self.defaulted += 1;
    }

    pub fn total(&self) -> u64 {
        self.native + self.reconstructed + self.replica + self.defaulted
    }

    /// Fraction of queries that needed something other than the deployed
    /// model's own prediction — the realized unavailability f_u.
    pub fn f_unavailable(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        (t - self.native) as f64 / t as f64
    }

    pub fn report(&mut self, label: &str) -> String {
        format!(
            "{} | native={} recon={} replica={} default={} (f_u={:.4})",
            self.latency.report(label),
            self.native,
            self.reconstructed,
            self.replica,
            self.defaulted,
            self.f_unavailable(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_counting_and_fu() {
        let mut m = RunMetrics::default();
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(10);
        m.record(t0, t1, Outcome::Native);
        m.record(t0, t1, Outcome::Native);
        m.record(t0, t1, Outcome::Reconstructed);
        m.record_default(Duration::from_millis(100));
        assert_eq!(m.total(), 4);
        assert_eq!(m.native, 2);
        assert_eq!(m.reconstructed, 1);
        assert_eq!(m.defaulted, 1);
        assert!((m.f_unavailable() - 0.5).abs() < 1e-12);
        // Default queries contribute the SLO as latency.
        assert_eq!(m.latency.max(), 100.0);
    }

    #[test]
    fn latency_in_ms() {
        let mut m = RunMetrics::default();
        let t0 = Instant::now();
        m.record(t0, t0 + Duration::from_millis(25), Outcome::Native);
        assert!((m.latency.median() - 25.0).abs() < 1.0);
    }
}

//! Per-query latency accounting and serving metrics.
//!
//! Latency is measured exactly as in the paper (§5.1): from frontend
//! arrival to the moment a prediction for the query is available at the
//! frontend — from the deployed model, from a reconstruction, from a
//! replica, or (failing all by the SLO) a default prediction.
//!
//! Two aggregation surfaces:
//!
//! - [`RunMetrics`] accumulates a whole run and is reported once at
//!   [`crate::coordinator::session::ServiceHandle::shutdown`];
//! - [`LatencyWindow`] is the *live* view: a sliding window of recent
//!   resolutions (and admission rejects) that can be snapshotted at any
//!   moment — by a [`crate::coordinator::session::ServiceHandle`] owner
//!   via `window_snapshot()`, or per client through the multi-client
//!   frontend in [`crate::coordinator::frontend`].

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::util::stats::Summary;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Deployed model's own prediction arrived first.
    Native,
    /// ParM reconstruction arrived first.
    Reconstructed,
    /// A replica / approximate backup answered first.
    Replica,
    /// Nothing by the SLO: default prediction returned.
    Default,
}

#[derive(Debug)]
pub struct QueryRecord {
    pub id: u64,
    pub arrived: Instant,
    pub resolved: Option<(Instant, Outcome)>,
}

/// Aggregates a full run.
#[derive(Default)]
pub struct RunMetrics {
    pub latency: Summary,
    pub native: u64,
    pub reconstructed: u64,
    pub replica: u64,
    pub defaulted: u64,
    /// Queries turned away by admission control before entering the
    /// session (never dispatched, so they contribute no latency sample
    /// and are excluded from [`RunMetrics::total`]).
    pub rejected: u64,
    /// Encode / decode time accounting (§5.2.5).
    pub encode_us: Summary,
    pub decode_us: Summary,
}

impl RunMetrics {
    pub fn record(&mut self, arrived: Instant, resolved: Instant, outcome: Outcome) {
        self.latency
            .record(resolved.duration_since(arrived).as_secs_f64() * 1e3);
        match outcome {
            Outcome::Native => self.native += 1,
            Outcome::Reconstructed => self.reconstructed += 1,
            Outcome::Replica => self.replica += 1,
            Outcome::Default => self.defaulted += 1,
        }
    }

    pub fn record_default(&mut self, slo: Duration) {
        self.latency.record(slo.as_secs_f64() * 1e3);
        self.defaulted += 1;
    }

    /// Fold in queries rejected by admission control (frontend-side).
    pub fn record_rejected(&mut self, n: u64) {
        self.rejected += n;
    }

    /// Fold another run's metrics into this one (used by the sharded
    /// tier to merge per-shard sessions into one fleet-wide record).
    /// Latency summaries concatenate raw samples, so merged percentiles
    /// are exact, not approximated.
    pub fn merge(&mut self, other: &RunMetrics) {
        self.latency.merge(&other.latency);
        self.native += other.native;
        self.reconstructed += other.reconstructed;
        self.replica += other.replica;
        self.defaulted += other.defaulted;
        self.rejected += other.rejected;
        self.encode_us.merge(&other.encode_us);
        self.decode_us.merge(&other.decode_us);
    }

    /// Queries that *resolved* (with any outcome). Rejected queries never
    /// entered the session and are counted separately in `rejected`.
    pub fn total(&self) -> u64 {
        self.native + self.reconstructed + self.replica + self.defaulted
    }

    /// All queries offered to the service: resolved plus rejected.
    pub fn offered(&self) -> u64 {
        self.total() + self.rejected
    }

    /// Fraction of queries that needed something other than the deployed
    /// model's own prediction — the realized unavailability f_u.
    pub fn f_unavailable(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        (t - self.native) as f64 / t as f64
    }

    pub fn report(&mut self, label: &str) -> String {
        format!(
            "{} | native={} recon={} replica={} default={} rejected={} (f_u={:.4})",
            self.latency.report(label),
            self.native,
            self.reconstructed,
            self.replica,
            self.defaulted,
            self.rejected,
            self.f_unavailable(),
        )
    }
}

// ------------------------------------------------------------------------
// Windowed live metrics
// ------------------------------------------------------------------------

/// Sliding-window aggregator for *live* serving metrics.
///
/// Holds the resolutions (and admission rejects) of the last `window` of
/// wall time and summarizes them on demand — tail percentiles, recovery
/// rate, reject rate — so a serving session can be observed while it runs
/// instead of only at shutdown. Events older than the window are pruned
/// on every `record`/`snapshot`, so memory is bounded by the event rate
/// times the window length.
///
/// ```
/// use std::time::{Duration, Instant};
/// use parm::coordinator::metrics::{LatencyWindow, Outcome};
///
/// let mut w = LatencyWindow::new(Duration::from_secs(60));
/// let t0 = Instant::now();
/// w.record(Outcome::Native, Duration::from_millis(10), t0);
/// w.record(Outcome::Reconstructed, Duration::from_millis(30), t0);
/// w.record_rejects(2, t0);
/// let s = w.snapshot(t0);
/// assert_eq!(s.resolved, 2);
/// assert_eq!(s.rejected, 2);
/// assert_eq!(s.p50_ms, 10.0);
/// assert_eq!(s.p99_ms, 30.0);
/// assert!((s.recovery_rate - 0.5).abs() < 1e-9); // the reconstruction
/// assert!((s.reject_rate - 0.5).abs() < 1e-9); // 2 rejected of 4 offered
/// ```
pub struct LatencyWindow {
    window: Duration,
    /// When the window was created (run start for a session's window) —
    /// the observation-span floor for throughput before the first full
    /// window elapses.
    created: Instant,
    /// (event time, latency in ms, outcome) per resolved query, oldest first.
    events: VecDeque<(Instant, f64, Outcome)>,
    /// Event times of admission rejects, oldest first.
    rejects: VecDeque<Instant>,
}

impl Default for LatencyWindow {
    /// A 10-second window — long enough for stable tail percentiles at
    /// the paper's query rates, short enough to track load shifts.
    fn default() -> LatencyWindow {
        LatencyWindow::new(Duration::from_secs(10))
    }
}

impl LatencyWindow {
    pub fn new(window: Duration) -> LatencyWindow {
        assert!(!window.is_zero(), "window must be non-zero");
        LatencyWindow {
            window,
            created: Instant::now(),
            events: VecDeque::new(),
            rejects: VecDeque::new(),
        }
    }

    pub fn window(&self) -> Duration {
        self.window
    }

    /// Record one resolved query. `at` is when the resolution happened
    /// (workers timestamp completions, so lazy recording stays accurate).
    pub fn record(&mut self, outcome: Outcome, latency: Duration, at: Instant) {
        self.events.push_back((at, latency.as_secs_f64() * 1e3, outcome));
        self.prune(at);
    }

    /// Record `n` admission-control rejects at `at`.
    pub fn record_rejects(&mut self, n: u64, at: Instant) {
        for _ in 0..n {
            self.rejects.push_back(at);
        }
        self.prune(at);
    }

    fn prune(&mut self, now: Instant) {
        while self
            .events
            .front()
            .is_some_and(|&(t, _, _)| now.saturating_duration_since(t) > self.window)
        {
            self.events.pop_front();
        }
        while self
            .rejects
            .front()
            .is_some_and(|&t| now.saturating_duration_since(t) > self.window)
        {
            self.rejects.pop_front();
        }
    }

    /// Just the windowed p99 latency (ms), `0.0` with no samples. Cheaper
    /// than a full [`LatencyWindow::snapshot`]: one latency copy plus an
    /// O(n) selection instead of building and sorting a whole summary —
    /// this runs on the frontend dispatcher's hot path at a ~10 ms
    /// cadence for [`crate::coordinator::frontend::AdmissionPolicy::SloAware`].
    pub fn p99_ms(&mut self, now: Instant) -> f64 {
        self.prune(now);
        if self.events.is_empty() {
            return 0.0;
        }
        let mut lat: Vec<f64> = self.events.iter().map(|&(_, ms, _)| ms).collect();
        // Nearest-rank p99, matching Summary::percentile. total_cmp keeps
        // the selection total even if a NaN ever slipped into the ring —
        // an observability readout must not panic the dispatcher.
        let rank = ((0.99 * lat.len() as f64).ceil() as usize).clamp(1, lat.len());
        let (_, v, _) = lat.select_nth_unstable_by(rank - 1, |a, b| a.total_cmp(b));
        *v
    }

    /// Summarize the events still inside the window as of `now`.
    pub fn snapshot(&mut self, now: Instant) -> WindowSnapshot {
        self.prune(now);
        let resolved = self.events.len() as u64;
        let rejected = self.rejects.len() as u64;
        let mut lat = Summary::with_capacity(self.events.len());
        let (mut recovered, mut defaulted) = (0u64, 0u64);
        for &(_, ms, outcome) in &self.events {
            lat.record(ms);
            match outcome {
                Outcome::Reconstructed | Outcome::Replica => recovered += 1,
                Outcome::Default => defaulted += 1,
                Outcome::Native => {}
            }
        }
        let (p50_ms, p99_ms, p999_ms) = if lat.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (lat.median(), lat.p99(), lat.p999())
        };
        let offered = resolved + rejected;
        // Throughput denominator: the full window once it has elapsed,
        // otherwise the time observed so far — idle time counts, so a
        // burst right before the snapshot is not reported as a high
        // sustained rate. Floored to avoid division blow-ups (the floor
        // must not exceed the window: Ord::clamp panics on min > max and
        // sub-millisecond windows are configurable).
        let floor = Duration::from_millis(1).min(self.window);
        let span = now.saturating_duration_since(self.created).clamp(floor, self.window);
        WindowSnapshot {
            window: self.window,
            resolved,
            rejected,
            p50_ms,
            p99_ms,
            p999_ms,
            recovery_rate: if resolved == 0 { 0.0 } else { recovered as f64 / resolved as f64 },
            reject_rate: if offered == 0 { 0.0 } else { rejected as f64 / offered as f64 },
            default_rate: if resolved == 0 { 0.0 } else { defaulted as f64 / resolved as f64 },
            qps: resolved as f64 / span.as_secs_f64(),
        }
    }
}

/// Point-in-time summary of a [`LatencyWindow`].
#[derive(Clone, Copy, Debug)]
pub struct WindowSnapshot {
    /// Length of the sliding window this snapshot summarizes.
    pub window: Duration,
    /// Queries resolved inside the window.
    pub resolved: u64,
    /// Queries rejected by admission control inside the window.
    pub rejected: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Fraction of resolved queries recovered by redundancy
    /// (reconstruction or replica) rather than the deployed model.
    pub recovery_rate: f64,
    /// rejected / (resolved + rejected).
    pub reject_rate: f64,
    /// Fraction of resolved queries that fell back to the SLO default.
    pub default_rate: f64,
    /// Resolved-query throughput over the observed span.
    pub qps: f64,
}

impl WindowSnapshot {
    /// An all-zero snapshot (identity element for [`WindowSnapshot::merge`]).
    pub fn zero(window: Duration) -> WindowSnapshot {
        WindowSnapshot {
            window,
            resolved: 0,
            rejected: 0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            p999_ms: 0.0,
            recovery_rate: 0.0,
            reject_rate: 0.0,
            default_rate: 0.0,
            qps: 0.0,
        }
    }

    /// Combine two snapshots into a fleet-wide view (used by the sharded
    /// serving tier, where each shard keeps its own window).
    ///
    /// Counts, and therefore rates, merge exactly: `resolved`/`rejected`
    /// add, `qps` adds, and the outcome rates are recomputed from the
    /// merged counts. Quantiles cannot be merged exactly from two
    /// summaries, so they are combined as resolved-weighted averages —
    /// exact when the shards are homogeneous, and always bounded by the
    /// per-shard minimum and maximum (a weighted mean never leaves the
    /// hull of its inputs; a side with `resolved == 0` carries no
    /// weight). For exact fleet quantiles over a whole run, merge
    /// [`RunMetrics`] instead, which keeps raw samples.
    ///
    /// The merge is NaN-proof: the fields are public, so a snapshot
    /// assembled elsewhere may carry non-finite quantiles or rates —
    /// those are treated as `0.0` rather than poisoning the fleet view,
    /// and the output is always finite.
    pub fn merge(&self, other: &WindowSnapshot) -> WindowSnapshot {
        // Non-finite inputs carry no information; treat them as absent.
        let finite = |v: f64| if v.is_finite() { v } else { 0.0 };
        let resolved = self.resolved + other.resolved;
        let rejected = self.rejected + other.rejected;
        let offered = resolved + rejected;
        let wavg = |a: f64, b: f64| {
            if resolved == 0 {
                0.0
            } else {
                (finite(a) * self.resolved as f64 + finite(b) * other.resolved as f64)
                    / resolved as f64
            }
        };
        // The rates are per-snapshot fractions; scale back to counts so
        // the merged rates are count-exact.
        let recovered = finite(self.recovery_rate) * self.resolved as f64
            + finite(other.recovery_rate) * other.resolved as f64;
        let defaulted = finite(self.default_rate) * self.resolved as f64
            + finite(other.default_rate) * other.resolved as f64;
        WindowSnapshot {
            window: self.window.max(other.window),
            resolved,
            rejected,
            p50_ms: wavg(self.p50_ms, other.p50_ms),
            p99_ms: wavg(self.p99_ms, other.p99_ms),
            p999_ms: wavg(self.p999_ms, other.p999_ms),
            recovery_rate: if resolved == 0 { 0.0 } else { recovered / resolved as f64 },
            reject_rate: if offered == 0 { 0.0 } else { rejected as f64 / offered as f64 },
            default_rate: if resolved == 0 { 0.0 } else { defaulted / resolved as f64 },
            qps: finite(self.qps) + finite(other.qps),
        }
    }

    /// Merge a whole fleet of per-shard snapshots (empty input yields
    /// [`WindowSnapshot::zero`]).
    pub fn merge_all(snaps: &[WindowSnapshot]) -> WindowSnapshot {
        snaps
            .iter()
            .fold(WindowSnapshot::zero(Duration::ZERO), |acc, s| acc.merge(s))
    }

    /// One-line report, e.g. for periodic printing from a live client.
    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: n={} p50={:.3}ms p99={:.3}ms p99.9={:.3}ms qps={:.0} recovery={:.3} rejects={} ({:.3})",
            self.resolved,
            self.p50_ms,
            self.p99_ms,
            self.p999_ms,
            self.qps,
            self.recovery_rate,
            self.rejected,
            self.reject_rate,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_counting_and_fu() {
        let mut m = RunMetrics::default();
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(10);
        m.record(t0, t1, Outcome::Native);
        m.record(t0, t1, Outcome::Native);
        m.record(t0, t1, Outcome::Reconstructed);
        m.record_default(Duration::from_millis(100));
        assert_eq!(m.total(), 4);
        assert_eq!(m.native, 2);
        assert_eq!(m.reconstructed, 1);
        assert_eq!(m.defaulted, 1);
        assert!((m.f_unavailable() - 0.5).abs() < 1e-12);
        // Default queries contribute the SLO as latency.
        assert_eq!(m.latency.max(), 100.0);
    }

    #[test]
    fn latency_in_ms() {
        let mut m = RunMetrics::default();
        let t0 = Instant::now();
        m.record(t0, t0 + Duration::from_millis(25), Outcome::Native);
        assert!((m.latency.median() - 25.0).abs() < 1.0);
    }

    #[test]
    fn rejected_counts_separately_from_total() {
        let mut m = RunMetrics::default();
        let t0 = Instant::now();
        m.record(t0, t0 + Duration::from_millis(5), Outcome::Native);
        m.record_rejected(3);
        assert_eq!(m.total(), 1);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.offered(), 4);
        assert_eq!(m.latency.len(), 1, "rejects contribute no latency sample");
    }

    #[test]
    fn window_prunes_expired_events() {
        let mut w = LatencyWindow::new(Duration::from_millis(100));
        let t0 = Instant::now();
        w.record(Outcome::Native, Duration::from_millis(1), t0);
        w.record_rejects(1, t0);
        let s = w.snapshot(t0);
        assert_eq!((s.resolved, s.rejected), (1, 1));
        // 50 ms later, both still inside the window; a fresh event joins.
        let t1 = t0 + Duration::from_millis(50);
        w.record(Outcome::Reconstructed, Duration::from_millis(2), t1);
        assert_eq!(w.snapshot(t1).resolved, 2);
        // 150 ms after t0, only the t1 event survives.
        let t2 = t0 + Duration::from_millis(150);
        let s = w.snapshot(t2);
        assert_eq!(s.resolved, 1);
        assert_eq!(s.rejected, 0);
        assert!((s.recovery_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn window_percentiles_and_rates() {
        let mut w = LatencyWindow::new(Duration::from_secs(60));
        let t0 = Instant::now();
        for i in 1..=100u64 {
            let outcome = if i % 10 == 0 { Outcome::Replica } else { Outcome::Native };
            w.record(outcome, Duration::from_millis(i), t0);
        }
        w.record_rejects(25, t0);
        let s = w.snapshot(t0);
        assert_eq!(s.resolved, 100);
        assert_eq!(s.p50_ms, 50.0);
        assert_eq!(s.p99_ms, 99.0);
        assert_eq!(s.p999_ms, 100.0);
        assert!((s.recovery_rate - 0.1).abs() < 1e-12);
        assert!((s.reject_rate - 0.2).abs() < 1e-12);
        assert!(s.report("w").contains("n=100"));
    }

    #[test]
    fn p99_only_path_matches_snapshot() {
        let mut w = LatencyWindow::new(Duration::from_secs(60));
        let t0 = Instant::now();
        assert_eq!(w.p99_ms(t0), 0.0, "empty window");
        for i in 1..=100u64 {
            w.record(Outcome::Native, Duration::from_millis(i), t0);
        }
        assert_eq!(w.p99_ms(t0), w.snapshot(t0).p99_ms);
        assert_eq!(w.p99_ms(t0), 99.0);
    }

    #[test]
    fn submillisecond_window_does_not_panic() {
        // Regression: the span floor used to be a hard 1 ms, which made
        // Ord::clamp panic (min > max) for configurable sub-ms windows.
        let mut w = LatencyWindow::new(Duration::from_micros(500));
        let t = Instant::now();
        w.record(Outcome::Native, Duration::from_micros(100), t);
        let s = w.snapshot(t + Duration::from_micros(200));
        assert_eq!(s.resolved, 1);
        assert!(s.qps > 0.0);
    }

    #[test]
    fn run_metrics_merge_adds_counts_and_samples() {
        let t0 = Instant::now();
        let mut a = RunMetrics::default();
        a.record(t0, t0 + Duration::from_millis(10), Outcome::Native);
        a.record_rejected(2);
        let mut b = RunMetrics::default();
        b.record(t0, t0 + Duration::from_millis(30), Outcome::Reconstructed);
        b.record_default(Duration::from_millis(100));
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.native, 1);
        assert_eq!(a.reconstructed, 1);
        assert_eq!(a.defaulted, 1);
        assert_eq!(a.rejected, 2);
        assert_eq!(a.offered(), 5);
        assert_eq!(a.latency.len(), 3, "raw samples concatenate");
        assert_eq!(a.latency.max(), 100.0);
    }

    #[test]
    fn window_snapshot_merge_counts_exact_quantiles_bounded() {
        let mk = |resolved: u64, rejected: u64, p50: f64, p99: f64, recovery: f64| {
            let mut s = WindowSnapshot::zero(Duration::from_secs(10));
            s.resolved = resolved;
            s.rejected = rejected;
            s.p50_ms = p50;
            s.p99_ms = p99;
            s.p999_ms = p99 * 1.5;
            s.recovery_rate = recovery;
            s.reject_rate = rejected as f64 / (resolved + rejected).max(1) as f64;
            s.qps = resolved as f64 / 10.0;
            s
        };
        let a = mk(100, 20, 10.0, 50.0, 0.1);
        let b = mk(300, 0, 20.0, 90.0, 0.3);
        let m = a.merge(&b);
        assert_eq!(m.resolved, 400);
        assert_eq!(m.rejected, 20);
        assert!((m.reject_rate - 20.0 / 420.0).abs() < 1e-12);
        // Recovered counts: 10 + 90 = 100 of 400.
        assert!((m.recovery_rate - 0.25).abs() < 1e-12);
        assert!((m.qps - 40.0).abs() < 1e-12);
        // Quantiles bounded by the per-shard extremes, weighted toward b.
        assert!(m.p50_ms >= 10.0 && m.p50_ms <= 20.0);
        assert!(m.p99_ms >= 50.0 && m.p99_ms <= 90.0);
        assert!((m.p50_ms - 17.5).abs() < 1e-9, "resolved-weighted mean");

        // Zero-weight sides carry nothing; zero() is the identity.
        let z = WindowSnapshot::zero(Duration::ZERO);
        let zm = z.merge(&a);
        assert_eq!(zm.resolved, a.resolved);
        assert!((zm.p99_ms - a.p99_ms).abs() < 1e-12);
        assert_eq!(WindowSnapshot::merge_all(&[]).resolved, 0);
        let all = WindowSnapshot::merge_all(&[a, b]);
        assert_eq!(all.resolved, m.resolved);
        assert!((all.p99_ms - m.p99_ms).abs() < 1e-12);
    }

    #[test]
    fn empty_window_snapshot_is_zeroed() {
        let mut w = LatencyWindow::default();
        let s = w.snapshot(Instant::now());
        assert_eq!(s.resolved, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert_eq!(s.qps, 0.0);
        assert_eq!(s.reject_rate, 0.0);
    }
}

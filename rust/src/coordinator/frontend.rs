//! Multi-client serving frontend: admission control and per-client
//! accounting over a single-consumer [`ServiceHandle`].
//!
//! The paper's setting is a prediction-serving system fronting many
//! concurrent users (§2.1), but [`ServiceHandle`] is deliberately
//! single-consumer — all of its methods take `&mut self` so the scheme,
//! batcher, and pending ring stay lock-free. This module closes the gap:
//!
//! ```text
//!  client threads                dispatcher thread             workers
//!  ──────────────               ───────────────────            ───────
//!  ServiceClient::submit ──┐
//!  ServiceClient::submit ──┼─ mpsc ─▶ ServiceHandle::submit ─▶ pools…
//!  ServiceClient::submit ──┘          ServiceHandle::poll  ◀── completions
//!                 ▲                        │
//!                 └── per-client inboxes ◀─┘ (routed by query id)
//! ```
//!
//! [`ServingFrontend::start`] moves the handle onto a dedicated
//! dispatcher thread. [`ServiceClient`]s (cloneable, `Send + Sync`) feed
//! it through an mpsc channel; the dispatcher routes every [`Resolved`]
//! back to the inbox of the client that submitted it (keyed by
//! [`QueryId`]) and keeps per-client counts and latency windows.
//!
//! **Admission control** runs on the client thread at `submit`, against
//! the dispatcher-published load (session [`ServiceHandle::backlog`] plus
//! submissions still in the channel): [`AdmissionPolicy::Unbounded`]
//! always admits, [`AdmissionPolicy::RejectAbove`] fails fast,
//! [`AdmissionPolicy::Block`] waits for headroom up to a timeout, and
//! [`AdmissionPolicy::SloAware`] sheds adaptively when the live windowed
//! p99 breaches the target (with a backlog backstop). Rejects
//! are folded back into the session's [`RunResult`] so a run's record
//! covers the *offered* traffic, not just the admitted part.
//!
//! Admission under the bounding policies is **weight-fair**: clients
//! carry a fairness weight ([`ServingFrontend::client_with_weight`];
//! default 1), and when the frontend saturates, clients still under
//! their weighted share keep admitting while the ones above it — the
//! greedy ones — absorb the rejects. The carve-out stops entirely at
//! twice the configured limit (a hard aggregate ceiling), and it is
//! weighted admission only; dispatch order is unchanged.
//!
//! ```no_run
//! use parm::artifacts::Manifest;
//! use parm::cluster::hardware::GPU;
//! use parm::coordinator::encoder::Encoder;
//! use parm::coordinator::frontend::AdmissionPolicy;
//! use parm::coordinator::service::{Mode, ServiceConfig};
//! use parm::coordinator::session::ServiceBuilder;
//! use parm::experiments::latency;
//! use parm::workload::QuerySource;
//!
//! # fn main() -> anyhow::Result<()> {
//! let manifest = Manifest::load_default()?;
//! let models = latency::load_models(&manifest, 1, 2, 1, false)?;
//! let source = QuerySource::from_dataset(&manifest, manifest.dataset(latency::LATENCY_DATASET)?)?;
//! let mut cfg =
//!     ServiceConfig::defaults(Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] }, &GPU);
//! cfg.admission = AdmissionPolicy::RejectAbove { backlog: 64 };
//!
//! let frontend = ServiceBuilder::new(cfg).serve(&models, &source.queries[0])?;
//! let client = frontend.client(); // one per submitter thread
//! let id = client.submit(source.queries[0].clone())?;
//! let answers = client.poll(); // routed back to *this* client only
//! println!("{}", client.window().report("client 0"));
//! # let _ = (id, answers);
//! let result = frontend.shutdown()?;
//! # let _ = result;
//! # Ok(())
//! # }
//! ```

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::coordinator::metrics::{LatencyWindow, Outcome, WindowSnapshot};
use crate::coordinator::service::{ModelSet, RunResult};
use crate::coordinator::session::{QueryId, Resolved, ServiceBuilder, ServiceHandle};
use crate::telemetry::{Counter, Gauge, Registry};
use crate::tensor::Tensor;
use crate::util::sync::{CondvarExt, LockExt, RwLockExt};

/// How the frontend admits queries when the cluster falls behind.
///
/// Enforced on the submitting client's thread against the most recently
/// published frontend load (session backlog + queued submissions), so it
/// is approximate by a few queries under racing submitters — the point is
/// bounding queue growth, not an exact semaphore.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything (the open-loop experiment default).
    Unbounded,
    /// Fail `submit` immediately once the load reaches `backlog`.
    RejectAbove { backlog: usize },
    /// Wait up to `timeout` for the load to drop below `backlog`, then
    /// fail with [`SubmitError::Timeout`].
    Block { backlog: usize, timeout: Duration },
    /// Adaptive shedding against the *live* windowed tail: fail `submit`
    /// when the frontend-wide windowed p99 latency has breached `p99`
    /// (published by the dispatcher at a ~10 ms cadence), or — the hard
    /// backstop — when the load reaches `backlog`. Unlike `RejectAbove`,
    /// this reacts to what clients are actually experiencing (queueing
    /// *and* service-time inflation from faults or contention), not just
    /// to queue depth; once the breach slides out of the metrics window,
    /// admission reopens on its own.
    SloAware { p99: Duration, backlog: usize },
}

/// Why a [`ServiceClient::submit`] did not enqueue the query.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum SubmitError {
    #[error("admission control rejected the query (load {load} >= limit {limit})")]
    Rejected { load: usize, limit: usize },
    #[error("admission control timed out after {timeout:?} (load {load} >= limit {limit})")]
    Timeout { load: usize, limit: usize, timeout: Duration },
    #[error("admission shed load (windowed p99 {live_p99:?} breaches SLO {slo:?})")]
    SloShed { live_p99: Duration, slo: Duration },
    #[error("frontend is shut down")]
    Closed,
}

/// Per-client counters, readable at any time via [`ServiceClient::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Queries this client successfully enqueued.
    pub submitted: u64,
    /// Queries resolved and routed back to this client.
    pub resolved: u64,
    /// Queries admission control turned away.
    pub rejected: u64,
    /// Resolved by the deployed model's own prediction.
    pub native: u64,
    /// Recovered by redundancy (ParM reconstruction or a replica).
    pub recovered: u64,
    /// Fell back to the SLO default prediction.
    pub defaulted: u64,
}

impl ClientStats {
    /// Accepted queries still awaiting their prediction. Saturating: the
    /// counters are snapshotted independently, so a concurrent submit +
    /// delivery between the two loads can make `resolved` read ahead.
    pub fn in_flight(&self) -> u64 {
        self.submitted.saturating_sub(self.resolved)
    }
}

/// Identity and accounting of one logical client.
struct ClientCore {
    id: u64,
    /// Admission-fairness weight (see [`ServingFrontend::client_with_weight`]):
    /// this client's share of the load limit is `weight / Σ weights`.
    weight: f64,
    /// Whether `weight` is currently folded into the frontend's total.
    /// The sharded tier mints one passive leg per shard and activates
    /// only the routed one, so weighted shares are not diluted by legs
    /// the router never sends traffic to (see
    /// [`ServiceClient::activate_weight`]).
    weight_registered: AtomicBool,
    submitted: AtomicU64,
    resolved: AtomicU64,
    rejected: AtomicU64,
    native: AtomicU64,
    recovered: AtomicU64,
    defaulted: AtomicU64,
    /// This client's latency sketch over the sliding window.
    window: Mutex<LatencyWindow>,
    /// Completions routed to this client, awaiting pickup.
    inbox: Mutex<VecDeque<Resolved>>,
    inbox_cv: Condvar,
}

impl ClientCore {
    fn new(id: u64, window: Duration, weight: f64, registered: bool) -> ClientCore {
        assert!(weight.is_finite() && weight > 0.0, "client weight must be finite and > 0");
        ClientCore {
            id,
            weight,
            weight_registered: AtomicBool::new(registered),
            submitted: AtomicU64::new(0),
            resolved: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            native: AtomicU64::new(0),
            recovered: AtomicU64::new(0),
            defaulted: AtomicU64::new(0),
            window: Mutex::new(LatencyWindow::new(window)),
            inbox: Mutex::new(VecDeque::new()),
            inbox_cv: Condvar::new(),
        }
    }

    /// Dispatcher-side delivery: account, record latency, wake waiters.
    fn deliver(&self, r: Resolved) {
        self.resolved.fetch_add(1, Ordering::Relaxed);
        match r.outcome {
            Outcome::Native => self.native.fetch_add(1, Ordering::Relaxed),
            Outcome::Reconstructed | Outcome::Replica => {
                self.recovered.fetch_add(1, Ordering::Relaxed)
            }
            Outcome::Default => self.defaulted.fetch_add(1, Ordering::Relaxed),
        };
        self.window.plock().record(r.outcome, r.latency, Instant::now());
        let mut inbox = self.inbox.plock();
        inbox.push_back(r);
        self.inbox_cv.notify_all();
    }
}

/// State shared by the frontend handle, every client, and the dispatcher.
struct FrontendShared {
    /// Admission policy, swappable at runtime by the control plane
    /// ([`ServingFrontend::set_policy`]); read per admission decision.
    policy: RwLock<AdmissionPolicy>,
    /// Window length for the frontend-wide and per-client aggregators.
    client_window: Duration,
    /// Next frontend-level query id (ids are unique across clients).
    next_id: AtomicU64,
    next_client: AtomicU64,
    /// Submissions accepted but not yet handed to the session.
    queued: AtomicUsize,
    /// Client threads currently inside `submit` (passed the open check,
    /// message possibly not sent yet). The dispatcher's shutdown path
    /// waits for this to clear so an accepted submit is never dropped.
    in_submit: AtomicUsize,
    /// Last [`ServiceHandle::backlog`] published by the dispatcher.
    session_backlog: AtomicUsize,
    /// Sum of all minted clients' fairness weights (f64 bits; clients are
    /// never unregistered, matching their cores' lifetime).
    total_weight: AtomicU64,
    /// Frontend-wide windowed p99 in microseconds, published by the
    /// dispatcher (~10 ms cadence) for [`AdmissionPolicy::SloAware`];
    /// 0 = no samples yet. Only refreshed when the policy needs it.
    window_p99_us: AtomicU64,
    /// Total admission rejects (all clients, whole run).
    rejected_total: AtomicU64,
    /// Rejects not yet folded into the session's metrics.
    rejects_unfolded: AtomicU64,
    /// Cleared by `shutdown`; new submits fail with [`SubmitError::Closed`].
    open: AtomicBool,
    /// Wait/notify surface for [`AdmissionPolicy::Block`] submitters.
    gate: Mutex<()>,
    gate_cv: Condvar,
    /// Frontend-wide sliding window across all clients.
    window: Mutex<LatencyWindow>,
    /// The session's metric registry (possibly shard-scoped) — the
    /// frontend publishes admission verdicts and client weights into it.
    registry: Registry,
    /// `parm_admission_total{verdict="accepted"}`.
    tele_accepted: Counter,
    /// `parm_admission_total{verdict="rejected"}` (every shed path:
    /// RejectAbove, Block timeout, SLO shed, shutdown-interrupted wait).
    tele_rejected: Counter,
    /// `parm_client_weight_total` — the live fair-share denominator.
    tele_weight_total: Gauge,
}

impl FrontendShared {
    /// Outstanding work the admission policies bound: session pool
    /// backlog plus submissions still queued toward the dispatcher.
    fn load(&self) -> usize {
        self.session_backlog.load(Ordering::Acquire) + self.queued.load(Ordering::Acquire)
    }

    /// Register a freshly minted client's fairness weight (CAS loop on
    /// the f64 bit pattern — contention only at client-mint time).
    fn add_weight(&self, w: f64) {
        let mut cur = self.total_weight.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + w).to_bits();
            match self.total_weight.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.tele_weight_total.set(f64::from_bits(next));
                    return;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Export one client's fairness weight as
    /// `parm_client_weight{client="<id>"}` (mint-time, not hot path).
    fn publish_client_weight(&self, id: u64, weight: f64) {
        self.registry
            .gauge(
                "parm_client_weight",
                "Admission-fairness weight of one client.",
                &[("client", &id.to_string())],
            )
            .set(weight);
    }

    fn total_weight(&self) -> f64 {
        f64::from_bits(self.total_weight.load(Ordering::Relaxed))
    }
}

/// Decrements the in-submit counter on every exit path of `submit`.
struct SubmitGuard<'a>(&'a AtomicUsize);

impl Drop for SubmitGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Messages from clients (and the frontend handle) to the dispatcher.
enum Msg {
    Submit { fid: QueryId, client: Arc<ClientCore>, input: Tensor },
    Shutdown { reply: mpsc::Sender<RunResult> },
}

/// A handle for one logical client of a [`ServingFrontend`].
///
/// `Send + Sync` and cheap to clone; a clone shares this client's
/// identity (inbox, counters, window) — use [`ServiceClient::fork`] or
/// [`ServingFrontend::client`] for a *new* identity with its own
/// accounting. All methods take `&self`, so one client can be driven
/// from several threads at once.
pub struct ServiceClient {
    core: Arc<ClientCore>,
    shared: Arc<FrontendShared>,
    /// Shared with the frontend handle only — the dispatcher must not
    /// hold a sender or it would never observe disconnect. The Mutex is
    /// for portability, not correctness: `mpsc::Sender` is only `Sync`
    /// on Rust >= 1.72, and the lock is held for a single non-blocking
    /// `send`, so contention is a few hundred nanoseconds per submit.
    tx: Arc<Mutex<mpsc::Sender<Msg>>>,
}

impl Clone for ServiceClient {
    fn clone(&self) -> ServiceClient {
        ServiceClient {
            core: self.core.clone(),
            shared: self.shared.clone(),
            tx: self.tx.clone(),
        }
    }
}

impl ServiceClient {
    /// This client's frontend-assigned id (stable across clones).
    pub fn id(&self) -> u64 {
        self.core.id
    }

    /// A new client identity on the same frontend (fresh inbox, counters,
    /// and latency window), inheriting this client's fairness weight.
    pub fn fork(&self) -> ServiceClient {
        let core = Arc::new(ClientCore::new(
            self.shared.next_client.fetch_add(1, Ordering::Relaxed),
            self.shared.client_window,
            self.core.weight,
            true,
        ));
        self.shared.add_weight(self.core.weight);
        self.shared.publish_client_weight(core.id, core.weight);
        ServiceClient { core, shared: self.shared.clone(), tx: self.tx.clone() }
    }

    /// This client's admission-fairness weight.
    pub fn weight(&self) -> f64 {
        self.core.weight
    }

    /// Fold this client's fairness weight into the frontend's total
    /// (idempotent across clones — the weight counts once). The sharded
    /// tier calls this on the leg its router assigns a client to, so a
    /// shard's fair-share denominator counts only the clients actually
    /// routed to it.
    pub fn activate_weight(&self) {
        if !self.core.weight_registered.swap(true, Ordering::SeqCst) {
            self.shared.add_weight(self.core.weight);
        }
    }

    /// Remove this client's fairness weight from the frontend's total
    /// (idempotent) — the counterpart of
    /// [`ServiceClient::activate_weight`] when the router moves the
    /// client to another shard (drain/restore).
    pub fn deactivate_weight(&self) {
        if self.core.weight_registered.swap(false, Ordering::SeqCst) {
            self.shared.add_weight(-self.core.weight);
        }
    }

    /// Whether this client's weight is currently registered here.
    pub fn weight_active(&self) -> bool {
        self.core.weight_registered.load(Ordering::SeqCst)
    }

    /// Submit one query through admission control. On success the query
    /// id is returned immediately; the prediction arrives later in this
    /// client's inbox ([`ServiceClient::poll`] / [`ServiceClient::next`]).
    pub fn submit(&self, input: Tensor) -> Result<QueryId, SubmitError> {
        // SeqCst pairs with the SeqCst open-store in shutdown: if the
        // open check below passes, this increment is globally ordered
        // before the store, so the dispatcher's shutdown wait loop is
        // guaranteed to observe it and absorb our message.
        self.shared.in_submit.fetch_add(1, Ordering::SeqCst);
        let _guard = SubmitGuard(&self.shared.in_submit);
        if !self.shared.open.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed);
        }
        self.admit()?;
        let fid = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        self.core.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.queued.fetch_add(1, Ordering::AcqRel);
        let sent = self
            .tx
            .lock()
            .unwrap()
            .send(Msg::Submit { fid, client: self.core.clone(), input });
        if sent.is_err() {
            // Dispatcher already gone (shutdown raced this submit).
            self.shared.queued.fetch_sub(1, Ordering::AcqRel);
            self.core.submitted.fetch_sub(1, Ordering::Relaxed);
            return Err(SubmitError::Closed);
        }
        self.shared.tele_accepted.inc();
        Ok(fid)
    }

    /// Non-blocking: take every prediction routed to this client so far.
    pub fn poll(&self) -> Vec<Resolved> {
        self.core.inbox.plock().drain(..).collect()
    }

    /// Non-blocking: take the single oldest prediction for this client,
    /// if any (the sharded tier sweeps many inboxes without draining).
    pub fn try_next(&self) -> Option<Resolved> {
        self.core.inbox.plock().pop_front()
    }

    /// This frontend's current admission-load estimate (session backlog
    /// plus queued submissions) — the same number
    /// [`ServingFrontend::load`] reports, readable from any client.
    pub fn load(&self) -> usize {
        self.shared.load()
    }

    /// Block up to `timeout` for the next prediction for this client.
    pub fn next(&self, timeout: Duration) -> Option<Resolved> {
        let deadline = Instant::now() + timeout;
        let mut inbox = self.core.inbox.plock();
        loop {
            if let Some(r) = inbox.pop_front() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .core
                .inbox_cv
                .pwait_timeout(inbox, deadline - now);
            inbox = guard;
        }
    }

    /// This client's counters (monotonic over the client's lifetime).
    pub fn stats(&self) -> ClientStats {
        ClientStats {
            submitted: self.core.submitted.load(Ordering::Relaxed),
            resolved: self.core.resolved.load(Ordering::Relaxed),
            rejected: self.core.rejected.load(Ordering::Relaxed),
            native: self.core.native.load(Ordering::Relaxed),
            recovered: self.core.recovered.load(Ordering::Relaxed),
            defaulted: self.core.defaulted.load(Ordering::Relaxed),
        }
    }

    /// This client's live windowed latency/recovery/reject summary.
    pub fn window(&self) -> WindowSnapshot {
        self.core.window.plock().snapshot(Instant::now())
    }

    /// Weighted-fairness carve-out: when the frontend is saturated, a
    /// client whose own in-flight count is still under its weighted
    /// share of `pool` keeps admitting — the clients above their share
    /// (the greedy ones) absorb the rejects. `pool` is the quantity
    /// being divided fairly: the load limit for backlog-style bounds, or
    /// the current load for SLO shedding (so uniformly loaded clients
    /// all shed during a breach instead of all dodging it). Every client
    /// gets a floor of one in-flight slot so many-client deployments
    /// never starve anyone outright — which is why the carve-out also
    /// has a hard ceiling: it never applies once the load reaches twice
    /// the limit, so the aggregate stays bounded (< 2x limit) no matter
    /// how many clients are minted.
    fn under_fair_share(&self, limit: usize, pool: usize) -> bool {
        if self.shared.load() >= limit.saturating_mul(2) {
            return false;
        }
        let total = self.shared.total_weight();
        if total <= 0.0 {
            return false;
        }
        let share = (pool as f64 * self.core.weight / total).max(1.0);
        let in_flight = self
            .core
            .submitted
            .load(Ordering::Relaxed)
            .saturating_sub(self.core.resolved.load(Ordering::Relaxed));
        (in_flight as f64) < share
    }

    fn admit(&self) -> Result<(), SubmitError> {
        let policy = *self.shared.policy.pread();
        match policy {
            AdmissionPolicy::Unbounded => Ok(()),
            AdmissionPolicy::RejectAbove { backlog: limit } => {
                let load = self.shared.load();
                if load < limit || self.under_fair_share(limit, limit) {
                    Ok(())
                } else {
                    self.note_reject();
                    Err(SubmitError::Rejected { load, limit })
                }
            }
            AdmissionPolicy::Block { backlog: limit, timeout } => {
                let deadline = Instant::now() + timeout;
                let mut waited = self.shared.gate.plock();
                loop {
                    // A shutdown mid-wait interrupts the waiter: the query
                    // was offered while the frontend was open, so it is
                    // tallied as shed load *before* this thread leaves
                    // `submit` (and therefore before the dispatcher's
                    // final reject fold — see the shutdown wait loop).
                    // Without this check, shutdown would have to wait out
                    // the waiter's full admission timeout.
                    if !self.shared.open.load(Ordering::SeqCst) {
                        drop(waited);
                        self.note_reject();
                        return Err(SubmitError::Closed);
                    }
                    let load = self.shared.load();
                    if load < limit {
                        return Ok(());
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        drop(waited);
                        self.note_reject();
                        return Err(SubmitError::Timeout { load, limit, timeout });
                    }
                    // Re-check at a few-ms cadence even without a notify,
                    // since load also drains via dispatcher publishes.
                    let wait = (deadline - now).min(Duration::from_millis(2));
                    let (guard, _) = self.shared.gate_cv.pwait_timeout(waited, wait);
                    waited = guard;
                }
            }
            AdmissionPolicy::SloAware { p99, backlog: limit } => {
                let load = self.shared.load();
                if load >= limit && !self.under_fair_share(limit, limit) {
                    self.note_reject();
                    return Err(SubmitError::Rejected { load, limit });
                }
                let live = Duration::from_micros(self.shared.window_p99_us.load(Ordering::Relaxed));
                // Under an SLO breach, shedding is weighted against the
                // *current load*, not the backlog limit: uniformly loaded
                // clients are all at their share of the load and shed
                // (preserving the policy's breach behavior), while a
                // client well below its share — the one not causing the
                // pressure — keeps service, down to the one-slot floor.
                if !live.is_zero() && live >= p99 && !self.under_fair_share(limit, load) {
                    self.note_reject();
                    return Err(SubmitError::SloShed { live_p99: live, slo: p99 });
                }
                Ok(())
            }
        }
    }

    /// Tally one shed query against this client, its frontend window, and
    /// (via the dispatcher's fold) the session's `RunResult`. Crate-wide
    /// so the sharded tier's global offered-load cap lands its rejects in
    /// the same accounting as per-shard admission.
    pub(crate) fn note_reject(&self) {
        self.core.rejected.fetch_add(1, Ordering::Relaxed);
        self.shared.rejected_total.fetch_add(1, Ordering::Relaxed);
        self.shared.rejects_unfolded.fetch_add(1, Ordering::Relaxed);
        self.shared.tele_rejected.inc();
        let now = Instant::now();
        self.core.window.plock().record_rejects(1, now);
        self.shared.window.plock().record_rejects(1, now);
    }
}

/// Owner of the dispatcher thread that multiplexes [`ServiceClient`]s
/// onto a [`ServiceHandle`]. Create with [`ServingFrontend::start`] (or
/// [`ServiceBuilder::serve`]), mint clients with
/// [`ServingFrontend::client`], and finish with
/// [`ServingFrontend::shutdown`] to get the session's [`RunResult`].
pub struct ServingFrontend {
    shared: Arc<FrontendShared>,
    tx: Arc<Mutex<mpsc::Sender<Msg>>>,
    /// The session's fault plan, retained so chaos drills can target
    /// this frontend's cluster after the handle moved to the dispatcher.
    faults: Arc<FaultPlan>,
    /// The session's link-contention model, retained for the same
    /// reason: network-chaos scripts degrade links through it.
    network: Arc<crate::cluster::network::Network>,
    dispatcher: Option<JoinHandle<()>>,
}

impl ServingFrontend {
    /// Wrap a built session, serving it from a new dispatcher thread,
    /// with the default 10 s metrics window.
    pub fn start(handle: ServiceHandle, policy: AdmissionPolicy) -> ServingFrontend {
        ServingFrontend::start_with_window(handle, policy, Duration::from_secs(10))
    }

    /// [`ServingFrontend::start`] with an explicit window length for the
    /// frontend-wide and per-client metrics aggregators.
    pub fn start_with_window(
        handle: ServiceHandle,
        policy: AdmissionPolicy,
        window: Duration,
    ) -> ServingFrontend {
        let (tx, rx) = mpsc::channel();
        let registry = handle.registry();
        let verdict = |v: &str| {
            registry.counter(
                "parm_admission_total",
                "Admission decisions at the frontend, by verdict.",
                &[("verdict", v)],
            )
        };
        let shared = Arc::new(FrontendShared {
            policy: RwLock::new(policy),
            client_window: window,
            next_id: AtomicU64::new(0),
            next_client: AtomicU64::new(0),
            queued: AtomicUsize::new(0),
            in_submit: AtomicUsize::new(0),
            session_backlog: AtomicUsize::new(0),
            total_weight: AtomicU64::new(0.0f64.to_bits()),
            window_p99_us: AtomicU64::new(0),
            rejected_total: AtomicU64::new(0),
            rejects_unfolded: AtomicU64::new(0),
            open: AtomicBool::new(true),
            gate: Mutex::new(()),
            gate_cv: Condvar::new(),
            window: Mutex::new(LatencyWindow::new(window)),
            tele_accepted: verdict("accepted"),
            tele_rejected: verdict("rejected"),
            tele_weight_total: registry.gauge(
                "parm_client_weight_total",
                "Sum of registered client fairness weights (fair-share denominator).",
                &[],
            ),
            registry,
        });
        let faults = handle.fault_plan();
        let network = handle.network();
        let dispatcher_shared = shared.clone();
        let dispatcher = std::thread::Builder::new()
            .name("frontend-dispatcher".into())
            .spawn(move || dispatcher_loop(handle, rx, dispatcher_shared))
            .expect("spawn frontend dispatcher");
        ServingFrontend {
            shared,
            tx: Arc::new(Mutex::new(tx)),
            faults,
            network,
            dispatcher: Some(dispatcher),
        }
    }

    /// Mint a new client (own inbox, counters, latency window) with the
    /// default fairness weight of 1.
    pub fn client(&self) -> ServiceClient {
        self.client_with_weight(1.0)
    }

    /// Mint a new client with an explicit fairness weight. Under the
    /// bounding admission policies ([`AdmissionPolicy::RejectAbove`],
    /// [`AdmissionPolicy::SloAware`]) a saturated frontend keeps
    /// admitting any client whose own in-flight count is below its
    /// weighted share of the load limit (`weight / Σ weights x backlog`),
    /// so a greedy client absorbs the rejects instead of starving light
    /// ones; the carve-out cuts off once the load reaches twice the
    /// limit, so the aggregate stays hard-bounded regardless of how many
    /// clients exist. Weights do not grant priority in *scheduling* —
    /// dispatch order is unchanged — only in admission.
    pub fn client_with_weight(&self, weight: f64) -> ServiceClient {
        // ClientCore::new validates the weight before it is folded into
        // the shared total.
        let core = Arc::new(ClientCore::new(
            self.shared.next_client.fetch_add(1, Ordering::Relaxed),
            self.shared.client_window,
            weight,
            true,
        ));
        self.shared.add_weight(weight);
        self.shared.publish_client_weight(core.id, weight);
        ServiceClient { core, shared: self.shared.clone(), tx: self.tx.clone() }
    }

    /// Mint a client whose fairness weight is *not* yet counted in this
    /// frontend's total. The sharded tier mints one such leg per shard
    /// and then [`ServiceClient::activate_weight`]s only the leg its
    /// router assigns — weights follow the routing instead of being
    /// diluted across every shard.
    pub fn passive_client_with_weight(&self, weight: f64) -> ServiceClient {
        let core = Arc::new(ClientCore::new(
            self.shared.next_client.fetch_add(1, Ordering::Relaxed),
            self.shared.client_window,
            weight,
            false,
        ));
        self.shared.publish_client_weight(core.id, weight);
        ServiceClient { core, shared: self.shared.clone(), tx: self.tx.clone() }
    }

    /// Sum of the fairness weights currently registered with this
    /// frontend (the fair-share denominator).
    pub fn total_weight(&self) -> f64 {
        self.shared.total_weight()
    }

    /// The admission policy clients are subject to.
    pub fn policy(&self) -> AdmissionPolicy {
        *self.shared.policy.pread()
    }

    /// Swap the admission policy at runtime (the control plane's
    /// `set-admission` op). Takes effect on the next admission decision;
    /// queries already admitted or mid-wait under the old policy finish
    /// under its terms. Block-policy waiters are woken so a loosened
    /// policy reaches them promptly.
    pub fn set_policy(&self, policy: AdmissionPolicy) {
        *self.shared.policy.pwrite() = policy;
        self.shared.gate_cv.notify_all();
    }

    /// Current admission-control load estimate (session backlog plus
    /// queued submissions).
    pub fn load(&self) -> usize {
        self.shared.load()
    }

    /// Total queries rejected so far, across all clients.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected_total.load(Ordering::Relaxed)
    }

    /// Frontend-wide live windowed metrics across all clients.
    pub fn window(&self) -> WindowSnapshot {
        self.shared.window.plock().snapshot(Instant::now())
    }

    /// The metric registry this frontend (and its session) publishes
    /// into — hand it to a [`crate::telemetry::Exporter`] to scrape.
    pub fn registry(&self) -> Registry {
        self.shared.registry.clone()
    }

    /// Fault-injection surface (mirrors
    /// [`crate::coordinator::session::ServiceHandle::kill_instance`]):
    /// permanently kill an instance of this frontend's cluster.
    pub fn kill_instance(&self, instance: usize) {
        self.faults.kill(instance);
    }

    /// Fail an instance of this frontend's cluster for a bounded window.
    pub fn fail_instance_for(&self, instance: usize, dur: Duration) {
        self.faults.fail_for(instance, dur);
    }

    /// This frontend's cluster fault plan (the surface the deterministic
    /// fault-injection harness in `tests/common` scripts against).
    pub fn fault_plan(&self) -> Arc<FaultPlan> {
        self.faults.clone()
    }

    /// This frontend's cluster link-contention model — the surface
    /// network-chaos scripts degrade and restore links through.
    pub fn network(&self) -> Arc<crate::cluster::network::Network> {
        self.network.clone()
    }

    /// Stop admitting, let in-flight queries resolve (deliveries keep
    /// flowing to client inboxes), shut the session down, and return its
    /// [`RunResult`]. Like [`ServiceHandle::drain`], resolution of *lost*
    /// queries needs an SLO in the config — give it one when serving
    /// under failures.
    pub fn shutdown(mut self) -> anyhow::Result<RunResult> {
        self.shared.open.store(false, Ordering::SeqCst);
        // Wake Block-policy waiters so they observe the close and bail
        // (tallying themselves as shed) instead of sitting out their
        // admission timeout.
        self.shared.gate_cv.notify_all();
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Msg::Shutdown { reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("frontend dispatcher already exited"))?;
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("frontend dispatcher dropped the run result"))
    }
}

impl Drop for ServingFrontend {
    fn drop(&mut self) {
        // Dropped without shutdown(): stop admitting. Once the last
        // client's sender clone is gone the dispatcher observes
        // disconnect and exits WITHOUT draining (nobody is left to
        // receive results), tearing the session down via
        // ServiceHandle's Drop.
        self.shared.open.store(false, Ordering::SeqCst);
        self.shared.gate_cv.notify_all();
    }
}

impl ServiceBuilder {
    /// Build the session and wrap it in a [`ServingFrontend`] configured
    /// from this builder's `admission` policy and `metrics_window`.
    pub fn serve(
        self,
        models: &ModelSet,
        sample_query: &Tensor,
    ) -> anyhow::Result<ServingFrontend> {
        let policy = self.config().admission;
        let window = self.config().metrics_window;
        let handle = self.build(models, sample_query)?;
        Ok(ServingFrontend::start_with_window(handle, policy, window))
    }
}

// ------------------------------------------------------------------------
// Dispatcher thread
// ------------------------------------------------------------------------

/// Pump cadence: how long the dispatcher blocks for a submission before
/// servicing completions anyway. Workers timestamp completions, so this
/// granularity never distorts recorded latency.
const PUMP: Duration = Duration::from_millis(1);

fn dispatcher_loop(
    mut handle: ServiceHandle,
    rx: mpsc::Receiver<Msg>,
    shared: Arc<FrontendShared>,
) {
    // Session query id -> (frontend query id, submitting client).
    let mut routes: HashMap<QueryId, (QueryId, Arc<ClientCore>)> = HashMap::new();
    let mut shutdown_reply: Option<mpsc::Sender<RunResult>> = None;
    let mut disconnected = false;
    // SloAware admission reads the published windowed p99; refreshing a
    // snapshot sorts the window's events, so throttle it and skip the
    // work entirely for policies that never read it. Re-checked every
    // iteration: the policy can be swapped at runtime (set_policy).
    const P99_REFRESH: Duration = Duration::from_millis(10);
    let mut p99_published_at = Instant::now();

    while shutdown_reply.is_none() && !disconnected {
        let publish_p99 =
            matches!(*shared.policy.pread(), AdmissionPolicy::SloAware { .. });
        match rx.recv_timeout(PUMP) {
            Ok(Msg::Submit { fid, client, input }) => {
                submit_one(&mut handle, &mut routes, &shared, fid, client, input);
                // Drain the burst that accumulated behind the first one.
                loop {
                    match rx.try_recv() {
                        Ok(Msg::Submit { fid, client, input }) => {
                            submit_one(&mut handle, &mut routes, &shared, fid, client, input);
                        }
                        Ok(Msg::Shutdown { reply }) => {
                            shutdown_reply = Some(reply);
                            break;
                        }
                        Err(_) => break,
                    }
                }
            }
            Ok(Msg::Shutdown { reply }) => shutdown_reply = Some(reply),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => disconnected = true,
        }
        for r in handle.poll() {
            route(&mut routes, &shared, r);
        }
        publish(&handle, &shared);
        if publish_p99 && p99_published_at.elapsed() >= P99_REFRESH {
            let now = Instant::now();
            // p99_ms is the cheap O(n)-selection path, not a full sorted
            // snapshot — this runs under the shared window lock that
            // route() also takes per completion.
            let p99 = shared.window.plock().p99_ms(now);
            shared.window_p99_us.store((p99 * 1e3) as u64, Ordering::Relaxed);
            p99_published_at = now;
        }
        fold_rejects(&mut handle, &shared);
        // Wake Block-policy submitters; cheap when nobody waits.
        shared.gate_cv.notify_all();
    }

    if disconnected {
        // Every client and the frontend handle are gone (mpsc reports
        // Disconnected only once the buffer is empty), so there is
        // nobody to deliver to and no reply destination. Skip the drain
        // — with lost queries and no SLO it could never terminate — and
        // let ServiceHandle's Drop close the pools gracefully.
        return;
    }

    // Absorb submissions that raced the shutdown message so "accepted"
    // always implies "will resolve": any client past the `open` check
    // shows up in `in_submit` (SeqCst, see submit), and anything it sent
    // shows up in `queued` until handed to the session — so drain until
    // both clear. Bounded and prompt: once `open` is false new submits
    // fail fast, and a Block-policy waiter observes the close on its next
    // gate wake-up and bails, noting its reject *before* it leaves
    // `submit` (i.e. before `in_submit` can reach zero) — which is what
    // guarantees the fold below sees every shed waiter.
    loop {
        while let Ok(msg) = rx.try_recv() {
            match msg {
                Msg::Submit { fid, client, input } => {
                    submit_one(&mut handle, &mut routes, &shared, fid, client, input);
                }
                Msg::Shutdown { reply } => {
                    if shutdown_reply.is_none() {
                        shutdown_reply = Some(reply);
                    }
                }
            }
        }
        if shared.in_submit.load(Ordering::SeqCst) == 0
            && shared.queued.load(Ordering::SeqCst) == 0
        {
            break;
        }
        // Keep the published load fresh and Block waiters awake so they
        // either get admitted or time out promptly.
        publish(&handle, &shared);
        shared.gate_cv.notify_all();
        std::thread::sleep(Duration::from_micros(100));
    }
    // Only now is the reject tally final: every Block waiter that gave up
    // (timeout or interrupted by the close) tallied itself while it still
    // held `in_submit`, so the loop above could not exit before those
    // rejects were noted — fold them into the session before its metrics
    // are frozen by `shutdown()`.
    fold_rejects(&mut handle, &shared);
    for r in handle.drain() {
        route(&mut routes, &shared, r);
    }
    publish(&handle, &shared);
    let result = handle.shutdown();
    if let Some(reply) = shutdown_reply {
        let _ = reply.send(result);
    }
    shared.gate_cv.notify_all();
}

fn submit_one(
    handle: &mut ServiceHandle,
    routes: &mut HashMap<QueryId, (QueryId, Arc<ClientCore>)>,
    shared: &FrontendShared,
    fid: QueryId,
    client: Arc<ClientCore>,
    input: Tensor,
) {
    let sid = handle.submit(input);
    routes.insert(sid, (fid, client));
    // Publish *before* decrementing `queued` so admission never observes
    // the query in neither place (transient double-count is the safe
    // direction for a load bound).
    publish(handle, shared);
    shared.queued.fetch_sub(1, Ordering::AcqRel);
}

fn route(
    routes: &mut HashMap<QueryId, (QueryId, Arc<ClientCore>)>,
    shared: &FrontendShared,
    r: Resolved,
) {
    match routes.remove(&r.id) {
        Some((fid, client)) => {
            let out = Resolved { id: fid, outcome: r.outcome, latency: r.latency };
            shared.window.plock().record(out.outcome, out.latency, Instant::now());
            client.deliver(out);
        }
        None => log::warn!("frontend: resolution for unknown query id {}", r.id),
    }
}

fn publish(handle: &ServiceHandle, shared: &FrontendShared) {
    shared.session_backlog.store(handle.backlog(), Ordering::Release);
}

fn fold_rejects(handle: &mut ServiceHandle, shared: &FrontendShared) {
    let n = shared.rejects_unfolded.swap(0, Ordering::AcqRel);
    handle.note_rejected(n);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_send_sync_clone() {
        fn assert_traits<T: Send + Sync + Clone>() {}
        assert_traits::<ServiceClient>();
    }

    #[test]
    fn frontend_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ServingFrontend>();
    }

    #[test]
    fn client_stats_in_flight() {
        let s = ClientStats { submitted: 10, resolved: 7, ..ClientStats::default() };
        assert_eq!(s.in_flight(), 3);
    }

    #[test]
    fn submit_errors_render() {
        let r = SubmitError::Rejected { load: 70, limit: 64 };
        assert!(r.to_string().contains("70"));
        let t = SubmitError::Timeout {
            load: 70,
            limit: 64,
            timeout: Duration::from_millis(50),
        };
        assert!(t.to_string().contains("50ms"));
        let s = SubmitError::SloShed {
            live_p99: Duration::from_millis(120),
            slo: Duration::from_millis(100),
        };
        assert!(s.to_string().contains("120ms"));
        assert_eq!(SubmitError::Closed.to_string(), "frontend is shut down");
    }

    /// The weighted carve-out, pinned at the unit level (end-to-end
    /// fairness under a real stalled cluster is in
    /// `tests/frontend_concurrency.rs`): with the load saturated, the
    /// client over its weighted share is rejected while the one under
    /// its share keeps admitting.
    #[test]
    fn fair_share_carve_out_arithmetic() {
        const LIMIT: usize = 16;
        let (tx, _rx) = mpsc::channel();
        let tx = Arc::new(Mutex::new(tx));
        let shared = Arc::new(FrontendShared {
            policy: RwLock::new(AdmissionPolicy::RejectAbove { backlog: LIMIT }),
            client_window: Duration::from_secs(1),
            next_id: AtomicU64::new(0),
            next_client: AtomicU64::new(0),
            queued: AtomicUsize::new(0),
            in_submit: AtomicUsize::new(0),
            // Saturated: load == limit, so only the carve-out admits.
            session_backlog: AtomicUsize::new(LIMIT),
            total_weight: AtomicU64::new(0.0f64.to_bits()),
            window_p99_us: AtomicU64::new(0),
            rejected_total: AtomicU64::new(0),
            rejects_unfolded: AtomicU64::new(0),
            open: AtomicBool::new(true),
            gate: Mutex::new(()),
            gate_cv: Condvar::new(),
            window: Mutex::new(LatencyWindow::default()),
        });
        let mint = |weight: f64| {
            shared.add_weight(weight);
            ServiceClient {
                core: Arc::new(ClientCore::new(
                    shared.next_client.fetch_add(1, Ordering::Relaxed),
                    shared.client_window,
                    weight,
                    true,
                )),
                shared: shared.clone(),
                tx: tx.clone(),
            }
        };
        let light = mint(1.0);
        let heavy = mint(3.0);
        assert!((shared.total_weight() - 4.0).abs() < 1e-12);
        // Shares of the 16-limit: light 4, heavy 12.
        heavy.core.submitted.store(12, Ordering::Relaxed);
        assert!(!heavy.under_fair_share(LIMIT, LIMIT), "heavy is at its share");
        light.core.submitted.store(3, Ordering::Relaxed);
        assert!(light.under_fair_share(LIMIT, LIMIT), "light is under its share");
        assert!(light.admit().is_ok(), "under-share client admits at saturation");
        assert!(matches!(heavy.admit(), Err(SubmitError::Rejected { .. })));
        assert_eq!(heavy.stats().rejected, 1);
        // Resolutions free share again.
        heavy.core.resolved.store(5, Ordering::Relaxed);
        assert!(heavy.under_fair_share(LIMIT, LIMIT));
        assert!(heavy.admit().is_ok());
        // Hard ceiling: past 2x the limit the carve-out stops entirely —
        // no client count or weight can stretch the aggregate further.
        shared.session_backlog.store(2 * LIMIT, Ordering::Release);
        assert!(!light.under_fair_share(LIMIT, LIMIT));
        assert!(matches!(light.admit(), Err(SubmitError::Rejected { .. })));
    }

    /// Passive legs count nothing until activated; activation and
    /// deactivation are idempotent (clones share the flag), so a weight
    /// is folded in exactly once no matter how often the router rehomes.
    #[test]
    fn passive_weight_activation_is_idempotent() {
        let (tx, _rx) = mpsc::channel();
        let tx = Arc::new(Mutex::new(tx));
        let shared = Arc::new(FrontendShared {
            policy: RwLock::new(AdmissionPolicy::Unbounded),
            client_window: Duration::from_secs(1),
            next_id: AtomicU64::new(0),
            next_client: AtomicU64::new(0),
            queued: AtomicUsize::new(0),
            in_submit: AtomicUsize::new(0),
            session_backlog: AtomicUsize::new(0),
            total_weight: AtomicU64::new(0.0f64.to_bits()),
            window_p99_us: AtomicU64::new(0),
            rejected_total: AtomicU64::new(0),
            rejects_unfolded: AtomicU64::new(0),
            open: AtomicBool::new(true),
            gate: Mutex::new(()),
            gate_cv: Condvar::new(),
            window: Mutex::new(LatencyWindow::default()),
        });
        let passive = ServiceClient {
            core: Arc::new(ClientCore::new(0, Duration::from_secs(1), 2.5, false)),
            shared: shared.clone(),
            tx,
        };
        assert!(!passive.weight_active());
        assert_eq!(shared.total_weight(), 0.0);
        let clone = passive.clone();
        passive.activate_weight();
        clone.activate_weight(); // shared flag: counted once
        assert!(clone.weight_active());
        assert!((shared.total_weight() - 2.5).abs() < 1e-12);
        clone.deactivate_weight();
        passive.deactivate_weight();
        assert!(!passive.weight_active());
        assert!(shared.total_weight().abs() < 1e-12);
    }

    /// End-to-end routing is covered by `tests/frontend_concurrency.rs`
    /// against a real simulated cluster; here we only pin the pure
    /// admission arithmetic.
    #[test]
    fn load_is_backlog_plus_queued() {
        let shared = FrontendShared {
            policy: RwLock::new(AdmissionPolicy::Unbounded),
            client_window: Duration::from_secs(1),
            next_id: AtomicU64::new(0),
            next_client: AtomicU64::new(0),
            queued: AtomicUsize::new(3),
            in_submit: AtomicUsize::new(0),
            session_backlog: AtomicUsize::new(5),
            total_weight: AtomicU64::new(0.0f64.to_bits()),
            window_p99_us: AtomicU64::new(0),
            rejected_total: AtomicU64::new(0),
            rejects_unfolded: AtomicU64::new(0),
            open: AtomicBool::new(true),
            gate: Mutex::new(()),
            gate_cv: Condvar::new(),
            window: Mutex::new(LatencyWindow::default()),
        };
        assert_eq!(shared.load(), 8);
    }
}

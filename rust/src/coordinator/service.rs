//! Serving-surface types and the one-shot experiment shim.
//!
//! The full ParM data path of Figure 4 lives in two sibling modules now:
//! [`crate::coordinator::scheme`] (the pluggable redundancy strategies)
//! and [`crate::coordinator::session`] (the `ServiceBuilder`/
//! `ServiceHandle` serving session). This module keeps the declarative
//! surface — [`Mode`], [`ServiceConfig`], [`ModelSet`], [`RunResult`] —
//! and [`Service::run`], the seed's one-shot open-loop experiment entry
//! point, now a thin compatibility shim: build a session, drive the
//! Poisson client through it, drain, shut down.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::hardware::Profile;
use crate::coordinator::encoder::Encoder;
use crate::coordinator::frontend::AdmissionPolicy;
use crate::coordinator::journal::Recorder;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::session::ServiceBuilder;
use crate::runtime::engine::Executable;
use crate::runtime::pool::Balancing;
use crate::tensor::Tensor;

/// Redundancy scheme under test (declarative form; [`Mode::scheme`] in
/// `coordinator::scheme` instantiates the strategy object).
#[derive(Clone, Debug)]
pub enum Mode {
    /// ParM with k data batches per coding group and r parity models.
    Parm { k: usize, encoders: Vec<Encoder> },
    /// No redundancy: just the m deployed instances.
    NoRedundancy,
    /// Same instance count as ParM, all serving the deployed model.
    EqualResources { k: usize },
    /// Replicate every batch to a pool of approximate (cheaper) models.
    ApproxBackup { k: usize },
    /// Replicate every batch `copies` times across the deployed pool.
    Replication { copies: usize },
    /// Adaptive rateless coding ([`crate::coordinator::adaptive`]): pools
    /// are provisioned for `r_max` parities per coding group, but the
    /// parity count actually dispatched is chosen at group-seal time in
    /// `[r_min, r_max]` from a learned straggler predictor whose
    /// observations decay with the given half-life.
    Rateless { k: usize, r_min: usize, r_max: usize, halflife: Duration },
    /// Cross-shard coding ([`crate::coordinator::cross_shard`]): coding
    /// groups stripe their k data batches over k *distinct* shards and
    /// send parities to a shared cross-shard pool, so a whole-shard
    /// fault costs each group at most one slot. Per-group r in
    /// `[r_min, r_max]` comes from a fleet-level straggler predictor
    /// with the given evidence half-life. Serve it through
    /// [`crate::coordinator::shards::CrossShardFrontend`] — a bare
    /// session cannot host it (groups span sessions), and
    /// `ServiceBuilder::build` rejects it with an error.
    CrossShard { k: usize, r_min: usize, r_max: usize, halflife: Duration },
}

impl Mode {
    /// Extra instances beyond m that this mode uses. (Kept as a pure
    /// function of the enum so config validation never has to build a
    /// scheme; `RedundancyScheme::extra_instances` must agree — pinned by
    /// a test in `coordinator::scheme`.)
    pub fn extra_instances(&self, m: usize) -> usize {
        match self {
            Mode::Parm { k, encoders } => (m + k - 1) / k * encoders.len().max(1),
            Mode::NoRedundancy => 0,
            Mode::EqualResources { k } | Mode::ApproxBackup { k } => (m + k - 1) / k,
            Mode::Replication { .. } => 0,
            // Provisioned for the ceiling: r_max parity pools.
            Mode::Rateless { k, r_max, .. } => (m + k - 1) / k * r_max,
            // Per *data shard* this mode adds nothing: the parity pool
            // is provisioned separately by the cross-shard tier
            // (ceil(shards*m/k) instances per r index).
            Mode::CrossShard { .. } => 0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Parm { .. } => "parm",
            Mode::NoRedundancy => "none",
            Mode::EqualResources { .. } => "equal-resources",
            Mode::ApproxBackup { .. } => "approx-backup",
            Mode::Replication { .. } => "replication",
            Mode::Rateless { .. } => "rateless",
            Mode::CrossShard { .. } => "cross-shard",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub mode: Mode,
    /// Number of deployed-model instances (the paper's m).
    pub m: usize,
    pub batch_size: usize,
    pub batch_timeout: Duration,
    /// Deadline after which a default prediction is returned; None = wait
    /// forever (the paper's latency runs measure true completion times).
    pub slo: Option<Duration>,
    pub profile: &'static Profile,
    /// Background shuffles to keep in flight (§5.1; Figure 13 varies it).
    pub shuffles: usize,
    /// Light co-located inference tenancy (§5.2.4, Figure 14).
    pub light_tenancy: bool,
    /// Multiplier on injected delays (time compression).
    pub time_scale: f64,
    /// Head-of-line delay per active flow as a fraction of mean service,
    /// sampled uniformly per query. See cluster::network.
    pub hol_range: (f64, f64),
    pub balancing: Balancing,
    pub seed: u64,
    /// Scheduled hard failures: (instance, start offset, duration;
    /// Duration::ZERO = permanent). Applied by the session's injector.
    pub fault_schedule: Vec<(usize, Duration, Duration)>,
    /// true (default): replay calibrated service times (parallel on any
    /// host); false: execute the engine per query (needs >= total-instances
    /// cores for faithful parallelism). See runtime::instance::Execution.
    pub modeled_execution: bool,
    /// Admission policy applied by the multi-client frontend
    /// ([`crate::coordinator::frontend`]) at `submit`. A bare
    /// `ServiceHandle` does not enforce it — single-consumer callers
    /// already control their own offered load.
    pub admission: AdmissionPolicy,
    /// Length of the live sliding-window metrics aggregator (see
    /// [`crate::coordinator::session::ServiceHandle::window_snapshot`]).
    pub metrics_window: Duration,
    /// Serving-path event journal ([`crate::coordinator::journal`]).
    /// Disabled by default; hand a live [`Recorder`] to capture every
    /// submit/dispatch/seal/complete/decode/fault/reconfig event for
    /// deterministic replay. Cloning the config clones the handle — the
    /// sharded tier re-tags per-shard clones so one journal records the
    /// whole fleet.
    pub recorder: Recorder,
    /// Fleet-wide metric registry ([`crate::telemetry::Registry`]). The
    /// session and everything stacked on it (frontend, scheme) publish
    /// counters/gauges through cloned handles of this registry; hand in
    /// one shared registry to aggregate a fleet (the sharded tier
    /// re-scopes per-shard clones with a `shard` label, mirroring the
    /// recorder). Defaults to a fresh private registry, so telemetry is
    /// always on and always consistent — exporting it is the caller's
    /// choice ([`crate::telemetry::Exporter`]).
    pub telemetry: crate::telemetry::Registry,
    /// Cadence at which the session folds its sliding window (and the
    /// scheme's operating point) into registry gauges from its pump
    /// loop. Snapshotting is O(window events); the default 250 ms
    /// matches the bench sampling cadence and costs well under 1% of a
    /// busy session's budget.
    pub telemetry_every: Duration,
}

impl ServiceConfig {
    pub fn defaults(mode: Mode, profile: &'static Profile) -> ServiceConfig {
        ServiceConfig {
            mode,
            m: profile.default_m,
            batch_size: 1,
            batch_timeout: Duration::from_millis(2),
            slo: None,
            profile,
            shuffles: 4,
            light_tenancy: false,
            time_scale: 1.0,
            hol_range: (2.0, 6.0),
            balancing: Balancing::SingleQueue,
            seed: 0xC0DE,
            fault_schedule: Vec::new(),
            modeled_execution: true,
            admission: AdmissionPolicy::Unbounded,
            metrics_window: Duration::from_secs(10),
            recorder: Recorder::disabled(),
            telemetry: crate::telemetry::Registry::new(),
            telemetry_every: Duration::from_millis(250),
        }
    }
}

/// Executables for the workload (loaded once, shared across configs).
/// Cloning is cheap — the executables themselves are `Arc`-shared; the
/// elastic tier keeps a clone so it can stamp out new shard sessions at
/// runtime.
#[derive(Clone)]
pub struct ModelSet {
    pub deployed: Arc<Executable>,
    /// Parity executables in r_index order (ParM only).
    pub parities: Vec<Arc<Executable>>,
    /// Approximate backup (ApproxBackup only).
    pub approx: Option<Arc<Executable>>,
}

/// Result of a service run / session.
pub struct RunResult {
    pub metrics: RunMetrics,
    pub mean_service: Duration,
    pub wall: Duration,
    pub dropped_jobs: u64,
    pub reconstructions: u64,
    /// Queries turned away by admission control (reject-vs-resolve split:
    /// `metrics.total()` resolved, `rejected` never entered the session).
    /// At-a-glance mirror of `metrics.rejected` — the session sets both
    /// from the same counter. Nonzero only when traffic arrived through a
    /// frontend with a bounding [`AdmissionPolicy`].
    pub rejected: u64,
}

impl RunResult {
    /// Merge per-shard run results into one fleet-wide record (the
    /// shutdown path of [`crate::coordinator::shards::ShardedFrontend`]).
    ///
    /// Counters add; latency summaries concatenate raw samples, so the
    /// merged percentiles are exact. `wall` is the slowest shard (the
    /// shards ran concurrently) and `mean_service` is weighted by each
    /// shard's resolved-query count.
    pub fn merged(parts: &[RunResult]) -> RunResult {
        let mut metrics = RunMetrics::default();
        let mut wall = Duration::ZERO;
        let mut dropped_jobs = 0u64;
        let mut reconstructions = 0u64;
        let mut rejected = 0u64;
        let mut svc_weighted = 0.0f64;
        let mut svc_weight = 0u64;
        for p in parts {
            metrics.merge(&p.metrics);
            wall = wall.max(p.wall);
            dropped_jobs += p.dropped_jobs;
            reconstructions += p.reconstructions;
            rejected += p.rejected;
            // Weight by resolved count, floored at 1 so an idle shard
            // still contributes its calibration instead of vanishing.
            let w = p.metrics.total().max(1);
            svc_weighted += p.mean_service.as_secs_f64() * w as f64;
            svc_weight += w;
        }
        let mean_service = if svc_weight == 0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(svc_weighted / svc_weight as f64)
        };
        RunResult { metrics, mean_service, wall, dropped_jobs, reconstructions, rejected }
    }
}

/// Measure the deployed model's uncontended mean service time.
pub fn measure_service(exe: &Executable, input: &Tensor, iters: usize) -> Duration {
    // Warmup.
    for _ in 0..3 {
        let _ = exe.run(input);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = exe.run(input);
    }
    t0.elapsed() / iters as u32
}

pub struct Service;

impl Service {
    /// Run an open-loop experiment: `n_queries` Poisson arrivals at `rate`
    /// qps, drawing query tensors cyclically from `queries`.
    ///
    /// Compatibility shim over the session API — equivalent to
    /// [`ServiceBuilder::build`] + [`crate::coordinator::session::ServiceHandle::run_open_loop`]
    /// + `drain` + `shutdown`. New code that wants to submit its own
    /// traffic should use the session API directly.
    pub fn run(
        cfg: &ServiceConfig,
        models: &ModelSet,
        queries: &[Tensor],
        n_queries: u64,
        rate: f64,
    ) -> anyhow::Result<RunResult> {
        let mut handle = ServiceBuilder::new(cfg.clone()).build(models, &queries[0])?;
        handle.run_open_loop(queries, n_queries, rate);
        let _ = handle.drain();
        Ok(handle.shutdown())
    }
}

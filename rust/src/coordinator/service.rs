//! The serving frontend: query intake, batching, dispatch, coding groups,
//! completion collection, decoding, SLO handling — the full ParM data
//! path of Figure 4, plus the paper's baselines in the same machinery.
//!
//! Threads:
//! - the caller's thread runs the open-loop Poisson generator (arrivals
//!   never wait for completions, as in the paper's client);
//! - one worker thread per model instance (deployed, parity, approx);
//! - one collector thread owns the [`GroupTracker`], resolves queries,
//!   applies the decode rule, and records latency.
//!
//! Baselines share every component except the redundancy scheme:
//! `NoRedundancy` (m instances), `EqualResources` (m + m/k deployed
//! instances, §5.1), `ApproxBackup` (replicate to m/k cheap models,
//! §5.2.6), `Replication` (full query replication, §2.2).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::cluster::hardware::Profile;
use crate::cluster::network::{Network, ShuffleGen};
use crate::cluster::tenancy::Tenancy;
use crate::coordinator::batcher::{Batcher, PendingQuery};
use crate::coordinator::coding::GroupTracker;
use crate::coordinator::encoder::Encoder;
use crate::coordinator::metrics::{Outcome, RunMetrics};
use crate::runtime::engine::Executable;
use crate::runtime::instance::{Completion, Execution, Job, JobKind, WorkerEnv};
use crate::runtime::pool::{Balancing, Pool};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Redundancy scheme under test.
#[derive(Clone, Debug)]
pub enum Mode {
    /// ParM with k data batches per coding group and r parity models.
    Parm { k: usize, encoders: Vec<Encoder> },
    /// No redundancy: just the m deployed instances.
    NoRedundancy,
    /// Same instance count as ParM, all serving the deployed model.
    EqualResources { k: usize },
    /// Replicate every batch to a pool of approximate (cheaper) models.
    ApproxBackup { k: usize },
    /// Replicate every batch `copies` times across the deployed pool.
    Replication { copies: usize },
}

impl Mode {
    /// Extra instances beyond m that this mode uses.
    pub fn extra_instances(&self, m: usize) -> usize {
        match self {
            Mode::Parm { k, encoders } => {
                (m + k - 1) / k * encoders.len().max(1)
            }
            Mode::NoRedundancy => 0,
            Mode::EqualResources { k } | Mode::ApproxBackup { k } => (m + k - 1) / k,
            Mode::Replication { .. } => 0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Parm { .. } => "parm",
            Mode::NoRedundancy => "none",
            Mode::EqualResources { .. } => "equal-resources",
            Mode::ApproxBackup { .. } => "approx-backup",
            Mode::Replication { .. } => "replication",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub mode: Mode,
    /// Number of deployed-model instances (the paper's m).
    pub m: usize,
    pub batch_size: usize,
    pub batch_timeout: Duration,
    /// Deadline after which a default prediction is returned; None = wait
    /// forever (the paper's latency runs measure true completion times).
    pub slo: Option<Duration>,
    pub profile: &'static Profile,
    /// Background shuffles to keep in flight (§5.1; Figure 13 varies it).
    pub shuffles: usize,
    /// Light co-located inference tenancy (§5.2.4, Figure 14).
    pub light_tenancy: bool,
    /// Multiplier on injected delays (time compression).
    pub time_scale: f64,
    /// Head-of-line delay per active flow as a fraction of mean service,
    /// sampled uniformly per query. See cluster::network.
    pub hol_range: (f64, f64),
    pub balancing: Balancing,
    pub seed: u64,
    /// Scheduled hard failures: (instance, start offset, duration;
    /// Duration::ZERO = permanent). Applied by a scheduler thread.
    pub fault_schedule: Vec<(usize, Duration, Duration)>,
    /// true (default): replay calibrated service times (parallel on any
    /// host); false: execute PJRT per query (needs >= total-instances
    /// cores for faithful parallelism). See runtime::instance::Execution.
    pub modeled_execution: bool,
}

impl ServiceConfig {
    pub fn defaults(mode: Mode, profile: &'static Profile) -> ServiceConfig {
        ServiceConfig {
            mode,
            m: profile.default_m,
            batch_size: 1,
            batch_timeout: Duration::from_millis(2),
            slo: None,
            profile,
            shuffles: 4,
            light_tenancy: false,
            time_scale: 1.0,
            hol_range: (2.0, 6.0),
            balancing: Balancing::SingleQueue,
            seed: 0xC0DE,
            fault_schedule: Vec::new(),
            modeled_execution: true,
        }
    }
}

/// Executables for the workload (loaded once, shared across configs).
pub struct ModelSet {
    pub deployed: Arc<Executable>,
    /// Parity executables in r_index order (ParM only).
    pub parities: Vec<Arc<Executable>>,
    /// Approximate backup (ApproxBackup only).
    pub approx: Option<Arc<Executable>>,
}

/// Result of a service run.
pub struct RunResult {
    pub metrics: RunMetrics,
    pub mean_service: Duration,
    pub wall: Duration,
    pub dropped_jobs: u64,
    pub reconstructions: u64,
}

enum Event {
    Register { group: u64, query_ids: Vec<Vec<u64>> },
    Arrived { query_ids: Vec<u64>, at: Instant },
    Done(Completion),
    GeneratorDone { total_queries: u64 },
}

/// Measure the deployed model's uncontended mean service time.
pub fn measure_service(exe: &Executable, input: &Tensor, iters: usize) -> Duration {
    // Warmup.
    for _ in 0..3 {
        let _ = exe.run(input);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = exe.run(input);
    }
    t0.elapsed() / iters as u32
}

pub struct Service;

impl Service {
    /// Run an open-loop experiment: `n_queries` Poisson arrivals at `rate`
    /// qps, drawing query tensors cyclically from `queries`.
    pub fn run(
        cfg: &ServiceConfig,
        models: &ModelSet,
        queries: &[Tensor],
        n_queries: u64,
        rate: f64,
    ) -> anyhow::Result<RunResult> {
        let t_run0 = Instant::now();
        let mut rng = Pcg64::new(cfg.seed);

        // ---- cluster substrate ----
        let extra = cfg.mode.extra_instances(cfg.m);
        let total_instances = cfg.m + extra;
        let network = Network::new(total_instances, cfg.profile);
        let faults = FaultPlan::new(total_instances);
        let sample = Tensor::batch(
            &std::iter::repeat(queries[0].clone())
                .take(cfg.batch_size)
                .collect::<Vec<_>>(),
        )?;
        // Per-pool execution mode: calibrate a service-time model from the
        // real executable, or run real PJRT per query (see Execution docs).
        let make_execution = |exe: &Arc<Executable>| -> anyhow::Result<Execution> {
            if cfg.modeled_execution {
                let model = crate::runtime::instance::ServiceModel::measure(exe, &sample, 60)
                    .map_err(|e| anyhow::anyhow!("calibration failed: {e}"))?;
                Ok(Execution::Modeled(Arc::new(model)))
            } else {
                Ok(Execution::Real)
            }
        };
        let deployed_execution = make_execution(&models.deployed)?;
        let mean_service = match &deployed_execution {
            Execution::Modeled(m) => m.mean(),
            Execution::Real => measure_service(&models.deployed, &sample, 10),
        };
        let tenancy = if cfg.light_tenancy {
            Tenancy::light(total_instances, mean_service, &mut rng)
        } else {
            Tenancy::none()
        };
        let env = Arc::new(WorkerEnv {
            profile: cfg.profile,
            network: network.clone(),
            tenancy,
            faults: faults.clone(),
            time_scale: cfg.time_scale,
            hol_range: cfg.hol_range,
            mean_service,
        });

        let shuffles = if cfg.shuffles > 0 {
            Some(ShuffleGen::start(
                network.clone(),
                cfg.shuffles,
                cfg.time_scale,
                rng.next_u64(),
            ))
        } else {
            None
        };

        // Scheduled hard failures (failure-injection experiments/tests).
        let fault_thread = if !cfg.fault_schedule.is_empty() {
            let plan = faults.clone();
            let schedule = cfg.fault_schedule.clone();
            Some(std::thread::spawn(move || {
                let start = Instant::now();
                let mut pending = schedule;
                pending.sort_by_key(|&(_, at, _)| at);
                for (inst, at, dur) in pending {
                    let now = start.elapsed();
                    if at > now {
                        std::thread::sleep(at - now);
                    }
                    if dur.is_zero() {
                        plan.kill(inst);
                        log::info!("fault: instance {inst} killed");
                    } else {
                        plan.fail_for(inst, dur);
                        log::info!("fault: instance {inst} down for {dur:?}");
                    }
                }
            }))
        } else {
            None
        };

        // ---- pools ----
        let (done_tx, done_rx) = mpsc::channel::<Event>();
        let comp_tx = {
            let tx = done_tx.clone();
            move |c: Completion| {
                let _ = tx.send(Event::Done(c));
            }
        };
        // Adapter: workers send Completion over an mpsc Sender<Completion>;
        // wrap via a relay thread-free trick: give workers their own channel
        // and forward. Simpler: a dedicated Sender<Completion> relay thread.
        let (raw_tx, raw_rx) = mpsc::channel::<Completion>();
        let relay = std::thread::spawn(move || {
            while let Ok(c) = raw_rx.recv() {
                comp_tx(c);
            }
        });

        let deployed_ids: Vec<usize> = match &cfg.mode {
            Mode::EqualResources { .. } => (0..total_instances).collect(),
            _ => (0..cfg.m).collect(),
        };
        let deployed_pool = Pool::spawn(
            "deployed",
            models.deployed.clone(),
            deployed_execution.clone(),
            deployed_ids,
            cfg.balancing,
            raw_tx.clone(),
            env.clone(),
            rng.next_u64(),
        );

        let (parity_pools, encoders): (Vec<Pool>, Vec<Encoder>) = match &cfg.mode {
            Mode::Parm { k, encoders } => {
                let per = (cfg.m + k - 1) / k;
                let mut pools = Vec::new();
                for (ri, _) in encoders.iter().enumerate() {
                    let ids: Vec<usize> =
                        (cfg.m + ri * per..cfg.m + (ri + 1) * per).collect();
                    pools.push(Pool::spawn(
                        &format!("parity{ri}"),
                        models.parities[ri].clone(),
                        make_execution(&models.parities[ri])?,
                        ids,
                        cfg.balancing,
                        raw_tx.clone(),
                        env.clone(),
                        rng.next_u64(),
                    ));
                }
                (pools, encoders.clone())
            }
            _ => (Vec::new(), Vec::new()),
        };

        let approx_pool = match &cfg.mode {
            Mode::ApproxBackup { k } => {
                let per = (cfg.m + k - 1) / k;
                let ids: Vec<usize> = (cfg.m..cfg.m + per).collect();
                Some(Pool::spawn(
                    "approx",
                    models
                        .approx
                        .clone()
                        .ok_or_else(|| anyhow::anyhow!("ApproxBackup needs models.approx"))?,
                    make_execution(models.approx.as_ref().unwrap())?,
                    ids,
                    cfg.balancing,
                    raw_tx.clone(),
                    env.clone(),
                    rng.next_u64(),
                ))
            }
            _ => None,
        };
        drop(raw_tx);

        // ---- collector ----
        let k_for_tracker = match &cfg.mode {
            Mode::Parm { k, .. } => *k,
            _ => 0,
        };
        let collector_cfg = CollectorCfg {
            k: k_for_tracker,
            encoders: encoders.clone(),
            slo: cfg.slo,
        };
        let collector =
            std::thread::spawn(move || collector_loop(done_rx, collector_cfg));

        // ---- open-loop generation ----
        let start = Instant::now();
        let mut batcher = Batcher::new(cfg.batch_size, cfg.batch_timeout);
        let mut next_arrival = 0.0f64;
        let mut group_accum: Vec<(Vec<u64>, Tensor)> = Vec::new();
        let mut group_id = 0u64;
        let dispatch_batch = |mut sealed: crate::coordinator::batcher::SealedBatch,
                                  group_accum: &mut Vec<(Vec<u64>, Tensor)>,
                                  group_id: &mut u64| {
            // Executables are compiled for a fixed batch size: pad partial
            // batches (timeout / shutdown flushes) by repeating the last
            // sample. Padded rows' outputs are never routed to a query id,
            // and padding keeps data/parity tensor shapes aligned for the
            // decoder.
            if sealed.input.shape()[0] < cfg.batch_size {
                let mut rows = sealed.input.unbatch();
                while rows.len() < cfg.batch_size {
                    rows.push(rows.last().unwrap().clone());
                }
                sealed.input = Tensor::batch(&rows).expect("uniform rows");
            }
            let slot = group_accum.len();
            let gid = *group_id;
            let job = Job {
                kind: if matches!(cfg.mode, Mode::Parm { .. }) {
                    JobKind::Data { group: gid, slot }
                } else {
                    JobKind::Replica { group: gid, slot: 0 }
                },
                input: sealed.input.clone(),
                query_ids: sealed.query_ids.clone(),
                dispatched_at: Instant::now(),
            };
            match &cfg.mode {
                Mode::Replication { copies } => {
                    for c in 0..*copies {
                        deployed_pool.dispatch(Job {
                            kind: JobKind::Replica { group: gid, slot: c },
                            input: sealed.input.clone(),
                            query_ids: sealed.query_ids.clone(),
                            dispatched_at: Instant::now(),
                        });
                    }
                    *group_id += 1;
                }
                Mode::ApproxBackup { .. } => {
                    deployed_pool.dispatch(job);
                    if let Some(ap) = &approx_pool {
                        ap.dispatch(Job {
                            kind: JobKind::Replica { group: gid, slot: 1 },
                            input: sealed.input.clone(),
                            query_ids: sealed.query_ids.clone(),
                            dispatched_at: Instant::now(),
                        });
                    }
                    *group_id += 1;
                }
                Mode::Parm { k, .. } => {
                    deployed_pool.dispatch(job);
                    group_accum.push((sealed.query_ids.clone(), sealed.input));
                    if group_accum.len() == *k {
                        // Seal the coding group: register, encode, dispatch.
                        let ids: Vec<Vec<u64>> =
                            group_accum.iter().map(|(i, _)| i.clone()).collect();
                        let _ = done_tx.send(Event::Register {
                            group: gid,
                            query_ids: ids,
                        });
                        let inputs: Vec<&Tensor> =
                            group_accum.iter().map(|(_, t)| t).collect();
                        for (ri, enc) in encoders.iter().enumerate() {
                            match enc.encode_batches(&inputs) {
                                Ok(parity) => parity_pools[ri].dispatch(Job {
                                    kind: JobKind::Parity { group: gid, r_index: ri },
                                    input: parity,
                                    query_ids: Vec::new(),
                                    dispatched_at: Instant::now(),
                                }),
                                Err(e) => log::error!("encode failed: {e}"),
                            }
                        }
                        group_accum.clear();
                        *group_id += 1;
                    }
                }
                _ => {
                    deployed_pool.dispatch(job);
                    *group_id += 1;
                }
            }
        };

        let mut qid = 0u64;
        while qid < n_queries {
            // Pace the open loop.
            next_arrival += rng.exponential(rate);
            let due = start + Duration::from_secs_f64(next_arrival);
            let now = Instant::now();
            if due > now {
                // Honor batch timeouts while idle.
                if let Some(deadline) = batcher.next_deadline() {
                    if deadline < due {
                        let wait = deadline.saturating_duration_since(now);
                        std::thread::sleep(wait);
                        if let Some(sealed) = batcher.flush_due(Instant::now()) {
                            dispatch_batch(sealed, &mut group_accum, &mut group_id);
                        }
                    }
                }
                let now2 = Instant::now();
                if due > now2 {
                    std::thread::sleep(due - now2);
                }
            }
            let arrived = Instant::now();
            let input = queries[(qid as usize) % queries.len()].clone();
            let _ = done_tx.send(Event::Arrived { query_ids: vec![qid], at: arrived });
            if let Some(sealed) = batcher.offer(PendingQuery { id: qid, input, arrived }) {
                dispatch_batch(sealed, &mut group_accum, &mut group_id);
            }
            qid += 1;
        }
        if let Some(sealed) = batcher.flush_all() {
            dispatch_batch(sealed, &mut group_accum, &mut group_id);
        }
        // Incomplete trailing coding group: its batches were already
        // dispatched to deployed instances; they resolve natively.
        let _ = done_tx.send(Event::GeneratorDone { total_queries: n_queries });
        drop(done_tx);

        // ---- wait for completion ----
        let (metrics, reconstructions) = collector.join().expect("collector panicked");
        if let Some(s) = shuffles {
            s.stop();
        }
        if let Some(t) = fault_thread {
            let _ = t.join();
        }
        deployed_pool.shutdown();
        for p in parity_pools {
            p.shutdown();
        }
        if let Some(p) = approx_pool {
            p.shutdown();
        }
        let _ = relay.join();

        Ok(RunResult {
            metrics,
            mean_service,
            wall: t_run0.elapsed(),
            dropped_jobs: crate::runtime::instance::DROPPED_JOBS.load(Ordering::Relaxed),
            reconstructions,
        })
    }
}

struct CollectorCfg {
    k: usize,
    encoders: Vec<Encoder>,
    slo: Option<Duration>,
}

fn collector_loop(rx: mpsc::Receiver<Event>, cfg: CollectorCfg) -> (RunMetrics, u64) {
    let mut metrics = RunMetrics::default();
    let mut tracker = if cfg.k > 0 {
        Some(GroupTracker::new(cfg.k, &cfg.encoders))
    } else {
        None
    };
    // query id -> arrival (pending only).
    let mut pending: HashMap<u64, Instant> = HashMap::new();
    // Completions that raced ahead of their group registration.
    let mut orphans: HashMap<u64, Vec<Completion>> = HashMap::new();
    // Groups ever registered (distinguishes "evicted" from "not yet
    // registered": completions for the former are safe no-ops in the
    // tracker, the latter must be buffered).
    let mut registered: std::collections::HashSet<u64> = std::collections::HashSet::new();
    let mut expected: Option<u64> = None;
    let mut resolved_count = 0u64;
    // Replica de-dup: group id -> resolved?
    let mut replica_done: HashMap<u64, bool> = HashMap::new();

    let resolve =
        |metrics: &mut RunMetrics,
         pending: &mut HashMap<u64, Instant>,
         ids: &[u64],
         at: Instant,
         outcome: Outcome,
         resolved_count: &mut u64| {
            for id in ids {
                if let Some(arrived) = pending.remove(id) {
                    metrics.record(arrived, at, outcome);
                    *resolved_count += 1;
                }
            }
        };

    loop {
        // SLO sweep granularity.
        let ev = match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(ev) => Some(ev),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        if let Some(ev) = ev {
            match ev {
                Event::Arrived { query_ids, at } => {
                    for id in query_ids {
                        pending.insert(id, at);
                    }
                }
                Event::Register { group, query_ids } => {
                    if let Some(tr) = tracker.as_mut() {
                        tr.register(group, query_ids);
                        registered.insert(group);
                        if let Some(cs) = orphans.remove(&group) {
                            for c in cs {
                                apply_completion(
                                    tr,
                                    c,
                                    &mut metrics,
                                    &mut pending,
                                    &mut resolved_count,
                                );
                            }
                        }
                    }
                }
                Event::Done(c) => match c.kind {
                    JobKind::Data { group, .. } | JobKind::Parity { group, .. } => {
                        // §3.1: predictions returned by model instances go
                        // straight back to clients, independent of coding
                        // group state.
                        if matches!(c.kind, JobKind::Data { .. }) {
                            resolve(
                                &mut metrics,
                                &mut pending,
                                &c.query_ids,
                                c.finished_at,
                                Outcome::Native,
                                &mut resolved_count,
                            );
                        }
                        if let Some(tr) = tracker.as_mut() {
                            if registered.contains(&group) {
                                apply_completion(
                                    tr,
                                    c,
                                    &mut metrics,
                                    &mut pending,
                                    &mut resolved_count,
                                );
                            } else {
                                orphans.entry(group).or_default().push(c);
                            }
                        }
                    }
                    JobKind::Replica { group, .. } => {
                        let done = replica_done.entry(group).or_insert(false);
                        let outcome = if c.instance_is_backup() {
                            Outcome::Replica
                        } else {
                            Outcome::Native
                        };
                        if !*done {
                            *done = true;
                            resolve(
                                &mut metrics,
                                &mut pending,
                                &c.query_ids,
                                c.finished_at,
                                outcome,
                                &mut resolved_count,
                            );
                        }
                    }
                    JobKind::Background => {}
                },
                Event::GeneratorDone { total_queries } => {
                    expected = Some(total_queries);
                }
            }
        }

        // SLO expirations.
        if let Some(slo) = cfg.slo {
            let now = Instant::now();
            let expired: Vec<u64> = pending
                .iter()
                .filter(|(_, &t)| now.duration_since(t) >= slo)
                .map(|(&id, _)| id)
                .collect();
            for id in expired {
                pending.remove(&id);
                metrics.record_default(slo);
                resolved_count += 1;
            }
        }

        if let Some(total) = expected {
            if resolved_count >= total {
                break;
            }
        }
    }
    let recon = tracker.map(|t| t.reconstructions).unwrap_or(0);
    (metrics, recon)
}

impl Completion {
    fn instance_is_backup(&self) -> bool {
        matches!(self.kind, JobKind::Replica { slot, .. } if slot > 0)
    }
}

fn apply_completion(
    tr: &mut GroupTracker,
    c: Completion,
    metrics: &mut RunMetrics,
    pending: &mut HashMap<u64, Instant>,
    resolved_count: &mut u64,
) {
    let res = match c.kind {
        JobKind::Data { group, slot } => tr.on_data(group, slot, c.output),
        JobKind::Parity { group, r_index } => tr.on_parity(group, r_index, c.output),
        _ => return,
    };
    for (_slot, ids, _out, reconstructed) in res.resolved {
        let outcome = if reconstructed {
            Outcome::Reconstructed
        } else {
            Outcome::Native
        };
        for id in ids {
            if let Some(arrived) = pending.remove(&id) {
                metrics.record(arrived, c.finished_at, outcome);
                *resolved_count += 1;
            }
        }
    }
}

//! Query batching policy (§2.1, §5.2.3).
//!
//! Most latency-sensitive deployments serve batch size 1; GPU-friendly
//! deployments batch a few queries with a short timeout. The batcher is a
//! pure state machine: `offer()` queries, receive sealed batches when the
//! size threshold is met; `flush_due()` seals a partial batch whose oldest
//! query has waited past the timeout.

use std::time::{Duration, Instant};

use crate::tensor::Tensor;

#[derive(Debug)]
pub struct PendingQuery {
    pub id: u64,
    pub input: Tensor,
    pub arrived: Instant,
}

#[derive(Debug)]
pub struct SealedBatch {
    pub query_ids: Vec<u64>,
    pub input: Tensor,
    /// Arrival of the oldest member (latency accounting starts here).
    pub oldest_arrival: Instant,
}

pub struct Batcher {
    batch_size: usize,
    timeout: Duration,
    pending: Vec<PendingQuery>,
}

impl Batcher {
    pub fn new(batch_size: usize, timeout: Duration) -> Batcher {
        assert!(batch_size >= 1);
        Batcher { batch_size, timeout, pending: Vec::new() }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Add a query; returns a sealed batch when full.
    pub fn offer(&mut self, q: PendingQuery) -> Option<SealedBatch> {
        self.pending.push(q);
        if self.pending.len() >= self.batch_size {
            return Some(self.seal());
        }
        None
    }

    /// Seal a partial batch if the oldest query exceeded the timeout.
    pub fn flush_due(&mut self, now: Instant) -> Option<SealedBatch> {
        if self.pending.is_empty() {
            return None;
        }
        if now.duration_since(self.pending[0].arrived) >= self.timeout {
            return Some(self.seal());
        }
        None
    }

    /// Force-seal whatever is pending (shutdown path).
    pub fn flush_all(&mut self) -> Option<SealedBatch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.seal())
        }
    }

    /// Next deadline at which `flush_due` could fire.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.first().map(|q| q.arrived + self.timeout)
    }

    fn seal(&mut self) -> SealedBatch {
        let taken: Vec<PendingQuery> =
            self.pending.drain(..self.pending.len().min(self.batch_size)).collect();
        let oldest = taken.iter().map(|q| q.arrived).min().unwrap();
        let ids = taken.iter().map(|q| q.id).collect();
        let tensors: Vec<Tensor> = taken.into_iter().map(|q| q.input).collect();
        SealedBatch {
            query_ids: ids,
            input: Tensor::batch(&tensors).expect("uniform query shapes"),
            oldest_arrival: oldest,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u64) -> PendingQuery {
        PendingQuery { id, input: Tensor::filled(vec![2], id as f32), arrived: Instant::now() }
    }

    #[test]
    fn batch_size_one_seals_immediately() {
        let mut b = Batcher::new(1, Duration::from_millis(10));
        let sealed = b.offer(q(1)).expect("immediate seal");
        assert_eq!(sealed.query_ids, vec![1]);
        assert_eq!(sealed.input.shape(), &[1, 2]);
    }

    #[test]
    fn accumulates_to_batch_size() {
        let mut b = Batcher::new(3, Duration::from_secs(1));
        assert!(b.offer(q(1)).is_none());
        assert!(b.offer(q(2)).is_none());
        let sealed = b.offer(q(3)).unwrap();
        assert_eq!(sealed.query_ids, vec![1, 2, 3]);
        assert_eq!(sealed.input.shape(), &[3, 2]);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(4, Duration::from_millis(5));
        b.offer(q(1));
        assert!(b.flush_due(Instant::now()).is_none(), "not due yet");
        let later = Instant::now() + Duration::from_millis(6);
        let sealed = b.flush_due(later).expect("due");
        assert_eq!(sealed.query_ids, vec![1]);
    }

    #[test]
    fn flush_all_on_shutdown() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        b.offer(q(1));
        b.offer(q(2));
        let sealed = b.flush_all().unwrap();
        assert_eq!(sealed.query_ids, vec![1, 2]);
        assert!(b.flush_all().is_none());
    }

    #[test]
    fn oldest_arrival_tracked() {
        let mut b = Batcher::new(2, Duration::from_secs(1));
        let first = q(1);
        let t0 = first.arrived;
        b.offer(first);
        std::thread::sleep(Duration::from_millis(2));
        let sealed = b.offer(q(2)).unwrap();
        assert_eq!(sealed.oldest_arrival, t0);
    }
}

//! Binary, delta-encoded serving-path event journal — record any run,
//! replay it deterministically, assert it byte-identical.
//!
//! Every serving surface in the stack can carry a [`Recorder`]: the
//! session records submits, dispatches and resolutions; the schemes
//! record group seals and decodes; the fault plan records every injected
//! failure (whatever injected it — a `FaultScript`, the scheduled
//! injector, or a manual `kill_instance`); the sharded tier records
//! routing; the control plane records reconfigurations. The result is a
//! single causally-ordered event log of the run — the debugging substrate
//! ROADMAP item 3 calls for: a failing chaos trial is no longer a
//! one-off, it is a file.
//!
//! ## Format
//!
//! A journal is `b"PMJL"` + a version byte, then a flat sequence of
//! records:
//!
//! ```text
//! [varint delta_ts_us] [varint shard] [u8 kind] [payload...]
//! ```
//!
//! - `delta_ts_us`: microseconds since the previous record (the first
//!   record's delta is since the recorder's epoch). Timestamps are read
//!   under the writer lock, so deltas are never negative and the log is
//!   totally ordered even when many shard sessions record concurrently.
//! - `shard`: which fault domain emitted the event (0 for a bare
//!   session; the sharded tier tags each shard's recorder clone).
//! - `kind` + payload: one of [`Event`]'s variants. Integers are
//!   minimal-length LEB128 varints, strings are length-prefixed UTF-8 —
//!   every event has exactly one encoding, which is what makes
//!   byte-identity a meaningful assertion.
//!
//! The log opens with exactly one [`Event::Start`] (the seed and mode —
//! the seeding contract: a journal names the seed that produced it) and
//! closes with exactly one [`Event::End`] carrying the run's resolved
//! totals, written by [`Recorder::finish`].
//!
//! ## Replay
//!
//! Live runs are threaded and racy: worker completions interleave
//! differently run to run, so re-running the *simulation* cannot
//! reproduce a journal bit-for-bit. [`replay`] therefore re-executes the
//! **event stream** through a deterministic interpreter: it walks every
//! record, enforces the serving path's causal invariants (no duplicate
//! submit, no resolution without a submit, exactly-once termination),
//! recomputes the outcome totals from the `Complete`/`Reject` events,
//! checks them against the recorded `End` footer, and re-encodes the
//! stream. Because the codec is canonical, the re-encoded journal is
//! byte-identical to the input — replaying a journal twice yields the
//! same bytes, the property the regression suite and the CI replay lane
//! pin. A journal that fails any invariant is a recorder bug, and
//! `replay` says so instead of round-tripping garbage.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::Outcome;
use crate::coordinator::service::RunResult;
use crate::util::rng::fnv1a;
use crate::util::sync::LockExt;

/// Journal magic: "PMJL" (Parity-Models JournaL).
pub const MAGIC: [u8; 4] = *b"PMJL";
/// Format version (bump on any codec change).
pub const VERSION: u8 = 1;

/// One serving-path event. See the module docs for where each kind is
/// recorded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// Run header: the seed and mode that produced this journal, and how
    /// many shards the run started with.
    Start { seed: u64, mode: String, shards: u64 },
    /// A query entered a session (`ServiceHandle::submit`). `qid` is the
    /// session-local query id; the (shard, qid) pair is unique run-wide.
    Submit { qid: u64 },
    /// The sharded router sent a query to a shard. `qid` is the
    /// shard-tagged id the client observed.
    Route { qid: u64, shard: u64 },
    /// A job left the session for an instance pool. `kind` is a
    /// [`JobClass`] byte; `detail` is the slot (data/replica) or r_index
    /// (parity); `queries` is the number of query ids riding the job.
    Dispatch { group: u64, kind: u8, detail: u64, queries: u64 },
    /// A coding group sealed with k data slots and r parities.
    Seal { group: u64, k: u64, r: u64 },
    /// A query resolved. `outcome` is an [`Outcome`] byte
    /// ([`outcome_byte`]); latency as observed by the session.
    Complete { qid: u64, outcome: u8, latency_us: u64 },
    /// A decoder reconstructed `slot` of coding group `group`.
    Decode { group: u64, slot: u64 },
    /// A fault-plan mutation. `kind` is a [`FaultKind`] byte; `arg` is
    /// the window in microseconds for `FailFor`, the phantom-flow count
    /// for `Degrade`, 0 otherwise.
    Fault { instance: u64, kind: u8, arg: u64 },
    /// A control-plane reconfiguration. `verb` is a [`ReconfigVerb`]
    /// byte; `shard` the target (0 for fleet-wide verbs).
    Reconfig { verb: u8, shard: u64 },
    /// Admission control turned away `n` queries.
    Reject { n: u64 },
    /// Run footer: the resolved totals the live run reported.
    End {
        native: u64,
        reconstructed: u64,
        replica: u64,
        defaulted: u64,
        rejected: u64,
        reconstructions: u64,
        wall_us: u64,
    },
}

/// Job classification for [`Event::Dispatch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum JobClass {
    Data = 0,
    Parity = 1,
    Replica = 2,
    Background = 3,
}

/// Fault classification for [`Event::Fault`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FaultKind {
    /// Bounded brown-out (`FaultPlan::fail_for`); `arg` = window in us.
    FailFor = 0,
    /// Permanent kill (`FaultPlan::kill`).
    Kill = 1,
    /// Failure cleared (`FaultPlan::heal`).
    Heal = 2,
    /// Link degraded (`Network::degrade_link`); `arg` = phantom flows.
    Degrade = 3,
    /// Link restored (`Network::restore_link`).
    Restore = 4,
}

/// Reconfiguration verbs for [`Event::Reconfig`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ReconfigVerb {
    AddShard = 0,
    RemoveShard = 1,
    Drain = 2,
    Restore = 3,
    SetAdmission = 4,
}

/// Canonical byte for an [`Outcome`] (stable across versions).
pub fn outcome_byte(o: Outcome) -> u8 {
    match o {
        Outcome::Native => 0,
        Outcome::Reconstructed => 1,
        Outcome::Replica => 2,
        Outcome::Default => 3,
    }
}

/// Inverse of [`outcome_byte`].
pub fn byte_outcome(b: u8) -> Option<Outcome> {
    Some(match b {
        0 => Outcome::Native,
        1 => Outcome::Reconstructed,
        2 => Outcome::Replica,
        3 => Outcome::Default,
        _ => return None,
    })
}

const K_START: u8 = 0;
const K_SUBMIT: u8 = 1;
const K_ROUTE: u8 = 2;
const K_DISPATCH: u8 = 3;
const K_SEAL: u8 = 4;
const K_COMPLETE: u8 = 5;
const K_DECODE: u8 = 6;
const K_FAULT: u8 = 7;
const K_RECONFIG: u8 = 8;
const K_REJECT: u8 = 9;
const K_END: u8 = 10;

// ---------------------------------------------------------------- codec

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            return;
        }
        buf.push(b | 0x80);
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

/// Decode errors. `NonCanonical` means the bytes parse but are not the
/// encoding this writer produces (over-long varint, trailing garbage) —
/// a journal we did not write.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum JournalError {
    #[error("journal io: {0}")]
    Io(String),
    #[error("bad magic (not a PMJL journal)")]
    BadMagic,
    #[error("unsupported journal version {0}")]
    BadVersion(u8),
    #[error("truncated journal at byte {0}")]
    Truncated(usize),
    #[error("non-canonical encoding at byte {0}")]
    NonCanonical(usize),
    #[error("unknown event kind {kind} at byte {at}")]
    UnknownKind { kind: u8, at: usize },
    #[error("journal invariant violated at record {at}: {msg}")]
    Invariant {
        /// Index of the first record that violated the invariant (the
        /// event index `parm replay` reports).
        at: u64,
        msg: String,
    },
}

struct Cursor<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn u8(&mut self) -> Result<u8, JournalError> {
        let b = *self.bytes.get(self.at).ok_or(JournalError::Truncated(self.at))?;
        self.at += 1;
        Ok(b)
    }

    fn varint(&mut self) -> Result<u64, JournalError> {
        let start = self.at;
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift == 63 && b > 1 {
                return Err(JournalError::NonCanonical(start));
            }
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                // Reject over-long encodings (a continuation byte that
                // contributed nothing): one value, one encoding.
                if b == 0 && shift != 0 {
                    return Err(JournalError::NonCanonical(start));
                }
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(JournalError::NonCanonical(start));
            }
        }
    }

    fn str(&mut self) -> Result<String, JournalError> {
        let len = self.varint()? as usize;
        let end = self.at.checked_add(len).ok_or(JournalError::Truncated(self.at))?;
        if end > self.bytes.len() {
            return Err(JournalError::Truncated(self.at));
        }
        let s = std::str::from_utf8(&self.bytes[self.at..end])
            .map_err(|_| JournalError::NonCanonical(self.at))?
            .to_string();
        self.at = end;
        Ok(s)
    }

    fn done(&self) -> bool {
        self.at >= self.bytes.len()
    }
}

/// An event with its decoded timing context.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TimedEvent {
    /// Absolute microseconds since the recorder epoch.
    pub ts_us: u64,
    /// Shard tag of the recorder clone that emitted it.
    pub shard: u64,
    pub event: Event,
}

fn encode_record(buf: &mut Vec<u8>, delta_us: u64, shard: u64, ev: &Event) {
    put_varint(buf, delta_us);
    put_varint(buf, shard);
    match ev {
        Event::Start { seed, mode, shards } => {
            buf.push(K_START);
            put_varint(buf, *seed);
            put_str(buf, mode);
            put_varint(buf, *shards);
        }
        Event::Submit { qid } => {
            buf.push(K_SUBMIT);
            put_varint(buf, *qid);
        }
        Event::Route { qid, shard } => {
            buf.push(K_ROUTE);
            put_varint(buf, *qid);
            put_varint(buf, *shard);
        }
        Event::Dispatch { group, kind, detail, queries } => {
            buf.push(K_DISPATCH);
            put_varint(buf, *group);
            buf.push(*kind);
            put_varint(buf, *detail);
            put_varint(buf, *queries);
        }
        Event::Seal { group, k, r } => {
            buf.push(K_SEAL);
            put_varint(buf, *group);
            put_varint(buf, *k);
            put_varint(buf, *r);
        }
        Event::Complete { qid, outcome, latency_us } => {
            buf.push(K_COMPLETE);
            put_varint(buf, *qid);
            buf.push(*outcome);
            put_varint(buf, *latency_us);
        }
        Event::Decode { group, slot } => {
            buf.push(K_DECODE);
            put_varint(buf, *group);
            put_varint(buf, *slot);
        }
        Event::Fault { instance, kind, arg } => {
            buf.push(K_FAULT);
            put_varint(buf, *instance);
            buf.push(*kind);
            put_varint(buf, *arg);
        }
        Event::Reconfig { verb, shard } => {
            buf.push(K_RECONFIG);
            buf.push(*verb);
            put_varint(buf, *shard);
        }
        Event::Reject { n } => {
            buf.push(K_REJECT);
            put_varint(buf, *n);
        }
        Event::End {
            native,
            reconstructed,
            replica,
            defaulted,
            rejected,
            reconstructions,
            wall_us,
        } => {
            buf.push(K_END);
            put_varint(buf, *native);
            put_varint(buf, *reconstructed);
            put_varint(buf, *replica);
            put_varint(buf, *defaulted);
            put_varint(buf, *rejected);
            put_varint(buf, *reconstructions);
            put_varint(buf, *wall_us);
        }
    }
}

fn decode_event(cur: &mut Cursor) -> Result<Event, JournalError> {
    let kind = cur.u8()?;
    Ok(match kind {
        K_START => Event::Start {
            seed: cur.varint()?,
            mode: cur.str()?,
            shards: cur.varint()?,
        },
        K_SUBMIT => Event::Submit { qid: cur.varint()? },
        K_ROUTE => Event::Route { qid: cur.varint()?, shard: cur.varint()? },
        K_DISPATCH => Event::Dispatch {
            group: cur.varint()?,
            kind: cur.u8()?,
            detail: cur.varint()?,
            queries: cur.varint()?,
        },
        K_SEAL => Event::Seal { group: cur.varint()?, k: cur.varint()?, r: cur.varint()? },
        K_COMPLETE => Event::Complete {
            qid: cur.varint()?,
            outcome: cur.u8()?,
            latency_us: cur.varint()?,
        },
        K_DECODE => Event::Decode { group: cur.varint()?, slot: cur.varint()? },
        K_FAULT => Event::Fault {
            instance: cur.varint()?,
            kind: cur.u8()?,
            arg: cur.varint()?,
        },
        K_RECONFIG => Event::Reconfig { verb: cur.u8()?, shard: cur.varint()? },
        K_REJECT => Event::Reject { n: cur.varint()? },
        K_END => Event::End {
            native: cur.varint()?,
            reconstructed: cur.varint()?,
            replica: cur.varint()?,
            defaulted: cur.varint()?,
            rejected: cur.varint()?,
            reconstructions: cur.varint()?,
            wall_us: cur.varint()?,
        },
        other => return Err(JournalError::UnknownKind { kind: other, at: cur.at - 1 }),
    })
}

/// Lazy record iterator over a journal's bytes — the iteration API the
/// trace/mining layer ([`crate::coordinator::trace`]) walks journals
/// with, without paying replay's re-verification. Decoding stops at the
/// first malformed record: the error is yielded once and the iterator
/// then fuses (no infinite loops on garbled input).
pub struct EventIter<'a> {
    cur: Cursor<'a>,
    ts: u64,
    failed: bool,
}

impl<'a> EventIter<'a> {
    fn read_one(&mut self) -> Result<TimedEvent, JournalError> {
        let start = self.cur.at;
        let delta = self.cur.varint()?;
        let shard = self.cur.varint()?;
        // A garbled varint can claim an absurd delta; wrapping here was
        // a debug-build panic. Overflow means bytes we never wrote.
        self.ts = self
            .ts
            .checked_add(delta)
            .ok_or(JournalError::NonCanonical(start))?;
        Ok(TimedEvent { ts_us: self.ts, shard, event: decode_event(&mut self.cur)? })
    }
}

impl<'a> Iterator for EventIter<'a> {
    type Item = Result<TimedEvent, JournalError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.failed || self.cur.done() {
            return None;
        }
        let item = self.read_one();
        self.failed = item.is_err();
        Some(item)
    }
}

/// Validate a journal's header and iterate its records lazily. Each
/// item is one decoded [`TimedEvent`] or the first decode error (after
/// which the iterator ends).
pub fn events(bytes: &[u8]) -> Result<EventIter<'_>, JournalError> {
    if bytes.len() < 5 || bytes[..4] != MAGIC {
        return Err(JournalError::BadMagic);
    }
    if bytes[4] != VERSION {
        return Err(JournalError::BadVersion(bytes[4]));
    }
    Ok(EventIter { cur: Cursor { bytes, at: 5 }, ts: 0, failed: false })
}

/// Decode a journal into its timed event sequence (header validated,
/// canonicality *not* asserted — [`replay`] does that).
pub fn decode(bytes: &[u8]) -> Result<Vec<TimedEvent>, JournalError> {
    events(bytes)?.collect()
}

/// Read a journal file's raw bytes (IO errors mapped into
/// [`JournalError::Io`], so callers stay in one error domain).
pub fn read_file(path: &str) -> Result<Vec<u8>, JournalError> {
    std::fs::read(path).map_err(|e| JournalError::Io(format!("{path}: {e}")))
}

/// FNV-1a digest of a journal's bytes — what the CI replay lane diffs.
pub fn digest(bytes: &[u8]) -> u64 {
    fnv1a(bytes)
}

// ------------------------------------------------------------- recorder

struct WriterState {
    buf: Vec<u8>,
    last_ts_us: u64,
    finished: bool,
    events: u64,
}

struct RecorderInner {
    epoch: Instant,
    state: Mutex<WriterState>,
}

/// Cheap-clone handle onto a shared journal writer. The default
/// ([`Recorder::disabled`]) records nothing and costs one branch per
/// hook, so every serving surface carries one unconditionally.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<RecorderInner>>,
    shard: u64,
}

impl fmt::Debug for Recorder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.inner {
            Some(_) => write!(f, "Recorder(shard={})", self.shard),
            None => write!(f, "Recorder(disabled)"),
        }
    }
}

impl Recorder {
    /// A recorder that drops everything (the default on every config).
    pub fn disabled() -> Recorder {
        Recorder::default()
    }

    /// Start a live journal: writes the header and the [`Event::Start`]
    /// record. `seed`/`mode` are the run's seeding contract; `shards`
    /// the starting fleet width (1 for a bare session).
    pub fn start(seed: u64, mode: &str, shards: u64) -> Recorder {
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(&MAGIC);
        buf.push(VERSION);
        let rec = Recorder {
            inner: Some(Arc::new(RecorderInner {
                epoch: Instant::now(),
                state: Mutex::new(WriterState {
                    buf,
                    last_ts_us: 0,
                    finished: false,
                    events: 0,
                }),
            })),
            shard: 0,
        };
        rec.record(&Event::Start { seed, mode: mode.to_string(), shards });
        rec
    }

    /// Whether events will actually be written. Hot paths check this
    /// before building event payloads.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// A clone tagged with a shard id: events it records carry `shard`
    /// in their framing. The sharded tier hands each shard session a
    /// tagged clone of one underlying writer.
    pub fn tagged(&self, shard: u64) -> Recorder {
        Recorder { inner: self.inner.clone(), shard }
    }

    /// Append one event. Timestamps are taken under the writer lock, so
    /// the log's deltas are non-negative by construction even with many
    /// threads recording.
    pub fn record(&self, ev: &Event) {
        let Some(inner) = &self.inner else { return };
        let mut st = inner.state.plock();
        if st.finished {
            return;
        }
        let ts = inner.epoch.elapsed().as_micros() as u64;
        let ts = ts.max(st.last_ts_us);
        let delta = ts - st.last_ts_us;
        st.last_ts_us = ts;
        st.events += 1;
        encode_record(&mut st.buf, delta, self.shard, ev);
    }

    /// Events recorded so far.
    pub fn events(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.state.plock().events)
    }

    /// Write the [`Event::End`] footer from a finished run's result and
    /// return the complete journal bytes. Idempotent: later calls (and
    /// later `record`s) are no-ops returning the sealed bytes.
    pub fn finish(&self, res: &RunResult) -> Vec<u8> {
        self.finish_totals(&EndTotals::of(res))
    }

    /// [`Recorder::finish`] from explicit totals (fleet-merged results).
    pub fn finish_totals(&self, t: &EndTotals) -> Vec<u8> {
        let Some(inner) = &self.inner else { return Vec::new() };
        {
            let st = inner.state.plock();
            if st.finished {
                return st.buf.clone();
            }
        }
        self.record(&Event::End {
            native: t.native,
            reconstructed: t.reconstructed,
            replica: t.replica,
            defaulted: t.defaulted,
            rejected: t.rejected,
            reconstructions: t.reconstructions,
            wall_us: t.wall_us,
        });
        let mut st = inner.state.plock();
        st.finished = true;
        st.buf.clone()
    }

    /// Finish and write the journal to a file.
    pub fn finish_to_file(&self, path: &str, res: &RunResult) -> Result<(), JournalError> {
        let bytes = self.finish(res);
        std::fs::write(path, bytes).map_err(|e| JournalError::Io(e.to_string()))
    }
}

/// The resolved totals carried by [`Event::End`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EndTotals {
    pub native: u64,
    pub reconstructed: u64,
    pub replica: u64,
    pub defaulted: u64,
    pub rejected: u64,
    pub reconstructions: u64,
    pub wall_us: u64,
}

impl EndTotals {
    pub fn of(res: &RunResult) -> EndTotals {
        EndTotals {
            native: res.metrics.native,
            reconstructed: res.metrics.reconstructed,
            replica: res.metrics.replica,
            defaulted: res.metrics.defaulted,
            rejected: res.metrics.rejected,
            reconstructions: res.reconstructions,
            wall_us: res.wall.as_micros() as u64,
        }
    }
}

// --------------------------------------------------------------- replay

/// What [`replay`] proved about a journal.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// The run's seed, from [`Event::Start`].
    pub seed: u64,
    /// The redundancy mode name, from [`Event::Start`].
    pub mode: String,
    /// Records interpreted (including Start/End).
    pub events: u64,
    /// Queries submitted across all shards.
    pub submits: u64,
    /// Outcome totals recomputed from the event stream — verified equal
    /// to the recorded [`Event::End`] footer.
    pub totals: EndTotals,
    /// Submitted queries with no terminal event (a run cut short; zero
    /// for drained runs).
    pub leaked: u64,
    /// Coding groups sealed / decoder reconstructions observed.
    pub seals: u64,
    pub decodes: u64,
    /// Fault / reconfiguration events observed.
    pub faults: u64,
    pub reconfigs: u64,
    /// The re-encoded journal — byte-identical to the input (verified).
    pub journal: Vec<u8>,
    /// [`digest`] of `journal`.
    pub digest: u64,
}

/// Deterministically re-execute a journal's event stream: validate the
/// serving path's causal invariants, recompute the outcome totals,
/// check them against the recorded footer, and re-encode the stream
/// byte-identically. See the module docs for why replay interprets the
/// log rather than re-running the threaded simulation.
pub fn replay(bytes: &[u8]) -> Result<ReplayReport, JournalError> {
    let events = decode(bytes)?;
    let inv = |at: usize, msg: String| JournalError::Invariant { at: at as u64, msg };

    let Some(first) = events.first() else {
        return Err(inv(0, "empty journal (no Start)".into()));
    };
    let Event::Start { seed, mode, .. } = &first.event else {
        return Err(inv(0, "journal does not begin with Start".into()));
    };

    // (shard, qid) -> still pending. The shard tag scopes session-local
    // query ids, which restart from zero in every shard session.
    let mut pending: HashMap<(u64, u64), ()> = HashMap::new();
    let mut totals = EndTotals::default();
    let mut submits = 0u64;
    let mut seals = 0u64;
    let mut decodes = 0u64;
    let mut faults = 0u64;
    let mut reconfigs = 0u64;
    let mut footer: Option<EndTotals> = None;

    for (i, te) in events.iter().enumerate() {
        if footer.is_some() {
            return Err(inv(i, "event after End".into()));
        }
        match &te.event {
            Event::Start { .. } => {
                if i != 0 {
                    return Err(inv(i, "second Start".into()));
                }
            }
            Event::Submit { qid } => {
                if pending.insert((te.shard, *qid), ()).is_some() {
                    return Err(inv(
                        i,
                        format!("duplicate submit of query {qid} on shard {}", te.shard),
                    ));
                }
                submits += 1;
            }
            Event::Complete { qid, outcome, .. } => {
                if pending.remove(&(te.shard, *qid)).is_none() {
                    return Err(inv(
                        i,
                        format!(
                            "completion of unknown or already-resolved query {qid} on shard {}",
                            te.shard
                        ),
                    ));
                }
                match byte_outcome(*outcome) {
                    Some(Outcome::Native) => totals.native += 1,
                    Some(Outcome::Reconstructed) => totals.reconstructed += 1,
                    Some(Outcome::Replica) => totals.replica += 1,
                    Some(Outcome::Default) => totals.defaulted += 1,
                    None => return Err(inv(i, format!("unknown outcome byte {outcome}"))),
                }
            }
            Event::Reject { n } => totals.rejected += n,
            Event::Seal { k, r, .. } => {
                if *k == 0 {
                    return Err(inv(i, "group sealed with k=0".into()));
                }
                seals += 1;
                let _ = r;
            }
            Event::Decode { .. } => decodes += 1,
            Event::Fault { .. } => faults += 1,
            Event::Reconfig { .. } => reconfigs += 1,
            Event::Route { .. } | Event::Dispatch { .. } => {}
            Event::End {
                native,
                reconstructed,
                replica,
                defaulted,
                rejected,
                reconstructions,
                wall_us,
            } => {
                footer = Some(EndTotals {
                    native: *native,
                    reconstructed: *reconstructed,
                    replica: *replica,
                    defaulted: *defaulted,
                    rejected: *rejected,
                    reconstructions: *reconstructions,
                    wall_us: *wall_us,
                });
            }
        }
    }

    let Some(f) = footer else {
        return Err(inv(events.len().saturating_sub(1), "journal does not end with End".into()));
    };
    // The recomputed outcome totals must equal what the live run
    // reported — this is the "replay reproduces the RunResult" check.
    if (f.native, f.reconstructed, f.replica, f.defaulted, f.rejected)
        != (
            totals.native,
            totals.reconstructed,
            totals.replica,
            totals.defaulted,
            totals.rejected,
        )
    {
        return Err(inv(
            events.len() - 1,
            format!(
            "footer totals (native={} reconstructed={} replica={} defaulted={} rejected={}) \
             disagree with replayed events (native={} reconstructed={} replica={} \
             defaulted={} rejected={})",
            f.native,
            f.reconstructed,
            f.replica,
            f.defaulted,
            f.rejected,
            totals.native,
            totals.reconstructed,
            totals.replica,
            totals.defaulted,
            totals.rejected,
        ),
        ));
    }
    totals.reconstructions = f.reconstructions;
    totals.wall_us = f.wall_us;

    // Re-encode with recorded timestamps; the canonical codec makes
    // this byte-identical to any journal this writer produced.
    let mut out = Vec::with_capacity(bytes.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    let mut last = 0u64;
    for te in &events {
        encode_record(&mut out, te.ts_us - last, te.shard, &te.event);
        last = te.ts_us;
    }
    if out != bytes {
        return Err(JournalError::NonCanonical(0));
    }

    let digest = digest(&out);
    Ok(ReplayReport {
        seed: *seed,
        mode: mode.clone(),
        events: events.len() as u64,
        submits,
        totals,
        leaked: pending.len() as u64,
        seals,
        decodes,
        faults,
        reconfigs,
        journal: out,
        digest,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample_events(rng: &mut Pcg64, n: usize) -> Vec<Event> {
        let mut evs = Vec::new();
        for qid in 0..n as u64 {
            evs.push(Event::Submit { qid });
        }
        for qid in 0..n as u64 {
            evs.push(Event::Complete {
                qid,
                outcome: (rng.below(4)) as u8,
                latency_us: rng.below(1_000_000),
            });
        }
        evs
    }

    fn record_all(evs: &[Event]) -> (Recorder, Vec<u8>) {
        let rec = Recorder::start(42, "parm", 1);
        for e in evs {
            rec.record(e);
        }
        let mut totals = EndTotals::default();
        for e in evs {
            if let Event::Complete { outcome, .. } = e {
                match byte_outcome(*outcome).unwrap() {
                    Outcome::Native => totals.native += 1,
                    Outcome::Reconstructed => totals.reconstructed += 1,
                    Outcome::Replica => totals.replica += 1,
                    Outcome::Default => totals.defaulted += 1,
                }
            }
        }
        let bytes = rec.finish_totals(&totals);
        (rec, bytes)
    }

    #[test]
    fn varint_roundtrip_canonical() {
        let mut rng = Pcg64::new(7);
        for _ in 0..2000 {
            let v = rng.next_u64() >> (rng.below(64) as u32);
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut cur = Cursor { bytes: &buf, at: 0 };
            assert_eq!(cur.varint().unwrap(), v);
            assert!(cur.done());
        }
        // Over-long encodings are rejected: 0x80 0x00 is 0 in two bytes.
        let mut cur = Cursor { bytes: &[0x80, 0x00], at: 0 };
        assert!(matches!(cur.varint(), Err(JournalError::NonCanonical(_))));
    }

    #[test]
    fn event_codec_roundtrip() {
        let evs = vec![
            Event::Start { seed: 0xDEAD, mode: "cross-shard".into(), shards: 4 },
            Event::Submit { qid: 17 },
            Event::Route { qid: (3 << 32) | 17, shard: 3 },
            Event::Dispatch { group: 2, kind: JobClass::Parity as u8, detail: 1, queries: 4 },
            Event::Seal { group: 2, k: 3, r: 2 },
            Event::Complete { qid: 17, outcome: 1, latency_us: 1234 },
            Event::Decode { group: 2, slot: 1 },
            Event::Fault { instance: 5, kind: FaultKind::Kill as u8, arg: 0 },
            Event::Reconfig { verb: ReconfigVerb::Drain as u8, shard: 2 },
            Event::Reject { n: 3 },
            Event::End {
                native: 1,
                reconstructed: 2,
                replica: 3,
                defaulted: 4,
                rejected: 5,
                reconstructions: 6,
                wall_us: 7,
            },
        ];
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(VERSION);
        for (i, e) in evs.iter().enumerate() {
            encode_record(&mut buf, i as u64 * 10, (i % 3) as u64, e);
        }
        let back = decode(&buf).unwrap();
        assert_eq!(back.len(), evs.len());
        for (te, e) in back.iter().zip(&evs) {
            assert_eq!(&te.event, e);
        }
        // Timestamps accumulate the deltas.
        assert_eq!(back[2].ts_us, 30);
    }

    #[test]
    fn recorded_journal_replays_byte_identical() {
        let mut rng = Pcg64::new(99);
        let evs = sample_events(&mut rng, 50);
        let (_rec, bytes) = record_all(&evs);
        let r1 = replay(&bytes).unwrap();
        assert_eq!(r1.journal, bytes, "replay re-encodes byte-identically");
        let r2 = replay(&r1.journal).unwrap();
        assert_eq!(r2.journal, r1.journal, "replay is idempotent");
        assert_eq!(r1.digest, r2.digest);
        assert_eq!(r1.submits, 50);
        assert_eq!(r1.leaked, 0);
        assert_eq!(r1.seed, 42);
        assert_eq!(r1.mode, "parm");
    }

    #[test]
    fn replay_rejects_causality_violations() {
        // Complete without submit.
        let rec = Recorder::start(1, "parm", 1);
        rec.record(&Event::Complete { qid: 9, outcome: 0, latency_us: 1 });
        let bytes = rec.finish_totals(&EndTotals { native: 1, ..EndTotals::default() });
        assert!(matches!(replay(&bytes), Err(JournalError::Invariant { .. })));

        // Duplicate submit.
        let rec = Recorder::start(1, "parm", 1);
        rec.record(&Event::Submit { qid: 4 });
        rec.record(&Event::Submit { qid: 4 });
        let bytes = rec.finish_totals(&EndTotals::default());
        assert!(matches!(replay(&bytes), Err(JournalError::Invariant { .. })));

        // Double completion.
        let rec = Recorder::start(1, "parm", 1);
        rec.record(&Event::Submit { qid: 4 });
        rec.record(&Event::Complete { qid: 4, outcome: 0, latency_us: 1 });
        rec.record(&Event::Complete { qid: 4, outcome: 0, latency_us: 1 });
        let bytes = rec.finish_totals(&EndTotals { native: 2, ..EndTotals::default() });
        assert!(matches!(replay(&bytes), Err(JournalError::Invariant { .. })));
    }

    #[test]
    fn replay_rejects_footer_mismatch() {
        let rec = Recorder::start(1, "parm", 1);
        rec.record(&Event::Submit { qid: 0 });
        rec.record(&Event::Complete { qid: 0, outcome: 0, latency_us: 10 });
        // Footer claims a reconstruction that never happened.
        let bytes = rec.finish_totals(&EndTotals { reconstructed: 1, ..EndTotals::default() });
        assert!(matches!(replay(&bytes), Err(JournalError::Invariant { .. })));
    }

    #[test]
    fn shard_tags_scope_query_ids() {
        // Two shards both submit qid 0 — distinct queries, no clash.
        let rec = Recorder::start(5, "cross-shard", 2);
        let s0 = rec.tagged(0);
        let s1 = rec.tagged(1);
        s0.record(&Event::Submit { qid: 0 });
        s1.record(&Event::Submit { qid: 0 });
        s0.record(&Event::Complete { qid: 0, outcome: 0, latency_us: 5 });
        s1.record(&Event::Complete { qid: 0, outcome: 1, latency_us: 9 });
        let bytes = rec.finish_totals(&EndTotals {
            native: 1,
            reconstructed: 1,
            ..EndTotals::default()
        });
        let rep = replay(&bytes).unwrap();
        assert_eq!(rep.submits, 2);
        assert_eq!((rep.totals.native, rep.totals.reconstructed), (1, 1));
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = Recorder::disabled();
        assert!(!rec.enabled());
        rec.record(&Event::Submit { qid: 1 });
        assert_eq!(rec.events(), 0);
        assert!(rec.finish_totals(&EndTotals::default()).is_empty());
    }

    #[test]
    fn finish_is_idempotent_and_seals() {
        let rec = Recorder::start(3, "rateless", 1);
        rec.record(&Event::Submit { qid: 0 });
        rec.record(&Event::Complete { qid: 0, outcome: 0, latency_us: 2 });
        let a = rec.finish_totals(&EndTotals { native: 1, ..EndTotals::default() });
        // Post-finish records are dropped; a second finish returns the
        // same sealed bytes.
        rec.record(&Event::Submit { qid: 1 });
        let b = rec.finish_totals(&EndTotals { native: 7, ..EndTotals::default() });
        assert_eq!(a, b);
        assert!(replay(&a).is_ok());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert_eq!(decode(b"nope"), Err(JournalError::BadMagic));
        let mut v = MAGIC.to_vec();
        v.push(99);
        assert_eq!(decode(&v), Err(JournalError::BadVersion(99)));
        let mut v = MAGIC.to_vec();
        v.push(VERSION);
        v.extend_from_slice(&[0, 0, 42]); // delta 0, shard 0, unknown kind 42
        assert!(matches!(decode(&v), Err(JournalError::UnknownKind { kind: 42, .. })));
        let mut v = MAGIC.to_vec();
        v.push(VERSION);
        v.push(0x80); // truncated varint
        assert!(matches!(decode(&v), Err(JournalError::Truncated(_))));
    }

    #[test]
    fn lazy_iterator_matches_decode_and_fuses_on_error() {
        let mut rng = Pcg64::new(0x17E2);
        let evs = sample_events(&mut rng, 30);
        let (_rec, bytes) = record_all(&evs);
        let eager = decode(&bytes).unwrap();
        let lazy: Vec<TimedEvent> =
            events(&bytes).unwrap().collect::<Result<_, _>>().unwrap();
        assert_eq!(lazy, eager, "events() and decode() agree record for record");

        // Cut mid-stream: the iterator yields the good prefix, exactly
        // one error, then fuses.
        let cut = &bytes[..bytes.len() - 3];
        let mut it = events(cut).unwrap();
        let mut good = 0usize;
        let mut errs = 0usize;
        for item in &mut it {
            match item {
                Ok(_) => good += 1,
                Err(_) => errs += 1,
            }
        }
        assert!(good < eager.len());
        assert_eq!(errs, 1, "exactly one error, then the iterator ends");
        assert!(it.next().is_none(), "fused after the error");
    }

    #[test]
    fn timestamp_overflow_is_an_error_not_a_panic() {
        // Two records whose deltas sum past u64::MAX: bytes we never
        // wrote (a garbled varint in the wild). `ts += delta` used to
        // wrap — a panic in debug builds.
        let mut v = MAGIC.to_vec();
        v.push(VERSION);
        for _ in 0..2 {
            put_varint(&mut v, u64::MAX); // delta
            put_varint(&mut v, 0); // shard
            v.push(K_SUBMIT);
            put_varint(&mut v, 1); // qid
        }
        assert!(matches!(decode(&v), Err(JournalError::NonCanonical(_))));
        assert!(matches!(replay(&v), Err(JournalError::NonCanonical(_))));
    }

    #[test]
    fn invariant_errors_carry_the_record_index() {
        let rec = Recorder::start(1, "parm", 1);
        rec.record(&Event::Submit { qid: 4 });
        rec.record(&Event::Submit { qid: 4 });
        let bytes = rec.finish_totals(&EndTotals::default());
        match replay(&bytes) {
            // Record 0 is Start; the duplicate is the third record.
            Err(JournalError::Invariant { at, ref msg }) => {
                assert_eq!(at, 2, "the duplicate submit's own index: {msg}");
                assert!(msg.contains("duplicate submit"), "{msg}");
            }
            other => panic!("expected an Invariant error, got {other:?}"),
        }
    }

    #[test]
    fn truncation_fuzz_never_panics_or_loops() {
        // Every truncation point of a real recorded journal must come
        // back as a structured error (never a panic, never a hang), and
        // seeded single-byte corruptions must return *something* —
        // Ok for benign flips, Err otherwise — without panicking.
        let mut rng = Pcg64::new(0xF022);
        let evs = sample_events(&mut rng, 40);
        let (_rec, bytes) = record_all(&evs);
        assert!(replay(&bytes).is_ok());
        for cut in 0..bytes.len() {
            let r = replay(&bytes[..cut]);
            assert!(r.is_err(), "a journal cut at byte {cut} cannot verify");
        }
        for _ in 0..500 {
            let mut garbled = bytes.clone();
            let at = rng.below(garbled.len() as u64) as usize;
            garbled[at] ^= 1 << rng.below(8);
            let _ = decode(&garbled); // must not panic
            let _ = replay(&garbled); // must not panic
        }
    }
}

//! Coding groups (§3.1): the stripes of ParM.
//!
//! As query batches are dispatched, they are appended to the open coding
//! group; when the group holds k batches it is sealed, encoded into a
//! parity batch, and the parity is dispatched to the parity-model pool.
//! [`GroupTracker`] then tracks completions for the group and decides —
//! purely as a function of which outputs have arrived — which unavailable
//! predictions can be reconstructed. It is deliberately free of threads
//! and clocks so its invariants are property-testable.
//!
//! In the serving stack this sits inside
//! [`crate::coordinator::scheme::ParmScheme`], which feeds it from the
//! session's dispatch/completion callbacks; the decode math itself lives
//! in [`crate::coordinator::decoder`].
//!
//! Groups do not all have to carry the same redundancy: a tracker built
//! with `r_max` encoders can register any group with `r <= r_max`
//! parities ([`GroupTracker::register_with_r`]), which is what lets the
//! adaptive rateless scheme ([`crate::coordinator::adaptive`]) pick a
//! per-group parity count at seal time while sharing this bookkeeping.
//!
//! Storage is a preallocated slab (ROADMAP item 2): group bodies live in
//! a recycled arena indexed by a [`ProbeMap`], so tracking a group costs
//! a probe plus in-place `Vec` reuse rather than a `HashMap` insert with
//! fresh heap boxes per group. Recycling can never alias a live group —
//! the index maps only live ids, and a stale id simply probes to nothing
//! (pinned by the property suite in `tests/coordinator_props.rs`).

use crate::coordinator::decoder;
use crate::coordinator::encoder::Encoder;
use crate::tensor::Tensor;
use crate::util::arena::ProbeMap;

/// A sealed coding group's bookkeeping.
#[derive(Debug)]
pub struct GroupState {
    pub id: u64,
    /// Per-slot deployed-model outputs (batched), as they arrive.
    pub data_outs: Vec<Option<Tensor>>,
    /// Per-parity outputs (batched), as they arrive.
    pub parity_outs: Vec<Option<Tensor>>,
    /// Per-slot query ids (for routing reconstructions back to clients).
    pub query_ids: Vec<Vec<u64>>,
    /// Per-slot fault-domain tag (shard index for cross-shard groups;
    /// all zero for intra-session groups). Reconstructions report it so
    /// a fleet-level coordinator can route the decoded slot back to the
    /// session that owns its queries.
    pub tags: Vec<usize>,
    /// Slots already resolved (own prediction arrived or reconstructed).
    pub resolved: Vec<bool>,
}

/// One slot of a coding group whose prediction just became available.
#[derive(Debug)]
pub struct SlotResolution {
    pub slot: usize,
    pub query_ids: Vec<u64>,
    pub output: Tensor,
    /// true when the decoder produced the output (the slot's own
    /// prediction never arrived); false for a native arrival.
    pub reconstructed: bool,
    /// The fault-domain tag the slot was registered with (see
    /// [`GroupTracker::register_tagged`]); 0 for untagged groups.
    pub tag: usize,
}

/// Outcome of feeding one completion to the tracker.
#[derive(Debug, Default)]
pub struct Resolutions {
    pub resolved: Vec<SlotResolution>,
}

/// Slab of group bodies with an id index and a free list. Evicted
/// bodies keep their `Vec` capacities and are reused for later groups,
/// so steady-state register/evict churn allocates nothing.
struct GroupArena {
    slots: Vec<GroupState>,
    free: Vec<u32>,
    index: ProbeMap<u32>,
}

impl GroupArena {
    fn new() -> GroupArena {
        GroupArena { slots: Vec::new(), free: Vec::new(), index: ProbeMap::new() }
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    /// Slab position of a live group (None for evicted/stale ids — the
    /// no-alias guarantee lives here: only the index resolves ids).
    fn slot_of(&self, id: u64) -> Option<usize> {
        self.index.get(id).map(|s| s as usize)
    }

    fn get(&self, id: u64) -> Option<&GroupState> {
        self.slot_of(id).map(|s| &self.slots[s])
    }

    /// Install a group body for `id`, recycling a freed slab entry when
    /// one is available. Re-registering a live id overwrites in place
    /// (matching the `HashMap::insert` this replaced).
    fn insert(&mut self, id: u64, k: usize, r: usize, query_ids: Vec<Vec<u64>>, tags: Vec<usize>) {
        let si = if let Some(s) = self.index.get(id) {
            s as usize
        } else if let Some(s) = self.free.pop() {
            self.index.insert(id, s);
            s as usize
        } else {
            let s = self.slots.len();
            self.slots.push(GroupState {
                id,
                data_outs: Vec::new(),
                parity_outs: Vec::new(),
                query_ids: Vec::new(),
                tags: Vec::new(),
                resolved: Vec::new(),
            });
            self.index.insert(id, s as u32);
            s
        };
        let g = &mut self.slots[si];
        g.id = id;
        g.data_outs.clear();
        g.data_outs.resize_with(k, || None);
        g.parity_outs.clear();
        g.parity_outs.resize_with(r, || None);
        g.query_ids = query_ids;
        g.tags = tags;
        g.resolved.clear();
        g.resolved.resize(k, false);
    }

    /// Evict a group: unmap the id and recycle the body (tensors dropped
    /// now, buffers kept for the next group).
    fn remove(&mut self, id: u64) -> bool {
        let Some(s) = self.index.remove(id) else {
            return false;
        };
        let g = &mut self.slots[s as usize];
        g.data_outs.clear();
        g.parity_outs.clear();
        g.query_ids = Vec::new();
        g.tags.clear();
        g.resolved.clear();
        self.free.push(s);
        true
    }

    fn live_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.index.iter().map(|(id, _)| id)
    }
}

/// Tracks in-flight coding groups and applies the decode rule.
pub struct GroupTracker {
    k: usize,
    /// Weight vectors per parity model (r rows of k).
    weights: Vec<Vec<f32>>,
    arena: GroupArena,
    /// Groups fully resolved and removed (stats).
    pub completed_groups: u64,
    /// Total reconstructions performed.
    pub reconstructions: u64,
}

impl GroupTracker {
    pub fn new(k: usize, encoders: &[Encoder]) -> GroupTracker {
        let weights = encoders
            .iter()
            .map(|e| match e {
                Encoder::Sum { weights } => weights.clone(),
                // Concat parity models are trained for the plain sum of
                // predictions, so decode weights are all-ones.
                Encoder::Concat { k } => vec![1.0; *k],
            })
            .collect();
        GroupTracker {
            k,
            weights,
            arena: GroupArena::new(),
            completed_groups: 0,
            reconstructions: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn r(&self) -> usize {
        self.weights.len()
    }

    pub fn open_groups(&self) -> usize {
        self.arena.len()
    }

    /// Ids of every group still tracked (order unspecified).
    pub fn open_group_ids(&self) -> Vec<u64> {
        self.arena.live_ids().collect()
    }

    /// Register a sealed group (slot -> query ids, in dispatch order)
    /// using every configured parity.
    pub fn register(&mut self, id: u64, query_ids: Vec<Vec<u64>>) {
        let r = self.weights.len();
        self.register_with_r(id, query_ids, r);
    }

    /// Register a sealed group that will receive only the first `r` of
    /// the configured parities — the per-group-r form used by adaptive
    /// schemes whose redundancy is chosen at seal time. Completions for
    /// parity indices `>= r` are ignored for this group.
    pub fn register_with_r(&mut self, id: u64, query_ids: Vec<Vec<u64>>, r: usize) {
        let k = query_ids.len();
        self.register_tagged(id, query_ids, r, vec![0; k]);
    }

    /// [`GroupTracker::register_with_r`] with a fault-domain tag per slot
    /// (the shard serving that slot's data queries, for groups that span
    /// shards). Tags ride every [`SlotResolution`], so the caller can
    /// route a decoded slot back to the session that owns its queries
    /// and attribute the loss to the right fault domain.
    pub fn register_tagged(
        &mut self,
        id: u64,
        query_ids: Vec<Vec<u64>>,
        r: usize,
        tags: Vec<usize>,
    ) {
        assert_eq!(query_ids.len(), self.k, "group must have k slots");
        assert_eq!(tags.len(), self.k, "group must have k slot tags");
        assert!(
            r >= 1 && r <= self.weights.len(),
            "group r={r} outside 1..={}",
            self.weights.len()
        );
        self.arena.insert(id, self.k, r, query_ids, tags);
    }

    /// Whether a group is still tracked (registered and not fully
    /// resolved or abandoned).
    pub fn contains(&self, group: u64) -> bool {
        self.arena.slot_of(group).is_some()
    }

    /// Parity count this group was registered with (None once gone).
    pub fn group_r(&self, group: u64) -> Option<usize> {
        self.arena.get(group).map(|g| g.parity_outs.len())
    }

    /// Fault-domain tag a slot was registered with (None once the group
    /// is gone). Used by fleet-level coordinators to attribute stuck
    /// slots to their shard.
    pub fn slot_tag(&self, group: u64, slot: usize) -> Option<usize> {
        self.arena.get(group).and_then(|g| g.tags.get(slot).copied())
    }

    /// Slots of a tracked group that have not resolved yet (empty when
    /// the group is gone). Used by adaptive schemes to turn stale groups
    /// into straggler-predictor loss observations.
    pub fn unresolved_slots(&self, group: u64) -> Vec<usize> {
        match self.arena.get(group) {
            Some(g) => (0..self.k).filter(|&i| !g.resolved[i]).collect(),
            None => Vec::new(),
        }
    }

    /// Feed a deployed-model completion for (group, slot).
    pub fn on_data(&mut self, group: u64, slot: usize, output: Tensor) -> Resolutions {
        let mut res = Resolutions::default();
        let Some(si) = self.arena.slot_of(group) else {
            return res; // group already fully resolved and evicted
        };
        let g = &mut self.arena.slots[si];
        if slot >= g.data_outs.len() {
            log::warn!("group {group}: data completion for slot {slot} out of range");
            return res;
        }
        if g.data_outs[slot].is_none() {
            g.data_outs[slot] = Some(output);
        }
        if !g.resolved[slot] {
            g.resolved[slot] = true;
            res.resolved.push(SlotResolution {
                slot,
                query_ids: g.query_ids[slot].clone(),
                output: g.data_outs[slot].clone().unwrap(),
                reconstructed: false,
                tag: g.tags[slot],
            });
        }
        self.try_decode(si, &mut res);
        self.evict_if_done(group, si);
        res
    }

    /// Feed a parity-model completion for (group, r_index).
    pub fn on_parity(&mut self, group: u64, r_index: usize, output: Tensor) -> Resolutions {
        let mut res = Resolutions::default();
        let Some(si) = self.arena.slot_of(group) else {
            return res;
        };
        let g = &mut self.arena.slots[si];
        if r_index >= g.parity_outs.len() {
            // A parity beyond this group's registered r (possible when an
            // adaptive scheme lowered r between groups): ignore, never
            // panic — the group decodes from the parities it does carry.
            log::debug!("group {group}: parity {r_index} beyond group r, ignored");
            return res;
        }
        if g.parity_outs[r_index].is_none() {
            g.parity_outs[r_index] = Some(output);
        }
        self.try_decode(si, &mut res);
        self.evict_if_done(group, si);
        res
    }

    /// Drop a group (e.g. SLO expired for all of its queries).
    pub fn abandon(&mut self, group: u64) {
        self.arena.remove(group);
    }

    fn try_decode(&mut self, si: usize, res: &mut Resolutions) {
        let g = &mut self.arena.slots[si];
        let missing: Vec<usize> = (0..self.k).filter(|&i| !g.resolved[i]).collect();
        if missing.is_empty() {
            return;
        }
        let parities_avail = g.parity_outs.iter().filter(|p| p.is_some()).count();
        if missing.len() > parities_avail {
            return; // cannot decode yet
        }
        match decoder::decode_general(&self.weights, &g.data_outs, &g.parity_outs) {
            Ok(recs) => {
                for (slot, tensor) in recs {
                    if !g.resolved[slot] {
                        g.resolved[slot] = true;
                        self.reconstructions += 1;
                        res.resolved.push(SlotResolution {
                            slot,
                            query_ids: g.query_ids[slot].clone(),
                            output: tensor,
                            reconstructed: true,
                            tag: g.tags[slot],
                        });
                    }
                }
            }
            Err(e) => log::debug!("group {}: decode not possible: {e}", g.id),
        }
    }

    fn evict_if_done(&mut self, group: u64, si: usize) {
        if self.arena.slots[si].resolved.iter().all(|&r| r) {
            self.arena.remove(group);
            self.completed_groups += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::new(vec![1, v.len()], v).unwrap()
    }

    fn tracker(k: usize) -> GroupTracker {
        GroupTracker::new(k, &[Encoder::sum(k)])
    }

    #[test]
    fn all_data_arrives_no_reconstruction() {
        let mut tr = tracker(2);
        tr.register(1, vec![vec![10], vec![11]]);
        let r = tr.on_data(1, 0, t(vec![1., 0.]));
        assert_eq!(r.resolved.len(), 1);
        assert!(!r.resolved[0].reconstructed);
        let r = tr.on_data(1, 1, t(vec![0., 1.]));
        assert_eq!(r.resolved.len(), 1);
        assert_eq!(tr.reconstructions, 0);
        assert_eq!(tr.completed_groups, 1);
        assert_eq!(tr.open_groups(), 0);
    }

    #[test]
    fn parity_plus_k_minus_1_reconstructs_straggler() {
        let mut tr = tracker(2);
        tr.register(7, vec![vec![1], vec![2]]);
        tr.on_data(7, 0, t(vec![1., 2.]));
        // Parity output = sum of the two data outputs.
        let r = tr.on_parity(7, 0, t(vec![4., 6.]));
        assert_eq!(r.resolved.len(), 1);
        let rec = &r.resolved[0];
        assert_eq!(rec.slot, 1);
        assert_eq!(rec.query_ids, vec![2]);
        assert_eq!(rec.output.data(), &[3., 4.]);
        assert!(rec.reconstructed);
        assert_eq!(rec.tag, 0, "untagged groups report tag 0");
        assert_eq!(tr.reconstructions, 1);
        assert_eq!(tr.completed_groups, 1);
    }

    #[test]
    fn parity_first_then_data_reconstructs() {
        let mut tr = tracker(3);
        tr.register(1, vec![vec![1], vec![2], vec![3]]);
        tr.on_parity(1, 0, t(vec![6.]));
        assert_eq!(tr.reconstructions, 0, "two still missing, r=1");
        tr.on_data(1, 0, t(vec![1.]));
        let r = tr.on_data(1, 1, t(vec![2.]));
        // Slot 1 resolves natively AND slot 2 reconstructs (6-1-2=3).
        assert_eq!(r.resolved.len(), 2);
        let rec = r.resolved.iter().find(|x| x.reconstructed).unwrap();
        assert_eq!(rec.slot, 2);
        assert_eq!(rec.output.data(), &[3.]);
    }

    #[test]
    fn late_straggler_after_reconstruction_is_ignored() {
        let mut tr = tracker(2);
        tr.register(1, vec![vec![1], vec![2]]);
        tr.on_data(1, 0, t(vec![1.]));
        tr.on_parity(1, 0, t(vec![3.]));
        assert_eq!(tr.completed_groups, 1);
        // The straggler finally answers: group is gone, no double-resolve.
        let r = tr.on_data(1, 1, t(vec![2.]));
        assert!(r.resolved.is_empty());
    }

    #[test]
    fn r2_tolerates_two_stragglers() {
        let encs = [Encoder::sum_r(2, 0), Encoder::sum_r(2, 1)];
        let mut tr = GroupTracker::new(2, &encs);
        tr.register(1, vec![vec![1], vec![2]]);
        tr.on_parity(1, 0, t(vec![3.])); // f1+f2
        let r = tr.on_parity(1, 1, t(vec![5.])); // f1+2*f2
        assert_eq!(r.resolved.len(), 2, "both reconstructed from parities");
        let mut outs: Vec<(usize, f32)> =
            r.resolved.iter().map(|x| (x.slot, x.output.data()[0])).collect();
        outs.sort_by_key(|x| x.0);
        assert!((outs[0].1 - 1.0).abs() < 1e-5);
        assert!((outs[1].1 - 2.0).abs() < 1e-5);
        assert_eq!(tr.reconstructions, 2);
    }

    #[test]
    fn abandon_removes_group() {
        let mut tr = tracker(2);
        tr.register(9, vec![vec![1], vec![2]]);
        tr.abandon(9);
        assert_eq!(tr.open_groups(), 0);
        assert!(tr.on_data(9, 0, t(vec![1.])).resolved.is_empty());
    }

    #[test]
    fn per_group_r_limits_decode_and_never_panics() {
        // Tracker provisioned for r_max=2, but this group registered with
        // r=1: the second parity must be ignored, so two losses are
        // undecodable (they default via the session SLO) — and nothing
        // panics along the way.
        let encs = [Encoder::sum_r(2, 0), Encoder::sum_r(2, 1)];
        let mut tr = GroupTracker::new(2, &encs);
        tr.register_with_r(5, vec![vec![1], vec![2]], 1);
        assert_eq!(tr.group_r(5), Some(1));
        let r = tr.on_parity(5, 0, t(vec![3.]));
        assert!(r.resolved.is_empty(), "one parity cannot decode two losses");
        // A parity index beyond the group's r is ignored, not a panic.
        let r = tr.on_parity(5, 1, t(vec![5.]));
        assert!(r.resolved.is_empty());
        assert_eq!(tr.unresolved_slots(5), vec![0, 1]);
        assert_eq!(tr.reconstructions, 0);
        // One data arrival + the single parity decodes the remaining loss.
        let r = tr.on_data(5, 0, t(vec![1.]));
        assert_eq!(r.resolved.len(), 2, "native + reconstruction");
        assert!(r.resolved.iter().any(|x| x.reconstructed && x.slot == 1));
        assert!(!tr.contains(5), "fully resolved group evicted");
    }

    #[test]
    fn tagged_registration_rides_tags_on_resolutions() {
        // A cross-shard-style group: slot 0 on shard 3, slot 1 on shard 1.
        let mut tr = tracker(2);
        tr.register_tagged(4, vec![vec![40], vec![41]], 1, vec![3, 1]);
        assert_eq!(tr.slot_tag(4, 0), Some(3));
        assert_eq!(tr.slot_tag(4, 1), Some(1));
        let r = tr.on_data(4, 0, t(vec![1., 2.]));
        assert_eq!(r.resolved[0].tag, 3, "native resolution carries its slot's tag");
        let r = tr.on_parity(4, 0, t(vec![4., 6.]));
        let rec = r.resolved.iter().find(|x| x.reconstructed).unwrap();
        assert_eq!(rec.tag, 1, "the decoded slot reports the shard that lost it");
        assert_eq!(rec.query_ids, vec![41]);
        assert_eq!(tr.slot_tag(4, 0), None, "evicted group has no tags");
    }

    #[test]
    fn variable_r_groups_coexist_in_one_tracker() {
        let encs = [Encoder::sum_r(2, 0), Encoder::sum_r(2, 1)];
        let mut tr = GroupTracker::new(2, &encs);
        tr.register_with_r(1, vec![vec![10], vec![11]], 1);
        tr.register_with_r(2, vec![vec![20], vec![21]], 2);
        // Group 2 (r=2) recovers a double loss from its two parities...
        tr.on_parity(2, 0, t(vec![3.])); // f1 + f2
        let r = tr.on_parity(2, 1, t(vec![5.])); // f1 + 2*f2
        assert_eq!(r.resolved.len(), 2);
        assert_eq!(tr.reconstructions, 2);
        // ...while group 1 (r=1) still needs k-1 data outputs.
        tr.on_data(1, 0, t(vec![7.]));
        let r = tr.on_parity(1, 0, t(vec![9.]));
        let rec = r.resolved.iter().find(|x| x.reconstructed).unwrap();
        assert_eq!(rec.output.data(), &[2.]);
        assert_eq!(tr.open_groups(), 0);
    }

    #[test]
    fn duplicate_completions_are_idempotent() {
        let mut tr = tracker(2);
        tr.register(1, vec![vec![1], vec![2]]);
        tr.on_data(1, 0, t(vec![1.]));
        let r = tr.on_data(1, 0, t(vec![99.]));
        assert!(r.resolved.is_empty(), "second completion for same slot ignored");
    }

    #[test]
    fn recycled_slab_entry_never_aliases_a_new_group() {
        let mut tr = tracker(2);
        // Group 1 completes and its slab entry is freed...
        tr.register(1, vec![vec![10], vec![11]]);
        tr.on_data(1, 0, t(vec![1.]));
        tr.on_data(1, 1, t(vec![2.]));
        assert_eq!(tr.open_groups(), 0);
        // ...group 2 recycles that entry.
        tr.register(2, vec![vec![20], vec![21]]);
        // Stale traffic for id 1 must hit nothing — not group 2's slots.
        assert!(tr.on_data(1, 0, t(vec![9.])).resolved.is_empty());
        assert!(tr.on_parity(1, 0, t(vec![9.])).resolved.is_empty());
        assert!(!tr.contains(1));
        assert_eq!(tr.unresolved_slots(2), vec![0, 1], "group 2 untouched by stale id 1");
        let r = tr.on_data(2, 0, t(vec![5.]));
        assert_eq!(r.resolved[0].query_ids, vec![20]);
        assert_eq!(tr.open_group_ids(), vec![2]);
    }
}

//! Cross-shard coding groups: one erasure code spanning fault domains.
//!
//! Every scheme so far kept a coding group *inside* one shard's session,
//! so a whole-shard fault killed the k data queries and their parity
//! together — exactly the correlated failure the paper's erasure-coding
//! framing is meant to absorb. This module stripes each group across
//! shards instead:
//!
//! ```text
//!   shard 0 session      shard 1 session       shard k-1 session
//!   CrossShardScheme     CrossShardScheme  …   CrossShardScheme
//!        │ offer(batch)       │ offer(batch)        │ offer(batch)
//!        └────────────┬───────┴─────────────────────┘
//!                     ▼
//!            CrossShardState (fleet-shared, one mutex)
//!              open groups: one slot per *distinct* shard
//!              seal at k slots: r ← FleetPredictor.recommend_r
//!              GroupTracker (shard-tagged slots) + decode
//!                     │ r parity jobs            ▲ parity outputs
//!                     ▼                          │
//!            parity driver thread ──▶ shared parity sessions
//!            (one session per r_index, ceil(shards·m / k) instances)
//! ```
//!
//! - **Topology.** A group's k slots come from k *distinct* shards (the
//!   state never places two batches of one shard in the same group), so
//!   killing an entire shard costs every group at most one slot — which
//!   decodes like any single-instance loss as long as one parity
//!   survives. The parity queries live in a *shared cross-shard pool*
//!   (their own sessions, their own fault domain), not in any data
//!   shard.
//! - **Redundancy.** Group r is chosen at seal time by a fleet-level
//!   [`FleetPredictor`]: per-shard unavailability estimates merged with
//!   a Poisson-binomial tail over the k most unavailable domains, so a
//!   correlated fault observed on one shard warms *every* group's r.
//! - **Resolution.** Data completions resolve natively inside their own
//!   shard's session as always. Decoded slots are routed back to the
//!   owning shard through per-shard queues, drained by that session's
//!   [`RedundancyScheme::drain_external`] hook at its pump cadence — so
//!   a fully dead shard still delivers reconstructions to its clients.
//! - **Tails.** Open groups that outlive the loss horizon (a drained or
//!   idle shard would otherwise strand them) are *short-sealed*: padded
//!   with zero-input phantom slots that resolve immediately, so the real
//!   queries still get parity protection instead of riding the SLO.
//!
//! The user-facing tier is
//! [`crate::coordinator::shards::CrossShardFrontend`]; this module holds
//! the shared state, the per-shard scheme, the parity-leg schemes, and
//! the parity driver thread. [`CrossShardState`] is deliberately
//! clock-free (every method takes the observation instant), so the
//! seeded property suites drive it without a cluster.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::faults::FaultPlan;
use crate::coordinator::adaptive::{FleetPredictor, PredictorConfig};
use crate::coordinator::batcher::SealedBatch;
use crate::coordinator::coding::{GroupTracker, Resolutions};
use crate::coordinator::encoder::Encoder;
use crate::coordinator::metrics::Outcome;
use crate::coordinator::scheme::{
    job, DispatchPlan, PoolLayout, RedundancyScheme, Resolution, SchemeTelemetry, Target,
};
use crate::coordinator::service::{ModelSet, RunResult, ServiceConfig};
use crate::util::sync::LockExt;
use crate::coordinator::session::{ServiceBuilder, ServiceHandle};
use crate::runtime::instance::{Completion, Job, JobKind};
use crate::tensor::Tensor;
use crate::util::rng::SplitMix64;

/// Sizing and pacing knobs of the cross-shard coding tier.
#[derive(Clone, Debug)]
pub struct CrossShardConfig {
    /// Coding-group size; each group's slots come from k distinct shards.
    pub k: usize,
    /// Per-group parity floor.
    pub r_min: usize,
    /// Per-group parity ceiling (the shared pool is provisioned for it).
    pub r_max: usize,
    /// Data shards the groups stripe over (must be >= k).
    pub shards: usize,
    /// Per-shard straggler-predictor knobs (fleet-merged at seal time).
    pub predictor: PredictorConfig,
    /// A sealed group still unresolved after this long counts its
    /// missing slots as per-shard losses; open groups older than it are
    /// short-sealed; groups are abandoned at 4x this horizon.
    pub miss_horizon: Duration,
}

impl CrossShardConfig {
    /// The declarative form used by `mode: "cross-shard"` configs.
    pub fn new(
        k: usize,
        r_min: usize,
        r_max: usize,
        shards: usize,
        halflife: Duration,
    ) -> CrossShardConfig {
        CrossShardConfig {
            k,
            r_min,
            r_max,
            shards,
            predictor: PredictorConfig { halflife, ..PredictorConfig::default() },
            miss_horizon: (halflife * 2).max(Duration::from_millis(200)),
        }
    }
}

/// One encoded parity batch bound for the shared pool, as b single-row
/// queries (the parity session's batcher reassembles them into the exact
/// `[b, …]` shape its executable was compiled for).
pub struct ParityJob {
    pub group: u64,
    pub r_index: usize,
    pub rows: Vec<Tensor>,
}

/// Messages to the parity driver thread.
pub(crate) enum ParityMsg {
    Job(ParityJob),
    /// Re-provision every per-r_index pool to `per` instances: fresh
    /// sessions (a new epoch) take over new jobs while the outgoing
    /// generation finishes its in-flight parity work before retiring —
    /// no open group loses its protection mid-resize.
    Resize { per: usize },
    Stop,
}

/// Live operating point of the cross-shard tier.
#[derive(Clone, Debug)]
pub struct CrossShardTelemetry {
    /// Redundancy chosen for the most recently sealed group.
    pub last_r: usize,
    /// Worst per-shard unavailability estimate.
    pub fleet_unavailability: f64,
    /// Per-shard unavailability estimates, indexed by shard.
    pub per_shard_unavailability: Vec<f64>,
    pub groups_sealed: u64,
    pub parity_jobs: u64,
    /// Total cross-shard reconstructions so far.
    pub reconstructions: u64,
    /// Groups currently tracked (open + sealed-unresolved).
    pub open_groups: usize,
}

/// A data batch waiting in an unsealed group.
struct OpenSlot {
    shard: usize,
    ids: Vec<u64>,
    input: Tensor,
    /// Data output that raced ahead of the group's seal.
    early: Option<(Tensor, Instant)>,
}

/// An unsealed coding group: at most one slot per shard.
struct OpenGroup {
    id: u64,
    created: Instant,
    slots: Vec<OpenSlot>,
    has_shard: Vec<bool>,
}

/// Bookkeeping for the stale-group sweep.
struct SealedMeta {
    group: u64,
    at: Instant,
    losses_counted: bool,
}

struct Inner {
    cfg: CrossShardConfig,
    /// `r_max` §3.5 weight rows; a group sealed with r uses the first r.
    encoders: Vec<Encoder>,
    tracker: GroupTracker,
    open: Vec<OpenGroup>,
    next_group: u64,
    predictor: FleetPredictor,
    /// Wired by the tier before any shard can seal; `None` in pure
    /// property tests (parities are then fed via `on_parity`).
    parity_tx: Option<mpsc::Sender<ParityMsg>>,
    /// (r_index, pool epoch, first session qid of the parity batch) ->
    /// group. The epoch disambiguates generations across parity-pool
    /// resizes: a fresh session restarts its qids at zero, so without it
    /// a stale route from a retired generation could claim a new job's
    /// completion.
    parity_routes: HashMap<(usize, u64, u64), u64>,
    /// (group, slot) -> data dispatch instant (predictor latency obs).
    dispatch_at: HashMap<(u64, usize), Instant>,
    /// Sealed groups awaiting the stale sweep, oldest first.
    sealed: VecDeque<SealedMeta>,
    /// Groups whose stuck slots were already counted as losses.
    loss_counted: HashSet<u64>,
    /// Decoded (query ids, at) per shard, awaiting that session's drain.
    external: Vec<VecDeque<(Vec<u64>, Instant)>>,
    recon_by_shard: Vec<u64>,
    /// Zero tensor shaped like model outputs (phantom slots of short
    /// groups); captured from the first output observed fleet-wide.
    out_zeros: Option<Tensor>,
    last_sweep: Instant,
    last_r: usize,
    groups_sealed: u64,
    parity_jobs: u64,
    /// Serving-path journal for fleet-level events (group seals and
    /// cross-shard decodes); the per-shard sessions record their own
    /// submit/dispatch/complete events through their tagged clones.
    recorder: crate::coordinator::journal::Recorder,
}

/// Throttle on the stale sweep (mirrors the rateless scheme's).
const SWEEP_EVERY: Duration = Duration::from_millis(25);

/// Route a batch of tracker resolutions: decoded slots go to their
/// owning shard's external queue (and count as that shard's loss unless
/// the sweep already counted the group); native verdicts were already
/// resolved inside their own session; phantom slots (empty ids) are
/// bookkeeping only.
fn apply_tracker(inner: &mut Inner, group: u64, res: Resolutions, at: Instant) {
    let counted = inner.loss_counted.contains(&group);
    for sr in res.resolved {
        if !sr.reconstructed || sr.query_ids.is_empty() {
            continue;
        }
        if !counted {
            inner.predictor.observe_losses(sr.tag, 1, at);
        }
        if sr.tag < inner.external.len() {
            inner.recon_by_shard[sr.tag] += 1;
            inner
                .recorder
                .record(&crate::coordinator::journal::Event::Decode {
                    group,
                    slot: sr.slot as u64,
                });
            inner.external[sr.tag].push_back((sr.query_ids, at));
        } else {
            log::error!("cross-shard: decoded slot with out-of-range tag {}", sr.tag);
        }
    }
}

/// Seal one group: pick r from the fleet predictor, register the
/// shard-tagged slots, encode + dispatch r parities, pad short groups
/// with phantom slots, and replay any early data completions.
fn seal(inner: &mut Inner, og: OpenGroup, now: Instant) {
    let k = inner.cfg.k;
    let gid = og.id;
    if og.slots.len() < k && inner.out_zeros.is_none() {
        // No output observed fleet-wide yet, so phantom slots cannot be
        // shaped (and nothing is decodable anyway): drop the group
        // uncoded — its queries resolve natively or via the session SLO.
        for s in 0..og.slots.len() {
            inner.dispatch_at.remove(&(gid, s));
        }
        return;
    }
    let r = inner.predictor.recommend_r(k, inner.cfg.r_min, inner.cfg.r_max, now);
    inner.last_r = r;
    inner.groups_sealed += 1;
    inner.recorder.record(&crate::coordinator::journal::Event::Seal {
        group: gid,
        k: k as u64,
        r: r as u64,
    });

    let mut ids = Vec::with_capacity(k);
    let mut tags = Vec::with_capacity(k);
    let mut inputs = Vec::with_capacity(k);
    let mut early = Vec::with_capacity(k);
    let first_shard = og.slots[0].shard;
    for s in og.slots {
        ids.push(s.ids);
        tags.push(s.shard);
        inputs.push(s.input);
        early.push(s.early);
    }
    let phantom_from = ids.len();
    while ids.len() < k {
        // Short groups (stale/drain flush) pad with phantoms: zero
        // input to the encoder, zero output fed back below, no query
        // ids — only the real slots remain "missing" to the decoder.
        ids.push(Vec::new());
        tags.push(first_shard);
        inputs.push(Tensor::zeros(inputs[0].shape().to_vec()));
        early.push(None);
    }
    inner.tracker.register_tagged(gid, ids, r, tags);
    inner.sealed.push_back(SealedMeta { group: gid, at: now, losses_counted: false });

    let mut parities = Vec::with_capacity(r);
    {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        for (ri, enc) in inner.encoders.iter().take(r).enumerate() {
            match enc.encode_batches(&refs) {
                Ok(parity) => parities.push((ri, parity)),
                Err(e) => log::error!("cross-shard encode failed: {e}"),
            }
        }
    }
    for (ri, parity) in parities {
        inner.parity_jobs += 1;
        if let Some(tx) = &inner.parity_tx {
            let _ = tx.send(ParityMsg::Job(ParityJob {
                group: gid,
                r_index: ri,
                rows: parity.unbatch(),
            }));
        }
    }

    if phantom_from < k {
        let zeros = inner.out_zeros.clone().expect("guarded above");
        for slot in phantom_from..k {
            let res = inner.tracker.on_data(gid, slot, zeros.clone());
            apply_tracker(inner, gid, res, now);
        }
    }
    for (slot, e) in early.into_iter().enumerate() {
        if let Some((out, at)) = e {
            let res = inner.tracker.on_data(gid, slot, out);
            apply_tracker(inner, gid, res, at);
        }
    }
}

impl Inner {
    fn sweep(&mut self, now: Instant) {
        if now.saturating_duration_since(self.last_sweep) < SWEEP_EVERY {
            return;
        }
        self.last_sweep = now;
        // Raise the horizon when the fleet itself is slow, so healthy
        // but slow groups are not misread as losses.
        let mean_ms = self.predictor.mean_latency_ms();
        let horizon = self
            .cfg
            .miss_horizon
            .max(Duration::from_secs_f64(8.0 * mean_ms / 1e3));
        let abandon_after = horizon * 4;

        // Open groups past the horizon will not fill on their own (a
        // drained or idle shard): short-seal them so their real slots
        // get parity protection instead of riding the SLO.
        let mut i = 0;
        while i < self.open.len() {
            if now.saturating_duration_since(self.open[i].created) > horizon {
                let og = self.open.remove(i);
                seal(self, og, now);
            } else {
                i += 1;
            }
        }

        // Sealed groups: stuck slots become per-shard loss observations
        // at the horizon; the group is abandoned at 4x (its queries
        // default via their sessions' SLO).
        let mut keep = VecDeque::with_capacity(self.sealed.len());
        while let Some(mut meta) = self.sealed.pop_front() {
            let age = now.saturating_duration_since(meta.at);
            if !self.tracker.contains(meta.group) {
                // Fully resolved (or abandoned): once old enough that no
                // in-flight completion can still reference it, drop the
                // dispatch stamps and parity routes its zombies never
                // consumed (a dead parity instance would otherwise leak
                // one route entry per swallowed parity job, forever).
                if age > horizon {
                    for s in 0..self.cfg.k {
                        self.dispatch_at.remove(&(meta.group, s));
                    }
                    self.loss_counted.remove(&meta.group);
                    let gid = meta.group;
                    self.parity_routes.retain(|_, g| *g != gid);
                } else {
                    keep.push_back(meta);
                }
                continue;
            }
            if age > horizon && !meta.losses_counted {
                let unresolved = self.tracker.unresolved_slots(meta.group);
                if !unresolved.is_empty() {
                    for &slot in &unresolved {
                        if let Some(tag) = self.tracker.slot_tag(meta.group, slot) {
                            self.predictor.observe_losses(tag, 1, now);
                        }
                    }
                    self.loss_counted.insert(meta.group);
                }
                meta.losses_counted = true;
            }
            if age > abandon_after {
                self.tracker.abandon(meta.group);
                for s in 0..self.cfg.k {
                    self.dispatch_at.remove(&(meta.group, s));
                }
                self.loss_counted.remove(&meta.group);
                let gid = meta.group;
                self.parity_routes.retain(|_, g| *g != gid);
                continue;
            }
            keep.push_back(meta);
        }
        self.sealed = keep;
    }
}

/// Fleet-shared coding state: open groups, the shard-tagged
/// [`GroupTracker`], the [`FleetPredictor`], and the per-shard decoded
/// queues. One mutex, short critical sections; every entry point takes
/// the observation instant so the property suites can drive it without
/// threads or clocks.
pub struct CrossShardState {
    inner: Mutex<Inner>,
}

impl CrossShardState {
    pub fn new(cfg: CrossShardConfig) -> CrossShardState {
        assert!(cfg.k >= 2, "cross-shard coding needs k >= 2");
        assert!(
            cfg.r_min >= 1 && cfg.r_min <= cfg.r_max && cfg.r_max <= cfg.k,
            "need 1 <= r_min <= r_max <= k, got r_min={} r_max={} k={}",
            cfg.r_min,
            cfg.r_max,
            cfg.k
        );
        assert!(
            cfg.shards >= cfg.k,
            "groups stripe k={} slots over distinct shards; need shards >= k, got {}",
            cfg.k,
            cfg.shards
        );
        let encoders: Vec<Encoder> =
            (0..cfg.r_max).map(|ri| Encoder::sum_r(cfg.k, ri)).collect();
        let inner = Inner {
            tracker: GroupTracker::new(cfg.k, &encoders),
            encoders,
            open: Vec::new(),
            next_group: 0,
            predictor: FleetPredictor::new(cfg.shards, cfg.predictor.clone()),
            parity_tx: None,
            parity_routes: HashMap::new(),
            dispatch_at: HashMap::new(),
            sealed: VecDeque::new(),
            loss_counted: HashSet::new(),
            external: (0..cfg.shards).map(|_| VecDeque::new()).collect(),
            recon_by_shard: vec![0; cfg.shards],
            out_zeros: None,
            last_sweep: Instant::now(),
            last_r: cfg.r_min,
            groups_sealed: 0,
            parity_jobs: 0,
            recorder: crate::coordinator::journal::Recorder::disabled(),
            cfg,
        };
        CrossShardState { inner: Mutex::new(inner) }
    }

    /// Wire the parity driver's channel (done by the tier before any
    /// shard serves traffic).
    pub(crate) fn set_parity_sender(&self, tx: mpsc::Sender<ParityMsg>) {
        self.inner.plock().parity_tx = Some(tx);
    }

    /// Join a serving-path journal: fleet-level seals and decodes are
    /// recorded through this handle (the tier wires it from the config's
    /// recorder at startup).
    pub fn set_recorder(&self, recorder: crate::coordinator::journal::Recorder) {
        self.inner.plock().recorder = recorder;
    }

    /// Extend the striping width to `shards` (elastic scale-out). Shard
    /// indices are append-only fleet-wide, so growth only ever extends
    /// the per-shard vectors; a smaller or equal count is a no-op.
    /// Already-open groups widen their shard masks so the new shard can
    /// join them immediately.
    pub fn grow_to(&self, shards: usize) {
        let mut g = self.inner.plock();
        if shards <= g.cfg.shards {
            return;
        }
        g.cfg.shards = shards;
        g.predictor.grow_to(shards);
        while g.external.len() < shards {
            g.external.push(VecDeque::new());
        }
        g.recon_by_shard.resize(shards, 0);
        for og in &mut g.open {
            og.has_shard.resize(shards, false);
        }
    }

    /// Take a shard out of the coding fleet (elastic scale-in). Its
    /// index stays valid forever (append-only), but the predictor stops
    /// counting it toward fleet unavailability and any decoded slots
    /// still queued for it are dropped — the owning session is already
    /// gone, so nobody could deliver them. Idempotent.
    pub fn retire_shard(&self, shard: usize) {
        let mut g = self.inner.plock();
        if shard >= g.cfg.shards {
            return;
        }
        g.predictor.set_active(shard, false);
        let dropped = g.external[shard].len();
        if dropped > 0 {
            log::debug!(
                "cross-shard: retiring shard {shard} dropped {dropped} \
                 undeliverable decoded batches"
            );
        }
        g.external[shard].clear();
    }

    /// Offer one sealed data batch from `shard`; returns the (group,
    /// slot) it was assigned — the batch joins the first open group not
    /// yet containing this shard (or starts a new one), and the group
    /// seals once it holds k slots from k distinct shards.
    pub fn offer(
        &self,
        shard: usize,
        ids: Vec<u64>,
        input: Tensor,
        now: Instant,
    ) -> (u64, usize) {
        let mut g = self.inner.plock();
        assert!(shard < g.cfg.shards, "shard {shard} out of range");
        let k = g.cfg.k;
        let idx = match g.open.iter().position(|og| !og.has_shard[shard]) {
            Some(i) => i,
            None => {
                let id = g.next_group;
                g.next_group += 1;
                let shards = g.cfg.shards;
                g.open.push(OpenGroup {
                    id,
                    created: now,
                    slots: Vec::with_capacity(k),
                    has_shard: vec![false; shards],
                });
                g.open.len() - 1
            }
        };
        let gid = g.open[idx].id;
        let slot = g.open[idx].slots.len();
        g.open[idx].slots.push(OpenSlot { shard, ids, input, early: None });
        g.open[idx].has_shard[shard] = true;
        g.dispatch_at.insert((gid, slot), now);
        if g.open[idx].slots.len() == k {
            let og = g.open.remove(idx);
            seal(&mut g, og, now);
        }
        g.sweep(now);
        (gid, slot)
    }

    /// Feed a data completion from `shard` for (group, slot).
    pub fn on_data(
        &self,
        shard: usize,
        group: u64,
        slot: usize,
        instance: usize,
        output: Tensor,
        at: Instant,
    ) {
        let mut g = self.inner.plock();
        if g.out_zeros.is_none() {
            g.out_zeros = Some(Tensor::zeros(output.shape().to_vec()));
        }
        if let Some(t0) = g.dispatch_at.remove(&(group, slot)) {
            g.predictor.observe_completion(
                shard,
                instance,
                at.saturating_duration_since(t0),
                at,
            );
        }
        if let Some(og) = g.open.iter_mut().find(|og| og.id == group) {
            // The group has not sealed yet: buffer the output so the
            // tracker sees it at registration time.
            if slot < og.slots.len() && og.slots[slot].early.is_none() {
                og.slots[slot].early = Some((output, at));
            }
        } else {
            let res = g.tracker.on_data(group, slot, output);
            apply_tracker(&mut g, group, res, at);
        }
        g.sweep(at);
    }

    /// Feed a parity output for a known (group, r_index) — the pure-test
    /// entry; the serving path arrives via [`CrossShardState::on_parity_output`].
    pub fn on_parity(&self, group: u64, r_index: usize, output: Tensor, at: Instant) {
        let mut g = self.inner.plock();
        if g.out_zeros.is_none() {
            g.out_zeros = Some(Tensor::zeros(output.shape().to_vec()));
        }
        let res = g.tracker.on_parity(group, r_index, output);
        apply_tracker(&mut g, group, res, at);
        g.sweep(at);
    }

    /// Feed a parity-session completion, resolving the (group, r_index)
    /// it belongs to via the route the driver recorded at submit time.
    pub(crate) fn on_parity_output(
        &self,
        r_index: usize,
        epoch: u64,
        first_qid: u64,
        output: Tensor,
        at: Instant,
    ) {
        let group = {
            let mut g = self.inner.plock();
            match g.parity_routes.remove(&(r_index, epoch, first_qid)) {
                Some(group) => group,
                None => {
                    // Benign for a straggling parity whose group already
                    // retired (the sweep cleans routes past the horizon).
                    log::debug!(
                        "cross-shard: parity completion with no live route \
                         (r{r_index}, epoch {epoch}, qid {first_qid})"
                    );
                    return;
                }
            }
        };
        self.on_parity(group, r_index, output, at);
    }

    /// Record which group a just-submitted parity batch serves (keyed by
    /// the pool generation and the batch's first parity-session query id).
    pub(crate) fn record_parity_route(
        &self,
        r_index: usize,
        epoch: u64,
        first_qid: u64,
        group: u64,
    ) {
        self.inner
            .lock()
            .unwrap()
            .parity_routes
            .insert((r_index, epoch, first_qid), group);
    }

    /// Take the decoded (query ids, at) pairs owed to `shard`, running
    /// the stale sweep on the way (this is the call every shard's
    /// session makes at its pump cadence, so it also drives sweeps when
    /// traffic stalls).
    pub fn drain_decoded(&self, shard: usize, now: Instant) -> Vec<(Vec<u64>, Instant)> {
        let mut g = self.inner.plock();
        g.sweep(now);
        g.external[shard].drain(..).collect()
    }

    pub(crate) fn drain_shard_resolutions(&self, shard: usize) -> Vec<Resolution> {
        self.drain_decoded(shard, Instant::now())
            .into_iter()
            .map(|(ids, at)| Resolution {
                query_ids: ids,
                at,
                outcome: Outcome::Reconstructed,
            })
            .collect()
    }

    /// Short-seal every open group now (drain aid): queries waiting in
    /// groups that will not fill get their parity protection instead of
    /// riding the session SLO.
    pub fn flush_open(&self, now: Instant) {
        let mut g = self.inner.plock();
        let open = std::mem::take(&mut g.open);
        for og in open {
            if og.slots.is_empty() {
                continue;
            }
            seal(&mut g, og, now);
        }
    }

    /// Cross-shard reconstructions whose decoded slot belonged to `shard`.
    pub fn reconstructions_for(&self, shard: usize) -> u64 {
        self.inner.plock().recon_by_shard[shard]
    }

    /// Total cross-shard reconstructions.
    pub fn reconstructions(&self) -> u64 {
        self.inner.plock().tracker.reconstructions
    }

    /// Parity count a sealed group carries (None once resolved/unknown).
    pub fn group_r(&self, group: u64) -> Option<usize> {
        self.inner.plock().tracker.group_r(group)
    }

    /// Whether a sealed group is still tracked.
    pub fn contains(&self, group: u64) -> bool {
        self.inner.plock().tracker.contains(group)
    }

    /// Unresolved slots of a sealed group.
    pub fn unresolved_slots(&self, group: u64) -> Vec<usize> {
        self.inner.plock().tracker.unresolved_slots(group)
    }

    /// Groups still accumulating slots.
    pub fn open_groups(&self) -> usize {
        self.inner.plock().open.len()
    }

    pub(crate) fn scheme_telemetry(&self) -> SchemeTelemetry {
        let g = self.inner.plock();
        SchemeTelemetry {
            last_r: g.last_r,
            unavailability: g.predictor.fleet_unavailability(Instant::now()),
            groups_sealed: g.groups_sealed,
            parity_jobs: g.parity_jobs,
        }
    }

    /// The tier-level view: fleet + per-shard estimates and counters.
    pub fn fleet_telemetry(&self) -> CrossShardTelemetry {
        let g = self.inner.plock();
        let now = Instant::now();
        CrossShardTelemetry {
            last_r: g.last_r,
            fleet_unavailability: g.predictor.fleet_unavailability(now),
            per_shard_unavailability: (0..g.cfg.shards)
                .map(|s| g.predictor.shard_unavailability(s, now))
                .collect(),
            groups_sealed: g.groups_sealed,
            parity_jobs: g.parity_jobs,
            reconstructions: g.tracker.reconstructions,
            open_groups: g.open.len() + g.tracker.open_groups(),
        }
    }
}

// ------------------------------------------------------------------------
// Per-shard data scheme
// ------------------------------------------------------------------------

/// The per-shard face of the cross-shard code: lives inside one shard's
/// session as its [`RedundancyScheme`], forwards every sealed batch to
/// the fleet state for group assignment, resolves its own data
/// completions natively, and drains decoded slots owed to this shard
/// through [`RedundancyScheme::drain_external`].
pub struct CrossShardScheme {
    shard: usize,
    state: Arc<CrossShardState>,
}

impl CrossShardScheme {
    pub fn new(shard: usize, state: Arc<CrossShardState>) -> CrossShardScheme {
        CrossShardScheme { shard, state }
    }
}

impl RedundancyScheme for CrossShardScheme {
    fn name(&self) -> &'static str {
        "cross-shard"
    }

    fn extra_instances(&self, _m: usize) -> usize {
        // Parity lives in the tier's shared pool, not in any data shard.
        0
    }

    fn layout(&self, m: usize) -> PoolLayout {
        PoolLayout { deployed: (0..m).collect(), parity: Vec::new(), approx: None }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let now = Instant::now();
        let (group, slot) =
            self.state
                .offer(self.shard, batch.query_ids.clone(), batch.input.clone(), now);
        DispatchPlan {
            jobs: vec![(
                Target::Deployed,
                Job {
                    kind: JobKind::Data { group, slot },
                    input: batch.input,
                    query_ids: batch.query_ids,
                    dispatched_at: now,
                },
            )],
            resolutions: self.state.drain_shard_resolutions(self.shard),
        }
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        let mut out = Vec::new();
        if let JobKind::Data { group, slot } = c.kind {
            // Predictions go straight back to clients (§3.1), then feed
            // the fleet decode state.
            out.push(Resolution {
                query_ids: c.query_ids.clone(),
                at: c.finished_at,
                outcome: Outcome::Native,
            });
            self.state.on_data(self.shard, group, slot, c.instance, c.output, c.finished_at);
        }
        out.extend(self.state.drain_shard_resolutions(self.shard));
        out
    }

    fn drain_external(&mut self) -> Vec<Resolution> {
        self.state.drain_shard_resolutions(self.shard)
    }

    fn reconstructions(&self) -> u64 {
        self.state.reconstructions_for(self.shard)
    }

    fn telemetry(&self) -> Option<SchemeTelemetry> {
        Some(self.state.scheme_telemetry())
    }
}

// ------------------------------------------------------------------------
// Parity leg
// ------------------------------------------------------------------------

/// Scheme of one shared-parity-pool session (one session per r_index):
/// every sealed batch — the driver submits exactly one encoded parity
/// batch's rows at a time, so batches align 1:1 with parity jobs — runs
/// on the parity pool, resolves natively within this session, and its
/// output feeds the fleet decode state via the route the driver
/// recorded.
pub(crate) struct ParityTapScheme {
    r_index: usize,
    /// Pool generation this session belongs to; baked into every route
    /// lookup so qids restarting at zero after a resize cannot collide
    /// with a retiring generation's in-flight routes.
    epoch: u64,
    state: Arc<CrossShardState>,
    next_group: u64,
}

impl ParityTapScheme {
    pub(crate) fn new(
        r_index: usize,
        epoch: u64,
        state: Arc<CrossShardState>,
    ) -> ParityTapScheme {
        ParityTapScheme { r_index, epoch, state, next_group: 0 }
    }
}

impl RedundancyScheme for ParityTapScheme {
    fn name(&self) -> &'static str {
        "cross-shard-parity"
    }

    fn extra_instances(&self, _m: usize) -> usize {
        0
    }

    fn layout(&self, m: usize) -> PoolLayout {
        PoolLayout { deployed: (0..m).collect(), parity: Vec::new(), approx: None }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let gid = self.next_group;
        self.next_group += 1;
        DispatchPlan {
            jobs: vec![(
                Target::Deployed,
                job(JobKind::Replica { group: gid, slot: 0 }, &batch),
            )],
            resolutions: Vec::new(),
        }
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        match c.kind {
            JobKind::Replica { .. } => {
                if let Some(&fid) = c.query_ids.first() {
                    self.state.on_parity_output(
                        self.r_index,
                        self.epoch,
                        fid,
                        c.output.clone(),
                        c.finished_at,
                    );
                }
                vec![Resolution {
                    query_ids: c.query_ids,
                    at: c.finished_at,
                    outcome: Outcome::Native,
                }]
            }
            _ => Vec::new(),
        }
    }
}

/// Builds one parity session: (r_index, per-pool instances, epoch) ->
/// handle. Owned by the driver thread so [`ParityMsg::Resize`] can stamp
/// out a fresh generation without touching the caller.
type ParityFactory = Box<dyn Fn(usize, usize, u64) -> anyhow::Result<ServiceHandle> + Send>;

fn parity_factory(
    cfg: &ServiceConfig,
    state: &Arc<CrossShardState>,
    models: &ModelSet,
    sample_query: &Tensor,
    r_max: usize,
) -> ParityFactory {
    let cfg = cfg.clone();
    let state = state.clone();
    let parities = models.parities.clone();
    let sample = sample_query.clone();
    Box::new(move |ri: usize, per: usize, epoch: u64| {
        let mut pc = cfg.clone();
        pc.m = per;
        // Independent fault domain with a decorrelated seed (the tier's
        // scheduled faults target data shard 0 only); the epoch keeps
        // successive generations of the same pool decorrelated too.
        pc.seed = SplitMix64::new(
            cfg.seed ^ 0x9A21_17CE ^ ((ri as u64) << 24) ^ (epoch << 48),
        )
        .next_u64();
        pc.fault_schedule.clear();
        // Teardown must terminate even if parity instances die: force an
        // SLO backstop on the leg.
        pc.slo = Some(cfg.slo.unwrap_or(Duration::from_secs(5)));
        // Parity sessions host internal parity jobs, not client queries;
        // their session-local events would collide with data-shard tags
        // in the journal. The journal sees parity activity through the
        // fleet state's Seal/Decode events instead.
        pc.recorder = crate::coordinator::journal::Recorder::disabled();
        // Metric families likewise: scope each parity session under its
        // r_index so parity traffic never collides with (or races) the
        // data shards' label spaces in the shared fleet registry.
        pc.telemetry = cfg.telemetry.scoped("parity_r", ri);
        let leg_models = ModelSet {
            deployed: parities
                .get(ri)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "cross-shard r_max={r_max} needs parity model {ri}, \
                         ModelSet has {}",
                        parities.len()
                    )
                })?
                .clone(),
            parities: Vec::new(),
            approx: None,
        };
        ServiceBuilder::new(pc)
            .with_scheme(Box::new(ParityTapScheme::new(ri, epoch, state.clone())))
            .build(&leg_models, &sample)
    })
}

/// The shared parity pool: one session per parity index (each pool runs
/// that index's parity model), all owned by one driver thread that
/// submits [`ParityJob`]s and pumps completions back into the fleet
/// state. [`ParityLeg::resize`] re-provisions every pool at runtime:
/// the driver stands up a fresh generation (next epoch) for new jobs and
/// keeps pumping the outgoing one until its in-flight parity work
/// resolves, so no coding group loses protection across the swap.
pub(crate) struct ParityLeg {
    tx: mpsc::Sender<ParityMsg>,
    handle: Option<JoinHandle<Vec<RunResult>>>,
    /// Current generation's fault plans, refreshed by the driver on each
    /// completed resize (chaos drills always target the live pools).
    faults: Arc<Mutex<Vec<Arc<FaultPlan>>>>,
    /// Instances per r_index pool in the current generation.
    per_pool: Arc<AtomicUsize>,
}

impl ParityLeg {
    /// Build `r_max` parity sessions (`per` instances each) and start
    /// the driver thread. `tx`/`rx` are the job channel the fleet state
    /// already holds a sender of.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn start(
        cfg: &ServiceConfig,
        state: &Arc<CrossShardState>,
        models: &ModelSet,
        sample_query: &Tensor,
        per: usize,
        r_max: usize,
        tx: mpsc::Sender<ParityMsg>,
        rx: mpsc::Receiver<ParityMsg>,
    ) -> anyhow::Result<ParityLeg> {
        let factory = parity_factory(cfg, state, models, sample_query, r_max);
        let mut handles = Vec::with_capacity(r_max);
        let mut plans = Vec::with_capacity(r_max);
        for ri in 0..r_max {
            let handle = factory(ri, per, 0)?;
            plans.push(handle.fault_plan());
            handles.push(handle);
        }
        let faults = Arc::new(Mutex::new(plans));
        let per_pool = Arc::new(AtomicUsize::new(per));
        let driver_state = state.clone();
        let driver_faults = faults.clone();
        let driver_per = per_pool.clone();
        let handle = std::thread::Builder::new()
            .name("cross-shard-parity".into())
            .spawn(move || {
                driver_loop(factory, handles, rx, driver_state, driver_faults, driver_per)
            })
            .expect("spawn cross-shard parity driver");
        Ok(ParityLeg { tx, handle: Some(handle), faults, per_pool })
    }

    /// Instances in each per-r_index parity pool (current generation).
    pub(crate) fn pool_size(&self) -> usize {
        self.per_pool.load(Ordering::SeqCst)
    }

    /// Ask the driver to re-provision every pool to `per` instances.
    /// Asynchronous and idempotent: a no-op if `per` already matches by
    /// the time the driver sees it; [`ParityLeg::pool_size`] reflects
    /// the swap once the new generation is serving.
    pub(crate) fn resize(&self, per: usize) {
        let _ = self.tx.send(ParityMsg::Resize { per });
    }

    /// Fault plan of the r_index-th parity pool (chaos drills).
    pub(crate) fn fault_plan(&self, r_index: usize) -> Arc<FaultPlan> {
        self.faults.plock()[r_index].clone()
    }

    /// Permanently kill one instance of the r_index-th parity pool.
    pub(crate) fn kill(&self, r_index: usize, instance: usize) {
        self.faults.plock()[r_index].kill(instance);
    }

    /// Stop the driver, drain the parity sessions, and return their run
    /// records (parity queries, separate from client traffic), one per
    /// r_index — resize generations of the same pool are merged.
    pub(crate) fn stop(mut self) -> Vec<RunResult> {
        let _ = self.tx.send(ParityMsg::Stop);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => Vec::new(),
        }
    }
}

impl Drop for ParityLeg {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = self.tx.send(ParityMsg::Stop);
            let _ = h.join();
        }
    }
}

fn submit_parity(
    handles: &mut [ServiceHandle],
    state: &CrossShardState,
    job: ParityJob,
    epoch: u64,
) {
    let Some(h) = handles.get_mut(job.r_index) else {
        log::error!("cross-shard: parity job for unprovisioned r_index {}", job.r_index);
        return;
    };
    // The rows of one job are exactly one session batch (rows.len() ==
    // the leg's batch_size), so the batch seals during the last submit
    // and its first query id keys the route. The route is recorded
    // before this thread next polls, and completions are only processed
    // in poll — no race.
    let mut first = None;
    for row in job.rows {
        let qid = h.submit(row);
        first.get_or_insert(qid);
    }
    if let Some(fid) = first {
        state.record_parity_route(job.r_index, epoch, fid, job.group);
    }
}

/// Swap in a fresh generation of parity sessions sized `per`. All-or-
/// nothing: if any pool fails to build, the current generation keeps
/// serving and the resize is dropped with an error log. Old sessions go
/// to `retiring`, where the driver pumps them until their in-flight
/// parity work resolves.
#[allow(clippy::too_many_arguments)]
fn apply_resize(
    factory: &ParityFactory,
    per: usize,
    epoch: &mut u64,
    handles: &mut [ServiceHandle],
    retiring: &mut Vec<(usize, ServiceHandle)>,
    faults: &Mutex<Vec<Arc<FaultPlan>>>,
    per_pool: &AtomicUsize,
) {
    if per == 0 || per == per_pool.load(Ordering::SeqCst) {
        return;
    }
    let next_epoch = *epoch + 1;
    let mut fresh = Vec::with_capacity(handles.len());
    for ri in 0..handles.len() {
        match factory(ri, per, next_epoch) {
            Ok(h) => fresh.push(h),
            Err(e) => {
                log::error!(
                    "cross-shard: parity resize to {per} failed at r{ri}: {e}; \
                     keeping the current pools"
                );
                return;
            }
        }
    }
    *epoch = next_epoch;
    let mut plans = faults.plock();
    for (ri, new) in fresh.into_iter().enumerate() {
        plans[ri] = new.fault_plan();
        let old = std::mem::replace(&mut handles[ri], new);
        retiring.push((ri, old));
    }
    per_pool.store(per, Ordering::SeqCst);
}

fn driver_loop(
    factory: ParityFactory,
    mut handles: Vec<ServiceHandle>,
    rx: mpsc::Receiver<ParityMsg>,
    state: Arc<CrossShardState>,
    faults: Arc<Mutex<Vec<Arc<FaultPlan>>>>,
    per_pool: Arc<AtomicUsize>,
) -> Vec<RunResult> {
    let r_max = handles.len();
    let mut epoch: u64 = 0;
    // Outgoing generations still owing parity completions, plus the
    // per-r_index run records of generations already retired.
    let mut retiring: Vec<(usize, ServiceHandle)> = Vec::new();
    let mut retired: Vec<Vec<RunResult>> = (0..r_max).map(|_| Vec::new()).collect();
    let mut stopping = false;
    while !stopping {
        match rx.recv_timeout(Duration::from_millis(1)) {
            Ok(ParityMsg::Job(job)) => submit_parity(&mut handles, &state, job, epoch),
            Ok(ParityMsg::Resize { per }) => apply_resize(
                &factory,
                per,
                &mut epoch,
                &mut handles,
                &mut retiring,
                &faults,
                &per_pool,
            ),
            Ok(ParityMsg::Stop) => stopping = true,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => stopping = true,
        }
        // Drain the burst behind the first message before pumping.
        while !stopping {
            match rx.try_recv() {
                Ok(ParityMsg::Job(job)) => submit_parity(&mut handles, &state, job, epoch),
                Ok(ParityMsg::Resize { per }) => apply_resize(
                    &factory,
                    per,
                    &mut epoch,
                    &mut handles,
                    &mut retiring,
                    &faults,
                    &per_pool,
                ),
                Ok(ParityMsg::Stop) => stopping = true,
                Err(_) => break,
            }
        }
        for h in &mut handles {
            let _ = h.poll();
        }
        // Pump outgoing generations; shut each down once its in-flight
        // parity work has resolved (the forced SLO bounds the wait).
        let mut i = 0;
        while i < retiring.len() {
            let _ = retiring[i].1.poll();
            if retiring[i].1.in_flight() == 0 {
                let (ri, h) = retiring.swap_remove(i);
                retired[ri].push(h.shutdown());
            } else {
                i += 1;
            }
        }
    }
    // Absorb jobs that raced the stop signal (shards seal tail groups
    // right up to their own drain), then drain and shut down. The leg's
    // forced SLO makes drain terminate even with dead parity instances.
    while let Ok(msg) = rx.try_recv() {
        if let ParityMsg::Job(job) = msg {
            submit_parity(&mut handles, &state, job, epoch);
        }
    }
    for (ri, mut h) in retiring {
        let _ = h.drain();
        retired[ri].push(h.shutdown());
    }
    handles
        .into_iter()
        .enumerate()
        .map(|(ri, mut h)| {
            let _ = h.drain();
            let last = h.shutdown();
            if retired[ri].is_empty() {
                last
            } else {
                let mut parts = std::mem::take(&mut retired[ri]);
                parts.push(last);
                RunResult::merged(&parts)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(k: usize, r_min: usize, r_max: usize, shards: usize) -> CrossShardConfig {
        CrossShardConfig::new(k, r_min, r_max, shards, Duration::from_millis(50))
    }

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::new(vec![1, v.len()], v).unwrap()
    }

    #[test]
    fn config_bounds_are_enforced() {
        for (k, r_min, r_max, shards) in
            [(1usize, 1usize, 1usize, 4usize), (2, 0, 1, 4), (2, 2, 1, 4), (2, 1, 3, 4), (3, 1, 2, 2)]
        {
            let res = std::panic::catch_unwind(|| CrossShardState::new(cfg(k, r_min, r_max, shards)));
            assert!(res.is_err(), "k={k} r_min={r_min} r_max={r_max} shards={shards} must be rejected");
        }
    }

    #[test]
    fn groups_stripe_across_distinct_shards() {
        let st = CrossShardState::new(cfg(2, 1, 2, 3));
        let now = Instant::now();
        let (g0, s0) = st.offer(0, vec![10], t(vec![1.0, 1.0]), now);
        assert_eq!((g0, s0), (0, 0));
        // A second batch from the same shard must open a NEW group.
        let (g1, s1) = st.offer(0, vec![11], t(vec![2.0, 2.0]), now);
        assert_eq!((g1, s1), (1, 0));
        assert_eq!(st.open_groups(), 2);
        // A different shard joins (and seals) the first open group.
        let (g2, s2) = st.offer(1, vec![12], t(vec![3.0, 3.0]), now);
        assert_eq!((g2, s2), (0, 1));
        assert_eq!(st.open_groups(), 1, "sealed group left the open set");
        assert_eq!(st.group_r(0), Some(1), "healthy fleet seals at the floor");
        assert!(st.contains(0));
    }

    #[test]
    fn whole_shard_loss_decodes_and_routes_to_the_owning_shard() {
        let st = CrossShardState::new(cfg(2, 1, 2, 3));
        let now = Instant::now();
        st.offer(0, vec![10], t(vec![1.0, 2.0]), now);
        st.offer(1, vec![20], t(vec![3.0, 4.0]), now); // seals group 0
        // Shard 0 answers; shard 1 is dead. The parity decodes slot 1
        // and the decoded ids land in shard 1's queue only.
        st.on_data(0, 0, 0, 0, t(vec![1.0, 2.0]), now);
        assert!(st.drain_decoded(1, now).is_empty(), "nothing decodable yet");
        st.on_parity(0, 0, t(vec![4.0, 6.0]), now);
        let owed0 = st.drain_decoded(0, now);
        assert!(owed0.is_empty(), "shard 0 resolved natively, nothing owed");
        let owed1 = st.drain_decoded(1, now);
        assert_eq!(owed1.len(), 1);
        assert_eq!(owed1[0].0, vec![20]);
        assert_eq!(st.reconstructions_for(1), 1);
        assert_eq!(st.reconstructions_for(0), 0);
        assert!(!st.contains(0), "fully resolved group evicted");
    }

    #[test]
    fn early_data_buffers_until_the_group_seals() {
        let st = CrossShardState::new(cfg(2, 1, 2, 2));
        let now = Instant::now();
        st.offer(0, vec![1], t(vec![1.0]), now);
        // Completion for the open group's slot 0 before the seal.
        st.on_data(0, 0, 0, 0, t(vec![1.0]), now);
        st.offer(1, vec![2], t(vec![2.0]), now); // seals; replays the buffer
        // Parity alone now decodes slot 1.
        st.on_parity(0, 0, t(vec![3.0]), now);
        let owed = st.drain_decoded(1, now);
        assert_eq!(owed.len(), 1);
        assert_eq!(owed[0].0, vec![2]);
    }

    #[test]
    fn flush_short_seals_the_tail_with_phantom_slots() {
        let st = CrossShardState::new(cfg(3, 1, 3, 3));
        let now = Instant::now();
        // Shape the phantom-output template: any observed output does it
        // (here a completion for a long-gone group).
        st.on_data(0, 999, 0, 0, t(vec![0.0, 0.0]), now);
        // One lonely slot from shard 0; the fleet then goes quiet.
        st.offer(0, vec![7], t(vec![1.0, 1.0]), now);
        st.flush_open(now);
        assert_eq!(st.open_groups(), 0);
        assert!(st.contains(0), "short group registered with the tracker");
        // Its real slot is the only unresolved one (phantoms resolved).
        assert_eq!(st.unresolved_slots(0), vec![0]);
        // The parity decodes it even though the group never filled.
        st.on_parity(0, 0, t(vec![5.0, 5.0]), now);
        let owed = st.drain_decoded(0, now);
        assert_eq!(owed.len(), 1);
        assert_eq!(owed[0].0, vec![7]);
    }

    #[test]
    fn stale_open_groups_short_seal_via_the_sweep() {
        let st = CrossShardState::new(cfg(2, 1, 2, 2));
        let t0 = Instant::now();
        st.on_data(0, 999, 0, 0, t(vec![0.0]), t0); // phantom template
        st.offer(0, vec![9], t(vec![1.0]), t0);
        assert_eq!(st.open_groups(), 1);
        // Past the horizon (200 ms floor), any drain sweeps it sealed.
        let later = t0 + Duration::from_millis(400);
        let _ = st.drain_decoded(0, later);
        assert_eq!(st.open_groups(), 0, "stale open group short-sealed");
        assert!(st.contains(0));
        st.on_parity(0, 0, t(vec![4.0]), later);
        let owed = st.drain_decoded(0, later);
        assert_eq!(owed.len(), 1, "tail query decoded instead of riding the SLO");
        assert_eq!(owed[0].0, vec![9]);
    }

    #[test]
    fn scheme_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<CrossShardScheme>();
        assert_send::<ParityTapScheme>();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn bare_session_rejects_cross_shard_mode() {
        use crate::cluster::hardware::GPU;
        use crate::coordinator::service::Mode;
        use crate::runtime::engine::Executable;

        let exe = Executable::load("no/such/file", "m.test", &[4], 1, 8).unwrap();
        let models = ModelSet { deployed: exe, parities: Vec::new(), approx: None };
        let cfg = ServiceConfig::defaults(
            Mode::CrossShard {
                k: 2,
                r_min: 1,
                r_max: 2,
                halflife: Duration::from_millis(500),
            },
            &GPU,
        );
        let sample = Tensor::zeros(vec![4]);
        let err = ServiceBuilder::new(cfg).build(&models, &sample);
        assert!(err.is_err(), "cross-shard groups span sessions; a bare build must fail");
        assert!(err.unwrap_err().to_string().contains("CrossShardFrontend"));
    }
}

//! Embedded control plane: runtime fleet reconfiguration behind an
//! operator-facing admin surface.
//!
//! The elastic primitives live on the tiers themselves —
//! [`ShardedFrontend::add_shard`], [`CrossShardFrontend::remove_shard`],
//! drain/restore, [`ShardedFrontend::set_admission`] — but an operator
//! needs one place that (a) owns whichever tier is serving, (b)
//! serializes reconfiguration commands against each other, (c) keeps
//! working after the fleet shuts down (every op degrades to a clean
//! [`ReconfigError::Closed`]), and (d) speaks a wire protocol a human
//! can drive with `parm admin`. That is [`ControlPlane`]:
//!
//! ```text
//!   parm admin status ──▶ UnixStream ──▶ AdminServer (accept thread)
//!                                             │ one line = one command
//!                                             ▼
//!                                      ControlPlane::handle_line
//!                                             │ add/remove/drain/…
//!                                             ▼
//!                              ShardedFrontend / CrossShardFrontend
//! ```
//!
//! **Strictly non-blocking for the data path.** Admin commands run on
//! the admin server's connection threads and only take the same brief
//! slot/ring lock windows the tiers' own reconfiguration entry points
//! take; the query path (`submit`/`poll`/`next`) never waits on an
//! in-progress admin command beyond those windows. Slow commands
//! (`add-shard` stands up a whole session) block only their own
//! connection.
//!
//! **Reconfiguration state machine.** Per shard slot:
//! `live ⇄ drained → retired` (drain/restore flip the ring flag;
//! remove retires the slot forever — indices are append-only). Every
//! transition is idempotent or a clean error, never a panic; see
//! [`ShardRouter::drain_shard`] for the `Ok(true)`/`Ok(false)`/`Err`
//! contract the whole module follows.
//!
//! **Wire protocol.** Line-oriented JSON over a local Unix socket: one
//! request object per line, one response object per line, keyed by
//! `"cmd"`. Responses always carry `"ok"`. See [`ControlPlane::handle_line`].
//!
//! **Predictor → scale flow.** For a cross-shard fleet, `recommend`
//! reads the [`FleetPredictor`]-backed fleet unavailability from the
//! tier's telemetry and compares it against the
//! [`ControlPlaneConfig`] thresholds: sustained unavailability above
//! `scale_out_threshold` recommends adding a shard; a calm fleet above
//! `min_shards` recommends retiring the worst drained-or-trailing
//! shard. The decision is advisory — the operator (or an external
//! autoscaler looping `parm admin recommend`) applies it.
//!
//! [`ShardedFrontend::add_shard`]: crate::coordinator::shards::ShardedFrontend::add_shard
//! [`CrossShardFrontend::remove_shard`]: crate::coordinator::shards::CrossShardFrontend::remove_shard
//! [`ShardedFrontend::set_admission`]: crate::coordinator::shards::ShardedFrontend::set_admission
//! [`ShardRouter::drain_shard`]: crate::coordinator::shards::ShardRouter::drain_shard
//! [`FleetPredictor`]: crate::coordinator::adaptive::FleetPredictor

use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::cluster::faults::FaultPlan;
use crate::coordinator::frontend::AdmissionPolicy;
use crate::coordinator::journal::{Event, Recorder, ReconfigVerb};
use crate::coordinator::metrics::WindowSnapshot;
use crate::coordinator::shards::{
    CrossShardFrontend, CrossShardRunResult, ReconfigError, ShardedClient,
    ShardedFrontend, ShardedRunResult,
};
use crate::telemetry::registry::SamplerId;
use crate::telemetry::{publish_window, Counter, Registry};
use crate::util::json::Json;
use crate::util::sync::{LockExt, RwLockExt};

/// The serving tier a control plane owns (either flavor exposes the
/// same elastic surface; the cross-shard tier adds parity-pool
/// re-provisioning and coding telemetry).
pub enum Fleet {
    Sharded(ShardedFrontend),
    CrossShard(CrossShardFrontend),
}

/// What [`ControlPlane::shutdown`] returns.
pub enum FleetRunResult {
    Sharded(ShardedRunResult),
    CrossShard(CrossShardRunResult),
}

impl FleetRunResult {
    /// The client-traffic fleet record, whichever tier produced it.
    pub fn fleet(&self) -> &ShardedRunResult {
        match self {
            FleetRunResult::Sharded(r) => r,
            FleetRunResult::CrossShard(r) => &r.fleet,
        }
    }
}

/// The fleet's base journal handle (disabled unless the run was started
/// with a live [`Recorder`] in its [`ServiceConfig`]).
///
/// [`ServiceConfig`]: crate::coordinator::service::ServiceConfig
fn fleet_recorder(fleet: &Fleet) -> Recorder {
    match fleet {
        Fleet::Sharded(t) => t.recorder(),
        Fleet::CrossShard(t) => t.recorder(),
    }
}

/// Journal one applied reconfiguration verb.
fn record_reconfig(fleet: &Fleet, verb: ReconfigVerb, shard: usize) {
    let rec = fleet_recorder(fleet);
    if rec.enabled() {
        rec.record(&Event::Reconfig { verb: verb as u8, shard: shard as u64 });
    }
}

/// The control plane's publications into the fleet metric registry:
/// pre-registered reconfiguration-verb counters (so every verb exports
/// as `0` from the first scrape) and the fleet generation, which
/// increments once per *applied* reconfiguration.
struct ControlTelemetry {
    registry: Registry,
    verb_add: Counter,
    verb_remove: Counter,
    verb_drain: Counter,
    verb_restore: Counter,
    verb_admission: Counter,
    generation: Counter,
}

impl ControlTelemetry {
    fn new(registry: Registry) -> ControlTelemetry {
        let verb = |v: &str| {
            registry.counter(
                "parm_reconfig_total",
                "Applied fleet reconfigurations, by verb.",
                &[("verb", v)],
            )
        };
        ControlTelemetry {
            verb_add: verb("add_shard"),
            verb_remove: verb("remove_shard"),
            verb_drain: verb("drain"),
            verb_restore: verb("restore"),
            verb_admission: verb("set_admission"),
            generation: registry.counter(
                "parm_fleet_generation",
                "Fleet configuration generation (one per applied reconfiguration).",
                &[],
            ),
            registry,
        }
    }

    /// Count one applied verb and advance the fleet generation.
    fn applied(&self, verb: ReconfigVerb) {
        match verb {
            ReconfigVerb::AddShard => self.verb_add.inc(),
            ReconfigVerb::RemoveShard => self.verb_remove.inc(),
            ReconfigVerb::Drain => self.verb_drain.inc(),
            ReconfigVerb::Restore => self.verb_restore.inc(),
            ReconfigVerb::SetAdmission => self.verb_admission.inc(),
        }
        self.generation.inc();
    }
}

/// Thresholds of the advisory autoscaling hook (`recommend`).
#[derive(Clone, Copy, Debug)]
pub struct ControlPlaneConfig {
    /// Fleet unavailability (cross-shard) or windowed reject rate
    /// (sharded) at or above which `recommend` suggests scale-out.
    pub scale_out_threshold: f64,
    /// Signal at or below which a fleet larger than `min_shards` gets a
    /// scale-in suggestion.
    pub scale_in_threshold: f64,
    /// `recommend` never suggests shrinking below this many live shards.
    pub min_shards: usize,
    /// `recommend` never suggests growing past this many provisioned
    /// shards.
    pub max_shards: usize,
}

impl Default for ControlPlaneConfig {
    fn default() -> ControlPlaneConfig {
        ControlPlaneConfig {
            scale_out_threshold: 0.25,
            scale_in_threshold: 0.02,
            min_shards: 2,
            max_shards: 16,
        }
    }
}

/// Advisory output of [`ControlPlane::recommendation`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Stand up one more shard.
    ScaleOut { reason: String },
    /// Drain-then-remove this shard.
    ScaleIn { shard: usize, reason: String },
    /// Leave the fleet alone.
    Hold,
}

/// Owns a live fleet and exposes every runtime-reconfiguration verb,
/// both programmatically and as the line-oriented JSON protocol the
/// admin socket speaks. All methods take `&self`; reconfiguration
/// commands are serialized by an internal mutex (on top of the tiers'
/// own serialization), and after [`ControlPlane::shutdown`] every op
/// returns [`ReconfigError::Closed`] instead of panicking.
pub struct ControlPlane {
    fleet: RwLock<Option<Fleet>>,
    /// Serializes reconfiguration verbs (add/remove/drain/restore/
    /// set-admission) so concurrent admin connections apply in a
    /// definite order. Read-only surfaces never take it.
    ops: Mutex<()>,
    cfg: ControlPlaneConfig,
    /// Verb counters + fleet generation in the fleet's metric registry.
    tele: ControlTelemetry,
}

impl ControlPlane {
    pub fn new(fleet: Fleet) -> ControlPlane {
        ControlPlane::with_config(fleet, ControlPlaneConfig::default())
    }

    pub fn with_config(fleet: Fleet, cfg: ControlPlaneConfig) -> ControlPlane {
        let registry = match &fleet {
            Fleet::Sharded(t) => t.registry(),
            Fleet::CrossShard(t) => t.registry(),
        };
        ControlPlane {
            fleet: RwLock::new(Some(fleet)),
            ops: Mutex::new(()),
            cfg,
            tele: ControlTelemetry::new(registry),
        }
    }

    /// The fleet's metric registry — what [`ControlPlane::publish`]
    /// folds fleet state into and a [`crate::telemetry::Exporter`]
    /// scrapes.
    pub fn registry(&self) -> Registry {
        self.tele.registry.clone()
    }

    /// Register a scrape-time sampler that folds this plane's fleet
    /// state into the registry ([`ControlPlane::publish`]) on every
    /// render/snapshot, so a scrape always sees fresh fleet/per-shard
    /// windows without anyone polling. The sampler holds only a weak
    /// reference — once the plane is dropped it degrades to a no-op
    /// (drop it explicitly with
    /// [`crate::telemetry::Registry::drop_sampler`] for a clean
    /// registry).
    pub fn register_sampler(self: &Arc<ControlPlane>) -> SamplerId {
        let weak = Arc::downgrade(self);
        self.tele.registry.sampler(move || {
            if let Some(plane) = weak.upgrade() {
                let _ = plane.publish();
            }
        })
    }

    /// Fold the fleet's current state into the metric registry: the
    /// merged fleet window (`parm_fleet_window_*`), every shard's
    /// window (`parm_shard_window_*{shard=...}`), shard counts, load,
    /// parity-pool occupancy vs. target, and the cross-shard coding
    /// telemetry. Runs on the caller's thread (scraper or admin
    /// connection), touching only the same brief windows the tiers' own
    /// read surfaces take — never the ops lock.
    pub fn publish(&self) -> Result<(), ReconfigError> {
        self.with_fleet(|fleet| {
            let reg = &self.tele.registry;
            let (shards, provisioned, live, load, rejected, merged) = match fleet {
                Fleet::Sharded(t) => (
                    t.shards(),
                    t.provisioned_shards(),
                    t.live_shards(),
                    t.load(),
                    t.rejected(),
                    t.window(),
                ),
                Fleet::CrossShard(t) => (
                    t.shards(),
                    t.provisioned_shards(),
                    t.live_shards(),
                    t.load(),
                    t.rejected(),
                    t.window(),
                ),
            };
            publish_window(reg, "parm_fleet_window_", &[], &merged);
            for s in 0..shards {
                let w = match fleet {
                    Fleet::Sharded(t) => t.shard_window(s),
                    Fleet::CrossShard(t) => t.shard_window(s),
                };
                let label = s.to_string();
                publish_window(reg, "parm_shard_window_", &[("shard", &label)], &w);
            }
            let shard_gauge = |state: &str, v: usize| {
                reg.gauge("parm_shards", "Shard slots, by lifecycle state.", &[("state", state)])
                    .set(v as f64);
            };
            shard_gauge("total", shards);
            shard_gauge("provisioned", provisioned);
            shard_gauge("live", live);
            reg.gauge("parm_fleet_load", "Summed admission-load estimate across live shards.", &[])
                .set(load as f64);
            reg.counter("parm_fleet_rejected_total", "Admission rejects across the fleet.", &[])
                .raise_to(rejected);
            if let Fleet::CrossShard(t) = fleet {
                reg.gauge(
                    "parm_parity_pool_size",
                    "Instances per r_index in the shared parity pool (active generation).",
                    &[],
                )
                .set(t.parity_pool_size() as f64);
                reg.gauge(
                    "parm_parity_pool_target",
                    "Parity pool size the current fleet calls for (ceil(shards*m/k)).",
                    &[],
                )
                .set(t.parity_pool_target() as f64);
                let tel = t.telemetry();
                reg.gauge("parm_coding_last_r", "Redundancy chosen for the last sealed group.", &[])
                    .set(tel.last_r as f64);
                reg.gauge(
                    "parm_coding_fleet_unavailability",
                    "Fleet-level straggler-predictor unavailability estimate.",
                    &[],
                )
                .set(tel.fleet_unavailability);
                for (s, &u) in tel.per_shard_unavailability.iter().enumerate() {
                    let label = s.to_string();
                    reg.gauge(
                        "parm_shard_unavailability",
                        "Per-shard straggler-predictor unavailability estimate.",
                        &[("shard", &label)],
                    )
                    .set(u);
                }
                reg.gauge("parm_coding_open_groups", "Cross-shard coding groups still open.", &[])
                    .set(tel.open_groups as f64);
                reg.counter("parm_coding_groups_sealed_total", "Cross-shard groups sealed.", &[])
                    .raise_to(tel.groups_sealed);
                reg.counter(
                    "parm_coding_parity_jobs_total",
                    "Parity jobs dispatched to the shared pool.",
                    &[],
                )
                .raise_to(tel.parity_jobs);
                reg.counter(
                    "parm_coding_reconstructions_total",
                    "Predictions recovered by cross-shard decode.",
                    &[],
                )
                .raise_to(tel.reconstructions);
            }
        })
    }

    /// Run `f` against the live fleet, or [`ReconfigError::Closed`]
    /// after shutdown.
    fn with_fleet<T>(&self, f: impl FnOnce(&Fleet) -> T) -> Result<T, ReconfigError> {
        match self.fleet.pread().as_ref() {
            Some(fleet) => Ok(f(fleet)),
            None => Err(ReconfigError::Closed),
        }
    }

    /// Mint a shard-transparent client of the live fleet (`None` after
    /// shutdown). Existing clients keep working across every
    /// reconfiguration — only shutdown ends them.
    pub fn client(&self) -> Option<ShardedClient> {
        self.fleet.pread().as_ref().map(|fleet| match fleet {
            Fleet::Sharded(t) => t.client(),
            Fleet::CrossShard(t) => t.client(),
        })
    }

    /// Mint a client with an explicit admission-fairness weight.
    pub fn client_with_weight(&self, weight: f64) -> Option<ShardedClient> {
        self.fleet.pread().as_ref().map(|fleet| match fleet {
            Fleet::Sharded(t) => t.client_with_weight(weight),
            Fleet::CrossShard(t) => t.client_with_weight(weight),
        })
    }

    /// Stand up one more shard (see [`ShardedFrontend::add_shard`];
    /// on a cross-shard fleet the parity pool is re-provisioned toward
    /// the new `ceil(shards·m/k)` target as well). Returns the new
    /// shard's index.
    ///
    /// [`ShardedFrontend::add_shard`]: crate::coordinator::shards::ShardedFrontend::add_shard
    pub fn add_shard(&self) -> anyhow::Result<usize> {
        let _ops = self.ops.plock();
        self.with_fleet(|fleet| {
            let s = match fleet {
                Fleet::Sharded(t) => t.add_shard(),
                Fleet::CrossShard(t) => t.add_shard(),
            }?;
            record_reconfig(fleet, ReconfigVerb::AddShard, s);
            self.tele.applied(ReconfigVerb::AddShard);
            Ok(s)
        })?
    }

    /// Drain, reroute, and tear one shard down (cross-shard fleets also
    /// retire its coding lane and shrink the parity pool). Idempotent
    /// per the module contract: double-remove is a clean
    /// [`ReconfigError::RemovedShard`].
    pub fn remove_shard(&self, shard: usize) -> anyhow::Result<()> {
        let _ops = self.ops.plock();
        self.with_fleet(|fleet| {
            match fleet {
                Fleet::Sharded(t) => t.remove_shard(shard),
                Fleet::CrossShard(t) => t.remove_shard(shard),
            }?;
            record_reconfig(fleet, ReconfigVerb::RemoveShard, shard);
            self.tele.applied(ReconfigVerb::RemoveShard);
            Ok(())
        })?
    }

    /// Take a shard out of the routing ring. `Ok(true)` = transitioned,
    /// `Ok(false)` = already drained (no-op).
    pub fn drain(&self, shard: usize) -> Result<bool, ReconfigError> {
        let _ops = self.ops.plock();
        self.with_fleet(|fleet| {
            let changed = match fleet {
                Fleet::Sharded(t) => t.drain_shard(shard),
                Fleet::CrossShard(t) => t.drain_shard(shard),
            }?;
            if changed {
                record_reconfig(fleet, ReconfigVerb::Drain, shard);
                self.tele.applied(ReconfigVerb::Drain);
            }
            Ok(changed)
        })?
    }

    /// Put a drained shard back. `Ok(false)` = it was already live.
    pub fn restore(&self, shard: usize) -> Result<bool, ReconfigError> {
        let _ops = self.ops.plock();
        self.with_fleet(|fleet| {
            let changed = match fleet {
                Fleet::Sharded(t) => t.restore_shard(shard),
                Fleet::CrossShard(t) => t.restore_shard(shard),
            }?;
            if changed {
                record_reconfig(fleet, ReconfigVerb::Restore, shard);
                self.tele.applied(ReconfigVerb::Restore);
            }
            Ok(changed)
        })?
    }

    /// Swap the admission policy on every live shard (late-added shards
    /// inherit it).
    pub fn set_admission(&self, policy: AdmissionPolicy) -> Result<(), ReconfigError> {
        let _ops = self.ops.plock();
        self.with_fleet(|fleet| {
            match fleet {
                Fleet::Sharded(t) => t.set_admission(policy),
                Fleet::CrossShard(t) => t.set_admission(policy),
            }
            record_reconfig(fleet, ReconfigVerb::SetAdmission, 0);
            self.tele.applied(ReconfigVerb::SetAdmission);
        })
    }

    /// Total shard slots ever allocated (including retired ones).
    pub fn shards(&self) -> Result<usize, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.shards(),
            Fleet::CrossShard(t) => t.shards(),
        })
    }

    /// Shards with running sessions (drained or not).
    pub fn provisioned_shards(&self) -> Result<usize, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.provisioned_shards(),
            Fleet::CrossShard(t) => t.provisioned_shards(),
        })
    }

    /// Shards currently accepting routes.
    pub fn live_shards(&self) -> Result<usize, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.live_shards(),
            Fleet::CrossShard(t) => t.live_shards(),
        })
    }

    /// Per-r_index parity pool size (`None` on a plain sharded fleet).
    pub fn parity_pool_size(&self) -> Result<Option<usize>, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(_) => None,
            Fleet::CrossShard(t) => Some(t.parity_pool_size()),
        })
    }

    /// The parity pool size the current fleet calls for (`None` on a
    /// plain sharded fleet).
    pub fn parity_pool_target(&self) -> Result<Option<usize>, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(_) => None,
            Fleet::CrossShard(t) => Some(t.parity_pool_target()),
        })
    }

    /// One shard's fault plan (deterministic-chaos harness surface).
    pub fn fault_plan(&self, shard: usize) -> Result<Arc<FaultPlan>, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.fault_plan(shard),
            Fleet::CrossShard(t) => t.fault_plan(shard),
        })
    }

    /// One live shard's link-contention model (`None` for retired
    /// shards) — the network-chaos surface.
    pub fn network(
        &self,
        shard: usize,
    ) -> Result<Option<Arc<crate::cluster::network::Network>>, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.network(shard),
            Fleet::CrossShard(t) => t.network(shard),
        })
    }

    /// Permanently kill one instance of one shard.
    pub fn kill_instance(&self, shard: usize, instance: usize) -> Result<(), ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.kill_instance(shard, instance),
            Fleet::CrossShard(t) => t.kill_instance(shard, instance),
        })
    }

    /// Short-seal every open cross-shard coding group (no-op on a plain
    /// sharded fleet).
    pub fn flush_open_groups(&self) -> Result<(), ReconfigError> {
        self.with_fleet(|fleet| {
            if let Fleet::CrossShard(t) = fleet {
                t.flush_open_groups();
            }
        })
    }

    /// Fleet-wide merged live window.
    pub fn window(&self) -> Result<WindowSnapshot, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.window(),
            Fleet::CrossShard(t) => t.window(),
        })
    }

    /// One shard's live window.
    pub fn shard_window(&self, shard: usize) -> Result<WindowSnapshot, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(t) => t.shard_window(shard),
            Fleet::CrossShard(t) => t.shard_window(shard),
        })
    }

    /// Fleet shape + health at a glance, as the admin protocol's
    /// `status` reply payload.
    pub fn status(&self) -> Result<Json, ReconfigError> {
        self.with_fleet(|fleet| {
            let (tier, shards, provisioned, live, load, rejected) = match fleet {
                Fleet::Sharded(t) => ("sharded", t.shards(), t.provisioned_shards(), t.live_shards(), t.load(), t.rejected()),
                Fleet::CrossShard(t) => ("cross-shard", t.shards(), t.provisioned_shards(), t.live_shards(), t.load(), t.rejected()),
            };
            let states: Vec<Json> = (0..shards)
                .map(|s| {
                    let state = match fleet {
                        Fleet::Sharded(t) => t.shard_state(s),
                        Fleet::CrossShard(t) => t.shard_state(s),
                    };
                    Json::obj().set("shard", s).set("state", state)
                })
                .collect();
            let mut out = Json::obj()
                .set("tier", tier)
                .set("shards", shards)
                .set("provisioned", provisioned)
                .set("live", live)
                .set("load", load)
                .set("rejected", rejected)
                .set("shard_states", Json::Arr(states));
            if let Fleet::CrossShard(t) = fleet {
                out = out.set(
                    "parity_pool",
                    Json::obj()
                        .set("size", t.parity_pool_size())
                        .set("target", t.parity_pool_target()),
                );
            }
            out
        })
    }

    /// The raw coding telemetry (`None` on a plain sharded fleet) — the
    /// programmatic counterpart of the JSON `telemetry` command.
    pub fn cross_telemetry(
        &self,
    ) -> Result<Option<crate::coordinator::cross_shard::CrossShardTelemetry>, ReconfigError> {
        self.with_fleet(|fleet| match fleet {
            Fleet::Sharded(_) => None,
            Fleet::CrossShard(t) => Some(t.telemetry()),
        })
    }

    /// Merged + per-shard windows, scheme telemetry, and per-shard
    /// predictor estimates, as the admin protocol's `telemetry` reply
    /// payload.
    ///
    /// The reply is a *compatibility view over the metric registry*:
    /// [`ControlPlane::publish`] folds the fleet state into the
    /// registry first, then every number here is read back out of the
    /// same gauges and counters a Prometheus scrape of the
    /// [`crate::telemetry::Exporter`] sees — the Unix-socket reply and
    /// the `/metrics` endpoint cannot drift.
    pub fn telemetry(&self) -> Result<Json, ReconfigError> {
        self.publish()?;
        let reg = &self.tele.registry;
        let shards = reg
            .value("parm_shards", &[("state", "total")])
            .unwrap_or(0.0) as usize;
        let per_shard: Vec<Json> = (0..shards)
            .map(|s| {
                let label = s.to_string();
                window_json_from_registry(reg, "parm_shard_window_", &[("shard", &label)])
                    .set("shard", s)
            })
            .collect();
        let mut out = Json::obj()
            .set("window", window_json_from_registry(reg, "parm_fleet_window_", &[]))
            .set("per_shard", Json::Arr(per_shard));
        if let Some(last_r) = reg.value("parm_coding_last_r", &[]) {
            let read = |name: &str| reg.value(name, &[]).unwrap_or(0.0);
            let per_u: Vec<Json> = (0..shards)
                .filter_map(|s| {
                    reg.value("parm_shard_unavailability", &[("shard", &s.to_string())])
                })
                .map(Json::Num)
                .collect();
            out = out.set(
                "coding",
                Json::obj()
                    .set("last_r", last_r)
                    .set(
                        "fleet_unavailability",
                        read("parm_coding_fleet_unavailability"),
                    )
                    .set("per_shard_unavailability", Json::Arr(per_u))
                    .set("groups_sealed", read("parm_coding_groups_sealed_total"))
                    .set("parity_jobs", read("parm_coding_parity_jobs_total"))
                    .set("reconstructions", read("parm_coding_reconstructions_total"))
                    .set("open_groups", read("parm_coding_open_groups")),
            );
        }
        Ok(out)
    }

    /// The advisory predictor→scale hook: compare the fleet's health
    /// signal against the configured thresholds. Cross-shard fleets use
    /// the [`FleetPredictor`]-backed fleet unavailability; plain sharded
    /// fleets fall back to the windowed reject rate (their only
    /// fleet-level pressure signal).
    ///
    /// [`FleetPredictor`]: crate::coordinator::adaptive::FleetPredictor
    pub fn recommendation(&self) -> Result<ScaleDecision, ReconfigError> {
        self.with_fleet(|fleet| {
            let (signal, label, shards, provisioned, live) = match fleet {
                Fleet::CrossShard(t) => {
                    let tel = t.telemetry();
                    (
                        tel.fleet_unavailability,
                        "fleet unavailability",
                        t.shards(),
                        t.provisioned_shards(),
                        t.live_shards(),
                    )
                }
                Fleet::Sharded(t) => (
                    t.window().reject_rate,
                    "windowed reject rate",
                    t.shards(),
                    t.provisioned_shards(),
                    t.live_shards(),
                ),
            };
            if signal >= self.cfg.scale_out_threshold && provisioned < self.cfg.max_shards {
                return ScaleDecision::ScaleOut {
                    reason: format!(
                        "{label} {signal:.3} >= {:.3} with {provisioned} provisioned shards",
                        self.cfg.scale_out_threshold
                    ),
                };
            }
            if signal <= self.cfg.scale_in_threshold && live > self.cfg.min_shards {
                // Prefer retiring an already-drained shard; otherwise
                // the newest live one (append-only indices make the
                // newest the natural elastic margin).
                let candidate = (0..shards)
                    .rev()
                    .find(|&s| {
                        let state = match fleet {
                            Fleet::Sharded(t) => t.shard_state(s),
                            Fleet::CrossShard(t) => t.shard_state(s),
                        };
                        state == "drained"
                    })
                    .or_else(|| {
                        (0..shards).rev().find(|&s| {
                            let state = match fleet {
                                Fleet::Sharded(t) => t.shard_state(s),
                                Fleet::CrossShard(t) => t.shard_state(s),
                            };
                            state == "live"
                        })
                    });
                if let Some(shard) = candidate {
                    return ScaleDecision::ScaleIn {
                        shard,
                        reason: format!(
                            "{label} {signal:.3} <= {:.3} with {live} live shards",
                            self.cfg.scale_in_threshold
                        ),
                    };
                }
            }
            ScaleDecision::Hold
        })
    }

    /// Handle one admin-protocol request line, returning the response
    /// line (without the trailing newline). Never panics; malformed
    /// input and invalid operations come back as `{"ok":false,...}`.
    ///
    /// Requests: `{"cmd":"ping"}` · `{"cmd":"status"}` ·
    /// `{"cmd":"telemetry"}` · `{"cmd":"recommend"}` ·
    /// `{"cmd":"drain","shard":N}` · `{"cmd":"restore","shard":N}` ·
    /// `{"cmd":"add-shard"}` · `{"cmd":"remove-shard","shard":N}` ·
    /// `{"cmd":"set-admission","policy":"unbounded"|"reject-above"|
    /// "block"|"slo-aware",...}` (with `backlog`, `timeout_ms`,
    /// `slo_ms` as each policy needs).
    pub fn handle_line(&self, line: &str) -> String {
        match self.handle(line) {
            Ok(body) => body.set("ok", true).to_string(),
            Err(e) => Json::obj().set("ok", false).set("error", e).to_string(),
        }
    }

    fn handle(&self, line: &str) -> Result<Json, String> {
        let req = Json::parse(line.trim()).map_err(|e| format!("bad request: {e}"))?;
        let cmd = req
            .at(&["cmd"])
            .as_str()
            .ok_or_else(|| "missing \"cmd\"".to_string())?;
        let shard_arg = || {
            req.at(&["shard"])
                .as_usize()
                .ok_or_else(|| format!("{cmd} needs a \"shard\" index"))
        };
        match cmd {
            "ping" => Ok(Json::obj()),
            "status" => self.status().map_err(|e| e.to_string()),
            "telemetry" => self.telemetry().map_err(|e| e.to_string()),
            "recommend" => {
                let d = self.recommendation().map_err(|e| e.to_string())?;
                Ok(decision_json(&d))
            }
            "drain" => {
                let changed = self.drain(shard_arg()?).map_err(|e| e.to_string())?;
                Ok(Json::obj().set("changed", changed))
            }
            "restore" => {
                let changed = self.restore(shard_arg()?).map_err(|e| e.to_string())?;
                Ok(Json::obj().set("changed", changed))
            }
            "add-shard" => {
                let s = self.add_shard().map_err(|e| e.to_string())?;
                Ok(Json::obj().set("shard", s))
            }
            "remove-shard" => {
                let s = shard_arg()?;
                self.remove_shard(s).map_err(|e| e.to_string())?;
                Ok(Json::obj().set("shard", s))
            }
            "set-admission" => {
                let policy = parse_policy(&req)?;
                self.set_admission(policy).map_err(|e| e.to_string())?;
                Ok(Json::obj().set("policy", format!("{policy:?}")))
            }
            other => Err(format!("unknown command {other:?}")),
        }
    }

    /// Take the fleet down (each tier drains in-flight queries) and
    /// return the merged run record. Every subsequent op — including a
    /// second `shutdown` — fails with [`ReconfigError::Closed`].
    pub fn shutdown(&self) -> anyhow::Result<FleetRunResult> {
        let _ops = self.ops.plock();
        let fleet = self.fleet.pwrite().take();
        match fleet {
            Some(Fleet::Sharded(t)) => Ok(FleetRunResult::Sharded(t.shutdown()?)),
            Some(Fleet::CrossShard(t)) => Ok(FleetRunResult::CrossShard(t.shutdown()?)),
            None => Err(ReconfigError::Closed.into()),
        }
    }
}

/// The admin protocol's window JSON shape, read back out of the
/// registry gauges [`publish_window`] wrote (`{prefix}seconds`,
/// `{prefix}resolved`, ...). Keeping the admin reply downstream of the
/// registry is what pins it to the Prometheus endpoint.
fn window_json_from_registry(reg: &Registry, prefix: &str, labels: &[(&str, &str)]) -> Json {
    let read = |suffix: &str| reg.value(&format!("{prefix}{suffix}"), labels).unwrap_or(0.0);
    Json::obj()
        .set("window_s", read("seconds"))
        .set("resolved", read("resolved"))
        .set("rejected", read("rejected"))
        .set("p50_ms", read("p50_ms"))
        .set("p99_ms", read("p99_ms"))
        .set("p999_ms", read("p999_ms"))
        .set("recovery_rate", read("recovery_rate"))
        .set("reject_rate", read("reject_rate"))
        .set("default_rate", read("default_rate"))
        .set("qps", read("qps"))
}

fn decision_json(d: &ScaleDecision) -> Json {
    match d {
        ScaleDecision::ScaleOut { reason } => Json::obj()
            .set("action", "scale-out")
            .set("reason", reason.clone()),
        ScaleDecision::ScaleIn { shard, reason } => Json::obj()
            .set("action", "scale-in")
            .set("shard", *shard)
            .set("reason", reason.clone()),
        ScaleDecision::Hold => Json::obj().set("action", "hold"),
    }
}

/// Parse the `set-admission` request body into a policy.
fn parse_policy(req: &Json) -> Result<AdmissionPolicy, String> {
    let name = req
        .at(&["policy"])
        .as_str()
        .ok_or_else(|| "set-admission needs a \"policy\"".to_string())?;
    let backlog = req.at(&["backlog"]).as_usize();
    match name {
        "unbounded" => Ok(AdmissionPolicy::Unbounded),
        "reject-above" => Ok(AdmissionPolicy::RejectAbove {
            backlog: backlog.ok_or_else(|| "reject-above needs \"backlog\"".to_string())?,
        }),
        "block" => Ok(AdmissionPolicy::Block {
            backlog: backlog.ok_or_else(|| "block needs \"backlog\"".to_string())?,
            timeout: Duration::from_millis(
                req.at(&["timeout_ms"]).as_f64().unwrap_or(100.0) as u64
            ),
        }),
        "slo-aware" => Ok(AdmissionPolicy::SloAware {
            p99: Duration::from_secs_f64(
                req.at(&["slo_ms"])
                    .as_f64()
                    .ok_or_else(|| "slo-aware needs \"slo_ms\"".to_string())?
                    / 1e3,
            ),
            backlog: backlog.unwrap_or(usize::MAX),
        }),
        other => Err(format!("unknown policy {other:?}")),
    }
}

// ------------------------------------------------------------------------
// Admin socket server
// ------------------------------------------------------------------------

/// Line-oriented JSON admin endpoint on a local Unix socket.
///
/// One accept thread; each connection gets its own thread (a slow
/// `add-shard` must not block a concurrent `status`). Stopping the
/// server (or dropping it) joins every thread and removes the socket
/// file. Unix-only — `parm serve --admin-socket` is gated accordingly.
#[cfg(unix)]
pub struct AdminServer {
    path: std::path::PathBuf,
    stop: Arc<std::sync::atomic::AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

#[cfg(unix)]
impl AdminServer {
    /// Bind `path` (an existing socket file there is replaced) and start
    /// serving `plane`.
    pub fn bind(
        path: impl AsRef<std::path::Path>,
        plane: Arc<ControlPlane>,
    ) -> anyhow::Result<AdminServer> {
        use std::os::unix::net::UnixListener;
        use std::sync::atomic::{AtomicBool, Ordering};

        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .map_err(|e| anyhow::anyhow!("bind admin socket {}: {e}", path.display()))?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let accept = std::thread::Builder::new()
            .name("parm-admin".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !thread_stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let plane = plane.clone();
                            let conn_stop = thread_stop.clone();
                            conns.push(std::thread::spawn(move || {
                                serve_conn(stream, &plane, &conn_stop);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(e) => {
                            log::warn!("admin socket accept failed: {e}");
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                    conns.retain(|h| !h.is_finished());
                }
                for h in conns {
                    let _ = h.join();
                }
            })
            .expect("spawn admin accept thread");
        Ok(AdminServer { path, stop, accept: Some(accept) })
    }

    /// The socket path this server is bound to.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Stop accepting, join every connection thread, remove the socket
    /// file.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(unix)]
impl Drop for AdminServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One admin connection: read a request line, write the response line,
/// repeat until EOF, error, or server stop. The read timeout bounds how
/// long a stopping server waits on an idle connection.
#[cfg(unix)]
fn serve_conn(
    stream: std::os::unix::net::UnixStream,
    plane: &ControlPlane,
    stop: &std::sync::atomic::AtomicBool,
) {
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::Ordering;

    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    while !stop.load(Ordering::SeqCst) {
        // read_line appends, so a request split across read timeouts
        // accumulates in `buf` until the newline lands — only a handled
        // line clears it.
        match reader.read_line(&mut buf) {
            Ok(0) => return, // EOF: client hung up.
            Ok(_) => {
                if !buf.trim().is_empty() {
                    let reply = plane.handle_line(&buf);
                    if writer
                        .write_all(reply.as_bytes())
                        .and_then(|()| writer.write_all(b"\n"))
                        .and_then(|()| writer.flush())
                        .is_err()
                    {
                        return;
                    }
                }
                buf.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protocol_rejects_malformed_input_cleanly() {
        // A closed plane still answers protocol errors without touching
        // the (absent) fleet.
        let plane = ControlPlane {
            fleet: RwLock::new(None),
            ops: Mutex::new(()),
            cfg: ControlPlaneConfig::default(),
            tele: ControlTelemetry::new(Registry::new()),
        };
        for bad in ["", "not json", "{}", "{\"cmd\":\"no-such\"}", "{\"cmd\":\"drain\"}"] {
            let reply = Json::parse(&plane.handle_line(bad)).unwrap();
            assert_eq!(reply.at(&["ok"]).as_bool(), Some(false), "input {bad:?}");
            assert!(reply.at(&["error"]).as_str().is_some());
        }
        // Ping needs no fleet.
        let reply = Json::parse(&plane.handle_line("{\"cmd\":\"ping\"}")).unwrap();
        assert_eq!(reply.at(&["ok"]).as_bool(), Some(true));
        // Fleet ops on a closed plane: clean Closed errors.
        let reply = Json::parse(&plane.handle_line("{\"cmd\":\"status\"}")).unwrap();
        assert_eq!(reply.at(&["ok"]).as_bool(), Some(false));
        assert!(reply.at(&["error"]).as_str().unwrap().contains("shut down"));
        assert!(matches!(plane.drain(0), Err(ReconfigError::Closed)));
        assert!(matches!(plane.restore(0), Err(ReconfigError::Closed)));
        assert!(plane.add_shard().is_err());
        assert!(plane.client().is_none());
    }

    #[test]
    fn policy_parsing_covers_every_variant() {
        let p = |s: &str| parse_policy(&Json::parse(s).unwrap());
        assert_eq!(
            p(r#"{"policy":"unbounded"}"#).unwrap(),
            AdmissionPolicy::Unbounded
        );
        assert_eq!(
            p(r#"{"policy":"reject-above","backlog":64}"#).unwrap(),
            AdmissionPolicy::RejectAbove { backlog: 64 }
        );
        assert_eq!(
            p(r#"{"policy":"block","backlog":32,"timeout_ms":50}"#).unwrap(),
            AdmissionPolicy::Block { backlog: 32, timeout: Duration::from_millis(50) }
        );
        assert_eq!(
            p(r#"{"policy":"slo-aware","slo_ms":250,"backlog":128}"#).unwrap(),
            AdmissionPolicy::SloAware { p99: Duration::from_millis(250), backlog: 128 }
        );
        assert!(p(r#"{"policy":"reject-above"}"#).is_err(), "backlog required");
        assert!(p(r#"{"policy":"slo-aware"}"#).is_err(), "slo_ms required");
        assert!(p(r#"{"policy":"martian"}"#).is_err());
        assert!(p(r#"{}"#).is_err());
    }
}

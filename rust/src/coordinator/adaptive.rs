//! Adaptive redundancy: a learned straggler predictor driving a rateless
//! parity scheme.
//!
//! ParM (§3) fixes its redundancy — one parity per k-query coding group —
//! at deployment time. But the paper's own framing (encoder, parity
//! model, decoder as interchangeable components) admits schemes whose
//! redundancy *adapts* to observed cluster health: ApproxIFER-style
//! rateless codes tolerate a variable number of stragglers, and NeRCC
//! frames straggler resilience as regression over observed worker
//! behavior. This module combines the two ideas:
//!
//! - [`StragglerPredictor`] learns, online, how unavailable the deployed
//!   pool currently is: per-instance EWMA latencies plus exponentially
//!   decayed slowdown/loss incidence counters, fed from the session's
//!   completion callbacks (completions carry worker timestamps and
//!   instance ids) and from coding-group outcomes (a reconstructed slot
//!   means its own prediction never arrived in time; a group still
//!   unresolved past the loss horizon means hard losses). From those it
//!   publishes a per-pool unavailability estimate and — via a binomial
//!   tail bound — a recommended per-group parity count.
//! - [`RatelessScheme`] implements
//!   [`crate::coordinator::scheme::RedundancyScheme`] with ParM's
//!   accumulate-k-batches group structure, but chooses `r ∈ [r_min,
//!   r_max]` *at group-seal time* from the predictor. Pools are
//!   provisioned for `r_max` (topology is the ceiling); healthy clusters
//!   pay `r_min` parities per group, and a straggler burst ramps `r`
//!   toward `r_max` within a few predictor half-lives, then decays back.
//!
//! The decoder side needs nothing new: each group registers its own `r`
//! with the shared [`GroupTracker`]
//! ([`GroupTracker::register_with_r`]), and the r>1 Gaussian-elimination
//! path in [`crate::coordinator::decoder`] reconstructs up to `r`
//! unavailable predictions per group.
//!
//! The predictor is deliberately clock-free — every method takes the
//! observation instant — so its ramp-up/decay behavior is testable
//! without sleeping (see the unit tests below).

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::SealedBatch;
use crate::coordinator::coding::GroupTracker;
use crate::coordinator::encoder::Encoder;
use crate::coordinator::metrics::Outcome;
use crate::coordinator::scheme::{
    job, per_pool, DispatchPlan, PoolLayout, RedundancyScheme, Resolution, SchemeTelemetry,
    Target,
};
use crate::runtime::instance::{Completion, JobKind};
use crate::tensor::Tensor;

// ------------------------------------------------------------------------
// Straggler predictor
// ------------------------------------------------------------------------

/// Knobs of the [`StragglerPredictor`]. Only `halflife` is exposed in
/// the JSON config / CLI (`predictor_halflife_ms`); the rest have
/// defaults that match the paper's regime and can be set
/// programmatically.
#[derive(Clone, Debug)]
pub struct PredictorConfig {
    /// Half-life of the decayed incidence counters: how fast evidence of
    /// past stragglers fades. Shorter = faster ramp-down after a burst.
    pub halflife: Duration,
    /// A completion slower than `slow_factor` x the pool's mean latency
    /// counts as a slowdown event.
    pub slow_factor: f64,
    /// Weight of a slowdown event relative to a hard loss when
    /// estimating unavailability.
    pub slow_weight: f64,
    /// Target residual probability that a coding group loses more slots
    /// than its parities can recover; `recommend_r` picks the smallest r
    /// meeting it.
    pub target_miss: f64,
    /// Prior unavailability assumed before any evidence arrives.
    pub prior: f64,
    /// Strength of the prior, in pseudo-observations. Larger = slower to
    /// react to the first few events.
    pub prior_strength: f64,
}

impl Default for PredictorConfig {
    fn default() -> PredictorConfig {
        PredictorConfig {
            halflife: Duration::from_millis(1000),
            slow_factor: 4.0,
            slow_weight: 0.25,
            target_miss: 0.02,
            prior: 0.01,
            prior_strength: 8.0,
        }
    }
}

/// Per-instance view kept by the predictor (observability surface).
#[derive(Clone, Copy, Debug, Default)]
pub struct InstanceStats {
    /// EWMA of this instance's completion latency, in ms.
    pub ewma_ms: f64,
    /// Completions observed from this instance.
    pub completions: u64,
    /// Of those, how many were classified as slowdowns.
    pub slow_events: u64,
}

/// Online estimator of deployed-pool unavailability.
///
/// State is a handful of exponentially time-decayed counters (`ok`,
/// `slow`, `loss` events) plus per-instance latency EWMAs. All methods
/// take the observation instant explicitly, so the estimator is a pure
/// function of its inputs — property-testable without a clock, like
/// [`GroupTracker`].
pub struct StragglerPredictor {
    cfg: PredictorConfig,
    /// Decayed count of timely completions.
    ok: f64,
    /// Decayed count of slowdown events (late but arrived).
    slow: f64,
    /// Decayed count of hard losses (reconstructed or never arrived).
    loss: f64,
    /// EWMA of completion latency across the pool, in ms (0 until the
    /// first observation).
    mean_ms: f64,
    /// Instant the decayed counters were last brought current.
    last: Option<Instant>,
    instances: HashMap<usize, InstanceStats>,
}

impl StragglerPredictor {
    pub fn new(cfg: PredictorConfig) -> StragglerPredictor {
        assert!(!cfg.halflife.is_zero(), "predictor half-life must be non-zero");
        StragglerPredictor {
            cfg,
            ok: 0.0,
            slow: 0.0,
            loss: 0.0,
            mean_ms: 0.0,
            last: None,
            instances: HashMap::new(),
        }
    }

    /// Multiplier that brings the decayed counters current at `now`.
    fn decay_factor(&self, now: Instant) -> f64 {
        match self.last {
            None => 1.0,
            Some(last) => {
                let dt = now.saturating_duration_since(last).as_secs_f64();
                0.5f64.powf(dt / self.cfg.halflife.as_secs_f64())
            }
        }
    }

    fn decay_to(&mut self, now: Instant) {
        let f = self.decay_factor(now);
        self.ok *= f;
        self.slow *= f;
        self.loss *= f;
        // `last` only moves forward: out-of-order worker timestamps must
        // not re-inflate already-decayed counts.
        if self.last.map_or(true, |l| now > l) {
            self.last = Some(now);
        }
    }

    /// Feed one completion: `latency` is dispatch -> worker-timestamped
    /// finish for `instance`. Classifies it as timely or a slowdown
    /// against the pool's running mean.
    pub fn observe_completion(&mut self, instance: usize, latency: Duration, now: Instant) {
        self.decay_to(now);
        let ms = latency.as_secs_f64() * 1e3;
        let slow = self.mean_ms > 0.0 && ms > self.cfg.slow_factor * self.mean_ms;
        if slow {
            self.slow += 1.0;
        } else {
            self.ok += 1.0;
        }
        self.mean_ms = if self.mean_ms == 0.0 {
            ms
        } else {
            self.mean_ms + 0.2 * (ms - self.mean_ms)
        };
        let inst = self.instances.entry(instance).or_default();
        inst.completions += 1;
        if slow {
            inst.slow_events += 1;
        }
        inst.ewma_ms =
            if inst.completions == 1 { ms } else { inst.ewma_ms + 0.3 * (ms - inst.ewma_ms) };
    }

    /// Feed `n` hard losses: predictions that never arrived in time (a
    /// reconstructed slot, or a group still unresolved past the loss
    /// horizon).
    pub fn observe_losses(&mut self, n: usize, now: Instant) {
        self.decay_to(now);
        self.loss += n as f64;
    }

    /// Current per-pool unavailability estimate in `[0, 0.95]`: the
    /// decayed loss (+ discounted slowdown) incidence, regularized by the
    /// prior.
    pub fn unavailability(&self, now: Instant) -> f64 {
        let f = self.decay_factor(now);
        let (ok, slow, loss) = (self.ok * f, self.slow * f, self.loss * f);
        let c = &self.cfg;
        let p = (loss + c.slow_weight * slow + c.prior * c.prior_strength)
            / (ok + slow + loss + c.prior_strength);
        p.clamp(0.0, 0.95)
    }

    /// Smallest `r` in `[r_min, r_max]` such that the probability of a
    /// k-slot coding group losing more than `r` slots (binomial at the
    /// current unavailability estimate) stays under `target_miss`;
    /// `r_max` if none does.
    pub fn recommend_r(&self, k: usize, r_min: usize, r_max: usize, now: Instant) -> usize {
        let p = self.unavailability(now);
        for r in r_min..=r_max {
            if binomial_tail(k, p, r) <= self.cfg.target_miss {
                return r;
            }
        }
        r_max
    }

    /// Pool-wide EWMA completion latency in ms (0 before any completion).
    pub fn mean_latency_ms(&self) -> f64 {
        self.mean_ms
    }

    /// Per-instance stats, if this instance has completed anything.
    pub fn instance(&self, id: usize) -> Option<InstanceStats> {
        self.instances.get(&id).copied()
    }
}

/// P(X > r) for X ~ Binomial(k, p). k is a coding-group size (<= 8 in
/// every supported config), so the exact sum is cheapest.
fn binomial_tail(k: usize, p: f64, r: usize) -> f64 {
    if r >= k {
        return 0.0;
    }
    let q = 1.0 - p;
    let mut head = 0.0f64;
    for i in 0..=r {
        head += choose(k, i) * p.powi(i as i32) * q.powi((k - i) as i32);
    }
    (1.0 - head).max(0.0)
}

fn choose(n: usize, k: usize) -> f64 {
    let k = k.min(n - k);
    let mut c = 1.0f64;
    for i in 0..k {
        c = c * (n - i) as f64 / (i + 1) as f64;
    }
    c
}

// ------------------------------------------------------------------------
// Fleet predictor (cross-shard)
// ------------------------------------------------------------------------

/// P(X > r) for X = Σ Bernoulli(p_i) with independent, heterogeneous
/// p_i (Poisson-binomial). The exact DP is O(n²); n is a coding-group
/// size (≤ 8), so this is cheaper than any approximation.
pub(crate) fn poisson_binomial_tail(ps: &[f64], r: usize) -> f64 {
    if r >= ps.len() {
        return 0.0;
    }
    // dp[j] = P(exactly j of the first i slots fail); update descending
    // so dp[j-1] is still the previous iteration's value.
    let mut dp = vec![0.0f64; ps.len() + 1];
    dp[0] = 1.0;
    for (i, &p) in ps.iter().enumerate() {
        for j in (0..=i + 1).rev() {
            dp[j] = dp[j] * (1.0 - p) + if j > 0 { dp[j - 1] * p } else { 0.0 };
        }
    }
    let head: f64 = dp[..=r].iter().sum();
    (1.0 - head).max(0.0)
}

/// Fleet-level straggler estimate: one [`StragglerPredictor`] per shard
/// (fault domain), merged when sizing redundancy for coding groups that
/// *span* shards ([`crate::coordinator::cross_shard`]).
///
/// Why merge instead of keeping per-shard recommendations: a cross-shard
/// group's slots sit on k distinct shards, so its loss distribution is
/// the Poisson-binomial over those domains' unavailabilities — and a
/// correlated fault observed on one shard must warm *every* group's
/// redundancy, not just the groups whose traffic happened to touch the
/// faulted shard (ROADMAP's "rateless over the sharded tier" gap).
/// [`FleetPredictor::recommend_r`] therefore evaluates the tail over the
/// k *most unavailable* shards: conservative for groups striped over
/// healthy shards, exact for the groups most at risk.
pub struct FleetPredictor {
    cfg: PredictorConfig,
    shards: Vec<StragglerPredictor>,
    /// Per-shard membership flag. Shard indices are append-only across
    /// the fleet's lifetime (the elastic tier never reuses a slot), so a
    /// retired shard keeps its predictor — frozen, excluded from every
    /// fleet-level aggregate — and [`FleetPredictor::grow_to`] only ever
    /// appends.
    active: Vec<bool>,
    target_miss: f64,
}

impl FleetPredictor {
    pub fn new(shards: usize, cfg: PredictorConfig) -> FleetPredictor {
        assert!(shards >= 1, "fleet predictor needs at least one shard");
        FleetPredictor {
            target_miss: cfg.target_miss,
            shards: (0..shards).map(|_| StragglerPredictor::new(cfg.clone())).collect(),
            active: vec![true; shards],
            cfg,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Append fresh (active) per-shard predictors up to `shards` total;
    /// a smaller or equal count is a no-op. A new shard starts from the
    /// prior, not from any retired shard's history.
    pub fn grow_to(&mut self, shards: usize) {
        while self.shards.len() < shards {
            self.shards.push(StragglerPredictor::new(self.cfg.clone()));
            self.active.push(true);
        }
    }

    /// Include or exclude `shard` from fleet-level aggregates (scale-in
    /// retires a shard; its index stays valid forever). Out-of-range is
    /// a no-op.
    pub fn set_active(&mut self, shard: usize, active: bool) {
        if let Some(a) = self.active.get_mut(shard) {
            *a = active;
        }
    }

    /// Whether `shard` currently counts toward fleet aggregates.
    pub fn is_active(&self, shard: usize) -> bool {
        self.active.get(shard).copied().unwrap_or(false)
    }

    /// Feed one data completion observed on `shard`.
    pub fn observe_completion(
        &mut self,
        shard: usize,
        instance: usize,
        latency: Duration,
        now: Instant,
    ) {
        if let Some(p) = self.shards.get_mut(shard) {
            p.observe_completion(instance, latency, now);
        }
    }

    /// Feed `n` hard losses attributed to `shard`.
    pub fn observe_losses(&mut self, shard: usize, n: usize, now: Instant) {
        if let Some(p) = self.shards.get_mut(shard) {
            p.observe_losses(n, now);
        }
    }

    /// One shard's unavailability estimate (retired shards report the
    /// decayed remainder of their history).
    pub fn shard_unavailability(&self, shard: usize, now: Instant) -> f64 {
        self.shards[shard].unavailability(now)
    }

    /// Iterator over the active shards' predictors.
    fn active_preds(&self) -> impl Iterator<Item = &StragglerPredictor> {
        self.shards
            .iter()
            .zip(self.active.iter())
            .filter_map(|(p, &a)| if a { Some(p) } else { None })
    }

    /// The worst active per-shard estimate — the headline number (a
    /// group's weakest fault domain dominates its loss probability).
    pub fn fleet_unavailability(&self, now: Instant) -> f64 {
        self.active_preds().map(|p| p.unavailability(now)).fold(0.0, f64::max)
    }

    /// The slowest active shard's pool-wide EWMA latency in ms (0 before
    /// any completion) — drives loss-horizon scaling like the
    /// single-pool predictor's mean.
    pub fn mean_latency_ms(&self) -> f64 {
        self.active_preds().map(StragglerPredictor::mean_latency_ms).fold(0.0, f64::max)
    }

    /// Smallest `r` in `[r_min, r_max]` keeping the Poisson-binomial
    /// tail over the k most unavailable *active* shards under
    /// `target_miss`; `r_max` if none does.
    pub fn recommend_r(&self, k: usize, r_min: usize, r_max: usize, now: Instant) -> usize {
        let mut ps: Vec<f64> = self.active_preds().map(|p| p.unavailability(now)).collect();
        if ps.is_empty() {
            return r_min;
        }
        ps.sort_by(|a, b| b.total_cmp(a));
        ps.truncate(k);
        // Guarded by the tier (shards >= k), but stay total: pad with
        // the least unavailable estimate if there are fewer shards.
        let pad = ps.last().copied().unwrap_or(0.0);
        while ps.len() < k {
            ps.push(pad);
        }
        for r in r_min..=r_max {
            if poisson_binomial_tail(&ps, r) <= self.target_miss {
                return r;
            }
        }
        r_max
    }
}

// ------------------------------------------------------------------------
// Rateless scheme
// ------------------------------------------------------------------------

/// Configuration of [`RatelessScheme`].
#[derive(Clone, Debug)]
pub struct RatelessConfig {
    /// Coding-group size (the paper's k).
    pub k: usize,
    /// Redundancy floor: every group gets at least this many parities.
    pub r_min: usize,
    /// Redundancy ceiling: pools are provisioned for this many parity
    /// pools; no group ever gets more.
    pub r_max: usize,
    pub predictor: PredictorConfig,
    /// A sealed group still unresolved after this long counts its
    /// missing slots as hard losses (raised automatically when the
    /// observed service time is larger). Groups are abandoned — their
    /// queries left to the session SLO — at 4x this horizon, which
    /// bounds tracker memory under persistent faults.
    pub miss_horizon: Duration,
}

impl RatelessConfig {
    /// The declarative form used by `mode: "rateless"` configs: bounds
    /// plus the predictor half-life, defaults for the rest.
    pub fn new(k: usize, r_min: usize, r_max: usize, halflife: Duration) -> RatelessConfig {
        RatelessConfig {
            k,
            r_min,
            r_max,
            predictor: PredictorConfig { halflife, ..PredictorConfig::default() },
            miss_horizon: (halflife * 2).max(Duration::from_millis(200)),
        }
    }
}

/// Bookkeeping for the stale-group sweep.
struct SealedMeta {
    group: u64,
    at: Instant,
    losses_counted: bool,
}

/// Rateless redundancy: k-batch coding groups encoded into a
/// predictor-chosen number of parities, decoded by the shared r>1 path.
///
/// Group structure and orphan handling mirror
/// [`crate::coordinator::scheme::ParmScheme`]; what differs is that the
/// group's parity count is decided per group at seal time, and every
/// completion doubles as a training observation for the predictor.
pub struct RatelessScheme {
    cfg: RatelessConfig,
    /// `r_max` encoders with §3.5 weight rows; group `g` with redundancy
    /// `r` uses the first `r`.
    encoders: Vec<Encoder>,
    tracker: GroupTracker,
    /// The open (unsealed) coding group's batches, in slot order.
    accum: Vec<(Vec<u64>, Tensor)>,
    /// Id of the open group (ids below it are sealed & registered).
    next_group: u64,
    /// Completions that raced ahead of their group's registration.
    orphans: HashMap<u64, Vec<Completion>>,
    predictor: StragglerPredictor,
    /// (group, slot) -> data-job dispatch instant, for latency
    /// observations; cleaned by the stale sweep once a group retires.
    dispatch_at: HashMap<(u64, usize), Instant>,
    /// Sealed groups awaiting the stale sweep, oldest first.
    sealed: VecDeque<SealedMeta>,
    /// Groups whose missing slots the sweep already counted as losses —
    /// a late reconstruction of such a slot must not count a second
    /// time. Entries are dropped when the group's meta retires.
    loss_counted: HashSet<u64>,
    last_sweep: Instant,
    last_r: usize,
    groups_sealed: u64,
    parity_jobs: u64,
    /// Serving-path journal (disabled unless the session attached one).
    recorder: crate::coordinator::journal::Recorder,
}

/// Throttle on the stale-group sweep.
const SWEEP_EVERY: Duration = Duration::from_millis(25);

impl RatelessScheme {
    pub fn new(cfg: RatelessConfig) -> RatelessScheme {
        assert!(cfg.k >= 1, "coding group size must be >= 1");
        assert!(
            cfg.r_min >= 1 && cfg.r_min <= cfg.r_max && cfg.r_max <= cfg.k,
            "need 1 <= r_min <= r_max <= k, got r_min={} r_max={} k={}",
            cfg.r_min,
            cfg.r_max,
            cfg.k
        );
        let encoders: Vec<Encoder> =
            (0..cfg.r_max).map(|ri| Encoder::sum_r(cfg.k, ri)).collect();
        RatelessScheme {
            tracker: GroupTracker::new(cfg.k, &encoders),
            predictor: StragglerPredictor::new(cfg.predictor.clone()),
            encoders,
            accum: Vec::new(),
            next_group: 0,
            orphans: HashMap::new(),
            dispatch_at: HashMap::new(),
            sealed: VecDeque::new(),
            loss_counted: HashSet::new(),
            last_sweep: Instant::now(),
            last_r: cfg.r_min,
            groups_sealed: 0,
            parity_jobs: 0,
            recorder: crate::coordinator::journal::Recorder::disabled(),
            cfg,
        }
    }

    /// Read access to the predictor (tests, dashboards).
    pub fn predictor(&self) -> &StragglerPredictor {
        &self.predictor
    }

    fn registered(&self, group: u64) -> bool {
        group < self.next_group
    }

    fn apply_tracked(&mut self, c: Completion, out: &mut Vec<Resolution>) {
        let at = c.finished_at;
        let (group, res) = match c.kind {
            JobKind::Data { group, slot } => {
                // Every data completion is a predictor observation: its
                // latency (dispatch -> worker-stamped finish) classifies
                // the instance as timely or slow.
                if let Some(t0) = self.dispatch_at.remove(&(group, slot)) {
                    self.predictor.observe_completion(
                        c.instance,
                        at.saturating_duration_since(t0),
                        at,
                    );
                }
                (group, self.tracker.on_data(group, slot, c.output))
            }
            JobKind::Parity { group, r_index } => {
                (group, self.tracker.on_parity(group, r_index, c.output))
            }
            _ => return,
        };
        // If the stale sweep already counted this group's missing slots
        // as losses, a late reconstruction must not count them again.
        let already_counted = self.loss_counted.contains(&group);
        for sr in res.resolved {
            if sr.reconstructed {
                self.recorder.record(&crate::coordinator::journal::Event::Decode {
                    group,
                    slot: sr.slot as u64,
                });
            }
            if sr.reconstructed && !already_counted {
                // A reconstructed slot's own prediction never arrived in
                // time: one hard-loss observation.
                self.predictor.observe_losses(1, at);
            }
            out.push(Resolution {
                query_ids: sr.query_ids,
                at,
                outcome: if sr.reconstructed {
                    Outcome::Reconstructed
                } else {
                    Outcome::Native
                },
            });
        }
    }

    /// Turn groups stuck past the loss horizon into predictor
    /// observations (and eventually abandon them so memory stays bounded
    /// under persistent faults — their queries default via the session
    /// SLO, and late-arriving data still resolves natively through
    /// `on_completion`'s immediate path).
    fn sweep_stale(&mut self, now: Instant) {
        if now.saturating_duration_since(self.last_sweep) < SWEEP_EVERY {
            return;
        }
        self.last_sweep = now;
        // Raise the horizon when the cluster itself is slow, so healthy
        // but slow groups are not misread as losses.
        let mean = self.predictor.mean_latency_ms();
        let horizon = self
            .cfg
            .miss_horizon
            .max(Duration::from_secs_f64(8.0 * mean / 1e3));
        let abandon_after = horizon * 4;
        let mut keep = VecDeque::with_capacity(self.sealed.len());
        while let Some(mut meta) = self.sealed.pop_front() {
            let age = now.saturating_duration_since(meta.at);
            if !self.tracker.contains(meta.group) {
                // Fully resolved (or already abandoned): once old enough
                // that no in-flight completion can still reference it,
                // drop any dispatch stamps its zombies never consumed.
                if age > horizon {
                    for s in 0..self.cfg.k {
                        self.dispatch_at.remove(&(meta.group, s));
                    }
                    self.loss_counted.remove(&meta.group);
                } else {
                    keep.push_back(meta);
                }
                continue;
            }
            if age > horizon && !meta.losses_counted {
                let unresolved = self.tracker.unresolved_slots(meta.group);
                if !unresolved.is_empty() {
                    self.predictor.observe_losses(unresolved.len(), now);
                    self.loss_counted.insert(meta.group);
                }
                meta.losses_counted = true;
            }
            if age > abandon_after {
                self.tracker.abandon(meta.group);
                for s in 0..self.cfg.k {
                    self.dispatch_at.remove(&(meta.group, s));
                }
                self.loss_counted.remove(&meta.group);
                continue;
            }
            keep.push_back(meta);
        }
        self.sealed = keep;
    }
}

impl RedundancyScheme for RatelessScheme {
    fn name(&self) -> &'static str {
        "rateless"
    }

    fn extra_instances(&self, m: usize) -> usize {
        per_pool(m, self.cfg.k) * self.cfg.r_max
    }

    fn layout(&self, m: usize) -> PoolLayout {
        let per = per_pool(m, self.cfg.k);
        PoolLayout {
            deployed: (0..m).collect(),
            parity: (0..self.cfg.r_max)
                .map(|ri| (m + ri * per..m + (ri + 1) * per).collect())
                .collect(),
            approx: None,
        }
    }

    fn plan_dispatch(&mut self, batch: SealedBatch) -> DispatchPlan {
        let mut plan = DispatchPlan::default();
        let now = Instant::now();
        let gid = self.next_group;
        let slot = self.accum.len();
        self.dispatch_at.insert((gid, slot), now);
        plan.jobs
            .push((Target::Deployed, job(JobKind::Data { group: gid, slot }, &batch)));
        self.accum.push((batch.query_ids, batch.input));

        if self.accum.len() == self.cfg.k {
            // Seal: pick r from the predictor, register, encode, dispatch.
            let r = self
                .predictor
                .recommend_r(self.cfg.k, self.cfg.r_min, self.cfg.r_max, now);
            self.last_r = r;
            self.groups_sealed += 1;
            let ids: Vec<Vec<u64>> = self.accum.iter().map(|(i, _)| i.clone()).collect();
            self.tracker.register_with_r(gid, ids, r);
            self.recorder.record(&crate::coordinator::journal::Event::Seal {
                group: gid,
                k: self.cfg.k as u64,
                r: r as u64,
            });
            self.next_group += 1;
            self.sealed
                .push_back(SealedMeta { group: gid, at: now, losses_counted: false });
            let inputs: Vec<&Tensor> = self.accum.iter().map(|(_, t)| t).collect();
            for (ri, enc) in self.encoders.iter().take(r).enumerate() {
                match enc.encode_batches(&inputs) {
                    Ok(parity) => {
                        self.parity_jobs += 1;
                        plan.jobs.push((
                            Target::Parity(ri),
                            crate::runtime::instance::Job {
                                kind: JobKind::Parity { group: gid, r_index: ri },
                                input: parity,
                                query_ids: Vec::new(),
                                dispatched_at: now,
                            },
                        ));
                    }
                    Err(e) => log::error!("rateless encode failed: {e}"),
                }
            }
            self.accum.clear();
            if let Some(cs) = self.orphans.remove(&gid) {
                for c in cs {
                    self.apply_tracked(c, &mut plan.resolutions);
                }
            }
        }
        self.sweep_stale(now);
        plan
    }

    fn on_completion(&mut self, c: Completion) -> Vec<Resolution> {
        let mut out = Vec::new();
        match c.kind {
            JobKind::Data { group, .. } => {
                // Predictions from model instances go straight back to
                // clients, independent of coding-group state (§3.1).
                out.push(Resolution {
                    query_ids: c.query_ids.clone(),
                    at: c.finished_at,
                    outcome: Outcome::Native,
                });
                if self.registered(group) {
                    self.apply_tracked(c, &mut out);
                } else {
                    self.orphans.entry(group).or_default().push(c);
                }
            }
            JobKind::Parity { group, .. } => {
                if self.registered(group) {
                    self.apply_tracked(c, &mut out);
                } else {
                    self.orphans.entry(group).or_default().push(c);
                }
            }
            JobKind::Replica { .. } | JobKind::Background => {}
        }
        self.sweep_stale(Instant::now());
        out
    }

    fn reconstructions(&self) -> u64 {
        self.tracker.reconstructions
    }

    fn telemetry(&self) -> Option<SchemeTelemetry> {
        Some(SchemeTelemetry {
            last_r: self.last_r,
            unavailability: self.predictor.unavailability(Instant::now()),
            groups_sealed: self.groups_sealed,
            parity_jobs: self.parity_jobs,
        })
    }

    fn attach_recorder(&mut self, recorder: crate::coordinator::journal::Recorder) {
        self.recorder = recorder;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn predictor(halflife_ms: u64) -> StragglerPredictor {
        StragglerPredictor::new(PredictorConfig {
            halflife: Duration::from_millis(halflife_ms),
            ..PredictorConfig::default()
        })
    }

    #[test]
    fn binomial_tail_sanity() {
        // P(X > 1) for X ~ B(2, p) is p^2.
        assert!((binomial_tail(2, 0.5, 1) - 0.25).abs() < 1e-12);
        assert!((binomial_tail(2, 0.1, 1) - 0.01).abs() < 1e-12);
        // r >= k can never be exceeded.
        assert_eq!(binomial_tail(2, 0.9, 2), 0.0);
        // P(X > 0) = 1 - (1-p)^k.
        let p = 0.3;
        assert!((binomial_tail(3, p, 0) - (1.0 - (1.0 - p).powi(3))).abs() < 1e-12);
    }

    /// The predictor's ramp is a pure function of timestamped
    /// observations: misses push the recommendation up, the half-life
    /// decays it back — no sleeping needed to test either direction.
    #[test]
    fn predictor_ramps_up_on_losses_and_decays_back() {
        let hl = 100u64;
        let mut p = predictor(hl);
        let base = Instant::now();
        assert_eq!(p.recommend_r(2, 1, 2, base), 1, "prior alone stays at the floor");

        for i in 0..50 {
            p.observe_completion(i % 4, Duration::from_millis(10), base);
        }
        assert_eq!(p.recommend_r(2, 1, 2, base), 1, "healthy traffic stays at the floor");
        let healthy = p.unavailability(base);
        assert!(healthy < 0.05, "healthy estimate ~prior, got {healthy}");

        // Burst: a third of recent slots are hard losses.
        p.observe_losses(25, base);
        let burst = p.unavailability(base);
        assert!(burst > 0.2, "losses must raise the estimate, got {burst}");
        assert_eq!(p.recommend_r(2, 1, 2, base), 2, "burst ramps r to the ceiling");

        // 20 half-lives later the evidence has decayed away.
        let later = base + Duration::from_millis(20 * hl);
        assert!(p.unavailability(later) < 0.05);
        assert_eq!(p.recommend_r(2, 1, 2, later), 1, "estimate decays back to the floor");
    }

    #[test]
    fn predictor_classifies_slowdowns_per_instance() {
        let mut p = predictor(1000);
        let base = Instant::now();
        for _ in 0..20 {
            p.observe_completion(0, Duration::from_millis(10), base);
        }
        // Instance 1 answers 10x slower than the pool mean: slowdowns.
        for _ in 0..5 {
            p.observe_completion(1, Duration::from_millis(100), base);
        }
        let healthy = p.instance(0).unwrap();
        let slowpoke = p.instance(1).unwrap();
        assert_eq!(healthy.slow_events, 0);
        assert!(slowpoke.slow_events > 0, "10x-mean completions classify as slow");
        assert!(slowpoke.ewma_ms > healthy.ewma_ms);
        // Slowdowns raise the estimate, but less than hard losses would.
        let with_slow = p.unavailability(base);
        assert!(with_slow > 0.005 && with_slow < 0.5, "got {with_slow}");
    }

    #[test]
    fn predictor_tolerates_out_of_order_timestamps() {
        let mut p = predictor(100);
        let base = Instant::now();
        p.observe_losses(10, base + Duration::from_millis(500));
        // A worker-stamped completion from the past must not panic or
        // re-inflate decayed counts.
        p.observe_completion(0, Duration::from_millis(5), base);
        assert!(p.unavailability(base + Duration::from_millis(500)) > 0.1);
    }

    fn sealed(ids: Vec<u64>, v: f32) -> SealedBatch {
        SealedBatch {
            input: Tensor::filled(vec![ids.len().max(1), 2], v),
            query_ids: ids,
            oldest_arrival: Instant::now(),
        }
    }

    fn completion(kind: JobKind, ids: Vec<u64>, out: Tensor) -> Completion {
        Completion {
            kind,
            instance: 0,
            query_ids: ids,
            output: out,
            finished_at: Instant::now(),
            exec_time: Duration::ZERO,
        }
    }

    fn scheme(k: usize, r_min: usize, r_max: usize) -> RatelessScheme {
        RatelessScheme::new(RatelessConfig::new(
            k,
            r_min,
            r_max,
            Duration::from_millis(200),
        ))
    }

    #[test]
    fn healthy_group_seals_with_r_min_parities() {
        let mut s = scheme(2, 1, 2);
        let p1 = s.plan_dispatch(sealed(vec![0], 1.0));
        assert_eq!(p1.jobs.len(), 1, "first batch: data only");
        let p2 = s.plan_dispatch(sealed(vec![1], 2.0));
        // No straggler evidence yet: r = r_min = 1 parity.
        assert_eq!(p2.jobs.len(), 2, "data + r_min parities");
        assert!(matches!(p2.jobs[1].0, Target::Parity(0)));
        assert!(matches!(p2.jobs[1].1.kind, JobKind::Parity { group: 0, r_index: 0 }));
        // First parity weights are all-ones: sum of the two batches.
        assert_eq!(p2.jobs[1].1.input.data()[0], 3.0);
        let t = s.telemetry().unwrap();
        assert_eq!((t.last_r, t.groups_sealed, t.parity_jobs), (1, 1, 1));
    }

    #[test]
    fn losses_ramp_next_groups_to_more_parities() {
        let mut s = scheme(2, 1, 2);
        // Pump straggler evidence straight into the predictor (the unit
        // seam; the end-to-end path is covered by tests/adaptive.rs).
        s.predictor.observe_losses(30, Instant::now());
        let _ = s.plan_dispatch(sealed(vec![0], 1.0));
        let plan = s.plan_dispatch(sealed(vec![1], 2.0));
        assert_eq!(plan.jobs.len(), 3, "data + 2 parities under a burst");
        assert!(matches!(plan.jobs[1].1.kind, JobKind::Parity { group: 0, r_index: 0 }));
        assert!(matches!(plan.jobs[2].1.kind, JobKind::Parity { group: 0, r_index: 1 }));
        // §3.5 weights on the second parity: X1 + 2*X2 = 1 + 2*2 = 5.
        assert_eq!(plan.jobs[2].1.input.data()[0], 5.0);
        let t = s.telemetry().unwrap();
        assert_eq!(t.last_r, 2);
        assert_eq!(t.parity_jobs, 2);

        // An r=2 group recovers a double loss entirely from parities.
        let r1 = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 0 },
            vec![],
            Tensor::new(vec![1, 2], vec![3.0, 3.0]).unwrap(),
        ));
        assert!(r1.is_empty(), "one parity cannot decode two losses");
        let r2 = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 1 },
            vec![],
            Tensor::new(vec![1, 2], vec![5.0, 5.0]).unwrap(),
        ));
        let recon: Vec<_> =
            r2.iter().filter(|r| r.outcome == Outcome::Reconstructed).collect();
        assert_eq!(recon.len(), 2, "both slots reconstructed");
        assert_eq!(s.reconstructions(), 2);
    }

    #[test]
    fn reconstruction_feeds_the_predictor() {
        let mut s = scheme(2, 1, 2);
        let _ = s.plan_dispatch(sealed(vec![10], 0.0));
        let _ = s.plan_dispatch(sealed(vec![11], 0.0));
        let before = s.predictor.unavailability(Instant::now());
        let _ = s.on_completion(completion(
            JobKind::Data { group: 0, slot: 0 },
            vec![10],
            Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap(),
        ));
        let r = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 0 },
            vec![],
            Tensor::new(vec![1, 2], vec![4.0, 6.0]).unwrap(),
        ));
        assert!(r.iter().any(|x| x.outcome == Outcome::Reconstructed));
        let after = s.predictor.unavailability(Instant::now());
        assert!(
            after > before,
            "a reconstructed slot is a loss observation ({before} -> {after})"
        );
    }

    /// Regression: a slot the stale sweep already counted as lost must
    /// not count a second time when a late parity reconstructs it.
    #[test]
    fn sweep_counted_losses_not_double_counted_on_late_decode() {
        // Long half-life so decay is negligible over the test; short
        // horizon so the sweep fires quickly.
        let mut cfg = RatelessConfig::new(2, 1, 2, Duration::from_secs(5));
        cfg.miss_horizon = Duration::from_millis(40);
        let mut s = RatelessScheme::new(cfg);
        let _ = s.plan_dispatch(sealed(vec![0], 0.0));
        let _ = s.plan_dispatch(sealed(vec![1], 0.0)); // seals group 0
        // Both slots stay lost past the horizon: the sweep counts them.
        std::thread::sleep(Duration::from_millis(70));
        let _ = s.plan_dispatch(sealed(vec![2], 0.0)); // runs the sweep
        let swept = s.predictor.unavailability(Instant::now());
        assert!(swept > 0.1, "sweep must observe the stuck group, got {swept}");
        // The data for slot 0 and the parity finally straggle in; the
        // parity reconstructs slot 1 — already counted, so the estimate
        // must not rise further (the ok observation even lowers it).
        let _ = s.on_completion(completion(
            JobKind::Data { group: 0, slot: 0 },
            vec![0],
            Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap(),
        ));
        let r = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 0 },
            vec![],
            Tensor::new(vec![1, 2], vec![3.0, 3.0]).unwrap(),
        ));
        assert!(r.iter().any(|x| x.outcome == Outcome::Reconstructed));
        let after = s.predictor.unavailability(Instant::now());
        assert!(
            after <= swept,
            "late decode of swept losses must not re-count them ({swept} -> {after})"
        );
    }

    #[test]
    fn orphan_completions_buffer_until_seal() {
        let mut s = scheme(2, 1, 2);
        let _ = s.plan_dispatch(sealed(vec![0], 0.0));
        let r = s.on_completion(completion(
            JobKind::Data { group: 0, slot: 0 },
            vec![0],
            Tensor::new(vec![1, 2], vec![1.0, 1.0]).unwrap(),
        ));
        assert_eq!(r.len(), 1, "native resolution still immediate");
        let plan = s.plan_dispatch(sealed(vec![1], 0.0));
        assert!(plan.resolutions.iter().all(|x| x.outcome == Outcome::Native));
        let r = s.on_completion(completion(
            JobKind::Parity { group: 0, r_index: 0 },
            vec![],
            Tensor::new(vec![1, 2], vec![3.0, 3.0]).unwrap(),
        ));
        let rec = r.iter().find(|x| x.outcome == Outcome::Reconstructed).unwrap();
        assert_eq!(rec.query_ids, vec![1]);
    }

    #[test]
    fn poisson_binomial_matches_binomial_when_homogeneous() {
        for &(k, p, r) in &[(2usize, 0.3f64, 1usize), (4, 0.1, 2), (5, 0.5, 0), (3, 0.9, 2)] {
            let ps = vec![p; k];
            let a = poisson_binomial_tail(&ps, r);
            let b = binomial_tail(k, p, r);
            assert!((a - b).abs() < 1e-12, "k={k} p={p} r={r}: {a} vs {b}");
        }
        // r >= n can never be exceeded.
        assert_eq!(poisson_binomial_tail(&[0.9, 0.9], 2), 0.0);
        // Heterogeneous sanity: P(X > 1) for p = [0.5, 0.1] is 0.05.
        assert!((poisson_binomial_tail(&[0.5, 0.1], 1) - 0.05).abs() < 1e-12);
    }

    /// The fleet merge the cross-shard tier relies on: one dead fault
    /// domain alone does NOT force r=2 (a group loses at most its one
    /// slot there), but a *correlated* two-domain fault does — and the
    /// evidence decays per shard like the single-pool predictor.
    #[test]
    fn fleet_predictor_sizes_r_to_correlated_domain_faults() {
        let cfg = PredictorConfig {
            halflife: Duration::from_millis(100),
            ..PredictorConfig::default()
        };
        let mut f = FleetPredictor::new(4, cfg);
        let base = Instant::now();
        for shard in 0..4 {
            for i in 0..30 {
                f.observe_completion(shard, i % 2, Duration::from_millis(10), base);
            }
        }
        assert_eq!(f.recommend_r(2, 1, 2, base), 1, "healthy fleet stays at the floor");

        // Shard 2 dies hard: its estimate saturates, the others stay low.
        f.observe_losses(2, 60, base);
        assert!(f.shard_unavailability(2, base) > 0.5);
        assert!(f.shard_unavailability(0, base) < 0.05);
        assert!(f.fleet_unavailability(base) > 0.5);
        assert_eq!(
            f.recommend_r(2, 1, 2, base),
            1,
            "one dead domain costs a group at most one slot — r=1 still suffices"
        );

        // A correlated second domain fault must warm r for every group.
        f.observe_losses(0, 60, base);
        assert_eq!(f.recommend_r(2, 1, 2, base), 2, "two hot domains need two parities");

        // Per-shard decay brings the fleet back to the floor.
        let later = base + Duration::from_secs(5);
        assert!(f.fleet_unavailability(later) < 0.05);
        assert_eq!(f.recommend_r(2, 1, 2, later), 1);
    }

    /// Elastic membership: a retired shard's (possibly terrible) history
    /// stops influencing fleet aggregates, and a freshly grown shard
    /// starts from the prior — indices are append-only, so both
    /// directions only ever flip flags or push new predictors.
    #[test]
    fn fleet_predictor_grows_and_retires_shards() {
        let cfg = PredictorConfig {
            halflife: Duration::from_millis(100),
            ..PredictorConfig::default()
        };
        let mut f = FleetPredictor::new(2, cfg);
        let base = Instant::now();
        for shard in 0..2 {
            for i in 0..30 {
                f.observe_completion(shard, i % 2, Duration::from_millis(10), base);
            }
        }
        f.observe_losses(1, 60, base);
        assert!(f.fleet_unavailability(base) > 0.5);

        // Retiring the sick shard drops it from every aggregate...
        f.set_active(1, false);
        assert!(!f.is_active(1));
        assert!(f.fleet_unavailability(base) < 0.05);
        assert_eq!(f.recommend_r(2, 1, 2, base), 1);
        // ...but its per-index estimate stays readable.
        assert!(f.shard_unavailability(1, base) > 0.5);

        // Growth appends fresh active predictors; smaller is a no-op.
        f.grow_to(4);
        assert_eq!(f.shards(), 4);
        assert!(f.is_active(3));
        f.grow_to(3);
        assert_eq!(f.shards(), 4);
        // Out-of-range observations are ignored, never a panic.
        f.observe_losses(99, 5, base);
        f.observe_completion(99, 0, Duration::from_millis(5), base);
        assert!(f.fleet_unavailability(base) < 0.05);
    }

    #[test]
    fn config_bounds_are_enforced() {
        for (k, r_min, r_max) in [(2usize, 0usize, 1usize), (2, 2, 1), (2, 1, 3)] {
            let res = std::panic::catch_unwind(|| {
                RatelessScheme::new(RatelessConfig::new(
                    k,
                    r_min,
                    r_max,
                    Duration::from_millis(100),
                ))
            });
            assert!(res.is_err(), "k={k} r_min={r_min} r_max={r_max} must be rejected");
        }
    }
}

//! Paper-figure experiment harnesses. Each figure in the paper's
//! evaluation maps to a bench target (see DESIGN.md's experiment index):
//!
//! | Figure / table | module | bench |
//! |---|---|---|
//! | Table 1 (toy)       | [`table1`]   | `table1_toy` |
//! | Fig 6, 8, 9, 10 (accuracy) | [`accuracy`] | `fig6_accuracy`, `fig9_vary_k` |
//! | Fig 7 (overall A_o) | [`accuracy`] | `fig7_overall_accuracy` |
//! | Fig 11-15, §5.2.3/5 (latency) | [`latency`] | `fig11_latency` … |

pub mod accuracy;
pub mod latency;
pub mod table1;

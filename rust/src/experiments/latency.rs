//! Latency experiments (§5, Figures 11-15): drive the full threaded
//! service against the simulated cluster and report the paper's rows
//! (median and 99.9th percentile per query rate / k / background load).

use std::time::Duration;

use crate::artifacts::Manifest;
use crate::cluster::hardware::Profile;
use crate::coordinator::encoder::Encoder;
use crate::coordinator::service::{Mode, ModelSet, RunResult, ServiceConfig};
use crate::coordinator::session::ServiceBuilder;
use crate::runtime::engine::Executable;
use crate::util::json::Json;
use crate::workload::QuerySource;

/// The latency workload of §5.1: Cat-v-Dog stand-in queries against the
/// ResNet-18 stand-in with 1000-float predictions.
pub const LATENCY_DATASET: &str = "synthpets";
pub const LATENCY_ARCH: &str = "microresnet";

#[derive(Clone, Debug)]
pub struct LatencyRow {
    pub label: String,
    pub rate_qps: f64,
    pub utilization: f64,
    pub median_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_ms: f64,
    pub f_u: f64,
    pub reconstructions: u64,
    pub n: usize,
}

impl LatencyRow {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("label", self.label.as_str())
            .set("rate_qps", self.rate_qps)
            .set("utilization", self.utilization)
            .set("median_ms", self.median_ms)
            .set("p99_ms", self.p99_ms)
            .set("p999_ms", self.p999_ms)
            .set("mean_ms", self.mean_ms)
            .set("f_u", self.f_u)
            .set("reconstructions", self.reconstructions)
            .set("n", self.n)
    }

    pub fn header() -> String {
        format!(
            "{:<28} {:>9} {:>6} {:>9} {:>9} {:>9} {:>8}",
            "config", "qps", "util", "p50(ms)", "p99(ms)", "p99.9(ms)", "f_u"
        )
    }

    pub fn line(&self) -> String {
        format!(
            "{:<28} {:>9.1} {:>6.2} {:>9.3} {:>9.3} {:>9.3} {:>8.4}",
            self.label, self.rate_qps, self.utilization, self.median_ms,
            self.p99_ms, self.p999_ms, self.f_u
        )
    }
}

/// Time-series rows now live in the telemetry layer so bench output and
/// operator-facing scrapes share one definition; re-exported here for
/// the benches that import them through `experiments::latency`.
pub use crate::telemetry::series::{Capture, TimeSeriesRow};

/// Load the executables for a latency run at the given batch size.
pub fn load_models(
    manifest: &Manifest,
    batch: usize,
    k: usize,
    r: usize,
    with_approx: bool,
) -> anyhow::Result<ModelSet> {
    let dep = manifest.model(&format!("{LATENCY_DATASET}.{LATENCY_ARCH}.deployed1000"))?;
    let deployed = Executable::load(
        manifest.hlo_path(dep, batch)?,
        &dep.name,
        &dep.input_shape,
        batch,
        dep.out_dim,
    )?;
    let mut parities = Vec::new();
    for ri in 0..r {
        // The latency artifacts ship r_index=0 parities per k; reuse the
        // k-th parity for every r index (service-time identical, which is
        // all the latency path observes).
        let _ = ri;
        let par = manifest.model(&format!(
            "{LATENCY_DATASET}.{LATENCY_ARCH}.parity1000.k{k}.sum"
        ))?;
        parities.push(Executable::load(
            manifest.hlo_path(par, batch)?,
            &par.name,
            &par.input_shape,
            batch,
            par.out_dim,
        )?);
    }
    let approx = if with_approx {
        let ap = manifest.model(&format!("{LATENCY_DATASET}.{LATENCY_ARCH}.approx1000"))?;
        Some(Executable::load(
            manifest.hlo_path(ap, batch)?,
            &ap.name,
            &ap.input_shape,
            batch,
            ap.out_dim,
        )?)
    } else {
        None
    };
    Ok(ModelSet { deployed, parities, approx })
}

/// Convert a target utilization of the *no-redundancy* system into a qps
/// rate, given measured mean service time: rate = util * m / E[S].
/// Assumes m truly parallel servers — use [`measure_capacity`] on hosts
/// where instances share cores (PJRT's pool serializes concurrent execs).
pub fn rate_for_utilization(util: f64, m: usize, mean_service: Duration) -> f64 {
    util * m as f64 / mean_service.as_secs_f64()
}

/// Empirically measure the cluster's saturation throughput (qps): `m`
/// threads hammer the executable for ~1.5 s and we count completions.
/// This captures whatever real parallelism the host provides (on a
/// 1-core CI image, capacity ≈ 1 / E[S] no matter how large m is), so
/// utilization-derived rates stay meaningful everywhere.
pub fn measure_capacity(
    exe: &std::sync::Arc<Executable>,
    m: usize,
    probe: &crate::tensor::Tensor,
) -> f64 {
    // Warmup.
    for _ in 0..3 {
        let _ = exe.run(probe);
    }
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let count = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..m.min(8))
        .map(|_| {
            let exe = exe.clone();
            let probe = probe.clone();
            let stop = stop.clone();
            let count = count.clone();
            std::thread::spawn(move || {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    if exe.run(&probe).is_ok() {
                        count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(1500));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let batch = probe.shape()[0] as f64;
    (count.load(std::sync::atomic::Ordering::Relaxed) as f64 * batch / elapsed).max(1.0)
}

/// Run one (config, rate) point and summarize: build a serving session,
/// drive the open-loop Poisson client through the handle, shut down.
pub fn run_point(
    cfg: &ServiceConfig,
    models: &ModelSet,
    source: &QuerySource,
    n_queries: u64,
    rate: f64,
    label: &str,
) -> anyhow::Result<LatencyRow> {
    let mut handle = ServiceBuilder::new(cfg.clone()).build(models, &source.queries[0])?;
    handle.run_open_loop(&source.queries, n_queries, rate);
    let _ = handle.drain();
    let RunResult { mut metrics, mean_service, wall, reconstructions, .. } = handle.shutdown();
    // mean_service is per *batch*; rate is per query.
    let util = rate * mean_service.as_secs_f64() / (cfg.batch_size.max(1) as f64 * cfg.m as f64);
    log::info!(
        "{label}: {} queries in {:.1}s (service {:.2}ms, util {:.2})",
        metrics.total(),
        wall.as_secs_f64(),
        mean_service.as_secs_f64() * 1e3,
        util
    );
    Ok(LatencyRow {
        label: label.to_string(),
        rate_qps: rate,
        utilization: util,
        median_ms: metrics.latency.median(),
        p99_ms: metrics.latency.p99(),
        p999_ms: metrics.latency.p999(),
        mean_ms: metrics.latency.mean(),
        f_u: metrics.f_unavailable(),
        reconstructions,
        n: metrics.latency.len(),
    })
}

/// Like [`run_point`], but also sample the session's live window every
/// `sample_every` through the telemetry registry, returning the
/// aggregate row *and* the captured time series. Each observed window
/// is published into `parm_session_window_*` and the row read back off
/// those gauges, so the bench timeline is byte-for-byte what a
/// concurrent `/metrics` scrape would have seen at the same instants.
/// Pair it with a `cfg.fault_schedule` entry to watch the tail latency
/// spike and (under ParM) recover across a fault event.
pub fn run_point_timeseries(
    cfg: &ServiceConfig,
    models: &ModelSet,
    source: &QuerySource,
    n_queries: u64,
    rate: f64,
    label: &str,
    sample_every: Duration,
) -> anyhow::Result<(LatencyRow, Capture)> {
    let mut handle = ServiceBuilder::new(cfg.clone()).build(models, &source.queries[0])?;
    let registry = handle.registry();
    let mut cap = Capture::session(&registry, sample_every);
    handle.run_open_loop_observed(
        &source.queries,
        n_queries,
        rate,
        Some(sample_every),
        &mut |_t, w| {
            crate::telemetry::publish_window(&registry, "parm_session_window_", &[], &w);
            cap.sample();
        },
    );
    let _ = handle.drain();
    // One last sample so the series covers the drain tail (which can
    // run long under faults/SLO).
    handle.publish_telemetry();
    cap.sample();
    let RunResult { mut metrics, mean_service, reconstructions, .. } = handle.shutdown();
    let util = rate * mean_service.as_secs_f64() / (cfg.batch_size.max(1) as f64 * cfg.m as f64);
    let row = LatencyRow {
        label: label.to_string(),
        rate_qps: rate,
        utilization: util,
        median_ms: metrics.latency.median(),
        p99_ms: metrics.latency.p99(),
        p999_ms: metrics.latency.p999(),
        mean_ms: metrics.latency.mean(),
        f_u: metrics.f_unavailable(),
        reconstructions,
        n: metrics.latency.len(),
    };
    Ok((row, cap))
}

/// The shared fault-event time-series scenario behind the fig11/13/14
/// benches: ParM (k=2, sum) under the given background load, one
/// deployed instance killed 40% into the run, the live window sampled
/// periodically, rows emitted to `bench_out/<name>.json`.
///
/// Env knobs: PARM_BENCH_TS_QUERIES (default 6000),
/// PARM_BENCH_TS_SAMPLE_MS (default 250).
pub fn run_fault_timeseries(
    manifest: &Manifest,
    name: &str,
    label: &str,
    util: f64,
    shuffles: usize,
    light_tenancy: bool,
    seed: u64,
) -> anyhow::Result<LatencyRow> {
    let env_u64 = |key: &str, default: u64| {
        std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    };
    let ts_n = env_u64("PARM_BENCH_TS_QUERIES", 6_000);
    let sample = Duration::from_millis(env_u64("PARM_BENCH_TS_SAMPLE_MS", 250).max(1));
    let models = load_models(manifest, 1, 2, 1, false)?;
    let ds = manifest.dataset(LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(manifest, ds)?;
    let probe = source.queries[0].clone();
    let mean = crate::coordinator::service::measure_service(&models.deployed, &probe, 20);
    let profile = &crate::cluster::hardware::GPU;
    let rate =
        util * profile.default_m as f64 / (mean.as_secs_f64() * profile.exec_scale.max(1.0));

    let mut cfg = ServiceConfig::defaults(
        Mode::Parm { k: 2, encoders: vec![Encoder::sum(2)] },
        profile,
    );
    cfg.seed = seed;
    cfg.shuffles = shuffles;
    cfg.light_tenancy = light_tenancy;
    cfg.slo = Some(Duration::from_secs(2)); // backstop for doubly-lost groups
    // A short window makes the timeline responsive: each sample reflects
    // roughly the last second of traffic, so the fault transient shows
    // as a spike instead of being averaged away.
    cfg.metrics_window = Duration::from_secs(1);
    // Kill one deployed instance ~40% of the way through the run.
    let kill_at = Duration::from_secs_f64(0.4 * ts_n as f64 / rate);
    cfg.fault_schedule = vec![(0, kill_at, Duration::ZERO)];
    println!(
        "\ntime series [{label}]: {ts_n} queries at {rate:.0} qps, \
         instance 0 dies at t={:.1}s",
        kill_at.as_secs_f64()
    );
    let (row, series) =
        run_point_timeseries(&cfg, &models, &source, ts_n, rate, label, sample)?;
    series.emit(name);
    println!("aggregate: {}", row.line());
    Ok(row)
}

/// ParM vs Equal-Resources at one rate (the Figure 11 comparison pair).
#[allow(clippy::too_many_arguments)]
pub fn parm_vs_equal_resources(
    manifest: &Manifest,
    profile: &'static Profile,
    k: usize,
    batch: usize,
    n_queries: u64,
    utils: &[f64],
    shuffles: usize,
    light_tenancy: bool,
    seed: u64,
) -> anyhow::Result<Vec<LatencyRow>> {
    let ds = manifest.dataset(LATENCY_DATASET)?;
    let source = QuerySource::from_dataset(manifest, ds)?;
    let models = load_models(manifest, batch, k, 1, false)?;
    // Modeled execution gives m truly parallel servers, so capacity is
    // m / E[S] with E[S] measured from the real executable.
    let mean = crate::coordinator::service::measure_service(
        &models.deployed, &batched_probe(&source, batch), 20);
    // Effective service time includes the profile's hardware scaling.
    let eff = mean.as_secs_f64() * profile.exec_scale.max(1.0);
    let capacity = batch as f64 * profile.default_m as f64 / eff;
    log::info!("calibrated capacity: {capacity:.0} qps (E[S]={:.2}ms eff)", eff * 1e3);

    let mut rows = Vec::new();
    for &util in utils {
        let rate = util * capacity;
        for (mode, tag) in [
            (Mode::Parm { k, encoders: vec![Encoder::sum(k)] }, "parm"),
            (Mode::EqualResources { k }, "equal-resources"),
        ] {
            let mut cfg = ServiceConfig::defaults(mode, profile);
            cfg.batch_size = batch;
            if batch > 1 {
                // Buffer long enough that batches usually fill (the paper
                // batches at rates scaled to keep throughput-per-batch
                // constant); padding half-empty batches would double the
                // offered compute and overload the cluster.
                cfg.batch_timeout =
                    Duration::from_secs_f64(3.0 * batch as f64 / rate);
            }
            cfg.shuffles = shuffles;
            cfg.light_tenancy = light_tenancy;
            cfg.seed = seed;
            let label = format!("{tag}[k={k},{},b{batch}]", profile.name);
            rows.push(run_point(&cfg, &models, &source, n_queries, rate, &label)?);
        }
    }
    Ok(rows)
}

fn batched_probe(source: &QuerySource, batch: usize) -> crate::tensor::Tensor {
    crate::tensor::Tensor::batch(
        &std::iter::repeat(source.queries[0].clone())
            .take(batch)
            .collect::<Vec<_>>(),
    )
    .unwrap()
}

/// Write rows to `bench_out/<name>.json` and print the table.
pub fn emit(name: &str, rows: &[LatencyRow]) {
    println!("\n=== {name} ===");
    println!("{}", LatencyRow::header());
    for r in rows {
        println!("{}", r.line());
    }
    let json = Json::Arr(rows.iter().map(|r| r.to_json()).collect());
    let _ = std::fs::create_dir_all("bench_out");
    let path = format!("bench_out/{name}.json");
    if std::fs::write(&path, json.to_string()).is_ok() {
        println!("(wrote {path})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_from_utilization() {
        // 12 instances, 10 ms service => capacity 1200 qps; 50% = 600.
        let r = rate_for_utilization(0.5, 12, Duration::from_millis(10));
        assert!((r - 600.0).abs() < 1e-9);
    }
}

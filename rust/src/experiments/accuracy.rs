//! Accuracy experiments (§4, Figures 6-10): run the *full Rust path* —
//! encode with the coordinator's encoder, infer via PJRT executables,
//! decode with the coordinator's decoder — over a dataset's test split,
//! simulating every single-unavailability scenario per stripe exactly as
//! the paper does (§4.1 Metrics).
//!
//! This doubles as the strongest integration test in the repo: if the
//! Rust encoder/decoder semantics diverged from the Python build-time
//! encoders that generated the parity training data, A_d would collapse
//! to chance.
//!
//! Meaningful only with trained artifacts and the `pjrt` engine backend;
//! under the synthetic backend the pipeline runs but A_a/A_d are noise
//! (the latency/serving experiments are the ones that stay faithful
//! there — see `runtime::engine`).

use crate::artifacts::{Labels, Manifest, ModelEntry};
use crate::coordinator::decoder;
use crate::coordinator::encoder::Encoder;
use crate::runtime::engine::Executable;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::workload::QuerySource;

#[derive(Clone, Debug)]
pub struct AccuracyResult {
    pub dataset: String,
    pub arch: String,
    pub k: usize,
    pub encoder: String,
    /// Accuracy when predictions are available (deployed model, A_a).
    pub available: f64,
    /// Degraded-mode accuracy of ParM reconstructions (A_d).
    pub degraded: f64,
    /// Accuracy of the Clipper-style default prediction.
    pub default_baseline: f64,
    /// "accuracy" is top-1 / top-5 / mean IoU depending on the dataset.
    pub metric: &'static str,
    pub n_stripes: usize,
}

impl AccuracyResult {
    /// Eq. (1): overall accuracy at unavailability fraction f_u.
    pub fn overall(&self, f_u: f64) -> f64 {
        (1.0 - f_u) * self.available + f_u * self.degraded
    }

    /// Overall accuracy of the default-prediction baseline at f_u.
    pub fn overall_default(&self, f_u: f64) -> f64 {
        (1.0 - f_u) * self.available + f_u * self.default_baseline
    }
}

/// Batched inference over arbitrary-length sample lists, padding the tail.
pub fn run_all(
    exe: &Executable,
    samples: &[Tensor],
) -> Result<Vec<Tensor>, crate::runtime::engine::EngineError> {
    let b = exe.batch;
    let mut outs = Vec::with_capacity(samples.len());
    let mut i = 0;
    while i < samples.len() {
        let end = (i + b).min(samples.len());
        let mut chunk: Vec<Tensor> = samples[i..end].to_vec();
        while chunk.len() < b {
            chunk.push(chunk.last().unwrap().clone()); // pad tail
        }
        let batched = Tensor::batch(&chunk).expect("uniform shapes");
        let out = exe.run(&batched)?;
        let per = out.unbatch();
        outs.extend(per.into_iter().take(end - i));
        i = end;
    }
    Ok(outs)
}

fn score(outputs: &[Tensor], indices: &[usize], source: &QuerySource, top5: bool) -> f64 {
    let mut correct = 0.0;
    for (out, &idx) in outputs.iter().zip(indices) {
        match &source.labels {
            Labels::Classes(labels) => {
                let label = labels[idx] as usize;
                if top5 {
                    if out.top_n(5).contains(&label) {
                        correct += 1.0;
                    }
                } else if out.argmax() == label {
                    correct += 1.0;
                }
            }
            Labels::Boxes(boxes) => {
                correct += iou(out.data(), &boxes[idx]) as f64;
            }
        }
    }
    correct / outputs.len() as f64
}

/// IoU of (cx, cy, w, h) boxes in normalized coordinates.
pub fn iou(a: &[f32], b: &[f32; 4]) -> f32 {
    let (ax0, ay0) = (a[0] - a[2] / 2.0, a[1] - a[3] / 2.0);
    let (ax1, ay1) = (a[0] + a[2] / 2.0, a[1] + a[3] / 2.0);
    let (bx0, by0) = (b[0] - b[2] / 2.0, b[1] - b[3] / 2.0);
    let (bx1, by1) = (b[0] + b[2] / 2.0, b[1] + b[3] / 2.0);
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    let union = a[2].max(0.0) * a[3].max(0.0) + b[2].max(0.0) * b[3].max(0.0) - inter;
    if union > 0.0 {
        inter / union
    } else {
        0.0
    }
}

/// Accuracy of the Clipper default-prediction fallback: a fixed prediction
/// (class 0 / centered box), evaluated against the test labels.
fn default_accuracy(source: &QuerySource, top5: bool, out_dim: usize) -> f64 {
    match &source.labels {
        Labels::Classes(labels) => {
            if top5 {
                // Default logits are all-zero: "top 5" is classes 0..5.
                labels.iter().filter(|&&l| (l as usize) < 5).count() as f64
                    / labels.len() as f64
            } else {
                labels.iter().filter(|&&l| l == 0).count() as f64 / labels.len() as f64
            }
        }
        Labels::Boxes(boxes) => {
            let default = [0.5f32, 0.5, 0.5, 0.5];
            let _ = out_dim;
            boxes.iter().map(|b| iou(&default, b) as f64).sum::<f64>()
                / boxes.len() as f64
        }
    }
}

/// Full degraded-mode evaluation for one (dataset, arch, k, encoder).
pub fn evaluate(
    manifest: &Manifest,
    deployed: &ModelEntry,
    parity: &ModelEntry,
    seed: u64,
) -> anyhow::Result<AccuracyResult> {
    let ds = manifest.dataset(&deployed.dataset)?;
    let source = QuerySource::from_dataset(manifest, ds)?;
    let k = parity.k;
    let enc = Encoder::from_name(&parity.encoder, k, parity.r_index)
        .ok_or_else(|| anyhow::anyhow!("unknown encoder {:?}", parity.encoder))?;
    let top5 = ds.task == "classify" && ds.num_classes > 10;

    let eval_batch = *deployed
        .files
        .keys()
        .max()
        .ok_or_else(|| anyhow::anyhow!("no batches for {}", deployed.name))?;
    let dep_exe = Executable::load(
        manifest.hlo_path(deployed, eval_batch)?,
        &deployed.name,
        &deployed.input_shape,
        eval_batch,
        deployed.out_dim,
    )?;
    let par_exe = Executable::load(
        manifest.hlo_path(parity, eval_batch)?,
        &parity.name,
        &parity.input_shape,
        eval_batch,
        parity.out_dim,
    )?;

    // Stripe the test set: random groups of k (paper §4.1).
    let mut rng = Pcg64::new(seed);
    let order = source.shuffled_indices(&mut rng);
    let n = (order.len() / k) * k;
    let order = &order[..n];

    // Deployed outputs for every test sample (also gives A_a).
    let samples: Vec<Tensor> = order.iter().map(|&i| source.queries[i].clone()).collect();
    let outs = run_all(&dep_exe, &samples)?;
    let available = score(&outs, order, &source, top5);

    // Encode each stripe, run the parity model.
    let mut parities = Vec::with_capacity(n / k);
    for stripe in samples.chunks(k) {
        let refs: Vec<&Tensor> = stripe.iter().collect();
        parities.push(enc.encode(&refs)?);
    }
    let parity_outs = run_all(&par_exe, &parities)?;

    // Decode every single-unavailability scenario.
    let weights = match &enc {
        Encoder::Sum { weights } => weights.clone(),
        Encoder::Concat { k } => vec![1.0; *k],
    };
    let mut recon = Vec::with_capacity(n);
    for (s, pout) in parity_outs.iter().enumerate() {
        let group = &outs[s * k..(s + 1) * k];
        for j in 0..k {
            let data: Vec<Option<Tensor>> = group
                .iter()
                .enumerate()
                .map(|(i, t)| if i == j { None } else { Some(t.clone()) })
                .collect();
            recon.push(decoder::decode_r1(&weights, pout, &data, j)?);
        }
    }
    let degraded = score(&recon, order, &source, top5);
    let default_baseline = default_accuracy(&source, top5, deployed.out_dim);

    Ok(AccuracyResult {
        dataset: deployed.dataset.clone(),
        arch: deployed.arch.clone(),
        k,
        encoder: parity.encoder.clone(),
        available,
        degraded,
        default_baseline,
        metric: if ds.task == "localize" {
            "mean-IoU"
        } else if top5 {
            "top-5"
        } else {
            "top-1"
        },
        n_stripes: n / k,
    })
}

/// §3.5: degraded accuracy under TWO concurrent unavailabilities, using
/// two parity models (r = 2, weights [1,1] and [1,2]). Every stripe loses
/// both data outputs; the decoder solves the 2x2 system from the two
/// parity outputs alone.
pub fn evaluate_r2(
    manifest: &Manifest,
    deployed: &ModelEntry,
    parity0: &ModelEntry,
    parity1: &ModelEntry,
    seed: u64,
) -> anyhow::Result<AccuracyResult> {
    let ds = manifest.dataset(&deployed.dataset)?;
    let source = QuerySource::from_dataset(manifest, ds)?;
    let k = parity0.k;
    assert_eq!(k, 2, "r2 evaluation shipped for k=2");
    let encs = [
        Encoder::from_name(&parity0.encoder, k, parity0.r_index).unwrap(),
        Encoder::from_name(&parity1.encoder, k, parity1.r_index).unwrap(),
    ];
    let weights: Vec<Vec<f32>> = encs
        .iter()
        .map(|e| match e {
            Encoder::Sum { weights } => weights.clone(),
            Encoder::Concat { k } => vec![1.0; *k],
        })
        .collect();

    let eval_batch = *deployed.files.keys().max().unwrap();
    let dep_exe = Executable::load(
        manifest.hlo_path(deployed, eval_batch)?,
        &deployed.name,
        &deployed.input_shape,
        eval_batch,
        deployed.out_dim,
    )?;
    let par_exes = [
        Executable::load(
            manifest.hlo_path(parity0, eval_batch)?,
            &parity0.name,
            &parity0.input_shape,
            eval_batch,
            parity0.out_dim,
        )?,
        Executable::load(
            manifest.hlo_path(parity1, eval_batch)?,
            &parity1.name,
            &parity1.input_shape,
            eval_batch,
            parity1.out_dim,
        )?,
    ];

    let mut rng = Pcg64::new(seed);
    let order = source.shuffled_indices(&mut rng);
    let n = (order.len() / k) * k;
    let order = &order[..n];
    let samples: Vec<Tensor> = order.iter().map(|&i| source.queries[i].clone()).collect();
    let outs = run_all(&dep_exe, &samples)?;
    let top5 = ds.task == "classify" && ds.num_classes > 10;
    let available = score(&outs, order, &source, top5);

    let mut recon = Vec::with_capacity(n);
    for s in 0..n / k {
        let stripe: Vec<&Tensor> = samples[s * k..(s + 1) * k].iter().collect();
        let pouts: Vec<Option<Tensor>> = encs
            .iter()
            .zip(&par_exes)
            .map(|(enc, exe)| {
                let p = enc.encode(&stripe).unwrap();
                Some(run_all(exe, &[p]).unwrap().remove(0))
            })
            .collect();
        // Both data outputs unavailable: decode from parities alone.
        let data: Vec<Option<Tensor>> = vec![None, None];
        let mut recs = decoder::decode_general(&weights, &data, &pouts)?;
        recs.sort_by_key(|(slot, _)| *slot);
        for (_, t) in recs {
            recon.push(t);
        }
    }
    let degraded = score(&recon, order, &source, top5);

    Ok(AccuracyResult {
        dataset: deployed.dataset.clone(),
        arch: deployed.arch.clone(),
        k,
        encoder: format!("{}+r1", parity0.encoder),
        available,
        degraded,
        default_baseline: if ds.task == "classify" {
            1.0 / ds.num_classes.max(1) as f64
        } else {
            0.0
        },
        metric: if top5 { "top-5" } else { "top-1" },
        n_stripes: n / k,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iou_identical_and_disjoint() {
        let a = [0.5f32, 0.5, 0.2, 0.2];
        assert!((iou(&a, &a) - 1.0).abs() < 1e-6);
        let b = [0.9f32, 0.9, 0.1, 0.1];
        assert_eq!(iou(&a, &b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // Box B shifted by half its width: I = 0.5*1, U = 1.5 => 1/3.
        let a = [0.5f32, 0.5, 1.0, 1.0];
        let b = [1.0f32, 0.5, 1.0, 1.0];
        assert!((iou(&a, &b) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn overall_accuracy_eq1() {
        let r = AccuracyResult {
            dataset: "d".into(),
            arch: "a".into(),
            k: 2,
            encoder: "sum".into(),
            available: 0.9,
            degraded: 0.8,
            default_baseline: 0.1,
            metric: "top-1",
            n_stripes: 10,
        };
        assert!((r.overall(0.0) - 0.9).abs() < 1e-12);
        assert!((r.overall(1.0) - 0.8).abs() < 1e-12);
        assert!((r.overall(0.1) - 0.89).abs() < 1e-12);
        assert!((r.overall_default(0.1) - 0.82).abs() < 1e-12);
    }
}

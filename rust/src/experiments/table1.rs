//! Table 1: the toy example motivating parity models.
//!
//! For linear F the plain addition code decodes exactly; for non-linear F
//! (here F(x) = x²) the naive decode F(P) - F(X1) is wrong by the cross
//! term 2·X1·X2 — the gap ParM closes by *learning* F_P. This module
//! computes the table's rows numerically so the bench can print them and
//! the tests can pin them.

#[derive(Debug, Clone)]
pub struct ToyRow {
    pub f_name: &'static str,
    pub f_p: f64,
    pub desired: f64,
    pub naive_decode_err: f64,
}

/// Evaluate the two Table-1 rows at (x1, x2) with parity P = x1 + x2.
pub fn rows(x1: f64, x2: f64) -> Vec<ToyRow> {
    let p = x1 + x2;
    let linear = |x: f64| 2.0 * x;
    let square = |x: f64| x * x;
    vec![
        ToyRow {
            f_name: "F(x) = 2x",
            f_p: linear(p),
            desired: linear(x1) + linear(x2),
            naive_decode_err: ((linear(p) - linear(x1)) - linear(x2)).abs(),
        },
        ToyRow {
            f_name: "F(x) = x^2",
            f_p: square(p),
            desired: square(x1) + square(x2),
            naive_decode_err: ((square(p) - square(x1)) - square(x2)).abs(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_decodes_exactly() {
        for (a, b) in [(1.0, 2.0), (-3.5, 7.25), (0.0, 0.0)] {
            let r = &rows(a, b)[0];
            assert!(r.naive_decode_err < 1e-12);
            assert!((r.f_p - r.desired).abs() < 1e-12);
        }
    }

    #[test]
    fn square_off_by_cross_term() {
        let r = &rows(3.0, 4.0)[1];
        // F(P) = 49, desired 25; naive decode error = 2*x1*x2 = 24.
        assert!((r.f_p - 49.0).abs() < 1e-12);
        assert!((r.desired - 25.0).abs() < 1e-12);
        assert!((r.naive_decode_err - 24.0).abs() < 1e-12);
    }
}

//! Stderr logger wired to the `log` facade. Level via `PARM_LOG`
//! (error|warn|info|debug|trace); defaults to `info`.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger. Safe to call multiple times (subsequent calls no-op).
pub fn init() {
    let _ = START.set(Instant::now());
    let level = match std::env::var("PARM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_twice_is_fine() {
        super::init();
        super::init();
        log::info!("logging test line");
    }
}

//! Deterministic PRNG substrate (no `rand` crate in the build image).
//!
//! `SplitMix64` seeds `Pcg64`; `Pcg64` drives everything random in the
//! system: Poisson arrivals, shuffle scheduling, stripe sampling, property
//! tests. All experiment configs carry explicit seeds so every figure is
//! exactly reproducible.

/// FNV-1a over arbitrary bytes: stable, dependency-free way to derive a
/// deterministic seed from a name (synthetic models and datasets must
/// agree on it, so there is exactly one copy).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// SplitMix64: used to expand a single u64 seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: solid statistical quality, 128-bit state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state, inc };
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponential with given rate (mean 1/rate): inter-arrival times of a
    /// Poisson process.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // Avoid ln(0).
        let u = 1.0 - self.next_f64();
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Pcg64::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Pcg64::new(17);
        for _ in 0..100 {
            let xs = r.choose_distinct(20, 8);
            let mut s = xs.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
            assert!(xs.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Poison-tolerant lock accessors for the serving tier.
//!
//! `std::sync::Mutex` poisons itself when a thread panics while holding
//! the guard, and every later `.lock().unwrap()` then re-panics — so a
//! single crashed scraper or worker thread cascades into the dispatcher,
//! the control plane, and anything else sharing the lock. None of the
//! state guarded in this crate becomes invalid when a holder panics
//! (counters, queues, and windows are updated in place and stay
//! internally consistent between statements that matter), so the right
//! policy everywhere is to *recover* the guard via
//! [`PoisonError::into_inner`] and keep serving.
//!
//! `lock.plock()` / `lock.pread()` / `lock.pwrite()` are drop-in
//! replacements for the `.lock().unwrap()` family, and
//! [`CondvarExt::pwait`] / [`CondvarExt::pwait_timeout`] cover the
//! condvar re-acquire path (which can also return a poisoned guard).

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::sync::{PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Poison-recovering accessors for `Mutex`.
pub trait LockExt<T> {
    /// Lock, recovering the guard if a previous holder panicked.
    fn plock(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn plock(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Poison-recovering accessors for `RwLock`.
pub trait RwLockExt<T> {
    /// Read-lock, recovering the guard if a writer panicked.
    fn pread(&self) -> RwLockReadGuard<'_, T>;
    /// Write-lock, recovering the guard if a previous holder panicked.
    fn pwrite(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn pread(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(PoisonError::into_inner)
    }

    fn pwrite(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Poison-recovering waits for `Condvar` (the re-acquired mutex can be
/// poisoned by a panic that happened while this thread was parked).
pub trait CondvarExt {
    fn pwait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T>;
    fn pwait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult);
}

impl CondvarExt for Condvar {
    fn pwait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.wait(guard).unwrap_or_else(PoisonError::into_inner)
    }

    fn pwait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        self.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    #[test]
    fn plock_recovers_after_holder_panics() {
        let m = Arc::new(Mutex::new(7u32));
        let mc = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = mc.lock().unwrap();
            panic!("holder dies with the guard");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*m.plock(), 7, "plock recovers the value");
        *m.plock() = 8;
        assert_eq!(*m.plock(), 8);
    }

    #[test]
    fn pwrite_and_pread_recover_after_writer_panics() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let lc = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = lc.write().unwrap();
            panic!("writer dies");
        })
        .join();
        assert!(l.read().is_err());
        assert_eq!(l.pread().len(), 3);
        l.pwrite().push(4);
        assert_eq!(l.pread().len(), 4);
    }

    #[test]
    fn pwait_timeout_survives_poisoned_reacquire() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        // Poison the mutex first...
        {
            let pc = pair.clone();
            let _ = std::thread::spawn(move || {
                let _g = pc.0.lock().unwrap();
                panic!("poison it");
            })
            .join();
        }
        // ...then wait on it: both the entry lock and the re-acquire
        // inside wait_timeout must recover rather than re-panic.
        let ok = catch_unwind(AssertUnwindSafe(|| {
            let g = pair.0.plock();
            let (_g, res) = pair.1.pwait_timeout(g, Duration::from_millis(5));
            res.timed_out()
        }));
        assert_eq!(ok.ok(), Some(true));
    }
}

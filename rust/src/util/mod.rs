//! From-scratch substrates the build image lacks crates for: PRNG, JSON,
//! latency statistics, CLI parsing, and logging.

pub mod arena;
pub mod bus;
pub mod cli;
pub mod json;
pub mod logging;
pub mod queue;
pub mod rng;
pub mod stats;
pub mod sync;
